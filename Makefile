# membig — build orchestration.
#
#   make artifacts   AOT-lower the JAX analytics graph to HLO text in
#                    rust/artifacts/ (requires jax; idempotent)
#   make build       release build of the Rust engine (default features:
#                    std-only, pure-Rust analytics backend)
#   make test        tier-1: cargo build --release && cargo test -q
#   make check-pjrt  typecheck the PJRT-gated code paths
#   make bench       run every custom-harness bench (MEMBIG_BENCH_SCALE=k
#                    divides workload sizes for quick runs)
#   make clean       drop build + bench outputs

ARTIFACTS_DIR := $(abspath rust/artifacts)

.PHONY: artifacts build test check-pjrt bench clean

artifacts:
	cd python && python -m compile.aot --out $(ARTIFACTS_DIR)

build:
	cd rust && cargo build --release

test: build
	cd rust && cargo test -q

check-pjrt:
	cd rust && cargo check --features pjrt --all-targets

bench:
	cd rust && cargo bench

clean:
	cd rust && cargo clean
	rm -rf bench_out
