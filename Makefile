# membig — build orchestration.
#
#   make artifacts   AOT-lower the JAX analytics graph to HLO text in
#                    rust/artifacts/ (requires jax; idempotent)
#   make build       release build of the Rust engine (default features:
#                    std-only, pure-Rust analytics backend)
#   make test        tier-1: cargo build --release && cargo test -q
#   make check-pjrt  typecheck the PJRT-gated code paths
#   make bench       run every custom-harness bench (MEMBIG_BENCH_SCALE=k
#                    divides workload sizes for quick runs)
#   make bench-smoke tiny-N run of the analytics + hashtable + server +
#                    recovery + ipc scale-out benches — catches bench
#                    bit-rot fast and emits machine-readable
#                    BENCH_<name>.json reports at the repo root (wired
#                    into CI, uploaded as artifacts)
#   make failover    hot-standby replication drill: spawn a real primary +
#                    standby pair, SIGKILL the primary under load and assert
#                    the promoted standby serves every acked write (also
#                    covers fault-injected reconnects and SIGTERM drain)
#   make faults      storage-fault drill: the faultcheck build's ordinal
#                    sweep (every fault class at every I/O op of each
#                    persistent surface) plus the fsync fail-stop property
#                    (see DESIGN.md §16)
#   make lint        repo-specific static checks (cargo xtask lint) plus
#                    the lint engine's own tests
#   make miri        UB-check the unsafe core under Miri (nightly; small
#                    cfg(miri) lane sizes — see DESIGN.md §13)
#   make tsan        ThreadSanitizer over the racecheck-perturbed stress
#                    suites (nightly + rust-src)
#   make clean       drop build + bench outputs

ARTIFACTS_DIR := $(abspath rust/artifacts)

.PHONY: artifacts build test check-pjrt bench bench-smoke failover faults lint miri tsan clean

artifacts:
	cd python && python -m compile.aot --out $(ARTIFACTS_DIR)

build:
	cd rust && cargo build --release

test: build
	cd rust && cargo test -q

check-pjrt:
	cd rust && cargo check --features pjrt --all-targets

bench:
	cd rust && cargo bench

# analytics is compile-smoked only (its runtime body is pjrt-gated and
# prints a skip line under default features); hashtable, server_throughput
# and recovery actually execute at tiny N. Every bench also writes its
# BENCH_<name>.json report to the repo root. server_throughput includes:
#  - the read-path contention sweep (BENCH_read_path.json): exits non-zero
#    on negative multi-reader GET scaling — runs even at tiny N, but only
#    on hosts with >=6 cores (4 readers + writer + main need headroom;
#    below that the sweep measures the scheduler, not the lock, and only
#    reports). It also compares against the committed BENCH_read_path.json
#    baseline; an all-n:0 baseline (zeroed seed) is unpopulated — reported,
#    never gated — and the run refreshes the file with measured figures.
#  - the idle-connection sweep (BENCH_connections.json, Linux): 0/64/256/
#    1024 open-but-idle conns vs active MUPDATE throughput on a 2-reactor
#    server, gated so the largest tier keeps >=90% of 0-idle throughput
#    (idle connections must cost <10%).
# memory_vs_disk additionally exercises the larger-than-RAM tier (resident /
# spilled / compacted point reads) and emits BENCH_tiered_read.json.
bench-smoke:
	cd rust && MEMBIG_BENCH_SCALE=100 cargo bench --bench analytics --bench hashtable --bench server_throughput --bench recovery --bench ipc_scaleout --bench memory_vs_disk

failover:
	cd rust && cargo test --release --test replication_kill -- --nocapture

# The shim's unit tests (--lib) plus the ordinal sweep and the fail-stop
# property. The sweep is file-heavy; --release keeps it quick.
faults:
	cd rust && cargo test --release --features faultcheck --lib --test fault_storage --test prop_durability

lint:
	cd rust && cargo xtask lint
	cd rust && cargo test -q -p xtask

# Separate invocations per target: Miri interprets each test binary and a
# failure in one suite shouldn't hide the others' results.
miri:
	cd rust && MIRIFLAGS=-Zmiri-disable-isolation cargo +nightly miri test --lib -- memstore:: pipeline::channel::
	cd rust && MIRIFLAGS=-Zmiri-disable-isolation cargo +nightly miri test --test stress_seqlock
	cd rust && MIRIFLAGS=-Zmiri-disable-isolation cargo +nightly miri test --test prop_memstore

tsan:
	cd rust && RUSTFLAGS=-Zsanitizer=thread TSAN_OPTIONS=halt_on_error=1 cargo +nightly test --features racecheck -Zbuild-std --target x86_64-unknown-linux-gnu --test stress_seqlock
	cd rust && RUSTFLAGS=-Zsanitizer=thread TSAN_OPTIONS=halt_on_error=1 cargo +nightly test --features racecheck -Zbuild-std --target x86_64-unknown-linux-gnu --lib -- memstore:: pipeline::channel:: util::racecheck::

clean:
	cd rust && cargo clean
	rm -rf bench_out BENCH_*.json
