//! Unstructured-data extension demo (paper §7 future work): apply the
//! memory-based multi-processing method to text — build an inverted index
//! over a synthetic web-document corpus in parallel, serve conjunctive
//! queries from RAM, and contrast with the disk-scan baseline.
//!
//! ```bash
//! cargo run --release --example document_search -- "t3 t7"
//! ```

use std::sync::Arc;

use membig::storage::latency::{DiskProfile, DiskSim};
use membig::textstore::corpus::write_corpus;
use membig::textstore::scan::scan_search;
use membig::textstore::{CorpusSpec, InvertedIndex};
use membig::util::fmt::{bytes, commas, human_duration};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let query = std::env::args().nth(1).unwrap_or_else(|| "t3 t7".to_string());
    let threads = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1).max(2);

    // 1. Corpus: synthetic "web documents" with zipf vocabulary.
    let spec = CorpusSpec { docs: 20_000, ..Default::default() };
    let corpus = membig::textstore::generate_corpus(&spec);
    println!("corpus: {} documents", commas(spec.docs));

    // 2. Memory-based: parallel inverted-index build, then RAM-speed search.
    let t0 = std::time::Instant::now();
    let index = InvertedIndex::build_parallel(&corpus, threads);
    println!(
        "indexed in {} with {} threads → {} terms, {} resident",
        human_duration(t0.elapsed()),
        threads,
        commas(index.term_count() as u64),
        bytes(index.memory_bytes() as u64)
    );

    let t0 = std::time::Instant::now();
    let hits = index.search(&query, 10);
    let mem_t = t0.elapsed();
    println!("\nquery {query:?} → {} hits in {} (in-memory):", hits.len(), human_duration(mem_t));
    for (id, score) in &hits {
        println!("  doc {id:>6}  score {score}");
    }

    // 3. Conventional: re-scan the corpus from disk per query (HDD model).
    let path = std::env::temp_dir().join("membig_docs.tsv");
    write_corpus(&path, &spec)?;
    let sim = Arc::new(DiskSim::new(DiskProfile::default()));
    let t0 = std::time::Instant::now();
    let scan_hits = scan_search(&path, &query, 10, &sim)?;
    println!(
        "\ndisk-scan baseline: same {} hits; wall {}, modeled HDD {}",
        scan_hits.len(),
        human_duration(t0.elapsed()),
        human_duration(sim.modeled())
    );
    assert_eq!(hits, scan_hits, "both paths must agree");
    println!(
        "\nmemory-based speedup: {:.0}x",
        sim.modeled().as_secs_f64() / mem_t.as_secs_f64().max(1e-9)
    );
    Ok(())
}
