//! Three-layer composition demo: Rust coordinator (L3) feeds the analytics
//! model — the AOT-compiled JAX graph (L2) wrapping the Pallas kernel (L1)
//! when built with `--features pjrt` and artifacts are present, or the
//! bit-identical pure-Rust reference backend otherwise. Python is nowhere
//! at runtime either way.
//!
//! Loads a store, stages a batch of pending updates, then runs the fused
//! masked-update + statistics + histogram through the analytics service,
//! compares against the Rust-side application of the same updates, and
//! prints the price histogram before/after.
//!
//! ```bash
//! cargo run --release --example analytics_pipeline
//! # PJRT path: make artifacts && cargo run --release --features pjrt --example analytics_pipeline
//! ```

use std::sync::Arc;

use membig::memstore::ShardedStore;
use membig::runtime::AnalyticsService;
use membig::util::fmt::{commas, human_duration};
use membig::workload::gen::{generate_stock_updates, DatasetSpec, KeyDist};

fn bar(v: f32, max: f32) -> String {
    "█".repeat(((v / max) * 40.0) as usize)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let svc = AnalyticsService::start_auto("artifacts")?;
    println!("analytics backend: {}\n", svc.backend_name());

    // L3: build a live store.
    let spec = DatasetSpec { records: 60_000, ..Default::default() };
    let store = Arc::new(ShardedStore::new(8, 1 << 13));
    for r in spec.iter() {
        store.insert(r);
    }
    println!("store: {} records in {} shards", commas(store.len() as u64), store.shard_count());

    // Stage pending updates (not yet applied to the store).
    let updates = generate_stock_updates(&spec, 30_000, KeyDist::Uniform, 99);

    // "Before" analytics: no updates staged.
    let before = svc.analytics_for_store(store.clone(), Vec::new())?;
    // "After" analytics: updates applied *inside the model* via the mask.
    let after = svc.analytics_for_store(store.clone(), updates.clone())?;

    println!("\n               before           after(staged updates)");
    println!("value      ${:>12.2}    ${:>12.2}", before.stats.total_value, after.stats.total_value);
    println!("mean price ${:>12.4}    ${:>12.4}", before.stats.mean_price, after.stats.mean_price);
    println!("applied    {:>13}    {:>13}", before.stats.updates_applied, after.stats.updates_applied);
    println!("exec time  {:>13}    {:>13}", human_duration(before.exec_time),
        human_duration(after.exec_time));

    // Cross-check: apply the same updates in Rust and compare value sums.
    for u in &updates {
        store.apply(u);
    }
    let (_, cents) = store.value_sum_cents();
    let rust_value = cents as f64 / 100.0;
    let rel = (after.stats.total_value - rust_value).abs() / rust_value;
    println!("\nrust-side apply agrees: analytics ${:.2} vs Rust ${:.2} (rel err {:.2e})",
        after.stats.total_value, rust_value, rel);
    assert!(rel < 1e-3);

    // Price histogram, rendered.
    println!("\nprice histogram after updates ($0.50 bins):");
    let max = after.histogram.iter().cloned().fold(0.0f32, f32::max);
    for (i, &count) in after.histogram.iter().enumerate() {
        println!(
            "  ${:>4.1}–${:>4.1} |{:<40}| {}",
            i as f32 * 0.5,
            (i + 1) as f32 * 0.5,
            bar(count, max),
            count as u64
        );
    }
    svc.shutdown();
    Ok(())
}
