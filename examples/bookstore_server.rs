//! One-server architecture demo (paper §4.3): serve a live inventory over
//! TCP from a single process — reads, updates, aggregate stats and
//! PJRT-backed analytics — then benchmark it with concurrent clients
//! running a read-heavy trace (single verbs vs pipelined MGET/MUPDATE
//! batches) and report throughput + latency percentiles and the server's
//! own connection/verb metrics via `STATS SERVER`.
//!
//! ```bash
//! cargo run --release --example bookstore_server
//! ```

use std::sync::Arc;

use membig::durability::{DurabilityOptions, Persistence};
use membig::memstore::ShardedStore;
use membig::metrics::Histogram;
use membig::runtime::AnalyticsService;
use membig::server::{Client, Server, ServerConfig};
use membig::util::fmt::{commas, human_duration, rate};
use membig::workload::gen::DatasetSpec;
use membig::workload::trace::{generate_trace, Mix, Op};

const CLIENTS: usize = 8;
const OPS_PER_CLIENT: usize = 5_000;
const BATCH_GROUP: usize = 64;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Build the store (the "database server" of the paper's one-server setup).
    let spec = DatasetSpec { records: 100_000, ..Default::default() };
    let store = Arc::new(ShardedStore::new(8, 1 << 14));
    for r in spec.iter() {
        store.insert(r);
    }
    println!("store ready: {} records", commas(store.len() as u64));

    // Analytics service (dedicated executor thread): PJRT when built with
    // `--features pjrt` and artifacts exist, pure-Rust reference otherwise.
    let analytics = match AnalyticsService::start_auto("artifacts") {
        Ok(s) => {
            println!("analytics: {} service online", s.backend_name());
            Some(Arc::new(s))
        }
        Err(e) => {
            println!("analytics: disabled ({e})");
            None
        }
    };

    // Event-driven front end: concurrency comes from the reactor threads
    // (default = cores); `workers` only sizes the blocking-verb executors
    // (ANALYTICS here), with admission control past 64 sockets.
    let cfg = ServerConfig { workers: CLIENTS, max_conns: 64, ..Default::default() };
    let handle = Server::with_config(store.clone(), analytics, cfg).spawn("127.0.0.1:0")?;
    println!("serving on {} ({} blocking-verb workers)\n", handle.addr, CLIENTS);
    let addr = handle.addr;

    // Concurrent clients replay a read-heavy trace.
    let lat = Histogram::new();
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let spec = spec.clone();
            let lat = &lat;
            s.spawn(move || {
                let trace =
                    generate_trace(&spec, OPS_PER_CLIENT, Mix::READ_HEAVY, 0.99, c as u64);
                let mut client = Client::connect(addr).expect("connect");
                for op in trace {
                    let line = match op {
                        Op::Get(k) => format!("GET {k}"),
                        Op::Update(u) => {
                            format!("UPDATE {} {} {}", u.isbn13, u.new_price_cents, u.new_quantity)
                        }
                        Op::Stats => "STATS".to_string(),
                    };
                    let t = std::time::Instant::now();
                    let resp = client.request(&line).expect("request");
                    lat.record_duration(t.elapsed());
                    assert!(
                        resp.starts_with("OK") || resp == "MISS",
                        "unexpected response: {resp}"
                    );
                }
                let _ = client.request("QUIT");
            });
        }
    });
    let elapsed = t0.elapsed();
    let total_ops = (CLIENTS * OPS_PER_CLIENT) as u64;
    let snap = lat.snapshot();

    println!("{} ops from {} concurrent clients in {}", commas(total_ops), CLIENTS,
        human_duration(elapsed));
    println!("throughput: {}", rate(total_ops, elapsed));
    println!(
        "latency: p50 {}  p90 {}  p99 {}  max {}",
        human_duration(std::time::Duration::from_nanos(snap.p50_ns)),
        human_duration(std::time::Duration::from_nanos(snap.p90_ns)),
        human_duration(std::time::Duration::from_nanos(snap.p99_ns)),
        human_duration(std::time::Duration::from_nanos(snap.max_ns)),
    );

    // Same ops again, grouped into pipelined batch verbs: GETs ride MGET,
    // updates ride MUPDATE — one round trip per BATCH_GROUP ops and one
    // shard-lock acquisition per touched shard. Note the trade: ops are
    // reordered within each buffering window (reads flush before writes),
    // which is what batching clients accept in exchange for the round trips.
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let spec = spec.clone();
            s.spawn(move || {
                let trace =
                    generate_trace(&spec, OPS_PER_CLIENT, Mix::READ_HEAVY, 0.99, c as u64);
                let mut client = Client::connect(addr).expect("connect");
                let mut gets: Vec<u64> = Vec::with_capacity(BATCH_GROUP);
                let mut ups: Vec<String> = Vec::with_capacity(BATCH_GROUP);
                let flush = |client: &mut Client, gets: &mut Vec<u64>, ups: &mut Vec<String>| {
                    if !gets.is_empty() {
                        let line = format!(
                            "MGET {}",
                            gets.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(" ")
                        );
                        let r = client.request(&line).expect("mget");
                        assert!(r.starts_with("OK"), "unexpected response: {r}");
                        gets.clear();
                    }
                    if !ups.is_empty() {
                        let r = client
                            .request(&format!("MUPDATE {}", ups.join(";")))
                            .expect("mupdate");
                        assert!(r.starts_with("OK applied="), "unexpected response: {r}");
                        ups.clear();
                    }
                };
                for op in trace {
                    match op {
                        Op::Get(k) => gets.push(k),
                        Op::Update(u) => ups.push(format!(
                            "{} {} {}",
                            u.isbn13, u.new_price_cents, u.new_quantity
                        )),
                        Op::Stats => {
                            // STATS has no batch form — issue it inline so
                            // both phases execute the same ops (modulo the
                            // in-window reordering noted above).
                            let r = client.request("STATS").expect("stats");
                            assert!(r.starts_with("OK count="), "unexpected response: {r}");
                        }
                    }
                    if gets.len() >= BATCH_GROUP || ups.len() >= BATCH_GROUP {
                        flush(&mut client, &mut gets, &mut ups);
                    }
                }
                flush(&mut client, &mut gets, &mut ups);
                let _ = client.request("QUIT");
            });
        }
    });
    let batched = t0.elapsed();
    println!(
        "\nsame workload via MGET/MUPDATE batches of {BATCH_GROUP}: {} ({})",
        human_duration(batched),
        rate(total_ops, batched)
    );
    println!(
        "pipelining speedup: {:.1}x",
        elapsed.as_secs_f64() / batched.as_secs_f64()
    );

    // Analytics + the server's own metrics through the same front door.
    let mut client = Client::connect(addr)?;
    let resp = client.request("ANALYTICS")?;
    println!("\nANALYTICS → {resp}");
    let resp = client.request("STATS SERVER")?;
    println!("STATS SERVER → {resp}");
    let _ = client.request("QUIT");

    handle.shutdown();
    println!("server stopped cleanly");

    // ---- Durability: the same front end with a WAL underneath ------------
    // Every acknowledged mutation is group-committed to a write-ahead log;
    // a restart over the same directory replays snapshot + WAL back to the
    // exact acknowledged state (DESIGN.md §9).
    let dur_dir = std::env::temp_dir().join(format!("bookstore_durable_{}", std::process::id()));
    std::fs::remove_dir_all(&dur_dir).ok();
    let small = DatasetSpec { records: 10_000, ..Default::default() };
    let opts = DurabilityOptions { fsync: false, ..Default::default() };
    let (dstore, persist, _) = Persistence::open(&dur_dir, opts.clone(), 8, || {
        let s = ShardedStore::new(8, 1 << 11);
        for r in small.iter() {
            s.insert(r);
        }
        Ok(Arc::new(s))
    })?;
    let persist = Arc::new(persist);
    let handle = Server::with_persistence(
        dstore,
        None,
        ServerConfig::default(),
        Some(persist.clone()),
    )
    .spawn("127.0.0.1:0")?;
    println!("\ndurable server on {} (dir: {})", handle.addr, dur_dir.display());
    let mut client = Client::connect(handle.addr)?;
    for i in 0..100u64 {
        let key = small.record_at(i).isbn13;
        let resp = client.request(&format!("UPDATE {key} {} {}", 5_000 + i, i))?;
        assert_eq!(resp, "OK");
    }
    println!("STATS SERVER → {}", client.request("STATS SERVER")?);
    let _ = client.request("QUIT");
    handle.shutdown();
    drop(persist);

    // "Restart": recover from disk and verify an acknowledged write survived.
    let (recovered, persist, report) =
        Persistence::open(&dur_dir, opts, 8, || Err("seed must not run on recovery".into()))?;
    let probe = recovered.get(small.record_at(0).isbn13).expect("recovered record");
    println!(
        "recovered snapshot gen {} + {} WAL frame(s); probe price_cents={} (expect 5000)",
        report.snapshot_generation, report.wal_frames, probe.price_cents
    );
    assert_eq!(probe.price_cents, 5_000);
    drop(persist);
    std::fs::remove_dir_all(&dur_dir).ok();
    Ok(())
}
