//! Quickstart: the whole system in ~40 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! Generates a small book inventory, builds the disk table, runs the
//! proposed memory-based multi-processing update, and prints the report.

use membig::config::EngineConfig;
use membig::coordinator::{Coordinator, Workbench};
use membig::util::fmt::{commas, human_duration};
use membig::workload::gen::DatasetSpec;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Configure: defaults = one worker thread per core, one shard each.
    //    The builder is the one construction path; build() validates.
    let cfg = EngineConfig::builder()
        .data_dir(std::env::temp_dir().join("membig_quickstart"))
        .writeback(true) // persist the updated store back to disk
        .build()?;

    // 2. Prepare the experiment inputs: 100k-record database + Stock.dat.
    let spec = DatasetSpec { records: 100_000, ..Default::default() };
    let wb = Workbench::new(&cfg.data_dir, spec);
    let table = wb.ensure_table(&cfg)?;
    let stock = wb.ensure_stock(100_000)?;
    println!("database: {} records at {}", commas(table.len()), wb.table_dir().display());

    // 3. Run the proposed application: load → parallel update → writeback.
    let coord = Coordinator::new(cfg);
    let out = coord.run_proposed(&table, &stock)?;

    println!("loaded    {} records in {}", commas(out.records), human_duration(out.load));
    println!(
        "updated   {} records in {} across {} shards",
        commas(out.stream.updates_applied),
        human_duration(out.update),
        out.store.shard_count()
    );
    println!("writeback {} records in {}", commas(out.written_back), human_duration(out.writeback));
    println!("inventory value: ${:.2}", out.inventory_value_cents as f64 / 100.0);
    println!("\nmetrics:\n{}", coord.metrics.render());
    Ok(())
}
