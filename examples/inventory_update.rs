//! **End-to-end validation driver** (EXPERIMENTS.md §E2E): the paper's §5
//! experiment at full scale — a 2,000,000-record book database updated from
//! a 2,000,000-entry Stock.dat — run through every layer of the system:
//!
//!   1. workload generator → disk table (real files) + stock feed
//!   2. proposed app: sequential load → sharded hash tables → one worker
//!      per core streaming the feed through bounded queues
//!   3. conventional app: per-record RMW under the HDD latency model
//!   4. PJRT analytics over the updated store (L2/L1 artifacts)
//!   5. writeback + verification (store ≡ table)
//!
//! ```bash
//! cargo run --release --example inventory_update -- [--records 2M] [--updates 2M]
//! ```

use std::sync::Arc;

use membig::config::{Args, EngineConfig, FlagSpec};
use membig::coordinator::report::{render_figure6, render_table1, RunReport};
use membig::coordinator::{Coordinator, Workbench};
use membig::memstore::snapshot::verify_against_table;
use membig::runtime::AnalyticsService;
use membig::storage::latency::{DiskProfile, DiskSim};
use membig::storage::table::{DiskTable, TableOptions};
use membig::util::fmt::{commas, human_duration, paper_hms, rate};
use membig::workload::gen::DatasetSpec;

fn flags() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "records", value: "N", help: "database size (default 2M)" },
        FlagSpec { name: "updates", value: "N", help: "feed size (default = records)" },
        FlagSpec { name: "skip-conventional", value: "", help: "skip the disk baseline" },
    ]
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1), &flags())?;
    let records = args.get_count("records")?.unwrap_or(2_000_000);
    let updates = args.get_count("updates")?.unwrap_or(records);

    let cfg = EngineConfig::builder()
        .data_dir("bench_out/data")
        .writeback(false)
        .build()?;

    println!("══ membig end-to-end: {} records, {} updates, {} threads ══\n",
        commas(records), commas(updates), cfg.threads);

    let spec = DatasetSpec { records, ..Default::default() };
    let wb = Workbench::new(&cfg.data_dir, spec.clone());

    // Phase 0: inputs.
    let (table, build_t) = membig::util::bench::time_once(|| wb.ensure_table(&cfg))
        ;
    let table = table?;
    let stock = wb.ensure_stock(updates)?;
    println!("[0] inputs ready in {} (table {} + stock {})\n", human_duration(build_t),
        wb.table_dir().display(), stock.display());
    drop(table);

    // Phase 1+2: proposed app.
    let coord = Coordinator::new(cfg.clone());
    let table = wb.ensure_table(&cfg)?;
    let out = coord.run_proposed(&table, &stock)?;
    println!("[1] load:   {} records in {}  ({})", commas(out.records),
        human_duration(out.load), rate(out.records, out.load));
    println!("[2] update: {} applied in {}  ({}, {} batches, {} missing)",
        commas(out.stream.updates_applied),
        human_duration(out.update),
        rate(out.stream.updates_applied, out.update),
        commas(out.stream.batches),
        out.stream.updates_missing);
    let proposed_total = out.load + out.update;

    // Phase 3: conventional app (modeled HDD).
    let conventional = if args.has("skip-conventional") {
        None
    } else {
        let sim = Arc::new(DiskSim::new(DiskProfile::default()));
        let conv_table = DiskTable::open(
            wb.table_dir(),
            sim,
            TableOptions { cache_pages: cfg.page_cache_pages, engine_overhead: true },
        )?;
        let m = membig::metrics::EngineMetrics::new();
        let rep = membig::baseline::run_conventional_stream(&conv_table, &stock, &m)?;
        println!("[3] conventional: {} applied; wall {} | modeled full-scale disk: {}",
            commas(rep.updates_applied), human_duration(rep.wall), paper_hms(rep.modeled));
        Some(rep)
    };

    // Phase 4: analytics over the updated store (PJRT when available, else
    // the pure-Rust reference backend — the phase always runs).
    match AnalyticsService::start_auto("artifacts") {
        Ok(svc) => {
            // Analytics over a sample (largest compiled batch) of the store.
            let sample: Vec<membig::workload::record::BookRecord> =
                out.store.shard_records(0).into_iter().take(65_536).collect();
            let price: Vec<f32> = sample.iter().map(|r| r.price_cents as f32 / 100.0).collect();
            let qty: Vec<f32> = sample.iter().map(|r| r.quantity as f32).collect();
            let mask = vec![0f32; price.len()];
            let result =
                svc.analytics(price.clone(), qty.clone(), price, qty, mask)?;
            println!(
                "[4] analytics ({}): {} rows → value ${:.2}, mean ${:.4}, exec {}",
                svc.backend_name(),
                commas(result.stats.count),
                result.stats.total_value,
                result.stats.mean_price,
                human_duration(result.exec_time)
            );
            svc.shutdown();
        }
        Err(e) => println!("[4] analytics skipped ({e})"),
    }

    // Phase 5: writeback + verification.
    let m = membig::metrics::EngineMetrics::new();
    let (written, wb_t) = membig::util::bench::time_once(|| {
        membig::memstore::snapshot::writeback(&out.store, &table, &m)
    });
    let written = written?;
    let diverged = verify_against_table(&out.store, &table)?;
    println!("[5] writeback {} records in {}; verification: {} divergent\n",
        commas(written), human_duration(wb_t), diverged);
    assert_eq!(diverged, 0, "store and table must agree after writeback");

    // Summary row (one Table-1 cell at full scale).
    if let Some(conv) = conventional {
        let row = RunReport {
            n_updates: updates,
            conventional: conv.modeled,
            conventional_wall: conv.wall,
            proposed: proposed_total,
        };
        println!("{}", render_table1(std::slice::from_ref(&row)));
        println!("{}", render_figure6(std::slice::from_ref(&row)));
    }
    println!("total proposed time (load+update): {}", human_duration(proposed_total));
    Ok(())
}
