"""L2 correctness: analytics graph shapes + semantics vs numpy, and the
histogram vs its searchsorted reference."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from compile import model
from compile.kernels.ref import price_histogram_ref
from compile.kernels.update_stats import N_STATS, TILE

jax.config.update("jax_platform_name", "cpu")


def inputs(n, seed=0, pad=0):
    rng = np.random.default_rng(seed)
    price = rng.uniform(0, 10, n).astype(np.float32)
    qty = rng.uniform(0, 500, n).astype(np.float32)
    new_price = rng.uniform(0, 10, n).astype(np.float32)
    new_qty = rng.uniform(0, 500, n).astype(np.float32)
    mask = (rng.uniform(0, 1, n) < 0.5).astype(np.float32)
    if pad:
        mask[n - pad:] = -1.0
    return tuple(jnp.asarray(x) for x in (price, qty, new_price, new_qty, mask))


class TestAnalytics:
    def test_output_shapes(self):
        n = 2 * TILE
        up, uq, summary = model.analytics(*inputs(n))
        assert up.shape == (n,)
        assert uq.shape == (n,)
        assert summary.shape == (N_STATS + model.HIST_BINS,)

    def test_summary_stats_vs_numpy(self):
        n = 4 * TILE
        price, qty, new_price, new_qty, mask = inputs(n, seed=1, pad=200)
        _, _, summary = model.analytics(price, qty, new_price, new_qty, mask)
        p, q = np.asarray(price), np.asarray(qty)
        np_p, np_q, m = np.asarray(new_price), np.asarray(new_qty), np.asarray(mask)
        up = np.where(m > 0, np_p, p)
        uq = np.where(m > 0, np_q, q)
        valid = m >= 0
        np.testing.assert_allclose(float(summary[0]),
                                   np.sum(up[valid] * uq[valid]),
                                   rtol=1e-4)
        assert int(summary[1]) == valid.sum()
        np.testing.assert_allclose(float(summary[3]), up[valid].min(), rtol=1e-6)
        np.testing.assert_allclose(float(summary[4]), up[valid].max(), rtol=1e-6)

    def test_histogram_counts_sum_to_valid(self):
        n = 2 * TILE
        price, qty, new_price, new_qty, mask = inputs(n, seed=2, pad=100)
        _, _, summary = model.analytics(price, qty, new_price, new_qty, mask)
        hist = np.asarray(summary[N_STATS:])
        assert hist.shape == (model.HIST_BINS,)
        assert int(hist.sum()) == n - 100

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31))
    def test_histogram_matches_ref(self, seed):
        n = TILE
        rng = np.random.default_rng(seed)
        prices = jnp.asarray(rng.uniform(0, 10, n).astype(np.float32))
        valid = jnp.asarray((rng.uniform(0, 1, n) < 0.9).astype(np.float32))
        ours = model.price_histogram(prices, valid)
        ref = price_histogram_ref(prices, valid, model.HIST_BINS,
                                  model.HIST_LO, model.HIST_HI)
        np.testing.assert_allclose(np.asarray(ours), np.asarray(ref))

    def test_value_sum_fast_path(self):
        n = TILE
        price, qty, _, _, mask = inputs(n, seed=3, pad=50)
        (total,) = model.value_sum(price, qty, mask)
        p, q, m = np.asarray(price), np.asarray(qty), np.asarray(mask)
        np.testing.assert_allclose(float(total), np.sum(p[m >= 0] * q[m >= 0]),
                                   rtol=1e-4)

    def test_jit_compiles_once_per_shape(self):
        f = jax.jit(model.analytics_tuple)
        n = TILE
        args = inputs(n, seed=4)
        f(*args)
        lowered = f.lower(*args)
        compiled = lowered.compile()
        # No giant constant folding / duplicate computations: cost analysis
        # flop count should be O(N * small_constant).
        flops = compiled.cost_analysis().get("flops", 0.0)
        assert flops < n * 200, f"suspiciously heavy graph: {flops} flops"
