"""AOT artifact pipeline: lowering produces loadable HLO text and a
manifest that matches what's on disk; numerics survive the text round-trip
(stablehlo → XlaComputation → HLO text → compile → execute)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels.update_stats import N_STATS, TILE

jax.config.update("jax_platform_name", "cpu")


def test_hlo_text_structure():
    text = aot.lower_analytics(TILE)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # 5 f32[TILE] params.
    assert text.count(f"f32[{TILE}]") >= 5


def test_value_sum_lowering():
    text = aot.lower_value_sum(TILE)
    assert text.startswith("HloModule")
    assert f"f32[{TILE}]" in text


def test_build_writes_manifest_and_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out)
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert len(on_disk["models"]) == 2 * len(aot.BATCHES)
    for m in on_disk["models"]:
        path = os.path.join(out, m["path"])
        assert os.path.exists(path), path
        assert os.path.getsize(path) > 100


def test_text_parses_back_to_module():
    """The emitted text must parse back through XLA's HLO text parser (the
    exact code path the Rust runtime uses via HloModuleProto::from_text_file).
    Full numeric verification of the round-trip lives in the Rust
    integration test `integration_runtime` (artifact → PJRT → execute)."""
    for batch in (TILE, 4 * TILE):
        for text in (aot.lower_value_sum(batch), aot.lower_analytics(batch)):
            module = xc._xla.hlo_module_from_text(text)
            back = module.to_string()
            assert back.startswith("HloModule")
            assert f"f32[{batch}]" in back


def test_analytics_artifact_has_expected_io_arity():
    text = aot.lower_analytics(TILE)
    module = xc._xla.hlo_module_from_text(text)
    back = module.to_string()
    # 5 inputs of f32[N]; outputs include the 28-wide summary vector.
    entry = [l for l in back.splitlines() if l.startswith("ENTRY")][0]
    assert entry.count(f"f32[{TILE}]") >= 5, entry
    assert f"f32[{N_STATS + model.HIST_BINS}]" in entry, entry
