"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, masks and value ranges; fixed cases pin the edge
behaviours (all-padding tiles, all-update, no-update, single tile).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.ref import update_stats_ref
from compile.kernels.update_stats import (N_STATS, TILE, combine_partials,
                                          update_stats)

jax.config.update("jax_platform_name", "cpu")


def make_inputs(rng, n, pad=0, update_frac=0.5):
    """Random inputs with `pad` trailing padding rows."""
    price = rng.uniform(0.0, 10.0, n).astype(np.float32)
    qty = rng.uniform(0.0, 500.0, n).astype(np.float32)
    new_price = rng.uniform(0.0, 10.0, n).astype(np.float32)
    new_qty = rng.uniform(0.0, 500.0, n).astype(np.float32)
    mask = (rng.uniform(0, 1, n) < update_frac).astype(np.float32)
    if pad:
        mask[n - pad:] = -1.0
    return price, qty, new_price, new_qty, mask


def run_both(price, qty, new_price, new_qty, mask, tile=TILE):
    up_k, uq_k, partials = update_stats(
        jnp.asarray(price), jnp.asarray(qty), jnp.asarray(new_price),
        jnp.asarray(new_qty), jnp.asarray(mask), tile=tile)
    stats_k = combine_partials(partials)
    up_r, uq_r, stats_r = update_stats_ref(
        jnp.asarray(price), jnp.asarray(qty), jnp.asarray(new_price),
        jnp.asarray(new_qty), jnp.asarray(mask))
    return (up_k, uq_k, stats_k), (up_r, uq_r, stats_r)


def assert_matches(kernel_out, ref_out, n_valid):
    (up_k, uq_k, stats_k), (up_r, uq_r, stats_r) = kernel_out, ref_out
    np.testing.assert_allclose(up_k, up_r, rtol=1e-6)
    np.testing.assert_allclose(uq_k, uq_r, rtol=1e-6)
    # Sums accumulate differently (per-tile vs flat) → loose tolerance
    # scaled by magnitude.
    np.testing.assert_allclose(stats_k, stats_r, rtol=1e-4, atol=1e-3)
    assert int(stats_k[1]) == n_valid


class TestFixedCases:
    def test_single_tile_half_updates(self):
        rng = np.random.default_rng(0)
        inputs = make_inputs(rng, TILE)
        k, r = run_both(*inputs)
        assert_matches(k, r, TILE)

    def test_multi_tile(self):
        rng = np.random.default_rng(1)
        inputs = make_inputs(rng, 4 * TILE)
        k, r = run_both(*inputs)
        assert_matches(k, r, 4 * TILE)

    def test_no_updates_is_identity(self):
        rng = np.random.default_rng(2)
        price, qty, new_price, new_qty, _ = make_inputs(rng, TILE)
        mask = np.zeros(TILE, np.float32)
        (up, uq, stats), _ = run_both(price, qty, new_price, new_qty, mask)
        np.testing.assert_array_equal(up, price)
        np.testing.assert_array_equal(uq, qty)
        assert float(stats[6]) == 0.0  # applied

    def test_all_updates(self):
        rng = np.random.default_rng(3)
        price, qty, new_price, new_qty, _ = make_inputs(rng, TILE)
        mask = np.ones(TILE, np.float32)
        (up, uq, stats), _ = run_both(price, qty, new_price, new_qty, mask)
        np.testing.assert_array_equal(up, new_price)
        np.testing.assert_array_equal(uq, new_qty)
        assert float(stats[6]) == TILE

    def test_padding_rows_excluded_from_stats(self):
        rng = np.random.default_rng(4)
        n, pad = 2 * TILE, 100
        inputs = make_inputs(rng, n, pad=pad)
        k, r = run_both(*inputs)
        assert_matches(k, r, n - pad)

    def test_entire_tile_padding(self):
        # Second tile is all padding: min/max must not be poisoned.
        rng = np.random.default_rng(5)
        inputs = make_inputs(rng, 2 * TILE, pad=TILE)
        k, r = run_both(*inputs)
        assert_matches(k, r, TILE)
        stats = np.asarray(k[2])
        assert 0.0 <= stats[3] <= 10.0  # price_min from the real tile
        assert 0.0 <= stats[4] <= 10.0

    def test_value_sum_exact_on_integer_cents(self):
        # Cents are < 2^24 → f32-exact; the kernel must agree with an
        # integer reference exactly.
        rng = np.random.default_rng(6)
        price_cents = rng.integers(0, 1000, TILE)
        qty = rng.integers(0, 500, TILE)
        exact = int(np.sum(price_cents * qty))
        price = (price_cents / 100.0).astype(np.float32)
        mask = np.zeros(TILE, np.float32)
        (_, _, stats), _ = run_both(price, qty.astype(np.float32), price,
                                    qty.astype(np.float32), mask)
        assert abs(float(stats[0]) * 100.0 - exact) / max(exact, 1) < 1e-5

    def test_rejects_non_multiple_of_tile(self):
        rng = np.random.default_rng(7)
        inputs = make_inputs(rng, TILE + 1)
        with pytest.raises(ValueError, match="multiple of tile"):
            update_stats(*[jnp.asarray(x) for x in inputs])

    def test_mean_price_matches_numpy(self):
        rng = np.random.default_rng(8)
        price, qty, new_price, new_qty, mask = make_inputs(rng, TILE, pad=17)
        (_, _, stats), _ = run_both(price, qty, new_price, new_qty, mask)
        up = np.where(mask > 0, new_price, price)
        expect = up[mask >= 0].mean()
        np.testing.assert_allclose(float(stats[7]), expect, rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=6),
    pad=st.integers(min_value=0, max_value=TILE - 1),
    update_frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kernel_matches_ref_sweep(tiles, pad, update_frac, seed):
    n = tiles * TILE
    hypothesis.assume(pad < n)
    rng = np.random.default_rng(seed)
    inputs = make_inputs(rng, n, pad=pad, update_frac=update_frac)
    k, r = run_both(*inputs)
    assert_matches(k, r, n - pad)


@settings(max_examples=10, deadline=None)
@given(
    tile_exp=st.integers(min_value=7, max_value=11),  # tile 128..2048
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_tile_size_invariance(tile_exp, seed):
    """The tiling is an implementation detail: results must not depend on it."""
    tile = 1 << tile_exp
    n = 4096
    hypothesis.assume(n % tile == 0)
    rng = np.random.default_rng(seed)
    inputs = make_inputs(rng, n, pad=33)
    k, r = run_both(*inputs, tile=tile)
    assert_matches(k, r, n - 33)


def test_partials_shape_and_determinism():
    rng = np.random.default_rng(9)
    price, qty, new_price, new_qty, mask = make_inputs(rng, 3 * TILE)
    args = [jnp.asarray(x) for x in (price, qty, new_price, new_qty, mask)]
    _, _, p1 = update_stats(*args)
    _, _, p2 = update_stats(*args)
    assert p1.shape == (3, N_STATS)
    np.testing.assert_array_equal(p1, p2)
