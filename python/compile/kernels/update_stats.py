"""L1 — Pallas kernel: fused masked bulk-update + per-tile partial statistics.

This is the compute hot-spot of the proposed method expressed for the TPU
memory hierarchy (DESIGN.md §Hardware-Adaptation): the paper shards its hash
tables across cores; here rows are tiled so each grid step stages one
``(TILE,)`` block of the five input columns from HBM into VMEM (BlockSpec),
applies the masked update, writes the updated block back, and emits one row
of partial reductions. A tiny jnp combine (L2) folds the per-tile partials —
the same leader/worker aggregation shape as the Rust pipeline.

The kernel is bandwidth-bound (no matmul → MXU is idle by design); the
roofline discussion lives in DESIGN.md §Perf.

interpret=True always: the CPU PJRT client cannot execute Mosaic
custom-calls. Real-TPU lowering would only change the BlockSpec constants.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One VMEM block: 8 sublanes x 128 lanes = 1024 rows per grid step. Five f32
# input columns + two outputs = 7 * 4KiB = 28KiB VMEM per step — comfortably
# inside a TPU core's ~16MiB VMEM with double-buffering headroom.
TILE = 1024

# Partial-statistics row emitted per tile:
# [value_sum, count, price_sum, price_min, price_max, qty_sum, upd_count, _pad]
N_STATS = 8

# Plain python float (not a jnp array): pallas kernels may not capture
# traced constants; a weak-typed literal folds into the kernel body.
_BIG = 3.4e38


def _kernel(price_ref, qty_ref, new_price_ref, new_qty_ref, mask_ref,
            out_price_ref, out_qty_ref, part_ref):
    """One grid step over a TILE-row block."""
    p = price_ref[...]
    q = qty_ref[...]
    npx = new_price_ref[...]
    nq = new_qty_ref[...]
    m = mask_ref[...]          # 1.0 = apply update, 0.0 = keep; <0 = padding

    valid = (m >= 0.0).astype(jnp.float32)   # padding rows excluded from stats
    apply = (m > 0.0).astype(jnp.float32)

    up = apply * npx + (1.0 - apply) * p
    uq = apply * nq + (1.0 - apply) * q
    out_price_ref[...] = up
    out_qty_ref[...] = uq

    val = up * uq * valid
    # Min/max over valid rows only: invalid rows are pushed to +/- inf.
    pmin = jnp.min(jnp.where(valid > 0.0, up, _BIG))
    pmax = jnp.max(jnp.where(valid > 0.0, up, -_BIG))

    part_ref[0, 0] = jnp.sum(val)
    part_ref[0, 1] = jnp.sum(valid)
    part_ref[0, 2] = jnp.sum(up * valid)
    part_ref[0, 3] = pmin
    part_ref[0, 4] = pmax
    part_ref[0, 5] = jnp.sum(uq * valid)
    part_ref[0, 6] = jnp.sum(apply * valid)
    part_ref[0, 7] = jnp.float32(0.0)


@functools.partial(jax.jit, static_argnames=("tile",))
def update_stats(price, qty, new_price, new_qty, mask, *, tile: int = TILE):
    """Masked bulk update + per-tile partial stats.

    Args:
      price, qty, new_price, new_qty: f32[N] columns (N multiple of ``tile``).
      mask: f32[N]; 1.0 = apply update, 0.0 = keep current, -1.0 = padding
        row (excluded from statistics entirely).

    Returns:
      (upd_price f32[N], upd_qty f32[N], partials f32[N/tile, N_STATS])
    """
    n = price.shape[0]
    if n % tile != 0:
        raise ValueError(f"N={n} must be a multiple of tile={tile}")
    grid = (n // tile,)
    col = pl.BlockSpec((tile,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[col, col, col, col, col],
        out_specs=[
            col,
            col,
            pl.BlockSpec((1, N_STATS), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], N_STATS), jnp.float32),
        ],
        interpret=True,
    )(price, qty, new_price, new_qty, mask)


def combine_partials(partials):
    """Fold per-tile partials into the final stats vector (pure jnp; L2).

    Returns f32[N_STATS]:
      [value_sum, count, price_sum, price_min, price_max, qty_sum,
       updates_applied, mean_price]
    """
    value_sum = jnp.sum(partials[:, 0])
    count = jnp.sum(partials[:, 1])
    price_sum = jnp.sum(partials[:, 2])
    price_min = jnp.min(partials[:, 3])
    price_max = jnp.max(partials[:, 4])
    qty_sum = jnp.sum(partials[:, 5])
    applied = jnp.sum(partials[:, 6])
    mean_price = jnp.where(count > 0, price_sum / jnp.maximum(count, 1.0), 0.0)
    return jnp.stack([
        value_sum, count, price_sum, price_min, price_max, qty_sum, applied,
        mean_price
    ])
