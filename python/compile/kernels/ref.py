"""Pure-jnp oracle for the Pallas kernel — the CORE correctness signal.

Everything here is written in the most obvious way possible (no tiling, no
fusion) so a reviewer can audit it in one read; pytest asserts the kernel
matches this to float tolerance across shapes, masks and value ranges.
"""

from __future__ import annotations

import jax.numpy as jnp

from .update_stats import N_STATS

_BIG = jnp.float32(3.4e38)


def update_stats_ref(price, qty, new_price, new_qty, mask):
    """Reference semantics of kernels.update_stats.update_stats.

    Returns (upd_price, upd_qty, stats f32[N_STATS]) where stats is the
    *combined* statistics vector (reference has no notion of tiles):
      [value_sum, count, price_sum, price_min, price_max, qty_sum,
       updates_applied, mean_price]
    """
    valid = mask >= 0.0
    apply = mask > 0.0

    up = jnp.where(apply, new_price, price)
    uq = jnp.where(apply, new_qty, qty)

    vf = valid.astype(jnp.float32)
    value_sum = jnp.sum(up * uq * vf)
    count = jnp.sum(vf)
    price_sum = jnp.sum(up * vf)
    price_min = jnp.min(jnp.where(valid, up, _BIG))
    price_max = jnp.max(jnp.where(valid, up, -_BIG))
    qty_sum = jnp.sum(uq * vf)
    applied = jnp.sum(apply.astype(jnp.float32) * vf)
    mean_price = jnp.where(count > 0, price_sum / jnp.maximum(count, 1.0), 0.0)

    stats = jnp.stack([
        value_sum, count, price_sum, price_min, price_max, qty_sum, applied,
        mean_price
    ])
    assert stats.shape == (N_STATS,)
    return up, uq, stats


def price_histogram_ref(prices, valid_mask, bins: int, lo: float, hi: float):
    """Reference for the L2 histogram: counts of updated prices per bin."""
    edges = jnp.linspace(lo, hi, bins + 1)
    idx = jnp.clip(jnp.searchsorted(edges, prices, side="right") - 1, 0, bins - 1)
    onehot = jnp.zeros((prices.shape[0], bins), jnp.float32).at[
        jnp.arange(prices.shape[0]), idx].set(1.0)
    return jnp.sum(onehot * valid_mask[:, None], axis=0)
