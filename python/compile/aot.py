"""AOT lowering: JAX → HLO *text* artifacts consumed by the Rust runtime.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format —
jax >= 0.5 emits protos with 64-bit instruction ids that xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects; the text
parser reassigns ids. See /opt/xla-example/README.md.

Emits, per batch size B in ``BATCHES``:
  artifacts/analytics_{B}.hlo.txt    5 x f32[B] -> (f32[B], f32[B], f32[28])
  artifacts/value_sum_{B}.hlo.txt    3 x f32[B] -> (f32[],)
plus ``artifacts/manifest.json`` describing every artifact (name, path,
batch, arity) for the Rust artifact registry.

Run via ``make artifacts`` (idempotent; skips when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Batch sizes compiled ahead of time. Rust picks the smallest that fits and
# pads with mask=-1. Must be multiples of the kernel TILE (1024).
BATCHES = (4096, 16384, 65536)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_analytics(batch: int) -> str:
    spec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    lowered = jax.jit(model.analytics_tuple).lower(spec, spec, spec, spec,
                                                   spec)
    return to_hlo_text(lowered)


def lower_value_sum(batch: int) -> str:
    spec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    lowered = jax.jit(model.value_sum).lower(spec, spec, spec)
    return to_hlo_text(lowered)


def build(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "models": []}
    for batch in BATCHES:
        for name, lower, outputs in (
            ("analytics", lower_analytics, ["upd_price", "upd_qty",
                                            "summary"]),
            ("value_sum", lower_value_sum, ["total_value"]),
        ):
            text = lower(batch)
            fname = f"{name}_{batch}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["models"].append({
                "name": name,
                "batch": batch,
                "path": fname,
                "inputs": 5 if name == "analytics" else 3,
                "outputs": outputs,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            })
            print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {out_dir}/manifest.json ({len(manifest['models'])} models)")
    return manifest


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    out = args.out if os.path.isabs(args.out) else os.path.abspath(args.out)
    build(out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
