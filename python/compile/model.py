"""L2 — the analytics compute graph (JAX), calling the L1 Pallas kernel.

Two exported computations, AOT-lowered by ``aot.py``:

``analytics``
    Masked bulk update fused with inventory statistics and a price
    histogram. Rust pads each shard export to the compiled batch size and
    feeds mask=-1 for padding rows.

``value_sum``
    Reduction-only fast path for the server's STATS op.

Units note: Rust stores prices as integer cents; the analytics path converts
to f32 dollars at the boundary (exact for the paper's <= $10 prices — cents
values < 2^24 are exactly representable in f32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.update_stats import combine_partials, update_stats

HIST_BINS = 20
HIST_LO = 0.0
HIST_HI = 10.0


def price_histogram(prices, valid_mask):
    """Histogram of prices over [HIST_LO, HIST_HI) in HIST_BINS bins.

    Branch-free one-hot formulation — lowers to a single fused loop, no
    scatter (scatters serialize on CPU PJRT).
    """
    width = (HIST_HI - HIST_LO) / HIST_BINS
    idx = jnp.clip(((prices - HIST_LO) / width).astype(jnp.int32), 0,
                   HIST_BINS - 1)
    onehot = (idx[:, None] == jnp.arange(HIST_BINS)[None, :]).astype(
        jnp.float32)
    return jnp.sum(onehot * valid_mask[:, None], axis=0)


def analytics(price, qty, new_price, new_qty, mask):
    """Full analytics: update + stats + histogram.

    Returns a 3-tuple:
      upd_price f32[N], upd_qty f32[N],
      summary f32[N_STATS + HIST_BINS]  (stats ++ histogram)
    """
    up, uq, partials = update_stats(price, qty, new_price, new_qty, mask)
    stats = combine_partials(partials)
    valid = (mask >= 0.0).astype(jnp.float32)
    hist = price_histogram(up, valid)
    return up, uq, jnp.concatenate([stats, hist])


def value_sum(price, qty, mask):
    """Σ price·qty over valid rows (server STATS fast path)."""
    valid = (mask >= 0.0).astype(jnp.float32)
    return (jnp.sum(price * qty * valid),)


def analytics_tuple(price, qty, new_price, new_qty, mask):
    """aot entry point: flat tuple output for the XLA text boundary."""
    up, uq, summary = analytics(price, qty, new_price, new_qty, mask)
    return (up, uq, summary)
