//! Multi-process scale-out: the shared-nothing `ipc::ServingPool` vs the
//! in-process `ShardedStore`, over MGET/MUPDATE-shaped workloads.
//!
//! The paper's multi-processing claim (§3) is that partitioning the table
//! across OS processes keeps scaling past the point where shared-memory
//! synchronization saturates — but every RPC pays two Unix-socket hops, so
//! there is a crossover batch size below which in-process wins. This bench
//! measures both sides of that crossover: the direct store (zero IPC) and
//! real spawned worker processes at 1/2/4/8, each call scatter-gathering a
//! 64-key batch across the owning workers.
//!
//! Informational only — per-machine process-spawn and socket latency vary
//! too much to gate on; the JSON trajectory (`BENCH_ipc_scaleout.json`) is
//! the record. Honors `MEMBIG_BENCH_SCALE` like every other bench.

use std::path::PathBuf;
use std::sync::Arc;

use membig::ipc::ProcessPool;
use membig::memstore::ShardedStore;
use membig::util::bench::{bench, bench_scale, write_bench_json, BenchJsonRow};
use membig::util::fmt::commas;
use membig::workload::gen::DatasetSpec;
use membig::workload::record::StockUpdate;

const GROUP: usize = 64;
const PROCS: [usize; 4] = [1, 2, 4, 8];

fn main() {
    let scale = bench_scale();
    let records = (200_000 / scale).max(2_000);
    let iters: usize = if scale > 1 { 10 } else { 40 };

    let spec = DatasetSpec { records, ..Default::default() };
    let all: Vec<_> = spec.iter().collect();
    let stride = records / GROUP as u64;
    let keys: Vec<u64> = (0..GROUP as u64).map(|i| spec.record_at(i * stride).isbn13).collect();
    let ups: Vec<StockUpdate> = keys
        .iter()
        .enumerate()
        .map(|(i, &k)| StockUpdate {
            isbn13: k,
            new_price_cents: 500 + i as u64,
            new_quantity: i as u32,
        })
        .collect();

    println!(
        "=== ipc scale-out: {} records, {GROUP}-key batches, {iters} iters ===\n",
        commas(records)
    );

    let mut rows: Vec<BenchJsonRow> = Vec::new();

    // Baseline: the in-process sharded store (what `serve --processes 0` uses).
    let store = Arc::new(ShardedStore::new(8, (records as usize / 8).next_power_of_two()));
    for r in &all {
        store.insert(*r);
    }
    let s = bench("store-mget64 (in-process)", 3, iters, || {
        let got = store.get_many(&keys);
        assert_eq!(got.iter().filter(|r| r.is_some()).count(), GROUP);
    });
    println!("{}", s.render(Some(GROUP as u64)));
    rows.push(s.json_row(GROUP as u64));
    let s = bench("store-mupdate64 (in-process)", 3, iters, || {
        let (applied, _) = store.apply_many(&ups);
        assert_eq!(applied, GROUP as u64);
    });
    println!("{}", s.render(Some(GROUP as u64)));
    rows.push(s.json_row(GROUP as u64));
    drop(store);

    // Real worker processes: spawn, scatter-load, drive the serving API.
    let exe = PathBuf::from(env!("CARGO_BIN_EXE_membig"));
    for n in PROCS {
        let mut pool = match ProcessPool::spawn_with_exe(n, exe.clone()) {
            Ok(p) => p,
            Err(e) => {
                // Sandboxed runners can forbid process spawn — report, not fail.
                println!("procs{n}: spawn unavailable ({e}); skipping");
                continue;
            }
        };
        pool.load(&all).expect("scatter-load");
        let serving = pool.into_serving();

        let s = bench(&format!("procs{n}-mget64"), 3, iters, || {
            let got = serving.get_many(&keys).expect("mget rpc");
            assert_eq!(got.iter().filter(|r| r.is_some()).count(), GROUP);
        });
        println!("{}", s.render(Some(GROUP as u64)));
        rows.push(s.json_row(GROUP as u64));

        let s = bench(&format!("procs{n}-mupdate64"), 3, iters, || {
            let (applied, _) = serving.update_many(&ups).expect("mupdate rpc");
            assert_eq!(applied, GROUP as u64);
        });
        println!("{}", s.render(Some(GROUP as u64)));
        rows.push(s.json_row(GROUP as u64));

        serving.shutdown().expect("pool shutdown");
    }

    let path = write_bench_json("ipc_scaleout", &rows).expect("write BENCH_ipc_scaleout.json");
    println!("\njson: {}", path.display());
}
