//! Recovery / load-path ablation: three ways to get a populated store into
//! RAM, which is the proposed method's startup cost ("data are loaded into
//! memory prior to start processing"):
//!
//!   1. scan the paged disk table (the paper's implied path),
//!   2. load a binary snapshot (our checkpoint extension),
//!   3. snapshot + WAL-suffix replay (crash recovery).
//!
//! CSV: bench_out/recovery.csv.

use std::sync::Arc;

use membig::durability::{load_snapshot, write_snapshot, Wal, WalReader};
use membig::memstore::snapshot::load_store;
use membig::metrics::EngineMetrics;
use membig::storage::latency::{DiskProfile, DiskSim};
use membig::storage::table::{DiskTable, TableOptions};
use membig::util::bench::{bench_out_dir, bench_scale, time_once, write_bench_json, BenchJsonRow};
use membig::util::csv::CsvWriter;
use membig::util::fmt::{commas, human_duration, rate};
use membig::workload::gen::{generate_stock_updates, DatasetSpec, KeyDist};

fn main() {
    let scale = bench_scale();
    let n = (2_000_000 / scale).max(50_000);
    let shards = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let spec = DatasetSpec { records: n, ..Default::default() };
    let dir = bench_out_dir().join("data").join("recovery");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();

    println!("=== recovery paths: {} records, {} shards ===\n", commas(n), shards);
    let csv_path = bench_out_dir().join("recovery.csv");
    let mut csv =
        CsvWriter::create(&csv_path, &["path", "seconds", "records_per_sec"]).unwrap();

    // Path 1: disk-table scan.
    let build_sim = Arc::new(DiskSim::new(DiskProfile::none()));
    let table = DiskTable::create(
        dir.join("table"),
        spec.iter(),
        n,
        build_sim,
        TableOptions::default(),
    )
    .unwrap();
    let m = EngineMetrics::new();
    let (store, t_scan) = time_once(|| load_store(&table, shards, &m).unwrap());
    println!("table scan:          {}  ({})", human_duration(t_scan), rate(n, t_scan));
    csv.row(&[
        "table_scan",
        &format!("{:.6}", t_scan.as_secs_f64()),
        &format!("{:.0}", n as f64 / t_scan.as_secs_f64()),
    ])
    .unwrap();

    // Path 2: binary snapshot.
    let snap_path = dir.join("store.snap");
    let (written, t_write) = time_once(|| write_snapshot(&store, &snap_path).unwrap());
    assert_eq!(written, n);
    let (loaded, t_snap) = time_once(|| load_snapshot(&snap_path, shards).unwrap());
    assert_eq!(loaded.len() as u64, n);
    assert_eq!(loaded.value_sum_cents(), store.value_sum_cents());
    println!("snapshot write:      {}  ({})", human_duration(t_write), rate(n, t_write));
    println!("snapshot load:       {}  ({})", human_duration(t_snap), rate(n, t_snap));
    csv.row(&[
        "snapshot_load",
        &format!("{:.6}", t_snap.as_secs_f64()),
        &format!("{:.0}", n as f64 / t_snap.as_secs_f64()),
    ])
    .unwrap();

    // Path 3: snapshot + WAL suffix (10% of n as un-checkpointed tail).
    let tail = (n / 10).max(1);
    let ups = generate_stock_updates(&spec, tail, KeyDist::Uniform, 5);
    let wal_path = dir.join("tail.wal");
    {
        let mut wal = Wal::open(&wal_path).unwrap();
        wal.append_batch(&ups).unwrap();
        wal.sync().unwrap();
    }
    let (recovered, t_recover) = time_once(|| {
        let s = load_snapshot(&snap_path, shards).unwrap();
        let (replayed, torn) = WalReader::open(&wal_path)
            .unwrap()
            .replay(|u| {
                s.apply(u);
            })
            .unwrap();
        assert_eq!(replayed, tail);
        assert!(!torn);
        s
    });
    assert_eq!(recovered.len() as u64, n);
    println!(
        "snapshot + WAL({}): {}  ({})",
        commas(tail),
        human_duration(t_recover),
        rate(n + tail, t_recover)
    );
    csv.row(&[
        "snapshot_plus_wal",
        &format!("{:.6}", t_recover.as_secs_f64()),
        &format!("{:.0}", (n + tail) as f64 / t_recover.as_secs_f64()),
    ])
    .unwrap();

    csv.flush().unwrap();
    let gain = t_scan.as_secs_f64() / t_snap.as_secs_f64();
    println!("\nsnapshot load is {gain:.1}x faster than the table scan — the startup-cost");
    println!("optimization the paper's \"load prior to processing\" step leaves on the table.");
    println!("wrote {}", csv_path.display());

    // Machine-readable report (single-shot measurements: p50 == p99 == the
    // one sample) — the EXPERIMENTS.md recovery-cost rows read from this.
    let row = |name: &str, ops: u64, d: std::time::Duration| BenchJsonRow {
        name: name.to_string(),
        ops_per_sec: ops as f64 / d.as_secs_f64(),
        p50_ns: d.as_nanos().min(u64::MAX as u128) as u64,
        p99_ns: d.as_nanos().min(u64::MAX as u128) as u64,
        n: 1,
    };
    let json_rows = vec![
        row("table_scan", n, t_scan),
        row("snapshot_write", n, t_write),
        row("snapshot_load", n, t_snap),
        row("snapshot_plus_wal", n + tail, t_recover),
    ];
    let json_path = write_bench_json("recovery", &json_rows).unwrap();
    println!("wrote {}", json_path.display());
}
