//! §5 reason 1 (the memory-vs-disk microfoundation): point-operation
//! latency of each storage backend under each latency model. The paper
//! quotes ~10ms HDD vs ~10ns RAM (10^6 ×); this bench measures our actual
//! memstore latency and the modeled disk latencies, and reports the ratios.
//!
//! Since ISSUE 8 the repo also has a real (not modeled) disk path: the
//! larger-than-RAM tier. The second half measures tiered point reads in
//! each placement state — resident (mem hit), spilled across runs (block
//! cache + bloom + binary search), and spilled-then-compacted — against
//! the pure memstore, and writes the repo-root `BENCH_tiered_read.json`
//! report that CI tracks.
//!
//! Series (CSV bench_out/memory_vs_disk.csv):
//!   memstore get / memstore update            (measured, ns)
//!   disktable get/update, HDD model           (modeled, per-op)
//!   disktable get/update, SSD model           (modeled, per-op)
//!   disktable get/update, no model            (measured file I/O only)
//!   tiered get: resident / spilled / compacted (measured, ns)

use std::sync::Arc;

use membig::memstore::ShardedStore;
use membig::metrics::EngineMetrics;
use membig::storage::latency::{DiskProfile, DiskSim};
use membig::storage::table::{DiskTable, TableOptions};
use membig::storage::{StorageEngine, TieredOptions, TieredStore};
use membig::util::bench::{bench_out_dir, bench_scale, stat_from, write_bench_json, BenchJsonRow};
use membig::util::csv::CsvWriter;
use membig::util::fmt::{commas, human_duration};
use membig::util::rng::Rng;
use membig::workload::gen::DatasetSpec;

fn main() {
    let scale = bench_scale();
    let records = (200_000 / scale).max(10_000);
    let ops = (50_000 / scale).max(5_000) as usize;
    let spec = DatasetSpec { records, ..Default::default() };
    println!("=== memory vs disk: {} records, {} point ops each ===\n", commas(records),
        commas(ops as u64));

    let keys: Vec<u64> = {
        let mut rng = Rng::new(7);
        (0..ops).map(|_| spec.record_at(rng.gen_range(records)).isbn13).collect()
    };

    let csv_path = bench_out_dir().join("memory_vs_disk.csv");
    let mut csv =
        CsvWriter::create(&csv_path, &["backend", "op", "per_op_ns", "kind"]).unwrap();
    let mut emit = |backend: &str, op: &str, ns: f64, kind: &str| {
        println!("{backend:<28} {op:<8} {:>12}/op  ({kind})", human_duration(std::time::Duration::from_nanos(ns as u64)));
        csv.row(&[backend.to_string(), op.to_string(), format!("{ns:.1}"), kind.to_string()])
            .unwrap();
    };

    // ---- memstore (measured) -----------------------------------------
    let store = ShardedStore::new(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        (records as usize).next_power_of_two(),
    );
    for r in spec.iter() {
        store.insert(r);
    }
    let mut mem_get_ns = 0.0;
    let mut json_rows: Vec<BenchJsonRow> = Vec::new();
    for (op, name) in [(0, "get"), (1, "update")] {
        let mut samples = Vec::new();
        for _ in 0..5 {
            let t0 = std::time::Instant::now();
            for &k in &keys {
                if op == 0 {
                    std::hint::black_box(store.get(k));
                } else {
                    store.update(k, |r| r.quantity ^= 1);
                }
            }
            samples.push(t0.elapsed());
        }
        let stat = stat_from(name, samples);
        let per_op = stat.mean.as_nanos() as f64 / ops as f64;
        if op == 0 {
            mem_get_ns = per_op;
            let mut row = stat.json_row(ops as u64);
            row.name = "memstore_get".into();
            json_rows.push(row);
        }
        emit("memstore (RAM)", name, per_op, "measured");
    }

    // ---- disk table under each latency model ----------------------------
    let dir = bench_out_dir().join("data").join("mvd_table");
    std::fs::remove_dir_all(&dir).ok();
    let build_sim = Arc::new(DiskSim::new(DiskProfile::none()));
    let table = DiskTable::create(
        &dir,
        spec.iter(),
        records,
        build_sim,
        TableOptions { cache_pages: 64, engine_overhead: false },
    )
    .unwrap();
    drop(table);

    let mut hdd_get_ns = 0.0;
    let m = EngineMetrics::new();
    for (profile, pname) in [
        (DiskProfile::default(), "disktable (HDD model)"),
        (DiskProfile::ssd(), "disktable (SSD model)"),
        (DiskProfile::none(), "disktable (file I/O only)"),
    ] {
        let sim = Arc::new(DiskSim::new(profile));
        let table = DiskTable::open(
            &dir,
            sim.clone(),
            TableOptions { cache_pages: 64, engine_overhead: profile != DiskProfile::none() },
        )
        .unwrap();
        for (op, name) in [(0usize, "get"), (1, "update")] {
            sim.reset();
            let t0 = std::time::Instant::now();
            for &k in &keys {
                if op == 0 {
                    std::hint::black_box(table.get(k).unwrap());
                } else {
                    table.update(k, |r| r.quantity ^= 1).unwrap();
                }
            }
            let wall = t0.elapsed();
            let modeled = sim.modeled();
            let (per_op, kind) = if profile == DiskProfile::none() {
                (wall.as_nanos() as f64 / ops as f64, "measured")
            } else {
                (modeled.as_nanos() as f64 / ops as f64, "modeled")
            };
            if op == 0 && pname.contains("HDD") {
                hdd_get_ns = per_op;
            }
            emit(pname, name, per_op, kind);
        }
        let _ = &m;
    }

    // ---- tiered store: real disk-run fallthrough (measured) --------------
    // Three placement states for the same dataset and key mix:
    //   resident  — budget >= dataset, every get is a seqlock mem hit
    //   spilled   — budget ~1/16 of dataset, flushed: gets fall through to
    //               the run set (bloom skip + block cache + binary search)
    //   compacted — same, after compact_now() merges the runs into one
    let tier_dir = bench_out_dir().join("data").join("mvd_tier");
    let tier_states: [(&str, u64, bool); 3] = [
        ("tiered_get_resident", records * 32, false),
        ("tiered_get_spilled", (records * 32 / 16).max(256), false),
        ("tiered_get_compacted", (records * 32 / 16).max(256), true),
    ];
    for (name, budget_bytes, compact) in tier_states {
        let tier = TieredStore::open_clean(
            &tier_dir,
            TieredOptions {
                budget_bytes,
                shards: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
                capacity_hint: (records as usize).next_power_of_two(),
                compact_at: 0,
                ..TieredOptions::default()
            },
        )
        .unwrap();
        for r in spec.iter() {
            tier.insert(r);
        }
        if budget_bytes < records * 32 {
            tier.flush().unwrap();
        }
        if compact {
            tier.compact_now().unwrap();
        }
        let mut samples = Vec::new();
        for _ in 0..5 {
            let t0 = std::time::Instant::now();
            for &k in &keys {
                std::hint::black_box(tier.get(k));
            }
            samples.push(t0.elapsed());
        }
        let stat = stat_from(name, samples);
        let per_op = stat.mean.as_nanos() as f64 / ops as f64;
        let tm = tier.tiered_metrics();
        emit(name, "get", per_op, "measured");
        println!(
            "    {} run(s), {} B on disk, {} resident | mem {} disk {} | cache hit {:.0}%",
            tier.run_count(),
            commas(tier.disk_bytes()),
            commas(tier.resident_records()),
            commas(tm.mem_hits.get()),
            commas(tm.disk_hits.get()),
            tm.cache_hit_rate() * 100.0
        );
        json_rows.push(stat.json_row(ops as u64));
        drop(tier);
    }
    std::fs::remove_dir_all(&tier_dir).ok();
    csv.flush().unwrap();

    let json_path = write_bench_json("tiered_read", &json_rows).unwrap();
    println!("\nwrote {}", json_path.display());

    let ratio = hdd_get_ns / mem_get_ns;
    println!("\nHDD-model get vs memstore get: {ratio:.0}x (paper's §5 claim: ~10^6x raw medium");
    println!("latency; end-to-end per-op ratio lands lower because a keyed disk read is");
    println!("several page touches while a RAM get is several cache-line touches).");
    println!("wrote {}", csv_path.display());
    assert!(ratio > 10_000.0, "memory must beat modeled HDD by >=4 orders of magnitude");
}
