//! Figure 1 (the "special Hash Table data structure"): our robin-hood table
//! vs `std::collections::HashMap` on the paper's workload shape — bulk
//! insert, point get, in-place update — plus probe-length diagnostics and a
//! load-factor sweep. CSV: bench_out/hashtable.csv.

use membig::memstore::HashTable;
use membig::util::bench::{bench_out_dir, bench_scale, stat_from, write_bench_json, BenchJsonRow};
use membig::util::csv::CsvWriter;
use membig::util::fmt::commas;
use membig::util::rng::Rng;
use membig::workload::gen::DatasetSpec;
use membig::workload::record::BookRecord;

fn main() {
    let scale = bench_scale();
    let n = (1_000_000 / scale).max(50_000);
    let spec = DatasetSpec { records: n, ..Default::default() };
    println!("=== hashtable: ours vs std::HashMap, {} records ===\n", commas(n));

    let records: Vec<BookRecord> = spec.iter().collect();
    let probe_keys: Vec<u64> = {
        let mut rng = Rng::new(3);
        (0..n).map(|_| records[rng.gen_range(n) as usize].isbn13).collect()
    };

    let csv_path = bench_out_dir().join("hashtable.csv");
    let mut csv = CsvWriter::create(&csv_path, &["table", "op", "ops_per_sec"]).unwrap();
    let mut json_rows: Vec<BenchJsonRow> = Vec::new();
    let iters = 5;

    // ---- ours -----------------------------------------------------------
    let mut ours = HashTable::with_capacity(n as usize);
    {
        let mut samples = Vec::new();
        for _ in 0..iters {
            ours = HashTable::with_capacity(n as usize);
            let t0 = std::time::Instant::now();
            for r in &records {
                ours.insert(*r);
            }
            samples.push(t0.elapsed());
        }
        let s = stat_from("ours insert", samples);
        println!("{}", s.render(Some(n)));
        csv.row(&["ours", "insert", &format!("{:.0}", s.ops_per_sec(n))]).unwrap();
        json_rows.push(s.json_row(n));
    }
    for (op, name) in [(0, "get"), (1, "update")] {
        let mut samples = Vec::new();
        for _ in 0..iters {
            let t0 = std::time::Instant::now();
            for &k in &probe_keys {
                if op == 0 {
                    std::hint::black_box(ours.get(k));
                } else {
                    ours.update(k, |r| r.quantity ^= 1);
                }
            }
            samples.push(t0.elapsed());
        }
        let s = stat_from(&format!("ours {name}"), samples);
        println!("{}", s.render(Some(n)));
        csv.row(&["ours", name, &format!("{:.0}", s.ops_per_sec(n))]).unwrap();
        json_rows.push(s.json_row(n));
    }
    println!("ours: capacity={} max_probe={} mem={}\n", commas(ours.capacity() as u64),
        ours.max_probe(), membig::util::fmt::bytes(ours.memory_bytes() as u64));

    // ---- std::HashMap ----------------------------------------------------
    let mut std_map: std::collections::HashMap<u64, (u64, u32)> = Default::default();
    {
        let mut samples = Vec::new();
        for _ in 0..iters {
            std_map = std::collections::HashMap::with_capacity(n as usize);
            let t0 = std::time::Instant::now();
            for r in &records {
                std_map.insert(r.isbn13, (r.price_cents, r.quantity));
            }
            samples.push(t0.elapsed());
        }
        let s = stat_from("std insert", samples);
        println!("{}", s.render(Some(n)));
        csv.row(&["std", "insert", &format!("{:.0}", s.ops_per_sec(n))]).unwrap();
        json_rows.push(s.json_row(n));
    }
    for (op, name) in [(0, "get"), (1, "update")] {
        let mut samples = Vec::new();
        for _ in 0..iters {
            let t0 = std::time::Instant::now();
            for &k in &probe_keys {
                if op == 0 {
                    std::hint::black_box(std_map.get(&k));
                } else if let Some(v) = std_map.get_mut(&k) {
                    v.1 ^= 1;
                }
            }
            samples.push(t0.elapsed());
        }
        let s = stat_from(&format!("std {name}"), samples);
        println!("{}", s.render(Some(n)));
        csv.row(&["std", name, &format!("{:.0}", s.ops_per_sec(n))]).unwrap();
        json_rows.push(s.json_row(n));
    }

    // ---- load-factor sweep (probe behaviour near capacity) ---------------
    // Fix the capacity (hint 800k → 2^20 buckets, grow threshold 917k) and
    // fill to each target load, watching the probe length climb.
    println!("\nload-factor sweep (ours, fixed 2^20-bucket table):");
    for load in [0.5f64, 0.7, 0.8, 0.85] {
        let mut t = HashTable::with_capacity(800_000);
        let cap = t.capacity();
        let items = ((cap as f64 * load) as usize).min(records.len());
        for r in records.iter().take(items) {
            t.insert(*r);
        }
        assert_eq!(t.capacity(), cap, "sweep must not trigger growth");
        println!(
            "  load {:.2} ({} items / {} buckets): max_probe {}",
            t.len() as f64 / cap as f64,
            commas(t.len() as u64),
            commas(cap as u64),
            t.max_probe()
        );
    }
    csv.flush().unwrap();
    println!("\nwrote {}", csv_path.display());
    let json_path = write_bench_json("hashtable", &json_rows).unwrap();
    println!("wrote {}", json_path.display());
}
