//! §5 reason 2 (Figure 2 / the multiprocessing claim): parallel speedup of
//! the proposed path as thread count grows. The paper asserts
//! `TotalExTime = ExTimePerInstr / N`; real shared-memory systems saturate
//! at the physical core count — this bench measures where.
//!
//! Sweep: threads ∈ {1, 2, 4, …, 2×cores}; fixed workload of 2M updates
//! over a 2M-record store (divided by MEMBIG_BENCH_SCALE). Reports ops/s,
//! speedup vs 1 thread, and parallel efficiency; CSV in
//! bench_out/thread_scaling.csv.

use membig::memstore::ShardedStore;
use membig::metrics::EngineMetrics;
use membig::pipeline::executor::run_update_in_memory;
use membig::util::bench::{bench_out_dir, bench_scale, stat_from};
use membig::util::csv::CsvWriter;
use membig::util::fmt::commas;
use membig::workload::gen::{generate_stock_updates, DatasetSpec, KeyDist};

fn main() {
    let scale = bench_scale();
    let records = 2_000_000 / scale;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut sweep = vec![1usize];
    while *sweep.last().unwrap() < cores * 2 {
        sweep.push(sweep.last().unwrap() * 2);
    }
    if !sweep.contains(&cores) {
        sweep.push(cores);
        sweep.sort_unstable();
    }

    println!("=== thread scaling: {} records / {} updates, cores={} ===\n", commas(records),
        commas(records), cores);

    let spec = DatasetSpec { records, ..Default::default() };
    let updates = generate_stock_updates(&spec, records, KeyDist::PermuteAll, 42);

    let csv_path = bench_out_dir().join("thread_scaling.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["threads", "mean_s", "ops_per_sec", "speedup", "efficiency", "ideal_speedup"],
    )
    .unwrap();

    let mut base: Option<f64> = None;
    for &threads in &sweep {
        // Fresh store per configuration (shards == threads, paper topology).
        let iters = if records > 500_000 { 3 } else { 5 };
        let mut samples = Vec::new();
        for _ in 0..iters {
            let store =
                ShardedStore::new(threads, (records as usize / threads).next_power_of_two());
            for r in spec.iter() {
                store.insert(r);
            }
            let m = EngineMetrics::new();
            let t0 = std::time::Instant::now();
            let rep = run_update_in_memory(&store, &updates, &m);
            samples.push(t0.elapsed());
            assert_eq!(rep.updates_applied, records);
        }
        let stat = stat_from(&format!("threads={threads}"), samples);
        let secs = stat.mean.as_secs_f64();
        let speedup = base.map(|b| b / secs).unwrap_or(1.0);
        if base.is_none() {
            base = Some(secs);
        }
        let eff = speedup / threads as f64;
        println!(
            "{}  {:>12}  speedup {:>5.2}x (ideal {:>2}x)  efficiency {:>5.1}%",
            stat.render(Some(records)),
            "",
            speedup,
            threads,
            eff * 100.0
        );
        csv.row(&[
            threads.to_string(),
            format!("{secs:.6}"),
            format!("{:.0}", stat.ops_per_sec(records)),
            format!("{speedup:.3}"),
            format!("{eff:.3}"),
            threads.to_string(),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    println!("\nwrote {}", csv_path.display());

    println!(
        "\npaper's model: T(n) = T(1)/n — holds up to the physical core count,\n\
         then flattens (memory bandwidth + hyperthread sharing), which is the\n\
         expected real-system deviation from the paper's idealized formula."
    );
}
