//! Request-path throughput: per-request round trips vs the pipelined batch
//! verbs, plus the lock-free read path. The serving claim (paper §4.3) only
//! holds if the front end keeps cores busy instead of paying one network
//! round trip per key — and the shared-memory claim (§4) only holds if
//! concurrent readers *scale*, which is what the contention sweep measures.
//!
//! Acceptance:
//! - ISSUE 2: an `MUPDATE` batch of 64 must sustain ≥5× the ops/sec of 64
//!   single `UPDATE` round-trips (enforced at full scale).
//! - ISSUE 4: 4 reader threads hammering `get_many` against a live writer
//!   must sustain ≥ the single-reader rate at any scale (no negative
//!   scaling — enforced even in CI smoke runs) and ≥2× at full scale.
//!   Both floors are enforced only on hosts with ≥6 cores: with less
//!   headroom the 4-reader config (plus writer and main thread) is
//!   oversubscribed and the gate would measure the scheduler.
//!
//! Configurations (one live server, one client, loopback TCP):
//!   update-single    64 UPDATE round-trips
//!   update-mupdate   one MUPDATE line carrying 64 groups (shard-affine)
//!   update-batch     BATCH 64 framing around single UPDATE lines
//!   get-single       64 GET round-trips
//!   get-mget         one MGET line carrying 64 keys
//!   get-heavy-mixed  BATCH 64 of 7/8 GET + 1/8 UPDATE (read-mostly serving)
//!
//! Read-path contention sweep (direct store, no TCP so the syscall cost
//! cannot mask the synchronization cost): 1/2/4 reader threads × get_many
//! batches of 64 uniformly-random keys, against one writer thread applying
//! 64-update batches continuously. Emits `BENCH_read_path.json`.
//!
//! CSV: bench_out/server_throughput.csv.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use membig::memstore::ShardedStore;
use membig::server::{Client, Server, ServerConfig};
use membig::util::bench::{
    bench, bench_out_dir, bench_scale, read_bench_json, stat_from, write_bench_json,
    BenchJsonRow, BenchStat,
};
use membig::util::csv::CsvWriter;
use membig::util::fmt::commas;
use membig::workload::gen::DatasetSpec;
use membig::workload::record::StockUpdate;

const GROUP: usize = 64;

fn main() {
    let scale = bench_scale();
    let records = (100_000 / scale).max(1_000);
    let iters: usize = if scale > 1 { 15 } else { 50 };

    let spec = DatasetSpec { records, ..Default::default() };
    let store = Arc::new(ShardedStore::new(8, (records as usize / 8).next_power_of_two()));
    for r in spec.iter() {
        store.insert(r);
    }
    let stride = records / GROUP as u64;
    let keys: Vec<u64> = (0..GROUP as u64).map(|i| spec.record_at(i * stride).isbn13).collect();

    let cfg = ServerConfig { workers: 4, max_conns: 16, ..Default::default() };
    let handle = Server::with_config(store, None, cfg).spawn("127.0.0.1:0").unwrap();
    let mut c = Client::connect(handle.addr).unwrap();

    println!(
        "=== server throughput: {} records, group size {GROUP}, {iters} iters ===\n",
        commas(records)
    );

    let update_single = bench("update-single (64 round-trips)", 3, iters, || {
        for (i, k) in keys.iter().enumerate() {
            let r = c.request(&format!("UPDATE {k} {} {i}", 100 + i)).unwrap();
            assert_eq!(r, "OK");
        }
    });

    let mupdate_line = {
        let groups: Vec<String> =
            keys.iter().enumerate().map(|(i, k)| format!("{k} {} {i}", 200 + i)).collect();
        format!("MUPDATE {}", groups.join(";"))
    };
    let update_mupdate = bench("update-mupdate (1 round-trip)", 3, iters, || {
        let r = c.request(&mupdate_line).unwrap();
        assert_eq!(r, format!("OK applied={GROUP} missed=0"));
    });

    let batch_lines: Vec<String> =
        keys.iter().enumerate().map(|(i, k)| format!("UPDATE {k} {} {i}", 300 + i)).collect();
    let update_batch = bench("update-batch (BATCH 64 framing)", 3, iters, || {
        let rs = c.batch(&batch_lines).unwrap();
        assert_eq!(rs.len(), GROUP);
    });

    let get_single = bench("get-single (64 round-trips)", 3, iters, || {
        for k in &keys {
            let r = c.request(&format!("GET {k}")).unwrap();
            assert!(r.starts_with("OK"), "{r}");
        }
    });

    let mget_line = format!(
        "MGET {}",
        keys.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(" ")
    );
    let get_mget = bench("get-mget (1 round-trip)", 3, iters, || {
        let r = c.request(&mget_line).unwrap();
        assert!(r.starts_with(&format!("OK {GROUP} ")), "{r}");
    });

    // GET-heavy mixed workload: the read-mostly serving shape the lock-free
    // read path targets — 56 GETs + 8 UPDATEs pipelined as one BATCH group.
    let mixed_lines: Vec<String> = keys
        .iter()
        .enumerate()
        .map(|(i, k)| {
            if i % 8 == 7 {
                format!("UPDATE {k} {} {i}", 400 + i)
            } else {
                format!("GET {k}")
            }
        })
        .collect();
    let get_mixed = bench("get-heavy-mixed (BATCH 56G+8U)", 3, iters, || {
        let rs = c.batch(&mixed_lines).unwrap();
        assert_eq!(rs.len(), GROUP);
        assert!(rs.iter().all(|r| r.starts_with("OK")), "{rs:?}");
    });

    let _ = c.request("QUIT");

    let rows: Vec<(&BenchStat, f64)> = vec![
        (&update_single, 1.0),
        (&update_mupdate, update_single.mean.as_secs_f64() / update_mupdate.mean.as_secs_f64()),
        (&update_batch, update_single.mean.as_secs_f64() / update_batch.mean.as_secs_f64()),
        (&get_single, 1.0),
        (&get_mget, get_single.mean.as_secs_f64() / get_mget.mean.as_secs_f64()),
        (&get_mixed, get_single.mean.as_secs_f64() / get_mixed.mean.as_secs_f64()),
    ];

    let csv_path = bench_out_dir().join("server_throughput.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["config", "mean_s", "ops_per_sec", "speedup_vs_single"],
    )
    .unwrap();
    for (stat, speedup) in &rows {
        println!("{}  speedup {:>5.1}x", stat.render(Some(GROUP as u64)), speedup);
        csv.row(&[
            stat.name.clone(),
            format!("{:.6}", stat.mean.as_secs_f64()),
            format!("{:.0}", stat.ops_per_sec(GROUP as u64)),
            format!("{speedup:.3}"),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    println!("\nwrote {}", csv_path.display());

    // Machine-readable report for the CI perf trajectory.
    let json_rows: Vec<_> = rows.iter().map(|(stat, _)| stat.json_row(GROUP as u64)).collect();
    let json_path = write_bench_json("server_throughput", &json_rows).unwrap();
    println!("wrote {}", json_path.display());

    let headline = update_single.mean.as_secs_f64() / update_mupdate.mean.as_secs_f64();
    println!(
        "\nMUPDATE batches of {GROUP}: {headline:.1}x the ops/sec of {GROUP} single \
         UPDATE round-trips (acceptance floor: 5x)"
    );
    handle.shutdown();
    if headline < 5.0 {
        if scale == 1 {
            // Full-scale runs enforce the acceptance criterion; tiny-N
            // smoke runs (CI) only report, since loopback timing at small
            // iteration counts is too noisy to gate on.
            eprintln!("FAIL: below the 5x acceptance floor");
            std::process::exit(1);
        }
        println!("WARNING: below the 5x acceptance floor (not enforced at tiny N)");
    }

    read_path_sweep(records, scale);
    idle_conn_sweep(scale);
}

/// 1/2/4-reader contention sweep over the lock-free read path, against a
/// live writer. Measures aggregate `get_many` key-reads/sec per thread
/// count and asserts the scaling acceptance (no negative scaling ever;
/// ≥2× for 4 readers at full scale).
fn read_path_sweep(records: u64, scale: u64) {
    // Snapshot the committed baseline BEFORE this run overwrites the file.
    let baseline = read_bench_json("read_path");
    // Even the smoke window must be long enough (tens of ms per config)
    // that one scheduler blip on a loaded CI runner cannot flip the
    // scaling gate below.
    let sweep_iters: usize = if scale > 1 { 2_000 } else { 8_000 };
    let spec = DatasetSpec { records, ..Default::default() };
    let store = Arc::new(ShardedStore::new(8, (records as usize / 8).next_power_of_two()));
    for r in spec.iter() {
        store.insert(r);
    }
    let keys: Vec<u64> = (0..records).map(|i| spec.record_at(i).isbn13).collect();

    println!(
        "\n=== read-path contention sweep: {} records, {sweep_iters} get_many(64) \
         batches/reader, live writer ===\n",
        commas(records)
    );

    let mut json_rows: Vec<BenchJsonRow> = Vec::new();
    let mut agg_by_threads: Vec<(usize, f64)> = Vec::new();
    for &threads in &[1usize, 2, 4] {
        // Best of two runs per thread count: the gate below compares
        // configs measured at different moments, so take the less
        // noise-perturbed sample of each.
        let (mut best_ops, mut best_samples): (f64, Vec<std::time::Duration>) = (0.0, Vec::new());
        for _attempt in 0..2 {
            let (ops, samples) = sweep_once(&store, &keys, records, threads, sweep_iters);
            if ops > best_ops {
                best_ops = ops;
                best_samples = samples;
            }
        }
        let ops = best_ops;
        let stat = stat_from(&format!("get_many-{threads}r"), best_samples);
        println!(
            "get_many {threads} reader(s): {:>12.0} keys/s aggregate (batch p50 {:?}, p99 {:?})",
            ops, stat.p50, stat.p99
        );
        json_rows.push(BenchJsonRow {
            name: format!("get_many-{threads}r"),
            ops_per_sec: ops,
            p50_ns: stat.p50.as_nanos().min(u64::MAX as u128) as u64,
            p99_ns: stat.p99.as_nanos().min(u64::MAX as u128) as u64,
            // `n` is the sample count behind the percentiles — reader 0's
            // sampled batches, not the total iteration count.
            n: stat.iters as u64,
        });
        agg_by_threads.push((threads, ops));
    }
    let stats = store.read_stats();
    println!(
        "read-path counters: retries={} fallbacks={}",
        stats.retries.get(),
        stats.fallbacks.get()
    );

    let json_path = write_bench_json("read_path", &json_rows).unwrap();
    println!("wrote {}", json_path.display());

    compare_with_baseline(baseline, &json_rows, scale);

    let one = agg_by_threads[0].1;
    let four = agg_by_threads[2].1;
    let scaling = four / one;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "\n4-reader GET throughput: {scaling:.2}x single-reader \
         (floors on >=6 cores: >=1x any scale, >=2x at full scale; {cores} cores here)"
    );
    // The comparison is only meaningful when 4 readers + 1 writer + the
    // main thread actually have cores to run on: with less headroom the
    // 4-reader config is oversubscribed while the 1-reader baseline is
    // not, and the gate would measure the scheduler, not the lock.
    if cores < 6 {
        println!("WARNING: <6 cores, read-scaling floors reported but not enforced");
        return;
    }
    // No negative scaling, at any N: lock-free readers must never be slower
    // together than alone. This is the bench-smoke gate.
    if four < one {
        eprintln!("FAIL: negative read scaling ({scaling:.2}x)");
        std::process::exit(1);
    }
    if scaling < 2.0 {
        if scale == 1 {
            eprintln!("FAIL: below the 2x read-scaling acceptance floor");
            std::process::exit(1);
        }
        println!("WARNING: below the 2x floor (not enforced at tiny N)");
    }
}

/// One sweep configuration: `threads` readers × `sweep_iters` get_many(64)
/// batches against one continuously-writing thread. Returns the aggregate
/// key-reads/sec and reader 0's per-batch latency samples.
fn sweep_once(
    store: &Arc<ShardedStore>,
    keys: &[u64],
    records: u64,
    threads: usize,
    sweep_iters: usize,
) -> (f64, Vec<std::time::Duration>) {
    let stop = AtomicBool::new(false);
    let total_reads = AtomicU64::new(0);
    let mut sample_src: Vec<std::time::Duration> = Vec::new();
    let elapsed = std::thread::scope(|scope| {
        // Writer: continuous churn so readers race real seqlock windows,
        // not an idle store.
        scope.spawn(|| {
            let mut round = 0u64;
            while !stop.load(Ordering::Acquire) {
                let ups: Vec<StockUpdate> = (0..64u64)
                    .map(|i| {
                        let k = keys[((round * 31 + i * 17) % records) as usize];
                        StockUpdate {
                            isbn13: k,
                            new_price_cents: 100 + round,
                            new_quantity: 1 + (i as u32),
                        }
                    })
                    .collect();
                store.apply_many(&ups);
                round += 1;
            }
        });
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            handles.push(scope.spawn(move || {
                let mut batch = [0u64; 64];
                let mut state = 0x2545_F491_4F6C_DD1Du64 ^ ((t as u64 + 1) << 21);
                let mut samples = Vec::with_capacity(64);
                let mut reads = 0u64;
                for it in 0..sweep_iters {
                    for slot in batch.iter_mut() {
                        // xorshift64*
                        state ^= state << 13;
                        state ^= state >> 7;
                        state ^= state << 17;
                        *slot = keys[(state % records) as usize];
                    }
                    // Thread 0 samples every 128th batch for latency
                    // percentiles without perturbing the hot loop.
                    if t == 0 && it % 128 == 0 {
                        let b0 = Instant::now();
                        reads += store.get_many(&batch).len() as u64;
                        samples.push(b0.elapsed());
                    } else {
                        reads += store.get_many(&batch).len() as u64;
                    }
                }
                (reads, samples)
            }));
        }
        let mut first_samples = Vec::new();
        for (t, h) in handles.into_iter().enumerate() {
            let (reads, samples) = h.join().expect("sweep reader panicked");
            total_reads.fetch_add(reads, Ordering::Relaxed);
            if t == 0 {
                first_samples = samples;
            }
        }
        let el = t0.elapsed();
        stop.store(true, Ordering::Release);
        sample_src = first_samples;
        el
    });
    let reads = total_reads.load(Ordering::Relaxed);
    (reads as f64 / elapsed.as_secs_f64(), sample_src)
}

/// Gate this run's read-scaling numbers against the committed
/// `BENCH_read_path.json` baseline. A baseline whose rows are all `n: 0`
/// is the zeroed schema-only seed a toolchain-less tree commits — it is
/// **unpopulated**: report that and let this run's freshly-written JSON
/// become the first real baseline, never gate against zeros. Populated
/// baselines gate only when comparable (same scale, full-scale run, enough
/// cores that the sweep measures the lock and not the scheduler).
fn compare_with_baseline(
    baseline: Option<(u64, Vec<BenchJsonRow>)>,
    fresh: &[BenchJsonRow],
    scale: u64,
) {
    let Some((base_scale, base_rows)) = baseline else {
        println!("no committed read-path baseline — reporting only");
        return;
    };
    if base_rows.iter().all(|r| r.n == 0) {
        println!(
            "committed read-path baseline is the zeroed seed (all n=0): unpopulated — \
             reporting only; this run refreshed BENCH_read_path.json with measured figures"
        );
        return;
    }
    if base_scale != scale {
        println!(
            "read-path baseline was recorded at scale {base_scale}, this run is scale {scale} \
             — not comparable, reporting only"
        );
        return;
    }
    for f in fresh {
        if let Some(b) = base_rows.iter().find(|b| b.name == f.name) {
            if b.ops_per_sec > 0.0 {
                println!(
                    "vs baseline: {} {:+.1}% ({:.0} → {:.0} ops/s)",
                    f.name,
                    (f.ops_per_sec / b.ops_per_sec - 1.0) * 100.0,
                    b.ops_per_sec,
                    f.ops_per_sec
                );
            }
        }
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if scale != 1 || cores < 6 {
        return; // smoke runs and small hosts report, never gate, on baselines
    }
    let pair = |name: &str| {
        let b = base_rows.iter().find(|r| r.name == name)?;
        let f = fresh.iter().find(|r| r.name == name)?;
        (b.ops_per_sec > 0.0).then_some((b.ops_per_sec, f.ops_per_sec))
    };
    if let Some((base4, fresh4)) = pair("get_many-4r") {
        if fresh4 < base4 * 0.5 {
            eprintln!(
                "FAIL: 4-reader read throughput collapsed to {:.0} ops/s \
                 (<50% of the {:.0} ops/s baseline)",
                fresh4, base4
            );
            std::process::exit(1);
        }
    }
}

/// Idle-connection sweep (reactor core): does connection *count* cost
/// active throughput? 0/64/256/1024 open-but-idle sockets against one
/// active client pushing MUPDATE×64 round trips on a 2-reactor server.
/// Under epoll an idle connection is a registration plus one timer-wheel
/// entry — the gate requires the largest idle tier to retain ≥90% of the
/// 0-idle throughput (<10% cost). Emits `BENCH_connections.json`, uploaded
/// by CI with the other bench reports. Pre-reactor this scenario cannot
/// even run: idle connections each pinned a pool worker, so anything past
/// `workers` idle sockets starved the active client outright.
#[cfg(target_os = "linux")]
fn idle_conn_sweep(scale: u64) {
    use std::net::TcpStream;

    let records = (50_000 / scale).max(1_000);
    let iters: usize = if scale > 1 { 15 } else { 50 };
    let limit = membig::server::raise_nofile_limit(8192);
    let spec = DatasetSpec { records, ..Default::default() };
    let store = Arc::new(ShardedStore::new(8, (records as usize / 8).next_power_of_two()));
    for r in spec.iter() {
        store.insert(r);
    }
    let stride = records / GROUP as u64;
    let keys: Vec<u64> =
        (0..GROUP as u64).map(|i| spec.record_at(i * stride).isbn13).collect();
    let cfg = ServerConfig { reactors: 2, max_conns: 2048, ..Default::default() };
    let handle = Server::with_config(store, None, cfg).spawn("127.0.0.1:0").unwrap();
    let addr = handle.addr;
    let mut active = Client::connect(addr).unwrap();
    let mupdate_line = {
        let groups: Vec<String> =
            keys.iter().enumerate().map(|(i, k)| format!("{k} {} {i}", 500 + i)).collect();
        format!("MUPDATE {}", groups.join(";"))
    };

    println!(
        "\n=== idle-connection sweep: 2 reactors, fd soft limit {limit}, {} records, \
         {iters} MUPDATE(64) iters/tier ===\n",
        commas(records)
    );

    let mut idle: Vec<TcpStream> = Vec::new();
    let mut rows: Vec<BenchJsonRow> = Vec::new();
    let mut measured: Vec<(usize, f64)> = Vec::new();
    for &target in &[0usize, 64, 256, 1024] {
        let mut capped = false;
        while idle.len() < target {
            match TcpStream::connect(addr) {
                Ok(s) => idle.push(s),
                Err(e) => {
                    println!("  (connection budget reached at {} idle conns: {e})", idle.len());
                    capped = true;
                    break;
                }
            }
        }
        let n_idle = idle.len();
        // Let the reactors drain the accept burst before measuring.
        std::thread::sleep(Duration::from_millis(50));
        let _ = active.request("STATS RESET").unwrap();
        // Best of two runs per tier: the gate compares tiers measured at
        // different moments, so take the less noise-perturbed sample.
        let mut best: Option<BenchStat> = None;
        for _ in 0..2 {
            let stat = bench(&format!("mupdate-64 @ {n_idle:>4} idle conns"), 2, iters, || {
                let r = active.request(&mupdate_line).unwrap();
                assert!(r.starts_with("OK applied="), "{r}");
            });
            let better = match &best {
                None => true,
                Some(b) => stat.mean < b.mean,
            };
            if better {
                best = Some(stat);
            }
        }
        let stat = best.expect("two attempts ran");
        println!("{}", stat.render(Some(GROUP as u64)));
        rows.push(stat.json_row(GROUP as u64));
        measured.push((n_idle, stat.ops_per_sec(GROUP as u64)));
        if capped {
            break;
        }
    }
    // The decoupling evidence next to the numbers: conns_active ≈ idle
    // count while epoll wakeups track the *active* client's traffic.
    let stats = active.request("STATS SERVER").unwrap();
    println!("\n{stats}\n");
    let _ = active.request("QUIT");
    drop(idle);
    let json_path = write_bench_json("connections", &rows).unwrap();
    println!("wrote {}", json_path.display());
    handle.shutdown();

    let base = measured[0].1;
    let &(top_idle, top_ops) = measured.last().expect("tier 0 always measured");
    if top_idle < 256 || base <= 0.0 {
        println!(
            "WARNING: only reached {top_idle} idle conns — idle-cost gate reported, not enforced"
        );
        return;
    }
    let ratio = top_ops / base;
    println!(
        "active MUPDATE throughput at {top_idle} idle conns: {:.1}% of 0-idle (floor: 90%)",
        ratio * 100.0
    );
    if ratio < 0.9 {
        eprintln!("FAIL: {top_idle} idle connections cost more than 10% of active throughput");
        std::process::exit(1);
    }
}

#[cfg(not(target_os = "linux"))]
fn idle_conn_sweep(_scale: u64) {
    println!(
        "\nidle-connection sweep skipped: requires the Linux reactor front end \
         (the fallback blocking pool parks idle connections on workers)"
    );
}
