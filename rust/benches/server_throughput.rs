//! Request-path throughput: per-request round trips vs the pipelined batch
//! verbs. The serving claim (paper §4.3) only holds if the front end keeps
//! cores busy instead of paying one network round trip per key — this bench
//! measures the gap. Acceptance (ISSUE 2): an `MUPDATE` batch of 64 must
//! sustain ≥5× the ops/sec of 64 single `UPDATE` round-trips.
//!
//! Configurations (one live server, one client, loopback TCP):
//!   update-single   64 UPDATE round-trips
//!   update-mupdate  one MUPDATE line carrying 64 groups (shard-affine)
//!   update-batch    BATCH 64 framing around single UPDATE lines
//!   get-single      64 GET round-trips
//!   get-mget        one MGET line carrying 64 keys
//!
//! CSV: bench_out/server_throughput.csv.

use std::sync::Arc;

use membig::memstore::ShardedStore;
use membig::server::{Client, Server, ServerConfig};
use membig::util::bench::{bench, bench_out_dir, bench_scale, write_bench_json, BenchStat};
use membig::util::csv::CsvWriter;
use membig::util::fmt::commas;
use membig::workload::gen::DatasetSpec;

const GROUP: usize = 64;

fn main() {
    let scale = bench_scale();
    let records = (100_000 / scale).max(1_000);
    let iters: usize = if scale > 1 { 15 } else { 50 };

    let spec = DatasetSpec { records, ..Default::default() };
    let store = Arc::new(ShardedStore::new(8, (records as usize / 8).next_power_of_two()));
    for r in spec.iter() {
        store.insert(r);
    }
    let stride = records / GROUP as u64;
    let keys: Vec<u64> = (0..GROUP as u64).map(|i| spec.record_at(i * stride).isbn13).collect();

    let cfg = ServerConfig { workers: 4, max_conns: 16, ..Default::default() };
    let handle = Server::with_config(store, None, cfg).spawn("127.0.0.1:0").unwrap();
    let mut c = Client::connect(handle.addr).unwrap();

    println!(
        "=== server throughput: {} records, group size {GROUP}, {iters} iters ===\n",
        commas(records)
    );

    let update_single = bench("update-single (64 round-trips)", 3, iters, || {
        for (i, k) in keys.iter().enumerate() {
            let r = c.request(&format!("UPDATE {k} {} {i}", 100 + i)).unwrap();
            assert_eq!(r, "OK");
        }
    });

    let mupdate_line = {
        let groups: Vec<String> =
            keys.iter().enumerate().map(|(i, k)| format!("{k} {} {i}", 200 + i)).collect();
        format!("MUPDATE {}", groups.join(";"))
    };
    let update_mupdate = bench("update-mupdate (1 round-trip)", 3, iters, || {
        let r = c.request(&mupdate_line).unwrap();
        assert_eq!(r, format!("OK applied={GROUP} missed=0"));
    });

    let batch_lines: Vec<String> =
        keys.iter().enumerate().map(|(i, k)| format!("UPDATE {k} {} {i}", 300 + i)).collect();
    let update_batch = bench("update-batch (BATCH 64 framing)", 3, iters, || {
        let rs = c.batch(&batch_lines).unwrap();
        assert_eq!(rs.len(), GROUP);
    });

    let get_single = bench("get-single (64 round-trips)", 3, iters, || {
        for k in &keys {
            let r = c.request(&format!("GET {k}")).unwrap();
            assert!(r.starts_with("OK"), "{r}");
        }
    });

    let mget_line = format!(
        "MGET {}",
        keys.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(" ")
    );
    let get_mget = bench("get-mget (1 round-trip)", 3, iters, || {
        let r = c.request(&mget_line).unwrap();
        assert!(r.starts_with(&format!("OK {GROUP} ")), "{r}");
    });

    let _ = c.request("QUIT");

    let rows: Vec<(&BenchStat, f64)> = vec![
        (&update_single, 1.0),
        (&update_mupdate, update_single.mean.as_secs_f64() / update_mupdate.mean.as_secs_f64()),
        (&update_batch, update_single.mean.as_secs_f64() / update_batch.mean.as_secs_f64()),
        (&get_single, 1.0),
        (&get_mget, get_single.mean.as_secs_f64() / get_mget.mean.as_secs_f64()),
    ];

    let csv_path = bench_out_dir().join("server_throughput.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["config", "mean_s", "ops_per_sec", "speedup_vs_single"],
    )
    .unwrap();
    for (stat, speedup) in &rows {
        println!("{}  speedup {:>5.1}x", stat.render(Some(GROUP as u64)), speedup);
        csv.row(&[
            stat.name.clone(),
            format!("{:.6}", stat.mean.as_secs_f64()),
            format!("{:.0}", stat.ops_per_sec(GROUP as u64)),
            format!("{speedup:.3}"),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    println!("\nwrote {}", csv_path.display());

    // Machine-readable report for the CI perf trajectory.
    let json_rows: Vec<_> = rows.iter().map(|(stat, _)| stat.json_row(GROUP as u64)).collect();
    let json_path = write_bench_json("server_throughput", &json_rows).unwrap();
    println!("wrote {}", json_path.display());

    let headline = update_single.mean.as_secs_f64() / update_mupdate.mean.as_secs_f64();
    println!(
        "\nMUPDATE batches of {GROUP}: {headline:.1}x the ops/sec of {GROUP} single \
         UPDATE round-trips (acceptance floor: 5x)"
    );
    handle.shutdown();
    if headline < 5.0 {
        if scale == 1 {
            // Full-scale runs enforce the acceptance criterion; tiny-N
            // smoke runs (CI) only report, since loopback timing at small
            // iteration counts is too noisy to gate on.
            eprintln!("FAIL: below the 5x acceptance floor");
            std::process::exit(1);
        }
        println!("WARNING: below the 5x acceptance floor (not enforced at tiny N)");
    }
}
