//! **Table 1 + Figure 6** — the paper's headline experiment.
//!
//! Database of 2M book records; for each N ∈ {100k, 500k, 1M, 1.5M, 2M},
//! update N records with fresh prices/quantities from a Stock.dat feed:
//!   * conventional app — disk-resident per-record read-modify-write
//!     (HDD latency model, full-scale *modeled* time reported; wall time is
//!     the scaled-sleep run, default scale 0);
//!   * proposed app — load into sharded in-memory hash tables, then one
//!     worker thread per core applies the feed (measured wall-clock, it
//!     really runs).
//!
//! Outputs: paper-style table + ASCII Figure 6 on stdout; CSV series in
//! bench_out/table1.csv; paper-reference comparison with shape checks.
//!
//! `MEMBIG_BENCH_SCALE=k` divides all sizes by k (CI). Paper scale: k=1.

use std::sync::Arc;

use membig::config::EngineConfig;
use membig::coordinator::report::{render_figure6, render_table1, RunReport};
use membig::coordinator::Workbench;
use membig::memstore::snapshot::load_store;
use membig::metrics::EngineMetrics;
use membig::pipeline::executor::run_streaming_update;
use membig::storage::latency::{DiskProfile, DiskSim};
use membig::storage::table::{DiskTable, TableOptions};
use membig::util::bench::{bench_out_dir, bench_scale, time_once};
use membig::util::csv::CsvWriter;
use membig::util::fmt::{commas, human_duration, paper_hms};
use membig::workload::gen::DatasetSpec;

/// Paper's Table 1 (seconds) for reference columns.
const PAPER: &[(u64, u64, u64)] = &[
    // (N, conventional_s, proposed_s)
    (100_000, 6_602, 4),
    (500_000, 29_535, 6),
    (1_000_000, 64_052, 16),
    (1_500_000, 97_325, 32),
    (2_000_000, 123_471, 63),
];

fn main() {
    let scale = bench_scale();
    let records = 2_000_000 / scale;
    let sweep: Vec<u64> =
        [100_000u64, 500_000, 1_000_000, 1_500_000, 2_000_000].iter().map(|n| n / scale).collect();

    let mut cfg = EngineConfig::default();
    cfg.data_dir = bench_out_dir().join("data");
    cfg.disk.scale = 0.0; // modeled time only; no sleeping
    let cfg = cfg.validated().unwrap();

    println!(
        "=== Table 1 bench: {} records, sweep {:?}, {} threads ===",
        commas(records),
        sweep.iter().map(|n| commas(*n)).collect::<Vec<_>>(),
        cfg.threads
    );
    println!("disk model: {:?}\n", cfg.disk);

    let spec = DatasetSpec { records, seed: 0xB00C, ..Default::default() };
    let wb = Workbench::new(&cfg.data_dir, spec.clone());

    // Build the database + stock files once (outside measurement, like the
    // paper's §5 setup).
    let table = wb.ensure_table(&cfg).expect("table build");
    drop(table);

    let mut rows = Vec::new();
    for &n in &sweep {
        let stock = wb.ensure_stock(n).expect("stock build");

        // ---- proposed -------------------------------------------------
        let metrics = EngineMetrics::new();
        let load_sim = Arc::new(DiskSim::new(DiskProfile::none()));
        let table = DiskTable::open(
            wb.table_dir(),
            load_sim,
            TableOptions { cache_pages: cfg.page_cache_pages, engine_overhead: false },
        )
        .expect("open table");
        let (store, load_time) =
            time_once(|| load_store(&table, cfg.shards, &metrics).expect("load"));
        let (rep, update_time) = time_once(|| {
            run_streaming_update(&store, &stock, cfg.batch_size, cfg.channel_depth, &metrics)
                .expect("pipeline")
        });
        assert_eq!(rep.updates_applied, n, "proposed must apply all updates");
        let proposed = load_time + update_time;
        drop(table);

        // ---- conventional ---------------------------------------------
        // Fresh latency sim; real file I/O + modeled mechanical time.
        let sim = Arc::new(DiskSim::new(cfg.disk));
        let table = DiskTable::open(
            wb.table_dir(),
            sim.clone(),
            TableOptions { cache_pages: cfg.page_cache_pages, engine_overhead: true },
        )
        .expect("open table");
        let metrics2 = EngineMetrics::new();
        let conv = membig::baseline::run_conventional_stream(&table, &stock, &metrics2)
            .expect("conventional");
        assert_eq!(conv.updates_applied, n);

        let row = RunReport {
            n_updates: n,
            conventional: conv.modeled,
            conventional_wall: conv.wall,
            proposed,
        };
        println!(
            "N={:>9}  conventional: modeled {} (wall {})  proposed: {} (load {} + update {})  speedup {:.0}x",
            commas(n),
            paper_hms(row.conventional),
            human_duration(row.conventional_wall),
            human_duration(row.proposed),
            human_duration(load_time),
            human_duration(update_time),
            row.speedup()
        );
        rows.push(row);
    }

    println!("\n{}", render_table1(&rows));
    println!("{}", render_figure6(&rows));

    // CSV series (Figure 6 data).
    let csv_path = bench_out_dir().join("table1.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &[
            "n_updates",
            "conventional_modeled_s",
            "conventional_wall_s",
            "proposed_s",
            "speedup",
            "paper_conventional_s",
            "paper_proposed_s",
            "paper_speedup",
        ],
    )
    .unwrap();
    for (row, paper) in rows.iter().zip(PAPER) {
        csv.row(&[
            row.n_updates.to_string(),
            format!("{:.3}", row.conventional.as_secs_f64()),
            format!("{:.3}", row.conventional_wall.as_secs_f64()),
            format!("{:.3}", row.proposed.as_secs_f64()),
            format!("{:.1}", row.speedup()),
            paper.1.to_string(),
            paper.2.to_string(),
            format!("{:.1}", paper.1 as f64 / paper.2 as f64),
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    println!("wrote {}", csv_path.display());

    // ---- shape checks vs the paper ------------------------------------
    println!("\n=== shape checks (paper vs measured) ===");
    let mut ok = true;
    for (row, &(pn, pc, pp)) in rows.iter().zip(PAPER) {
        let paper_speedup = pc as f64 / pp as f64;
        let ours = row.speedup();
        // Same winner by a large factor at every N.
        let pass = ours > 100.0;
        println!(
            "N={:>9} (paper N={:>9}): paper speedup {:>6.0}x | measured {:>8.0}x | {}",
            commas(row.n_updates),
            commas(pn),
            paper_speedup,
            ours,
            if pass { "✓" } else { "✗" }
        );
        ok &= pass;
    }
    // Conventional time must grow ~linearly in N (the paper's key shape).
    let first = &rows[0];
    let last = rows.last().unwrap();
    let growth = last.conventional.as_secs_f64() / first.conventional.as_secs_f64();
    let n_growth = last.n_updates as f64 / first.n_updates as f64;
    println!(
        "conventional growth {:.1}x over {:.0}x more updates (paper: {:.1}x) {}",
        growth,
        n_growth,
        123_471.0 / 6_602.0,
        if growth > 0.5 * n_growth { "✓ ~linear" } else { "✗" }
    );
    assert!(ok, "speedup shape check failed");
    assert!(growth > 0.5 * n_growth, "conventional must scale ~linearly with N");

    // Paper's §5 reason 1 sanity: modeled per-record cost in the tens of ms.
    let per_rec = last.conventional.as_secs_f64() / last.n_updates as f64;
    println!(
        "conventional per-record cost: {:.1}ms (paper: {:.1}ms)",
        per_rec * 1e3,
        123_471_000.0 / 2_000_000.0
    );
}
