//! Analytics-path throughput (L1/L2 extension experiment in DESIGN.md):
//! the AOT-compiled PJRT analytics model vs an equivalent hand-written Rust
//! loop, per compiled batch size. Proves the three-layer path is fast
//! enough that analytics over the full store is interactive.
//!
//! CSV: bench_out/analytics.csv. Skips cleanly if `make artifacts` hasn't run.

#[cfg(feature = "pjrt")]
use membig::runtime::AnalyticsEngine;
#[cfg(feature = "pjrt")]
use membig::util::bench::{bench_out_dir, stat_from, write_bench_json, BenchJsonRow};
#[cfg(feature = "pjrt")]
use membig::util::csv::CsvWriter;
#[cfg(feature = "pjrt")]
use membig::util::fmt::commas;
#[cfg(feature = "pjrt")]
use membig::util::rng::Rng;

#[cfg(not(feature = "pjrt"))]
fn main() {
    println!("analytics bench skipped: rebuild with `--features pjrt` (PJRT-only bench)");
    // Still emit the machine-readable report (empty results) so CI's
    // BENCH_*.json artifact set is stable across feature configurations.
    let path = membig::util::bench::write_bench_json("analytics", &[]).unwrap();
    println!("wrote {}", path.display());
}

#[cfg(feature = "pjrt")]
fn rust_reference(price: &[f32], qty: &[f32], new_price: &[f32], new_qty: &[f32], mask: &[f32]) -> (f64, u64) {
    let mut value = 0f64;
    let mut count = 0u64;
    for i in 0..price.len() {
        let (p, q) = if mask[i] > 0.0 { (new_price[i], new_qty[i]) } else { (price[i], qty[i]) };
        if mask[i] >= 0.0 {
            value += p as f64 * q as f64;
            count += 1;
        }
    }
    (value, count)
}

#[cfg(feature = "pjrt")]
fn main() {
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("analytics bench skipped: run `make artifacts` first");
        let _ = write_bench_json("analytics", &[]);
        return;
    }
    let engine = match AnalyticsEngine::load(&artifacts) {
        Ok(e) => e,
        Err(e) => {
            println!("analytics bench skipped: PJRT unavailable ({e})");
            let _ = write_bench_json("analytics", &[]);
            return;
        }
    };
    println!("=== analytics path: PJRT ({}) vs pure-Rust loop ===\n", engine.platform());

    let mut json_rows: Vec<BenchJsonRow> = Vec::new();
    let csv_path = bench_out_dir().join("analytics.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["batch", "pjrt_mean_us", "pjrt_rows_per_sec", "rust_mean_us", "rust_rows_per_sec"],
    )
    .unwrap();

    for &batch in &[4096usize, 16384, 65536] {
        let mut rng = Rng::new(batch as u64);
        let gen = |rng: &mut Rng, hi: f64, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.range_f64(0.0, hi) as f32).collect()
        };
        let price = gen(&mut rng, 10.0, batch);
        let qty = gen(&mut rng, 500.0, batch);
        let new_price = gen(&mut rng, 10.0, batch);
        let new_qty = gen(&mut rng, 500.0, batch);
        let mask: Vec<f32> =
            (0..batch).map(|_| if rng.chance(0.5) { 1.0f32 } else { 0.0 }).collect();

        // PJRT path (full call: pad + copy + execute + unpack).
        let mut samples = Vec::new();
        let mut pjrt_value = 0.0;
        for _ in 0..20 {
            let t0 = std::time::Instant::now();
            let r = engine.analytics(&price, &qty, &new_price, &new_qty, &mask).unwrap();
            samples.push(t0.elapsed());
            pjrt_value = r.stats.total_value;
        }
        let pjrt = stat_from(&format!("pjrt analytics n={batch}"), samples);
        println!("{}", pjrt.render(Some(batch as u64)));

        // Pure-Rust loop.
        let mut samples = Vec::new();
        let mut rust_value = 0.0;
        for _ in 0..20 {
            let t0 = std::time::Instant::now();
            let (v, _) = std::hint::black_box(rust_reference(&price, &qty, &new_price, &new_qty, &mask));
            samples.push(t0.elapsed());
            rust_value = v;
        }
        let rust = stat_from(&format!("rust loop     n={batch}"), samples);
        println!("{}", rust.render(Some(batch as u64)));

        let rel = (pjrt_value - rust_value).abs() / rust_value;
        assert!(rel < 1e-4, "paths disagree: pjrt={pjrt_value} rust={rust_value}");
        println!("  values agree (rel err {rel:.2e}); pjrt does {}x the work (updates+stats+histogram)\n",
            3);

        csv.row(&[
            batch.to_string(),
            format!("{:.1}", pjrt.mean.as_secs_f64() * 1e6),
            format!("{:.0}", pjrt.ops_per_sec(batch as u64)),
            format!("{:.1}", rust.mean.as_secs_f64() * 1e6),
            format!("{:.0}", rust.ops_per_sec(batch as u64)),
        ])
        .unwrap();
        json_rows.push(pjrt.json_row(batch as u64));
        json_rows.push(rust.json_row(batch as u64));
    }
    csv.flush().unwrap();
    println!("wrote {}", csv_path.display());
    let json_path = write_bench_json("analytics", &json_rows).unwrap();
    println!("wrote {}", json_path.display());
    let _ = commas(0);
}
