//! Unstructured-data extension bench (paper §7): the memory-based
//! multi-processing method applied to text search.
//!
//!   * build: inverted-index construction, 1 thread vs N threads
//!     (map/reduce-shaped local-index merge);
//!   * query: in-memory index search vs disk-scan baseline under the HDD
//!     latency model — the Table-1 shape on a text workload.
//!
//! CSV: bench_out/textsearch.csv.

use std::sync::Arc;

use membig::storage::latency::{DiskProfile, DiskSim};
use membig::textstore::corpus::write_corpus;
use membig::textstore::scan::scan_search;
use membig::textstore::{CorpusSpec, InvertedIndex};
use membig::util::bench::{bench_out_dir, bench_scale, stat_from, time_once};
use membig::util::csv::CsvWriter;
use membig::util::fmt::{bytes, commas, human_duration, rate};

fn main() {
    let scale = bench_scale();
    let docs = (50_000 / scale).max(2_000);
    let threads = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1).max(2);
    let spec = CorpusSpec { docs, ..Default::default() };
    println!("=== textsearch: {} docs, vocab {} ===\n", commas(docs), commas(spec.vocab));

    let corpus = membig::textstore::generate_corpus(&spec);
    let total_bytes: usize = corpus.iter().map(|d| d.text.len()).sum();
    println!("corpus: {}", bytes(total_bytes as u64));

    let csv_path = bench_out_dir().join("textsearch.csv");
    let mut csv = CsvWriter::create(&csv_path, &["metric", "variant", "value"]).unwrap();

    // ---- build scaling -----------------------------------------------------
    let (idx1, t1) = time_once(|| InvertedIndex::build(&corpus));
    println!("index build 1t:  {}  ({})", human_duration(t1), rate(docs, t1));
    let (idxn, tn) = time_once(|| InvertedIndex::build_parallel(&corpus, threads));
    println!("index build {threads}t:  {}  ({})", human_duration(tn), rate(docs, tn));
    assert_eq!(idx1.term_count(), idxn.term_count());
    println!(
        "index: {} terms, {} resident\n",
        commas(idx1.term_count() as u64),
        bytes(idxn.memory_bytes() as u64)
    );
    csv.row(&["build_s", "1_thread", &format!("{:.4}", t1.as_secs_f64())]).unwrap();
    csv.row(&["build_s", &format!("{threads}_threads"), &format!("{:.4}", tn.as_secs_f64())])
        .unwrap();

    // ---- query: memory vs disk ----------------------------------------------
    let dir = bench_out_dir().join("data");
    std::fs::create_dir_all(&dir).unwrap();
    let corpus_path = dir.join("corpus.tsv");
    write_corpus(&corpus_path, &spec).unwrap();

    let queries = ["t0", "t3 t7", "t1 t4 t9", "t12 t55", "t2"];
    // In-memory index.
    let mut samples = Vec::new();
    for _ in 0..10 {
        let t0 = std::time::Instant::now();
        for q in &queries {
            std::hint::black_box(idxn.search(q, 10));
        }
        samples.push(t0.elapsed() / queries.len() as u32);
    }
    let mem_stat = stat_from("index query", samples);
    println!("in-memory query:   mean {}", human_duration(mem_stat.mean));

    // Disk scan (modeled HDD + real file I/O).
    let sim = Arc::new(DiskSim::new(DiskProfile::default()));
    let mut scan_results = Vec::new();
    let t0 = std::time::Instant::now();
    for q in &queries {
        scan_results.push(scan_search(&corpus_path, q, 10, &sim).unwrap());
    }
    let scan_wall = t0.elapsed() / queries.len() as u32;
    let scan_modeled = sim.modeled() / queries.len() as u32;
    println!(
        "disk-scan query:   wall {} | modeled (HDD) {}",
        human_duration(scan_wall),
        human_duration(scan_modeled)
    );

    // Results must agree between paths.
    for (q, scan_hits) in queries.iter().zip(&scan_results) {
        assert_eq!(&idxn.search(q, 10), scan_hits, "query {q:?}");
    }

    let speedup = scan_modeled.as_secs_f64() / mem_stat.mean.as_secs_f64().max(1e-9);
    println!("\nmemory-based speedup on text: {speedup:.0}x (same winner/shape as Table 1)");
    csv.row(&["query_us", "memory", &format!("{:.1}", mem_stat.mean.as_secs_f64() * 1e6)])
        .unwrap();
    csv.row(&["query_us", "disk_modeled", &format!("{:.1}", scan_modeled.as_secs_f64() * 1e6)])
        .unwrap();
    csv.row(&["speedup", "memory_vs_disk", &format!("{speedup:.0}")]).unwrap();
    csv.flush().unwrap();
    println!("wrote {}", csv_path.display());
    assert!(speedup > 100.0, "memory must dominate the modeled disk scan");
}
