//! Ablations of the design choices DESIGN.md calls out:
//!
//!   A. The 2×2 grid of the paper's two ingredients (memory × threads):
//!      conventional | disk+threads | memory 1-thread | proposed.
//!   B. Threads vs processes (message passing, the paper's §7 future work):
//!      shared-memory pipeline vs Unix-socket RPC pool.
//!   C. Pipeline parameters: batch size and queue depth (backpressure).
//!   D. Key distribution: permute-all vs uniform vs zipf(0.99) skew.
//!
//! CSV: bench_out/ablations.csv.

use std::sync::Arc;

use membig::baseline::run_conventional;
use membig::baseline::variants::{run_disk_multithread, run_memory_singlethread};
use membig::ipc::ProcessPool;
use membig::memstore::snapshot::load_store;
use membig::memstore::ShardedStore;
use membig::metrics::EngineMetrics;
use membig::pipeline::executor::{run_streaming_update, run_update_in_memory};
use membig::storage::latency::{DiskProfile, DiskSim};
use membig::storage::table::{DiskTable, TableOptions};
use membig::util::bench::{bench_out_dir, bench_scale, time_once};
use membig::util::csv::CsvWriter;
use membig::util::fmt::{commas, human_duration, rate};
use membig::workload::gen::{generate_stock_updates, DatasetSpec, KeyDist};
use membig::workload::stockfile::write_stock_file;

fn store_for(spec: &DatasetSpec, shards: usize) -> Arc<ShardedStore> {
    let s = Arc::new(ShardedStore::new(
        shards,
        (spec.records as usize / shards + 1).next_power_of_two(),
    ));
    for r in spec.iter() {
        s.insert(r);
    }
    s
}

fn main() {
    let scale = bench_scale();
    let n = (500_000 / scale).max(20_000);
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let threads = cores.max(2); // topology is meaningful even on 1 core
    let spec = DatasetSpec { records: n, ..Default::default() };
    let ups = generate_stock_updates(&spec, n, KeyDist::PermuteAll, 7);
    let dir = bench_out_dir().join("data").join("ablations");
    std::fs::remove_dir_all(&dir).ok();

    let csv_path = bench_out_dir().join("ablations.csv");
    let mut csv =
        CsvWriter::create(&csv_path, &["ablation", "variant", "seconds", "notes"]).unwrap();

    // ---- A: 2x2 memory × threads grid --------------------------------------
    println!("=== A. memory × multiprocessing grid ({} updates) ===", commas(n));
    let build_sim = Arc::new(DiskSim::new(DiskProfile::none()));
    let table = DiskTable::create(
        dir.join("grid"),
        spec.iter(),
        n,
        build_sim,
        TableOptions { cache_pages: 256, engine_overhead: true },
    )
    .unwrap();
    drop(table);

    // conventional (disk, 1 thread) — modeled.
    let sim = Arc::new(DiskSim::new(DiskProfile::default()));
    let table = DiskTable::open(dir.join("grid"), sim.clone(), TableOptions::default()).unwrap();
    let m = EngineMetrics::new();
    let conv = run_conventional(&table, &ups, &m).unwrap();
    println!("  disk 1t (conventional): modeled {}", human_duration(conv.modeled));
    csv.row(&["grid", "disk_1t", &format!("{:.3}", conv.modeled.as_secs_f64()), "modeled"])
        .unwrap();

    // disk + threads — modeled (single spindle: threads don't help).
    let sim = Arc::new(DiskSim::new(DiskProfile::default()));
    let table =
        Arc::new(DiskTable::open(dir.join("grid"), sim.clone(), TableOptions::default()).unwrap());
    sim.reset();
    let (_, _, modeled) = run_disk_multithread(&table, &ups, threads, &m).unwrap();
    println!("  disk {threads}t:                modeled {}", human_duration(modeled));
    csv.row(&["grid", "disk_nt", &format!("{:.3}", modeled.as_secs_f64()), "modeled"]).unwrap();
    drop(table);

    // memory 1 thread — measured.
    let s1 = store_for(&spec, 1);
    let (_, mem1) = run_memory_singlethread(&s1, &ups, &m);
    println!("  memory 1t:              {}", human_duration(mem1));
    csv.row(&["grid", "mem_1t", &format!("{:.6}", mem1.as_secs_f64()), "measured"]).unwrap();

    // memory + threads (proposed) — measured.
    let sn = store_for(&spec, threads);
    let (rep, memn) = time_once(|| run_update_in_memory(&sn, &ups, &m));
    assert_eq!(rep.updates_applied, n);
    println!("  memory {threads}t (proposed):   {}  ({})\n", human_duration(memn), rate(n, memn));
    csv.row(&["grid", "mem_nt", &format!("{:.6}", memn.as_secs_f64()), "measured"]).unwrap();

    // ---- B: threads vs processes (message passing) -------------------------
    // NOTE: must point at the real `membig` binary — current_exe() inside a
    // bench is the bench itself and would re-enter this main() (fork bomb).
    println!("=== B. shared memory vs message passing ({} updates) ===", commas(n));
    let membig_bin = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target/release/membig");
    if membig_bin.exists() {
        let records: Vec<_> = spec.iter().collect();
        let mut pool =
            ProcessPool::spawn_with_exe(threads, membig_bin).expect("worker processes");
        let (_, load_t) = time_once(|| pool.load(&records).unwrap());
        let ((applied, _), ipc_t) = time_once(|| pool.update(&ups).unwrap());
        assert_eq!(applied, n);
        pool.shutdown().unwrap();
        println!("  processes (RPC/socket): load {} + update {}  ({})", human_duration(load_t),
            human_duration(ipc_t), rate(n, ipc_t));
        println!("  threads  (shared mem):  update {}  ({})", human_duration(memn), rate(n, memn));
        let tax = ipc_t.as_secs_f64() / memn.as_secs_f64();
        println!("  message-passing tax: {tax:.1}x (serialization + socket hops)\n");
        csv.row(&["ipc", "processes", &format!("{:.6}", ipc_t.as_secs_f64()), "measured"]).unwrap();
        csv.row(&["ipc", "threads", &format!("{:.6}", memn.as_secs_f64()), "measured"]).unwrap();
    } else {
        println!("  skipped: build the membig binary first (cargo build --release)\n");
    }

    // ---- C: batch size × queue depth ---------------------------------------
    println!("=== C. pipeline parameters (streaming path) ===");
    let stock = dir.join("abl_stock.dat");
    write_stock_file(&stock, &ups).unwrap();
    for (batch, depth) in
        [(64usize, 2usize), (1024, 2), (8192, 2), (8192, 64), (65536, 64), (1024, 64)]
    {
        let build_sim = Arc::new(DiskSim::new(DiskProfile::none()));
        let table = DiskTable::create(
            dir.join(format!("c_{batch}_{depth}")),
            spec.iter(),
            n,
            build_sim,
            TableOptions::default(),
        )
        .unwrap();
        let m = EngineMetrics::new();
        let store = load_store(&table, threads, &m).unwrap();
        let (rep, t) = time_once(|| {
            run_streaming_update(&store, &stock, batch, depth, &m).unwrap()
        });
        assert_eq!(rep.updates_applied, n);
        println!("  batch {batch:>6} depth {depth:>3}: {}  ({})", human_duration(t), rate(n, t));
        csv.row(&[
            "pipeline",
            &format!("b{batch}_d{depth}"),
            &format!("{:.6}", t.as_secs_f64()),
            "measured",
        ])
        .unwrap();
    }

    // ---- D: key distribution ------------------------------------------------
    println!("\n=== D. key distribution (skew) ===");
    for (dist, name) in [
        (KeyDist::PermuteAll, "permute_all"),
        (KeyDist::Uniform, "uniform"),
        (KeyDist::Zipf(0.99), "zipf_0.99"),
    ] {
        let dups = generate_stock_updates(&spec, n, dist, 13);
        let store = store_for(&spec, threads);
        let m = EngineMetrics::new();
        let (rep, t) = time_once(|| run_update_in_memory(&store, &dups, &m));
        assert_eq!(rep.updates_applied, n);
        println!("  {name:<12}: {}  ({})", human_duration(t), rate(n, t));
        csv.row(&["keydist", name, &format!("{:.6}", t.as_secs_f64()), "measured"]).unwrap();
    }

    csv.flush().unwrap();
    println!("\nwrote {}", csv_path.display());
}
