//! `cargo xtask lint` — repo-specific static checks over `rust/src`
//! (DESIGN.md §13). Zero dependencies: a line-oriented scanner, not a full
//! parser, tuned to this tree's idiom.
//!
//! Rules:
//!
//! - **safety-comment** — every `unsafe` keyword in code must be preceded
//!   by a `// SAFETY:` line comment (scanning upward through comments,
//!   attributes, blank lines, sibling `unsafe impl` lines and mid-statement
//!   continuation lines).
//! - **unsafe-module** — `unsafe` code may appear only in the whitelisted
//!   modules: `memstore/hashtable.rs`, `memstore/shard.rs`,
//!   `server/sys.rs`.
//! - **hot-path-panic** — no `.unwrap()` / `.expect(` / `panic!` /
//!   `unreachable!` / `todo!` / `unimplemented!` in the server hot-path
//!   modules (`server/mod.rs`, `server/reactor.rs`, `ipc/proto.rs`, the
//!   larger-than-RAM tier, and the `replication/` tree) outside
//!   `#[cfg(test)]` regions.
//!
//! Escape hatch: a `// lint:allow(<rule>): <why>` comment on the same line
//! or in the comment block directly above the flagged line suppresses that
//! rule there. String literals and comments are stripped before matching,
//! so prose mentioning `unsafe` or `panic!` never trips a rule.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules allowed to contain `unsafe` code (paths relative to `src/`).
const UNSAFE_WHITELIST: &[&str] =
    &["memstore/hashtable.rs", "memstore/shard.rs", "server/sys.rs"];

/// Modules where panicking calls are forbidden outside tests. The
/// replication tree counts as hot path: the shipper runs inside the commit
/// sink and the standby applier is the only thing keeping a replica alive —
/// a panic in either silently forfeits durability guarantees.
const HOT_PATH: &[&str] = &[
    "server/mod.rs",
    "server/reactor.rs",
    "ipc/proto.rs",
    "storage/tiered.rs",
    "replication/mod.rs",
    "replication/ship.rs",
    "replication/apply.rs",
    "replication/heartbeat.rs",
    // The fault shim sits inside every persistent write path, and the
    // health block is read by the same paths to report degradation — a
    // panic in either turns an injected (or real) disk error into a crash.
    "util/iofault.rs",
    "metrics/health.rs",
];

/// Panicking constructs forbidden in hot-path modules. `.expect(` keeps its
/// paren so a field named `expect` does not match; `.unwrap()` keeps both so
/// `unwrap_or_else(` does not match.
const PANIC_PATTERNS: &[&str] =
    &[".unwrap()", ".expect(", "panic!", "unreachable!", "todo!", "unimplemented!"];

/// How many lines the upward `// SAFETY:` scan will cross.
const SAFETY_SCAN_LINES: usize = 20;

#[derive(Debug, PartialEq, Eq)]
struct Violation {
    file: String,
    line: usize, // 1-based
    rule: &'static str,
    message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

// ---------------------------------------------------------------------------
// Sanitizer: strip comments, strings and char literals, preserving line
// structure, so rule matching only ever sees code.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq)]
enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

/// Replace every comment, string and char literal with spaces. Newlines are
/// preserved, so line numbers in the output match the input exactly.
fn sanitize(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut st = State::Code;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            // Newlines survive every state; line comments end here.
            if st == State::LineComment {
                st = State::Code;
            }
            out.push('\n');
            i += 1;
            continue;
        }
        match st {
            State::Code => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = State::LineComment;
                    out.push_str("  ");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = State::BlockComment(1);
                    out.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    st = State::Str;
                    out.push(' ');
                    i += 1;
                } else if let Some((hashes, skip)) = raw_str_open(&b, i) {
                    st = State::RawStr(hashes);
                    for _ in 0..skip {
                        out.push(' ');
                    }
                    i += skip;
                } else if c == '\'' {
                    // Char literal vs lifetime: a literal closes with a
                    // quote after one (possibly escaped) character.
                    if let Some(len) = char_literal_len(&b, i) {
                        for _ in 0..len {
                            out.push(' ');
                        }
                        i += len;
                    } else {
                        out.push(c); // lifetime / label: plain code
                        i += 1;
                    }
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                out.push(' ');
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = State::BlockComment(depth + 1);
                    out.push_str("  ");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if depth == 1 { State::Code } else { State::BlockComment(depth - 1) };
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(' ');
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' && i + 1 < b.len() {
                    // Escapes, including the line-continuation `\<newline>`:
                    // keep the newline so line numbers stay aligned.
                    out.push(' ');
                    out.push(if b[i + 1] == '\n' { '\n' } else { ' ' });
                    i += 2;
                } else {
                    if c == '"' {
                        st = State::Code;
                    }
                    out.push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&b, i, hashes) {
                    for _ in 0..=hashes {
                        out.push(' ');
                    }
                    i += 1 + hashes as usize;
                    st = State::Code;
                } else {
                    out.push(' ');
                    i += 1;
                }
            }
        }
    }
    out
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_' || b[i - 1] == '"')
}

/// If `b[i]` opens a raw/byte string (`r"`, `r#"`, `b"`, `br#"`, ...) and
/// is not the tail of an identifier, return (hash count, chars to skip
/// through the opening quote).
fn raw_str_open(b: &[char], i: usize) -> Option<(u32, usize)> {
    if (b[i] != 'r' && b[i] != 'b') || prev_is_ident(b, i) {
        return None;
    }
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) == Some(&'"') {
        // b"..." — plain byte string, no hashes.
        return if j > i { Some((0, j - i + 1)) } else { None };
    }
    if b.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&'"') {
        Some((hashes, j - i + 1))
    } else {
        None
    }
}

fn closes_raw(b: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| b.get(i + k) == Some(&'#'))
}

/// Length of a char/byte literal starting at the `'` at `b[i]`, or None if
/// this is a lifetime.
fn char_literal_len(b: &[char], i: usize) -> Option<usize> {
    match b.get(i + 1) {
        Some('\\') => {
            // Escaped: scan to the closing quote (handles \u{...}).
            let mut j = i + 2;
            while j < b.len() && b[j] != '\'' && b[j] != '\n' {
                j += 1;
            }
            (b.get(j) == Some(&'\'')).then_some(j - i + 1)
        }
        Some(_) if b.get(i + 2) == Some(&'\'') => Some(3),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Rule engine
// ---------------------------------------------------------------------------

/// Does `line` contain `word` bounded by non-identifier characters?
fn has_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut from = 0;
    while let Some(pos) = line[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let ok_before = start == 0 || !is_ident_byte(bytes[start - 1]);
        let ok_after = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if ok_before && ok_after {
            return true;
        }
        from = end;
    }
    false
}

fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Is line `idx` (0-based) excused from `rule` by a `lint:allow` marker on
/// the same line or in the contiguous comment block directly above?
fn allowed(raw: &[&str], idx: usize, rule: &str) -> bool {
    let marker = format!("lint:allow({rule})");
    if raw[idx].contains(&marker) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = raw[j].trim_start();
        if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#![") || t.is_empty() {
            if t.contains(&marker) {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

/// Mark each line of `sanitized` that lies inside a `#[cfg(test)]`-gated
/// braced item (the repo's test modules). Brace depth is tracked on the
/// sanitized text, so braces in strings/comments don't confuse it.
fn test_region_mask(sanitized: &[&str]) -> Vec<bool> {
    let mut mask = vec![false; sanitized.len()];
    let mut depth: i64 = 0;
    let mut pending_cfg_test = false;
    let mut region_floor: Option<i64> = None;
    for (i, line) in sanitized.iter().enumerate() {
        let trimmed = line.trim();
        if region_floor.is_some() {
            mask[i] = true;
        }
        if trimmed.contains("#[cfg(test)]") {
            pending_cfg_test = true;
        } else if pending_cfg_test && !trimmed.is_empty() && !trimmed.starts_with("#[") {
            // The gated item follows its attributes directly.
            if trimmed.contains('{') {
                if region_floor.is_none() {
                    region_floor = Some(depth);
                    mask[i] = true;
                }
                pending_cfg_test = false;
            } else if trimmed.ends_with(';') {
                pending_cfg_test = false; // gated single-line item (use, fn decl)
            }
        }
        for c in line.chars() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if let Some(floor) = region_floor {
                        if depth <= floor {
                            region_floor = None;
                        }
                    }
                }
                _ => {}
            }
        }
    }
    mask
}

/// Upward scan for a `// SAFETY:` comment above line `idx` (0-based).
fn has_safety_comment(raw: &[&str], sanitized: &[&str], idx: usize) -> bool {
    let mut j = idx;
    for _ in 0..SAFETY_SCAN_LINES {
        if j == 0 {
            return false;
        }
        j -= 1;
        let rt = raw[j].trim_start();
        if rt.starts_with("//") {
            if rt.contains("SAFETY:") {
                return true;
            }
            continue; // other comment line: keep scanning
        }
        let st = sanitized[j].trim();
        if st.is_empty() || st.starts_with("#[") || st.starts_with("#![") {
            continue;
        }
        if st.starts_with("unsafe impl") {
            continue; // sibling impls may share one SAFETY comment
        }
        // Mid-statement continuation (`let x: T =` etc.): keep scanning.
        // A completed statement or block edge ends the search.
        if st.ends_with(';') || st.ends_with('{') || st.ends_with('}') {
            return false;
        }
    }
    false
}

fn lint_source(rel_path: &str, src: &str) -> Vec<Violation> {
    let sanitized_text = sanitize(src);
    let raw: Vec<&str> = src.lines().collect();
    let sanitized: Vec<&str> = sanitized_text.lines().collect();
    debug_assert_eq!(raw.len(), sanitized.len());
    let tests = test_region_mask(&sanitized);
    let whitelisted = UNSAFE_WHITELIST.iter().any(|w| rel_path == *w);
    let hot = HOT_PATH.iter().any(|h| rel_path == *h);
    let mut out = Vec::new();

    for (i, line) in sanitized.iter().enumerate() {
        if has_word(line, "unsafe") {
            if !whitelisted && !allowed(&raw, i, "unsafe-module") {
                out.push(Violation {
                    file: rel_path.to_string(),
                    line: i + 1,
                    rule: "unsafe-module",
                    message: format!(
                        "`unsafe` outside the whitelisted modules ({})",
                        UNSAFE_WHITELIST.join(", ")
                    ),
                });
            }
            if !has_safety_comment(&raw, &sanitized, i)
                && !allowed(&raw, i, "safety-comment")
            {
                out.push(Violation {
                    file: rel_path.to_string(),
                    line: i + 1,
                    rule: "safety-comment",
                    message: "`unsafe` without a preceding `// SAFETY:` comment".to_string(),
                });
            }
        }
        if hot && !tests[i] {
            for pat in PANIC_PATTERNS {
                if line.contains(pat) && !allowed(&raw, i, "hot-path-panic") {
                    out.push(Violation {
                        file: rel_path.to_string(),
                        line: i + 1,
                        rule: "hot-path-panic",
                        message: format!("`{pat}` in a server hot-path module outside tests"),
                    });
                    break;
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Tree walk + CLI
// ---------------------------------------------------------------------------

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn lint_tree(src_root: &Path) -> std::io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files)?;
    if files.is_empty() {
        return Err(std::io::Error::other(format!(
            "no .rs files under {} — wrong root?",
            src_root.display()
        )));
    }
    let mut violations = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)?;
        violations.extend(lint_source(&rel, &src));
    }
    Ok(violations)
}

/// The membig source tree, located relative to this crate so the lint works
/// from any working directory.
fn default_src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("src")
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            let root = args.get(1).map(PathBuf::from).unwrap_or_else(default_src_root);
            let violations = match lint_tree(&root) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("xtask lint: {e}");
                    return ExitCode::from(2);
                }
            };
            if violations.is_empty() {
                println!("xtask lint: clean");
                ExitCode::SUCCESS
            } else {
                for v in &violations {
                    eprintln!("{v}");
                }
                eprintln!("xtask lint: {} violation(s)", violations.len());
                ExitCode::FAILURE
            }
        }
        _ => {
            eprintln!("usage: cargo xtask lint [src-root]");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(rel: &str, src: &str) -> Vec<&'static str> {
        lint_source(rel, src).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn sanitize_strips_strings_comments_chars() {
        let src = r##"let a = "unsafe { }"; // unsafe comment .unwrap()
let b = 'x'; let c: &'static str = r#"panic!"#;
/* block unsafe
   still comment */ let d = 1;"##;
        let s = sanitize(src);
        assert!(!s.contains("unsafe"), "sanitized: {s}");
        assert!(!s.contains("panic"), "sanitized: {s}");
        assert!(s.contains("let b ="), "code survives: {s}");
        assert!(s.contains("&'static str"), "lifetimes survive: {s}");
        assert!(s.contains("let d = 1;"), "code after block comment survives: {s}");
        assert_eq!(s.lines().count(), src.lines().count(), "line structure preserved");
    }

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(lint("memstore/hashtable.rs", src), vec!["safety-comment"]);
    }

    #[test]
    fn safety_comment_accepted_through_continuations_and_attrs() {
        let src = "\
// SAFETY: p is valid for the whole call.
#[inline]
fn f(p: *const u8) -> u8 {
    unsafe { *p }
}
";
        // The unsafe line's predecessor is `fn f(...) {` — a block edge —
        // so the comment above the attribute must NOT satisfy it...
        assert_eq!(lint("memstore/shard.rs", src), vec!["safety-comment"]);
        let good = "\
fn g(p: *const u8) -> u8 {
    // SAFETY: p is valid for the whole call.
    let v: u8 =
        unsafe { *p };
    v
}
// SAFETY: no shared state.
unsafe impl Send for X {}
unsafe impl Sync for X {}
";
        assert_eq!(lint("memstore/shard.rs", good), Vec::<&str>::new());
    }

    #[test]
    fn unsafe_outside_whitelist_is_flagged() {
        let src = "// SAFETY: fine.\nlet x = unsafe { danger() };\n";
        assert_eq!(lint("pipeline/channel.rs", src), vec!["unsafe-module"]);
        assert_eq!(lint("server/sys.rs", src), Vec::<&str>::new());
    }

    #[test]
    fn unsafe_in_identifiers_and_prose_not_flagged() {
        let src = "#![deny(unsafe_code)]\n#![deny(unsafe_op_in_unsafe_fn)]\n// unsafe is bad\nlet s = \"unsafe\";\n";
        assert_eq!(lint("server/mod.rs", src), Vec::<&str>::new());
    }

    #[test]
    fn hot_path_panics_flagged_outside_tests_only() {
        let src = "\
fn serve() {
    let v = q.lock().unwrap();
    let w = conn.batch.as_mut().expect(\"live\");
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        x.unwrap();
        panic!(\"fine in tests\");
    }
}
";
        assert_eq!(lint("server/reactor.rs", src), vec!["hot-path-panic", "hot-path-panic"]);
        // Same content in a non-hot-path file: clean.
        assert_eq!(lint("memstore/mod.rs", src), Vec::<&str>::new());
    }

    #[test]
    fn lint_allow_escapes_a_rule() {
        let src = "\
fn serve() {
    // lint:allow(hot-path-panic): poisoning means a thread panicked;
    // propagating is correct.
    let v = q.lock().unwrap();
}
";
        assert_eq!(lint("server/reactor.rs", src), Vec::<&str>::new());
        let wrong_rule = "\
fn serve() {
    // lint:allow(safety-comment): wrong rule name.
    let v = q.lock().unwrap();
}
";
        assert_eq!(lint("server/reactor.rs", wrong_rule), vec!["hot-path-panic"]);
    }

    #[test]
    fn expect_field_access_is_not_a_panic() {
        let src = "fn f(st: &St) -> usize { st.expect }\n";
        assert_eq!(lint("server/reactor.rs", src), Vec::<&str>::new());
    }

    #[test]
    fn seeded_violations_reproduce_acceptance_criteria() {
        // The two seeds named in the acceptance criteria: an unsafe block
        // without SAFETY, and an unwrap() in server/reactor.rs.
        let unsafe_seed = "fn f() { unsafe { core::hint::unreachable_unchecked() } }\n";
        assert!(lint_source("memstore/hashtable.rs", unsafe_seed)
            .iter()
            .any(|v| v.rule == "safety-comment"));
        let unwrap_seed = "fn f() { q.lock().unwrap(); }\n";
        assert!(lint_source("server/reactor.rs", unwrap_seed)
            .iter()
            .any(|v| v.rule == "hot-path-panic"));
    }

    #[test]
    fn real_tree_is_clean() {
        // The shipped source must lint clean — this is the same invariant
        // CI enforces via `cargo xtask lint`, checked here so plain
        // `cargo test -p xtask` catches regressions too.
        let violations = lint_tree(&default_src_root()).expect("lint real tree");
        assert!(
            violations.is_empty(),
            "violations in shipped tree:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
