//! Storage-health metrics: the degraded/ok verdict behind the `HEALTH`
//! verb plus per-surface I/O error counters, rendered into
//! `STATS SERVER` as `health_*` keys (DESIGN.md §16).
//!
//! One [`HealthMetrics`] instance is owned by whichever persistent
//! backend a server runs (`durability::Persistence` or the tiered
//! store) and written from its I/O error paths:
//!
//! - **Flags** (gauges, `0`/`1`) are *state*, not traffic — they mark a
//!   surface as currently degraded and survive `STATS RESET`:
//!   `wal_failstop` (WAL poisoned, fail-stop until restart),
//!   `snapshot_backoff` (checkpointer in capped-exponential retry),
//!   `tier_spill_stopped` (spills paused after ENOSPC; resident +
//!   existing runs still serve). Any set flag makes
//!   `health_degraded=1` and a non-`ok` `HEALTH` answer.
//! - **Error counters** are traffic and reset with the epoch: one bump
//!   per failed I/O operation, bucketed by surface (`wal`, `snapshot`,
//!   `tier`, `repl`).
//! - `health_io_faults_injected` mirrors the `faultcheck` shim's
//!   injection count (`util::iofault::injected`) so a fault drill can
//!   assert its plan actually fired; always 0 in default builds.

use crate::util::json::Json;

use super::{Counter, Gauge};

/// Health bundle for one server's persistent backend. See the module
/// docs for flag vs counter semantics.
#[derive(Default)]
pub struct HealthMetrics {
    /// Failed WAL appends/syncs (each one either rolled back or poisoned).
    pub wal_errors: Counter,
    /// Failed checkpoint/snapshot writes (state stays recoverable).
    pub snapshot_errors: Counter,
    /// Failed tier run writes/reads (spill aborted or run quarantined).
    pub tier_errors: Counter,
    /// Failed replication disk I/O (catch-up reads, snapshot send,
    /// standby marker) — the link severs and reconnects.
    pub repl_errors: Counter,
    /// `1` while the WAL is poisoned: fsyncgate fail-stop, every
    /// mutation is refused until restart.
    pub wal_failstop: Gauge,
    /// `1` while the snapshotter is holding back after a failed
    /// checkpoint (capped exponential retry); clears on first success.
    pub snapshot_backoff: Gauge,
    /// `1` while the tier refuses to spill after ENOSPC; reads and
    /// mutations keep working, clears on the next successful spill.
    pub tier_spill_stopped: Gauge,
}

impl HealthMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// `true` when any degradation flag is set.
    pub fn degraded(&self) -> bool {
        self.wal_failstop.get() != 0
            || self.snapshot_backoff.get() != 0
            || self.tier_spill_stopped.get() != 0
    }

    /// Stable reason tokens for every set flag (the `HEALTH` verb body).
    pub fn reasons(&self) -> Vec<&'static str> {
        let mut r = Vec::new();
        if self.wal_failstop.get() != 0 {
            r.push("wal-failstop");
        }
        if self.snapshot_backoff.get() != 0 {
            r.push("snapshot-backoff");
        }
        if self.tier_spill_stopped.get() != 0 {
            r.push("tier-spill-stopped");
        }
        r
    }

    /// The one-line `HEALTH` answer: `ok`, or `degraded: <reasons>`.
    pub fn health_line(&self) -> String {
        let reasons = self.reasons();
        if reasons.is_empty() {
            "ok".to_string()
        } else {
            format!("degraded: {}", reasons.join(","))
        }
    }

    /// Joins a `STATS RESET` epoch: zero the error *counters*; the
    /// degradation flags are live state and must survive — a reset
    /// must never make a degraded server look healthy.
    pub fn reset_epoch_counters(&self) {
        self.wal_errors.reset();
        self.snapshot_errors.reset();
        self.tier_errors.reset();
        self.repl_errors.reset();
    }

    /// Suffix appended to `STATS SERVER` (leading space included, like
    /// `DurabilityMetrics::stats_suffix`).
    pub fn stats_suffix(&self) -> String {
        format!(
            " health_degraded={} health_wal_failstop={} health_snapshot_backoff={} \
             health_tier_spill_stopped={} health_wal_errors={} health_snapshot_errors={} \
             health_tier_errors={} health_repl_errors={} health_io_faults_injected={}",
            u64::from(self.degraded()),
            self.wal_failstop.get(),
            self.snapshot_backoff.get(),
            self.tier_spill_stopped.get(),
            self.wal_errors.get(),
            self.snapshot_errors.get(),
            self.tier_errors.get(),
            self.repl_errors.get(),
            crate::util::iofault::injected()
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("degraded", Json::num(u64::from(self.degraded()) as f64)),
            ("wal_failstop", Json::num(self.wal_failstop.get() as f64)),
            ("snapshot_backoff", Json::num(self.snapshot_backoff.get() as f64)),
            ("tier_spill_stopped", Json::num(self.tier_spill_stopped.get() as f64)),
            ("wal_errors", Json::num(self.wal_errors.get() as f64)),
            ("snapshot_errors", Json::num(self.snapshot_errors.get() as f64)),
            ("tier_errors", Json::num(self.tier_errors.get() as f64)),
            ("repl_errors", Json::num(self.repl_errors.get() as f64)),
            (
                "io_faults_injected",
                Json::num(crate::util::iofault::injected() as f64),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_metrics_render_and_reset() {
        let h = HealthMetrics::new();
        assert!(!h.degraded());
        assert_eq!(h.health_line(), "ok");
        h.wal_errors.add(2);
        h.tier_errors.inc();
        h.snapshot_backoff.set(1);
        h.tier_spill_stopped.set(1);
        assert!(h.degraded());
        assert_eq!(h.health_line(), "degraded: snapshot-backoff,tier-spill-stopped");
        let s = h.stats_suffix();
        for needle in [
            " health_degraded=1",
            " health_wal_failstop=0",
            " health_snapshot_backoff=1",
            " health_tier_spill_stopped=1",
            " health_wal_errors=2",
            " health_snapshot_errors=0",
            " health_tier_errors=1",
            " health_repl_errors=0",
            " health_io_faults_injected=",
        ] {
            assert!(s.contains(needle), "missing {needle:?} in {s:?}");
        }
        let j = h.to_json();
        assert_eq!(j.get("degraded").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("wal_errors").unwrap().as_f64().unwrap(), 2.0);
        // Epoch reset zeroes the error counters; the flags are state.
        h.reset_epoch_counters();
        assert_eq!(h.wal_errors.get(), 0);
        assert_eq!(h.tier_errors.get(), 0);
        assert_eq!(h.snapshot_backoff.get(), 1, "degradation flags survive the reset");
        assert!(h.degraded(), "a reset must never hide a degraded state");
        h.snapshot_backoff.set(0);
        h.tier_spill_stopped.set(0);
        assert_eq!(h.health_line(), "ok");
    }

    #[test]
    fn wal_failstop_is_a_reason() {
        let h = HealthMetrics::new();
        h.wal_failstop.set(1);
        assert_eq!(h.health_line(), "degraded: wal-failstop");
        assert_eq!(h.reasons(), vec!["wal-failstop"]);
    }
}
