//! Metrics: counters, log-bucketed latency histograms, phase timers and a
//! registry that renders human and JSON reports. Used by the coordinator,
//! pipeline and benches; all types are thread-safe and allocation-free on
//! the record path.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::Json;

mod health;

pub use health::HealthMetrics;

// ---------------------------------------------------------------------------
// Counter
// ---------------------------------------------------------------------------

/// Monotonic counter. Relaxed ordering: metrics never guard data.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1)
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Gauge
// ---------------------------------------------------------------------------

/// Up/down gauge (e.g. currently-active connections). Signed so a stray
/// extra `dec` shows up as a negative reading instead of wrapping to 2^64.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicI64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite the reading (last-observation gauges: snapshot duration,
    /// current WAL generation, ...).
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Histogram
// ---------------------------------------------------------------------------

/// HDR-style latency histogram: values are bucketed into powers of two with
/// `SUB_BITS` linear sub-buckets each, giving ~3% relative error over
/// 1ns..~18s. Recording is one atomic add — safe to share across workers.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    min: AtomicU64,
}

const SUB_BITS: u32 = 5; // 32 sub-buckets per power of two
const SUB: usize = 1 << SUB_BITS;
const ORDERS: usize = 40; // covers up to 2^40 ns ≈ 18 minutes

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..ORDERS * SUB).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
        }
    }

    #[inline]
    fn index(value: u64) -> usize {
        let v = value.max(1);
        let order = 63 - v.leading_zeros();
        if order < SUB_BITS {
            // Small values map linearly into the first SUB slots.
            return v as usize;
        }
        let sub = ((v >> (order - SUB_BITS)) as usize) & (SUB - 1);
        let idx = ((order - SUB_BITS + 1) as usize) * SUB + sub;
        idx.min(ORDERS * SUB - 1)
    }

    /// Lower edge of a bucket (inverse of `index`, approximate).
    fn bucket_value(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        let order = (idx / SUB) as u32 + SUB_BITS - 1;
        let sub = (idx % SUB) as u64;
        (1u64 << order) + (sub << (order - SUB_BITS))
    }

    #[inline]
    pub fn record(&self, value: u64) {
        self.buckets[Self::index(value)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.max.fetch_max(value, Ordering::Relaxed);
        self.min.fetch_min(value, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    pub fn max(&self) -> u64 {
        let m = self.max.load(Ordering::Relaxed);
        if self.count() == 0 {
            0
        } else {
            m
        }
    }

    pub fn min(&self) -> u64 {
        let m = self.min.load(Ordering::Relaxed);
        if m == u64::MAX {
            0
        } else {
            m
        }
    }

    /// Approximate quantile (0.0..=1.0) from bucket boundaries.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_value(i);
            }
        }
        self.max()
    }

    /// Zero every bucket and the count/sum/min/max registers. Not atomic
    /// with respect to concurrent `record` calls — a racing sample may land
    /// in either epoch — which is fine for its purpose: separating
    /// consecutive measurement runs (`STATS RESET`).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count(),
            mean_ns: self.mean(),
            min_ns: self.min(),
            p50_ns: self.quantile(0.50),
            p90_ns: self.quantile(0.90),
            p99_ns: self.quantile(0.99),
            p999_ns: self.quantile(0.999),
            max_ns: self.max(),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub mean_ns: f64,
    pub min_ns: u64,
    pub p50_ns: u64,
    pub p90_ns: u64,
    pub p99_ns: u64,
    pub p999_ns: u64,
    pub max_ns: u64,
}

impl HistogramSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean_ns", Json::num(self.mean_ns)),
            ("min_ns", Json::num(self.min_ns as f64)),
            ("p50_ns", Json::num(self.p50_ns as f64)),
            ("p90_ns", Json::num(self.p90_ns as f64)),
            ("p99_ns", Json::num(self.p99_ns as f64)),
            ("p999_ns", Json::num(self.p999_ns as f64)),
            ("max_ns", Json::num(self.max_ns as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Phase timer
// ---------------------------------------------------------------------------

/// Wall-clock span recorder for coordinator phases (load/update/analytics/...).
#[derive(Debug, Default)]
pub struct PhaseTimer {
    phases: Mutex<Vec<(String, Duration)>>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it under `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.phases.lock().unwrap().push((name.to_string(), t0.elapsed()));
        out
    }

    pub fn record(&self, name: &str, d: Duration) {
        self.phases.lock().unwrap().push((name.to_string(), d));
    }

    pub fn get(&self, name: &str) -> Option<Duration> {
        self.phases
            .lock()
            .unwrap()
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
    }

    pub fn total(&self) -> Duration {
        self.phases.lock().unwrap().iter().map(|(_, d)| *d).sum()
    }

    pub fn entries(&self) -> Vec<(String, Duration)> {
        self.phases.lock().unwrap().clone()
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.entries()
                .into_iter()
                .map(|(n, d)| (n, Json::num(d.as_secs_f64())))
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------------
// Engine metrics bundle
// ---------------------------------------------------------------------------

/// All metrics the engine exposes; one instance per run, shared by reference.
#[derive(Default)]
pub struct EngineMetrics {
    pub records_loaded: Counter,
    pub records_updated: Counter,
    pub records_missing: Counter,
    pub parse_errors: Counter,
    pub batches: Counter,
    pub backpressure_waits: Counter,
    pub disk_reads: Counter,
    pub disk_writes: Counter,
    pub disk_seek_ns: Counter,
    pub update_latency: Histogram,
    pub batch_latency: Histogram,
    pub phases: PhaseTimer,
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("records_loaded", Json::num(self.records_loaded.get() as f64)),
            ("records_updated", Json::num(self.records_updated.get() as f64)),
            ("records_missing", Json::num(self.records_missing.get() as f64)),
            ("parse_errors", Json::num(self.parse_errors.get() as f64)),
            ("batches", Json::num(self.batches.get() as f64)),
            ("backpressure_waits", Json::num(self.backpressure_waits.get() as f64)),
            ("disk_reads", Json::num(self.disk_reads.get() as f64)),
            ("disk_writes", Json::num(self.disk_writes.get() as f64)),
            ("update_latency", self.update_latency.snapshot().to_json()),
            ("batch_latency", self.batch_latency.snapshot().to_json()),
            ("phases", self.phases.to_json()),
        ])
    }

    /// Multi-line human report.
    pub fn render(&self) -> String {
        use crate::util::fmt::commas;
        let u = self.update_latency.snapshot();
        let mut s = String::new();
        s.push_str(&format!(
            "records: loaded={} updated={} missing={} parse_errors={}\n",
            commas(self.records_loaded.get()),
            commas(self.records_updated.get()),
            commas(self.records_missing.get()),
            commas(self.parse_errors.get()),
        ));
        s.push_str(&format!(
            "pipeline: batches={} backpressure_waits={}\n",
            commas(self.batches.get()),
            commas(self.backpressure_waits.get())
        ));
        if self.disk_reads.get() + self.disk_writes.get() > 0 {
            s.push_str(&format!(
                "disk: reads={} writes={} modeled_seek_time={:.2}s\n",
                commas(self.disk_reads.get()),
                commas(self.disk_writes.get()),
                self.disk_seek_ns.get() as f64 / 1e9,
            ));
        }
        if u.count > 0 {
            s.push_str(&format!(
                "update latency: p50={}ns p99={}ns max={}ns (n={})\n",
                u.p50_ns,
                u.p99_ns,
                u.max_ns,
                commas(u.count)
            ));
        }
        for (name, d) in self.phases.entries() {
            s.push_str(&format!("phase {:<12} {}\n", name, crate::util::fmt::human_duration(d)));
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Server metrics bundle
// ---------------------------------------------------------------------------

/// Metrics for the TCP front end: connection lifecycle counters plus
/// per-verb latency and batch-size histograms. One instance per server,
/// shared by the acceptor and every pool worker.
#[derive(Default)]
pub struct ServerMetrics {
    pub conns_accepted: Counter,
    pub conns_rejected: Counter,
    pub conns_active: Gauge,
    pub accept_errors: Counter,
    pub requests: Counter,
    /// Bumped by [`ServerMetrics::reset_epoch`] (`STATS RESET`); lets a
    /// reader tell which measurement window a report belongs to.
    pub epoch: Counter,
    /// Responses formatted straight into the pooled per-connection buffer
    /// (byte tokenizer + integer formatter) instead of a fresh `String` —
    /// one saved allocation each. GET/UPDATE/MGET/MUPDATE/PING/QUIT take
    /// this path; STATS/ANALYTICS and error replies are cold and don't.
    pub allocs_saved: Counter,
    /// Reactor event-loop wakeups (`epoll_wait` returns, including timer
    /// ticks). The headline decoupling signal: idle connections add
    /// nothing to it — compare against `conns_active`. Always 0 on the
    /// non-Linux fallback front end.
    pub epoll_wakeups: Counter,
    /// Readiness events delivered across all wakeups; `ready_events /
    /// epoll_wakeups` is the batching factor of the event loop.
    pub ready_events: Counter,
    /// Connections closed because their bounded write buffer overflowed
    /// (`ServerConfig::write_buf_cap`): a peer stopped reading its
    /// responses. Pre-reactor this scenario pinned a worker thread inside
    /// the socket write timeout instead.
    pub backpressure_closes: Counter,
    /// Timer-wheel idle-deadline expirations (connections evicted idle).
    pub timer_expirations: Counter,
    /// Keys (MGET) / update groups (MUPDATE) / lines (BATCH) per batch verb.
    pub batch_sizes: Histogram,
    pub get_latency: Histogram,
    pub update_latency: Histogram,
    pub mget_latency: Histogram,
    pub mupdate_latency: Histogram,
    pub batch_latency: Histogram,
    pub stats_latency: Histogram,
    pub analytics_latency: Histogram,
    pub other_latency: Histogram,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// The latency histogram charged for a request verb.
    pub fn latency_for(&self, verb: &str) -> &Histogram {
        match verb {
            "GET" => &self.get_latency,
            "UPDATE" => &self.update_latency,
            "MGET" => &self.mget_latency,
            "MUPDATE" => &self.mupdate_latency,
            "BATCH" => &self.batch_latency,
            "STATS" => &self.stats_latency,
            "ANALYTICS" => &self.analytics_latency,
            _ => &self.other_latency,
        }
    }

    fn verbs(&self) -> [(&'static str, &Histogram); 8] {
        [
            ("get", &self.get_latency),
            ("update", &self.update_latency),
            ("mget", &self.mget_latency),
            ("mupdate", &self.mupdate_latency),
            ("batch", &self.batch_latency),
            ("stats", &self.stats_latency),
            ("analytics", &self.analytics_latency),
            ("other", &self.other_latency),
        ]
    }

    /// Start a fresh measurement window (`STATS RESET`): zero the request
    /// and connection *counters* and every latency/batch-size histogram,
    /// then bump and return the epoch. The `conns_active` gauge is live
    /// state, not a measurement, and is deliberately left alone — right
    /// after a reset `conns_active` may exceed `conns_accepted`.
    pub fn reset_epoch(&self) -> u64 {
        self.conns_accepted.reset();
        self.conns_rejected.reset();
        self.accept_errors.reset();
        self.requests.reset();
        self.allocs_saved.reset();
        self.epoll_wakeups.reset();
        self.ready_events.reset();
        self.backpressure_closes.reset();
        self.timer_expirations.reset();
        self.batch_sizes.reset();
        for (_, h) in self.verbs() {
            h.reset();
        }
        self.epoch.inc();
        self.epoch.get()
    }

    /// Connection-counter suffix appended to the basic `STATS` line.
    pub fn stats_suffix(&self) -> String {
        format!(
            " conns_accepted={} conns_active={} conns_rejected={} accept_errors={} requests={} epoch={}",
            self.conns_accepted.get(),
            self.conns_active.get(),
            self.conns_rejected.get(),
            self.accept_errors.get(),
            self.requests.get(),
            self.epoch.get()
        )
    }

    /// One-line detailed report for `STATS SERVER`: connection counters,
    /// batch-size distribution and per-verb latency percentiles.
    pub fn stats_server_line(&self) -> String {
        // Reuse stats_suffix for the connection counters so STATS and
        // STATS SERVER can never report different counter sets.
        let mut s = format!(
            "OK{} allocs_saved={} epoll_wakeups={} ready_events={} backpressure_closes={} \
             timer_expirations={} batches={} batch_p50={} batch_max={}",
            self.stats_suffix(),
            self.allocs_saved.get(),
            self.epoll_wakeups.get(),
            self.ready_events.get(),
            self.backpressure_closes.get(),
            self.timer_expirations.get(),
            self.batch_sizes.count(),
            self.batch_sizes.quantile(0.5),
            self.batch_sizes.max()
        );
        for (name, h) in self.verbs() {
            s.push_str(&format!(
                " {name}_n={} {name}_p50_ns={} {name}_p99_ns={}",
                h.count(),
                h.quantile(0.5),
                h.quantile(0.99)
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("conns_accepted", Json::num(self.conns_accepted.get() as f64)),
            ("conns_rejected", Json::num(self.conns_rejected.get() as f64)),
            ("conns_active", Json::num(self.conns_active.get() as f64)),
            ("accept_errors", Json::num(self.accept_errors.get() as f64)),
            ("requests", Json::num(self.requests.get() as f64)),
            ("epoch", Json::num(self.epoch.get() as f64)),
            ("allocs_saved", Json::num(self.allocs_saved.get() as f64)),
            ("epoll_wakeups", Json::num(self.epoll_wakeups.get() as f64)),
            ("ready_events", Json::num(self.ready_events.get() as f64)),
            ("backpressure_closes", Json::num(self.backpressure_closes.get() as f64)),
            ("timer_expirations", Json::num(self.timer_expirations.get() as f64)),
            ("batch_sizes", self.batch_sizes.snapshot().to_json()),
            ("get_latency", self.get_latency.snapshot().to_json()),
            ("update_latency", self.update_latency.snapshot().to_json()),
            ("mget_latency", self.mget_latency.snapshot().to_json()),
            ("mupdate_latency", self.mupdate_latency.snapshot().to_json()),
            ("batch_latency", self.batch_latency.snapshot().to_json()),
        ])
    }
}

// ---------------------------------------------------------------------------
// Durability metrics bundle
// ---------------------------------------------------------------------------

/// Metrics for the persistence layer behind the server: WAL traffic,
/// group-commit syncs and checkpoint activity. One instance per
/// `durability::Persistence`, shared by the commit path and the
/// snapshotter thread; rendered into `STATS SERVER`.
#[derive(Default)]
pub struct DurabilityMetrics {
    /// WAL frames appended (one per acknowledged mutation).
    pub wal_appends: Counter,
    /// WAL bytes appended (lifetime total, not current-file size).
    pub wal_bytes: Counter,
    /// Group-commit sync operations (fsync, or flush-only when fsync off).
    pub wal_syncs: Counter,
    /// Checkpoints completed since startup.
    pub snapshots: Counter,
    /// Background checkpoints that failed (state stays recoverable from the
    /// previous snapshot + longer WAL chain).
    pub snapshot_errors: Counter,
    /// Wall-clock of the most recent checkpoint, in milliseconds.
    pub snapshot_last_ms: Gauge,
    /// Records written by the most recent checkpoint.
    pub snapshot_last_records: Gauge,
    /// Current WAL generation (bumped by every checkpoint rotation).
    pub generation: Gauge,
}

impl DurabilityMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Joins a `STATS RESET` epoch: zero the traffic *counters* so two
    /// measurement runs can compare WAL/checkpoint activity, keeping the
    /// state gauges (last-snapshot readings, current generation) intact.
    pub fn reset_epoch_counters(&self) {
        self.wal_appends.reset();
        self.wal_bytes.reset();
        self.wal_syncs.reset();
        self.snapshots.reset();
        self.snapshot_errors.reset();
    }

    /// Suffix appended to `STATS SERVER` when a persistence layer is live.
    pub fn stats_suffix(&self) -> String {
        format!(
            " wal_appends={} wal_bytes={} wal_syncs={} snapshots={} snapshot_errors={} \
             snapshot_last_ms={} snapshot_last_records={} generation={}",
            self.wal_appends.get(),
            self.wal_bytes.get(),
            self.wal_syncs.get(),
            self.snapshots.get(),
            self.snapshot_errors.get(),
            self.snapshot_last_ms.get(),
            self.snapshot_last_records.get(),
            self.generation.get()
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("wal_appends", Json::num(self.wal_appends.get() as f64)),
            ("wal_bytes", Json::num(self.wal_bytes.get() as f64)),
            ("wal_syncs", Json::num(self.wal_syncs.get() as f64)),
            ("snapshots", Json::num(self.snapshots.get() as f64)),
            ("snapshot_errors", Json::num(self.snapshot_errors.get() as f64)),
            ("snapshot_last_ms", Json::num(self.snapshot_last_ms.get() as f64)),
            ("snapshot_last_records", Json::num(self.snapshot_last_records.get() as f64)),
            ("generation", Json::num(self.generation.get() as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Replication metrics bundle
// ---------------------------------------------------------------------------

/// Role gauge values for [`ReplicationMetrics::role`].
pub const REPL_ROLE_PRIMARY: i64 = 1;
pub const REPL_ROLE_STANDBY: i64 = 2;

/// Metrics for the hot-standby replication layer (`replication::`): WAL
/// frames shipped/applied, ack traffic, replication lag, link health and
/// failover activity. Each process (primary or standby) owns one instance
/// and reports its own side of the link; rendered into `STATS SERVER`.
#[derive(Default)]
pub struct ReplicationMetrics {
    /// WAL frames shipped to standbys (primary) — lifetime, incl. resends.
    pub frames_shipped: Counter,
    /// WAL bytes shipped to standbys (primary).
    pub bytes_shipped: Counter,
    /// WAL frames applied from the stream (standby).
    pub frames_applied: Counter,
    /// Acks received (primary) or sent (standby).
    pub acks: Counter,
    /// Heartbeats sent (primary) or received (standby).
    pub heartbeats: Counter,
    /// Heartbeat intervals that lapsed without any traffic (standby).
    pub heartbeats_missed: Counter,
    /// Link re-establishments after the initial connect.
    pub reconnects: Counter,
    /// Full snapshot re-syncs (bootstrap, ship-queue overflow, gap).
    pub snapshot_resyncs: Counter,
    /// Stream messages dropped for framing/CRC corruption (forces resync).
    pub corrupt_frames: Counter,
    /// Standby promotions to read-write after a lapsed heartbeat.
    pub failovers: Counter,
    /// Replication lag in WAL bytes (primary: tip − last ack; standby:
    /// heartbeat tip − applied). Same-generation only; resyncs re-zero it.
    pub lag_bytes: Gauge,
    /// Replication lag in whole WAL frames (`lag_bytes / FRAME_BYTES`).
    pub lag_frames: Gauge,
    /// Current role: [`REPL_ROLE_PRIMARY`] or [`REPL_ROLE_STANDBY`].
    pub role: Gauge,
}

impl ReplicationMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Joins a `STATS RESET` epoch: zero the traffic counters so two
    /// measurement runs compare replication activity cleanly; the state
    /// gauges (current lag, role) persist — a reset must never make a
    /// standby look caught-up or flip its reported role.
    pub fn reset_epoch_counters(&self) {
        self.frames_shipped.reset();
        self.bytes_shipped.reset();
        self.frames_applied.reset();
        self.acks.reset();
        self.heartbeats.reset();
        self.heartbeats_missed.reset();
        self.reconnects.reset();
        self.snapshot_resyncs.reset();
        self.corrupt_frames.reset();
        self.failovers.reset();
    }

    /// Suffix appended to `STATS SERVER` when replication is live (leading
    /// space included, like `DurabilityMetrics::stats_suffix`).
    pub fn stats_suffix(&self) -> String {
        format!(
            " repl_frames_shipped={} repl_bytes_shipped={} repl_frames_applied={} repl_acks={} \
             repl_heartbeats={} repl_heartbeats_missed={} repl_reconnects={} \
             repl_snapshot_resyncs={} repl_corrupt_frames={} repl_failovers={} \
             repl_lag_bytes={} repl_lag_frames={} repl_role={}",
            self.frames_shipped.get(),
            self.bytes_shipped.get(),
            self.frames_applied.get(),
            self.acks.get(),
            self.heartbeats.get(),
            self.heartbeats_missed.get(),
            self.reconnects.get(),
            self.snapshot_resyncs.get(),
            self.corrupt_frames.get(),
            self.failovers.get(),
            self.lag_bytes.get(),
            self.lag_frames.get(),
            self.role.get()
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("frames_shipped", Json::num(self.frames_shipped.get() as f64)),
            ("bytes_shipped", Json::num(self.bytes_shipped.get() as f64)),
            ("frames_applied", Json::num(self.frames_applied.get() as f64)),
            ("acks", Json::num(self.acks.get() as f64)),
            ("heartbeats", Json::num(self.heartbeats.get() as f64)),
            ("heartbeats_missed", Json::num(self.heartbeats_missed.get() as f64)),
            ("reconnects", Json::num(self.reconnects.get() as f64)),
            ("snapshot_resyncs", Json::num(self.snapshot_resyncs.get() as f64)),
            ("corrupt_frames", Json::num(self.corrupt_frames.get() as f64)),
            ("failovers", Json::num(self.failovers.get() as f64)),
            ("lag_bytes", Json::num(self.lag_bytes.get() as f64)),
            ("lag_frames", Json::num(self.lag_frames.get() as f64)),
            ("role", Json::num(self.role.get() as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Tiered-store metrics bundle
// ---------------------------------------------------------------------------

/// Metrics for the larger-than-RAM tier (`storage::tiered`): spill and
/// compaction activity, per-tier read fallthrough, block-cache traffic and
/// on-disk footprint. One instance per `TieredStore`; rendered into
/// `STATS SERVER` via `StorageEngine::stats_suffix`.
#[derive(Default)]
pub struct TieredMetrics {
    /// Cold-shard spills (one immutable run written each).
    pub spills: Counter,
    /// Records written to runs by spills (lifetime, including re-spills).
    pub spilled_records: Counter,
    /// Spills that failed with an I/O error (records stayed in RAM).
    pub spill_errors: Counter,
    /// Point reads served by the memstore (seqlock hot path).
    pub mem_hits: Counter,
    /// Point reads that fell through to a disk run.
    pub disk_hits: Counter,
    /// Point reads absent from every tier.
    pub misses: Counter,
    /// Spilled records pulled back into the memstore by a write.
    pub promotions: Counter,
    /// Block-cache hits on the run-read path.
    pub cache_hits: Counter,
    /// Block-cache misses (each one is a run-file read).
    pub cache_misses: Counter,
    /// Blocks evicted from the block cache.
    pub cache_evictions: Counter,
    /// Background + explicit compactions completed.
    pub compactions: Counter,
    /// Run records that failed their CRC frame (skipped, never served).
    pub corrupt_records: Counter,
    /// Run reads or compactions that failed with an I/O error.
    pub disk_errors: Counter,
    /// Runs currently quarantined after a read I/O error: skipped by
    /// reads and excluded from compaction inputs, files kept on disk.
    /// State, not traffic — a restart re-probes them.
    pub quarantined: Gauge,
    /// Live runs in the published manifest.
    pub runs: Gauge,
    /// Bytes across all live run files.
    pub disk_bytes: Gauge,
    /// Records currently resident in the hot tier.
    pub resident_records: Gauge,
}

impl TieredMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Block-cache hit rate over the current epoch, `0.0` when idle.
    pub fn cache_hit_rate(&self) -> f64 {
        let h = self.cache_hits.get();
        let total = h + self.cache_misses.get();
        if total == 0 {
            0.0
        } else {
            h as f64 / total as f64
        }
    }

    /// Joins a `STATS RESET` epoch: zero the traffic counters so two
    /// measurement windows compare cleanly; state gauges (runs on disk,
    /// disk bytes, resident records) persist.
    pub fn reset_epoch_counters(&self) {
        self.spills.reset();
        self.spilled_records.reset();
        self.spill_errors.reset();
        self.mem_hits.reset();
        self.disk_hits.reset();
        self.misses.reset();
        self.promotions.reset();
        self.cache_hits.reset();
        self.cache_misses.reset();
        self.cache_evictions.reset();
        self.compactions.reset();
        self.corrupt_records.reset();
        self.disk_errors.reset();
    }

    /// Suffix appended to `STATS SERVER` when the tier is live (leading
    /// space included, like `DurabilityMetrics::stats_suffix`).
    pub fn stats_suffix(&self) -> String {
        format!(
            " tier_spills={} tier_spilled_records={} tier_spill_errors={} tier_mem_hits={} \
             tier_disk_hits={} tier_misses={} tier_promotions={} tier_cache_hits={} \
             tier_cache_misses={} tier_cache_evictions={} tier_cache_hit_rate={:.3} \
             tier_compactions={} tier_corrupt_records={} tier_disk_errors={} \
             tier_quarantined={} tier_runs={} tier_disk_bytes={} tier_resident_records={}",
            self.spills.get(),
            self.spilled_records.get(),
            self.spill_errors.get(),
            self.mem_hits.get(),
            self.disk_hits.get(),
            self.misses.get(),
            self.promotions.get(),
            self.cache_hits.get(),
            self.cache_misses.get(),
            self.cache_evictions.get(),
            self.cache_hit_rate(),
            self.compactions.get(),
            self.corrupt_records.get(),
            self.disk_errors.get(),
            self.quarantined.get(),
            self.runs.get(),
            self.disk_bytes.get(),
            self.resident_records.get()
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("spills", Json::num(self.spills.get() as f64)),
            ("spilled_records", Json::num(self.spilled_records.get() as f64)),
            ("spill_errors", Json::num(self.spill_errors.get() as f64)),
            ("mem_hits", Json::num(self.mem_hits.get() as f64)),
            ("disk_hits", Json::num(self.disk_hits.get() as f64)),
            ("misses", Json::num(self.misses.get() as f64)),
            ("promotions", Json::num(self.promotions.get() as f64)),
            ("cache_hits", Json::num(self.cache_hits.get() as f64)),
            ("cache_misses", Json::num(self.cache_misses.get() as f64)),
            ("cache_evictions", Json::num(self.cache_evictions.get() as f64)),
            ("cache_hit_rate", Json::num(self.cache_hit_rate())),
            ("compactions", Json::num(self.compactions.get() as f64)),
            ("corrupt_records", Json::num(self.corrupt_records.get() as f64)),
            ("disk_errors", Json::num(self.disk_errors.get() as f64)),
            ("quarantined", Json::num(self.quarantined.get() as f64)),
            ("runs", Json::num(self.runs.get() as f64)),
            ("disk_bytes", Json::num(self.disk_bytes.get() as f64)),
            ("resident_records", Json::num(self.resident_records.get() as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// IPC (multi-process serving) metrics bundle
// ---------------------------------------------------------------------------

/// Per-worker RPC traffic of one `ipc::ServingPool` worker connection.
#[derive(Default)]
pub struct IpcWorkerMetrics {
    /// Request frames sent to (and answered by) this worker.
    pub rpcs: Counter,
    /// Failed exchanges — each one poisons the connection.
    pub errors: Counter,
    /// Round-trip latency per exchange (a scatter records its group
    /// round-trip on every worker it touched).
    pub latency: Histogram,
}

/// Metrics for the multi-process serving backend (`serve --processes N`):
/// one [`IpcWorkerMetrics`] per worker process, rendered into
/// `STATS SERVER` next to the per-verb server histograms.
pub struct IpcMetrics {
    workers: Vec<IpcWorkerMetrics>,
}

impl IpcMetrics {
    pub fn new(n: usize) -> Self {
        IpcMetrics { workers: (0..n).map(|_| IpcWorkerMetrics::default()).collect() }
    }

    pub fn workers(&self) -> &[IpcWorkerMetrics] {
        &self.workers
    }

    /// One successful exchange with `worker`: `frames` request frames
    /// answered, `elapsed` wall-clock for the whole exchange.
    pub fn record_rpc(&self, worker: usize, frames: u64, elapsed: Duration) {
        let w = &self.workers[worker];
        w.rpcs.add(frames);
        w.latency.record_duration(elapsed);
    }

    pub fn record_error(&self, worker: usize) {
        self.workers[worker].errors.inc();
    }

    pub fn total_rpcs(&self) -> u64 {
        self.workers.iter().map(|w| w.rpcs.get()).sum()
    }

    pub fn total_errors(&self) -> u64 {
        self.workers.iter().map(|w| w.errors.get()).sum()
    }

    /// Joins a `STATS RESET` epoch: zero counters and latency windows.
    pub fn reset_epoch_counters(&self) {
        for w in &self.workers {
            w.rpcs.reset();
            w.errors.reset();
            w.latency.reset();
        }
    }

    /// Suffix appended to `STATS SERVER` in multi-process mode: pool-wide
    /// totals, then per-worker RPC counters and latency quantiles.
    pub fn stats_suffix(&self) -> String {
        let mut s = format!(
            " ipc_workers={} ipc_rpcs={} ipc_errors={}",
            self.workers.len(),
            self.total_rpcs(),
            self.total_errors()
        );
        for (i, w) in self.workers.iter().enumerate() {
            s.push_str(&format!(
                " ipc_w{}_rpcs={} ipc_w{}_errors={} ipc_w{}_p50_ns={} ipc_w{}_p99_ns={}",
                i,
                w.rpcs.get(),
                i,
                w.errors.get(),
                i,
                w.latency.quantile(0.5),
                i,
                w.latency.quantile(0.99)
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workers", Json::num(self.workers.len() as f64)),
            ("rpcs", Json::num(self.total_rpcs() as f64)),
            ("errors", Json::num(self.total_errors() as f64)),
            (
                "per_worker",
                Json::arr(
                    self.workers
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("rpcs", Json::num(w.rpcs.get() as f64)),
                                ("errors", Json::num(w.errors.get() as f64)),
                                ("latency", w.latency.snapshot().to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent() {
        let c = Counter::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn histogram_index_monotone() {
        let mut last = 0;
        for v in [0u64, 1, 2, 10, 31, 32, 33, 100, 1000, 1 << 20, 1 << 30, u64::MAX] {
            let i = Histogram::index(v);
            assert!(i >= last, "index not monotone at {v}");
            last = i;
        }
    }

    #[test]
    fn histogram_relative_error_bounded() {
        // bucket_value(index(v)) should be within ~2*2^-SUB_BITS of v.
        for v in [100u64, 999, 5_000, 123_456, 9_999_999, 1 << 33] {
            let approx = Histogram::bucket_value(Histogram::index(v));
            let rel = (v as f64 - approx as f64).abs() / v as f64;
            assert!(rel < 0.07, "v={v} approx={approx} rel={rel}");
        }
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v * 1000); // 1µs..1ms uniform
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, 1000);
        let p50 = snap.p50_ns as f64;
        assert!((p50 - 500_000.0).abs() / 500_000.0 < 0.1, "p50={p50}");
        let p99 = snap.p99_ns as f64;
        assert!((p99 - 990_000.0).abs() / 990_000.0 < 0.1, "p99={p99}");
        assert_eq!(snap.min_ns, 1000);
        assert_eq!(snap.max_ns, 1_000_000);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.max_ns, 0);
        assert_eq!(s.min_ns, 0);
        assert_eq!(h.quantile(0.5), 0);
    }

    #[test]
    fn histogram_concurrent_totals() {
        let h = Histogram::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = &h;
                s.spawn(move || {
                    for i in 0..25_000u64 {
                        h.record(1 + (i ^ t) % 1000);
                    }
                });
            }
        });
        assert_eq!(h.count(), 100_000);
    }

    #[test]
    fn phase_timer() {
        let pt = PhaseTimer::new();
        let v = pt.time("load", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(pt.get("load").unwrap() >= Duration::from_millis(5));
        assert!(pt.get("nope").is_none());
        pt.record("update", Duration::from_secs(1));
        assert!(pt.total() >= Duration::from_secs(1));
    }

    #[test]
    fn metrics_json_renders() {
        let m = EngineMetrics::new();
        m.records_updated.add(5);
        m.update_latency.record(1234);
        let j = m.to_json();
        assert_eq!(j.get("records_updated").unwrap().as_f64().unwrap(), 5.0);
        let text = m.render();
        assert!(text.contains("updated=5"));
    }

    #[test]
    fn gauge_up_down() {
        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        g.dec();
        assert_eq!(g.get(), -1, "extra dec must be visible, not wrap");
    }

    #[test]
    fn gauge_set_and_add() {
        let g = Gauge::new();
        g.set(41);
        g.add(2);
        g.dec();
        assert_eq!(g.get(), 42);
        g.set(-7);
        assert_eq!(g.get(), -7);
    }

    #[test]
    fn histogram_reset_clears_all_registers() {
        let h = Histogram::new();
        for v in [1u64, 1000, 1 << 20] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        h.reset();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.min_ns, 0);
        assert_eq!(s.max_ns, 0);
        assert_eq!(h.quantile(0.99), 0);
        // The histogram is reusable: post-reset samples are a clean run.
        h.record(500);
        assert_eq!(h.count(), 1);
        assert_eq!(h.min(), 500);
        assert_eq!(h.max(), 500);
    }

    #[test]
    fn reset_epoch_separates_two_measurement_runs() {
        let m = ServerMetrics::new();
        // Run 1.
        m.conns_accepted.inc();
        m.requests.add(10);
        m.allocs_saved.add(9);
        m.latency_for("GET").record(100);
        m.latency_for("MUPDATE").record(200);
        m.batch_sizes.record(64);
        m.conns_active.inc();
        assert!(m.stats_server_line().contains("allocs_saved=9"));
        assert_eq!(m.reset_epoch(), 1);
        // Run 2 starts clean (except the live gauge).
        assert_eq!(m.requests.get(), 0);
        assert_eq!(m.allocs_saved.get(), 0);
        assert_eq!(m.conns_accepted.get(), 0);
        assert_eq!(m.get_latency.count(), 0);
        assert_eq!(m.mupdate_latency.count(), 0);
        assert_eq!(m.batch_sizes.count(), 0);
        assert_eq!(m.conns_active.get(), 1, "live gauge must survive the reset");
        m.latency_for("GET").record(300);
        assert_eq!(m.get_latency.count(), 1);
        assert_eq!(m.get_latency.min(), 300, "run 1 samples must not contaminate run 2");
        assert!(m.stats_suffix().contains("epoch=1"), "{}", m.stats_suffix());
        assert_eq!(m.reset_epoch(), 2);
    }

    #[test]
    fn durability_metrics_render_and_json() {
        let d = DurabilityMetrics::new();
        d.wal_appends.add(5);
        d.wal_bytes.add(120);
        d.wal_syncs.inc();
        d.snapshots.inc();
        d.snapshot_last_ms.set(17);
        d.snapshot_last_records.set(1000);
        d.generation.set(3);
        let s = d.stats_suffix();
        for needle in [
            " wal_appends=5",
            " wal_bytes=120",
            " wal_syncs=1",
            " snapshots=1",
            " snapshot_errors=0",
            " snapshot_last_ms=17",
            " snapshot_last_records=1000",
            " generation=3",
        ] {
            assert!(s.contains(needle), "missing {needle:?} in {s:?}");
        }
        let j = d.to_json();
        assert_eq!(j.get("wal_appends").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(j.get("generation").unwrap().as_f64().unwrap(), 3.0);
        // Epoch reset zeroes the traffic counters but keeps state gauges.
        d.reset_epoch_counters();
        assert_eq!(d.wal_appends.get(), 0);
        assert_eq!(d.wal_bytes.get(), 0);
        assert_eq!(d.wal_syncs.get(), 0);
        assert_eq!(d.snapshots.get(), 0);
        assert_eq!(d.snapshot_last_ms.get(), 17, "last-snapshot gauge is state, not traffic");
        assert_eq!(d.generation.get(), 3);
    }

    #[test]
    fn replication_metrics_render_and_reset() {
        let r = ReplicationMetrics::new();
        r.frames_shipped.add(300);
        r.bytes_shipped.add(7200);
        r.frames_applied.add(299);
        r.acks.add(12);
        r.heartbeats.add(40);
        r.heartbeats_missed.add(2);
        r.reconnects.inc();
        r.snapshot_resyncs.inc();
        r.failovers.inc();
        r.lag_bytes.set(24);
        r.lag_frames.set(1);
        r.role.set(REPL_ROLE_STANDBY);
        let s = r.stats_suffix();
        for needle in [
            " repl_frames_shipped=300",
            " repl_bytes_shipped=7200",
            " repl_frames_applied=299",
            " repl_acks=12",
            " repl_heartbeats=40",
            " repl_heartbeats_missed=2",
            " repl_reconnects=1",
            " repl_snapshot_resyncs=1",
            " repl_corrupt_frames=0",
            " repl_failovers=1",
            " repl_lag_bytes=24",
            " repl_lag_frames=1",
            " repl_role=2",
        ] {
            assert!(s.contains(needle), "missing {needle:?} in {s:?}");
        }
        let j = r.to_json();
        assert_eq!(j.get("frames_shipped").unwrap().as_f64().unwrap(), 300.0);
        assert_eq!(j.get("role").unwrap().as_f64().unwrap(), 2.0);
        // Epoch reset zeroes traffic counters; lag and role are state.
        r.reset_epoch_counters();
        assert_eq!(r.frames_shipped.get(), 0);
        assert_eq!(r.failovers.get(), 0);
        assert_eq!(r.lag_bytes.get(), 24, "lag gauge is state, not traffic");
        assert_eq!(r.role.get(), REPL_ROLE_STANDBY, "role survives the reset");
    }

    #[test]
    fn tiered_metrics_render_and_reset() {
        let t = TieredMetrics::new();
        t.spills.add(2);
        t.spilled_records.add(500);
        t.mem_hits.add(90);
        t.disk_hits.add(9);
        t.misses.inc();
        t.promotions.add(3);
        t.cache_hits.add(30);
        t.cache_misses.add(10);
        t.compactions.inc();
        t.runs.set(4);
        t.disk_bytes.set(12_288);
        t.resident_records.set(250);
        assert!((t.cache_hit_rate() - 0.75).abs() < 1e-9);
        let s = t.stats_suffix();
        for needle in [
            " tier_spills=2",
            " tier_spilled_records=500",
            " tier_mem_hits=90",
            " tier_disk_hits=9",
            " tier_misses=1",
            " tier_promotions=3",
            " tier_cache_hits=30",
            " tier_cache_misses=10",
            " tier_cache_hit_rate=0.750",
            " tier_compactions=1",
            " tier_corrupt_records=0",
            " tier_quarantined=0",
            " tier_runs=4",
            " tier_disk_bytes=12288",
            " tier_resident_records=250",
        ] {
            assert!(s.contains(needle), "missing {needle:?} in {s:?}");
        }
        let j = t.to_json();
        assert_eq!(j.get("spills").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("cache_hit_rate").unwrap().as_f64().unwrap(), 0.75);
        assert_eq!(j.get("runs").unwrap().as_f64().unwrap(), 4.0);
        // Epoch reset zeroes traffic counters; state gauges persist.
        t.reset_epoch_counters();
        assert_eq!(t.spills.get(), 0);
        assert_eq!(t.mem_hits.get(), 0);
        assert_eq!(t.cache_hit_rate(), 0.0);
        assert_eq!(t.runs.get(), 4, "run-count gauge is state, not traffic");
        assert_eq!(t.disk_bytes.get(), 12_288);
    }

    #[test]
    fn ipc_metrics_render_and_reset() {
        let m = IpcMetrics::new(2);
        m.record_rpc(0, 3, Duration::from_micros(50));
        m.record_rpc(1, 1, Duration::from_micros(80));
        m.record_error(1);
        assert_eq!(m.total_rpcs(), 4);
        assert_eq!(m.total_errors(), 1);
        let s = m.stats_suffix();
        for needle in [
            " ipc_workers=2",
            " ipc_rpcs=4",
            " ipc_errors=1",
            " ipc_w0_rpcs=3",
            " ipc_w1_rpcs=1",
            " ipc_w1_errors=1",
            " ipc_w0_p50_ns=",
            " ipc_w1_p99_ns=",
        ] {
            assert!(s.contains(needle), "missing {needle:?} in {s:?}");
        }
        let j = m.to_json();
        assert_eq!(j.get("workers").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("rpcs").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(j.get("per_worker").unwrap().as_arr().unwrap().len(), 2);
        m.reset_epoch_counters();
        assert_eq!(m.total_rpcs(), 0);
        assert_eq!(m.total_errors(), 0);
        assert_eq!(m.workers()[0].latency.count(), 0);
    }

    #[test]
    fn server_metrics_routes_verbs_and_renders() {
        let m = ServerMetrics::new();
        m.latency_for("GET").record(100);
        m.latency_for("MUPDATE").record(200);
        m.latency_for("NOPE").record(300);
        assert_eq!(m.get_latency.count(), 1);
        assert_eq!(m.mupdate_latency.count(), 1);
        assert_eq!(m.other_latency.count(), 1);
        m.conns_accepted.inc();
        m.conns_active.inc();
        m.batch_sizes.record(64);
        m.epoll_wakeups.add(3);
        m.ready_events.add(5);
        m.backpressure_closes.inc();
        m.timer_expirations.inc();
        let suffix = m.stats_suffix();
        assert!(suffix.contains("conns_accepted=1"), "{suffix}");
        assert!(suffix.contains("conns_active=1"), "{suffix}");
        let line = m.stats_server_line();
        assert!(line.starts_with("OK "), "{line}");
        assert!(line.contains("batches=1"), "{line}");
        assert!(line.contains("get_n=1"), "{line}");
        assert!(line.contains("mupdate_p50_ns="), "{line}");
        assert!(line.contains("epoll_wakeups=3"), "{line}");
        assert!(line.contains("ready_events=5"), "{line}");
        assert!(line.contains("backpressure_closes=1"), "{line}");
        assert!(line.contains("timer_expirations=1"), "{line}");
        let j = m.to_json();
        assert_eq!(j.get("conns_accepted").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("epoll_wakeups").unwrap().as_f64().unwrap(), 3.0);
        // Reactor counters join the measurement epoch.
        m.reset_epoch();
        assert_eq!(m.epoll_wakeups.get(), 0);
        assert_eq!(m.ready_events.get(), 0);
        assert_eq!(m.backpressure_closes.get(), 0);
        assert_eq!(m.timer_expirations.get(), 0);
    }
}
