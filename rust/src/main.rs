//! `membig` — CLI launcher for the memory-based multi-processing engine.
//!
//! Subcommands:
//!   gen           build the book-inventory database + Stock.dat feed
//!   run           the proposed app (load → parallel update → report)
//!   conventional  the disk-based baseline app
//!   compare       both apps over the same inputs → one Table-1 row
//!   analytics     PJRT analytics over the store (L1/L2 path)
//!   serve         one-server TCP request loop
//!   info          environment + config dump

use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;

use membig::config::{parse_ini, Args, EngineConfig, FlagSpec};
use membig::coordinator::{Coordinator, Workbench};
use membig::coordinator::report::{render_figure6, render_table1, RunReport};
use membig::durability::{DurabilityOptions, Persistence};
use membig::runtime::AnalyticsService;
use membig::server::{Server, ServerConfig};
use membig::storage::{StorageEngine, TieredOptions, TieredStore};
use membig::util::fmt::{commas, human_duration, paper_hms};
use membig::workload::gen::DatasetSpec;

fn spec() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "records", value: "N", help: "database size (default 2M; suffixes k/M)" },
        FlagSpec { name: "updates", value: "N", help: "update feed size (default = records)" },
        FlagSpec { name: "threads", value: "N", help: "worker threads (default = cores)" },
        FlagSpec { name: "shards", value: "N", help: "hash-table shards (default = threads)" },
        FlagSpec { name: "batch-size", value: "N", help: "pipeline batch size (default 8192)" },
        FlagSpec { name: "data-dir", value: "DIR", help: "experiment data directory" },
        FlagSpec { name: "artifacts", value: "DIR", help: "AOT artifacts directory" },
        FlagSpec { name: "backend", value: "B", help: "analytics backend: auto|reference|pjrt|off" },
        FlagSpec { name: "config", value: "FILE", help: "INI config file" },
        FlagSpec { name: "seed", value: "N", help: "workload RNG seed" },
        FlagSpec { name: "disk-scale", value: "F", help: "fraction of modeled disk delay to sleep (default 0)" },
        FlagSpec { name: "cache-pages", value: "N", help: "disk store page-cache capacity" },
        FlagSpec { name: "bind", value: "ADDR", help: "serve: TCP bind address" },
        FlagSpec { name: "workers", value: "N", help: "serve: blocking-verb worker threads (default = max(cores, 4))" },
        FlagSpec { name: "max-conns", value: "N", help: "serve: max concurrent connections (default 1024)" },
        FlagSpec { name: "reactors", value: "N", help: "serve: event-loop reactor threads (default = cores)" },
        FlagSpec { name: "processes", value: "N", help: "serve: shard-owning worker processes (default 0 = in-process store)" },
        FlagSpec { name: "write-buf-kb", value: "N", help: "serve: per-connection write-buffer cap in KiB before a non-reading client is disconnected (default 8192, min 256)" },
        FlagSpec { name: "memstore-budget-mb", value: "MB", help: "serve: memstore budget in MiB; 0 (default) = pure memory, N > 0 spills cold shards to disk runs under data-dir (tiered store)" },
        FlagSpec { name: "durable-dir", value: "DIR", help: "serve: WAL + snapshot directory; enables crash recovery (default off)" },
        FlagSpec { name: "fsync", value: "BOOL", help: "serve: fsync every group commit (default true; false = kernel flush only)" },
        FlagSpec { name: "snapshot-every", value: "SECS", help: "serve: checkpoint interval in seconds (default 60; 0 = off)" },
        FlagSpec { name: "snapshot-wal-mb", value: "MB", help: "serve: checkpoint when the WAL exceeds MB MiB (default 64; 0 = off)" },
        FlagSpec { name: "replicate-listen", value: "ADDR", help: "serve: primary — bind ADDR and ship the WAL to standbys (requires --durable-dir)" },
        FlagSpec { name: "standby-of", value: "ADDR", help: "serve: hot standby of the primary at ADDR (its --replicate-listen; requires --durable-dir)" },
        FlagSpec { name: "failover-after", value: "MS", help: "serve: standby promotes to primary after MS ms without a heartbeat (default 3000)" },
        FlagSpec { name: "writeback", value: "", help: "persist memstore back to disk after update" },
        FlagSpec { name: "json", value: "", help: "emit machine-readable JSON report" },
        FlagSpec { name: "help", value: "", help: "show this help" },
    ]
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<(), String> {
    // Hidden worker-process entrypoint (see ipc::leader) — must be handled
    // before normal flag parsing.
    {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        if raw.first().map(|s| s.as_str()) == Some("ipc-worker") {
            let sock = raw
                .iter()
                .position(|a| a == "--socket")
                .and_then(|i| raw.get(i + 1))
                .ok_or("ipc-worker requires --socket <path>")?;
            return membig::ipc::worker_main(sock);
        }
    }
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(raw, &spec()).map_err(|e| e.to_string())?;
    let cmd = args.positional(0).unwrap_or("help").to_string();
    if args.has("help") || cmd == "help" {
        print!(
            "{}",
            Args::usage(
                "membig <gen|run|conventional|compare|analytics|serve|info>",
                "membig — memory-based multi-processing engine (Bassil 2019 reproduction)",
                &spec()
            )
        );
        return Ok(());
    }

    let cfg = build_config(&args)?;
    let records = args.get_count("records").map_err(|e| e.to_string())?.unwrap_or(2_000_000);
    let updates = args.get_count("updates").map_err(|e| e.to_string())?.unwrap_or(records);
    let dataset = DatasetSpec { records, seed: cfg.seed, ..Default::default() };
    let wb = Workbench::new(&cfg.data_dir, dataset.clone());

    match cmd.as_str() {
        "gen" => {
            let t = wb.ensure_table(&cfg).map_err(|e| e.to_string())?;
            let stock = wb.ensure_stock(updates).map_err(|e| e.to_string())?;
            println!("table: {} ({} records)", wb.table_dir().display(), commas(t.len()));
            println!("stock: {} ({} updates)", stock.display(), commas(updates));
            Ok(())
        }
        "run" => {
            let coord = Coordinator::new(cfg.clone());
            let table = wb.ensure_table(&cfg).map_err(|e| e.to_string())?;
            let stock = wb.ensure_stock(updates).map_err(|e| e.to_string())?;
            let out = coord.run_proposed(&table, &stock).map_err(|e| e.to_string())?;
            println!("proposed app: {} records, {} updates applied", commas(out.records),
                commas(out.stream.updates_applied));
            println!("  load      {}", human_duration(out.load));
            println!("  update    {}", human_duration(out.update));
            if cfg.writeback {
                println!("  writeback {}", human_duration(out.writeback));
            }
            println!("  inventory value: ${:.2}", out.inventory_value_cents as f64 / 100.0);
            if args.has("json") {
                println!("{}", coord.metrics.to_json().to_string_pretty());
            } else {
                print!("{}", coord.metrics.render());
            }
            Ok(())
        }
        "conventional" => {
            let coord = Coordinator::new(cfg.clone());
            let table = wb.ensure_table(&cfg).map_err(|e| e.to_string())?;
            let stock = wb.ensure_stock(updates).map_err(|e| e.to_string())?;
            let rep = coord.run_conventional(&table, &stock).map_err(|e| e.to_string())?;
            println!(
                "conventional app: {} applied; wall {} | modeled (full-scale disk) {}",
                commas(rep.updates_applied),
                human_duration(rep.wall),
                paper_hms(rep.modeled)
            );
            Ok(())
        }
        "compare" => {
            let row = compare_once(&cfg, &wb, updates)?;
            println!("{}", render_table1(std::slice::from_ref(&row)));
            println!("{}", render_figure6(std::slice::from_ref(&row)));
            if args.has("json") {
                println!("{}", row.to_json().to_string_pretty());
            }
            Ok(())
        }
        "analytics" => {
            let coord = Coordinator::new(cfg.clone());
            let table = wb.ensure_table(&cfg).map_err(|e| e.to_string())?;
            let store = coord.load_only(&table).map_err(|e| e.to_string())?;
            let svc = start_analytics(&cfg, args.get("backend"))?
                .ok_or("analytics needs a backend (got --backend off)")?;
            println!("analytics backend: {}", svc.backend_name());
            let result = svc.analytics_for_store(store, Vec::new())?;
            println!(
                "inventory: count={} value=${:.2} mean=${:.4} min=${:.2} max=${:.2} (exec {})",
                commas(result.stats.count),
                result.stats.total_value,
                result.stats.mean_price,
                result.stats.price_min,
                result.stats.price_max,
                human_duration(result.exec_time)
            );
            println!("price histogram ($0.50 bins): {:?}", result.histogram);
            Ok(())
        }
        "serve" => {
            // Deterministic storage-fault injection (`faultcheck` builds):
            // parse MEMBIG_IO_FAULTS before any persistent path opens so a
            // malformed plan fails loud instead of silently injecting
            // nothing. Default builds compile the shim to a passthrough.
            membig::util::iofault::init_from_env()
                .map_err(|e| format!("MEMBIG_IO_FAULTS: {e}"))?;
            if std::env::var_os("MEMBIG_IO_FAULTS").is_some() && !cfg!(feature = "faultcheck") {
                eprintln!(
                    "membig: MEMBIG_IO_FAULTS is set but this binary was built without \
                     the `faultcheck` feature — no faults will be injected"
                );
            }
            preflight_serve(&cfg)?;
            // Arm the SIGTERM/SIGINT latch before any state is built so a
            // signal during a slow load/recovery still drains cleanly once
            // the serve loop starts polling.
            membig::server::install_shutdown_handler()
                .map_err(|e| format!("signal handler: {e}"))?;
            if cfg.server_processes > 0 {
                return serve_processes(&cfg, &wb);
            }
            if let Some(primary) = cfg.standby_of.clone() {
                return serve_standby(&cfg, primary, &args);
            }
            // With --durable-dir: recover `snapshot + WAL chain` when the
            // directory has state, else seed it from the workbench table;
            // every acknowledged mutation is then WAL-logged before its OK.
            // (Budget × durability is rejected at config build, so the
            // tiered branch below only ever pairs with persist = None.)
            let (store, persist): (Arc<dyn StorageEngine>, Option<Arc<Persistence>>) = match cfg
                .durable_dir
                .clone()
            {
                Some(dir) => {
                    let opts = DurabilityOptions {
                        fsync: cfg.fsync,
                        snapshot_every: std::time::Duration::from_secs(cfg.snapshot_every_secs),
                        snapshot_wal_bytes: cfg.snapshot_wal_mb.saturating_mul(1 << 20),
                    };
                    let seed_cfg = cfg.clone();
                    let seed_wb = &wb;
                    let (store, persist, report) =
                        Persistence::open(&dir, opts, cfg.shards, move || {
                            let coord = Coordinator::new(seed_cfg.clone());
                            let table = seed_wb.ensure_table(&seed_cfg).map_err(|e| e.to_string())?;
                            coord.load_only(&table).map_err(|e| e.to_string())
                        })
                        .map_err(|e| e.to_string())?;
                    if report.fresh {
                        println!(
                            "durability: initialized {} (snapshot of {} records, fsync={})",
                            dir.display(),
                            commas(report.snapshot_records),
                            cfg.fsync
                        );
                    } else {
                        println!(
                            "durability: recovered {} — snapshot gen {} ({} records) + {} WAL \
                             frame(s) across {} segment(s){}",
                            dir.display(),
                            report.snapshot_generation,
                            commas(report.snapshot_records),
                            commas(report.wal_frames),
                            report.chain,
                            if report.torn_tail { " (torn tail dropped)" } else { "" }
                        );
                    }
                    (store, Some(Arc::new(persist)))
                }
                None => {
                    let coord = Coordinator::new(cfg.clone());
                    let table = wb.ensure_table(&cfg).map_err(|e| e.to_string())?;
                    let mem = coord.load_only(&table).map_err(|e| e.to_string())?;
                    if cfg.memstore_budget_mb > 0 {
                        // Larger-than-RAM tier: re-home the loaded records
                        // into a budgeted tiered store — cold shards spill
                        // to immutable runs under <data-dir>/tier as the
                        // budget is exceeded during this load.
                        let opts = TieredOptions {
                            budget_bytes: cfg.memstore_budget_mb << 20,
                            shards: cfg.shards,
                            capacity_hint: cfg.shard_capacity_hint,
                            cache_blocks: cfg.page_cache_pages,
                            ..TieredOptions::default()
                        };
                        let tier = TieredStore::open_clean(cfg.data_dir.join("tier"), opts)
                            .map_err(|e| format!("tiered store: {e}"))?;
                        for s in 0..mem.shard_count() {
                            for r in mem.shard_records(s) {
                                tier.insert(r);
                            }
                        }
                        drop(mem);
                        println!(
                            "tiered store: budget {} MiB — {} resident record(s), {} run(s) \
                             on disk ({} bytes) under {}",
                            cfg.memstore_budget_mb,
                            commas(tier.resident_records()),
                            tier.run_count(),
                            tier.disk_bytes(),
                            cfg.data_dir.join("tier").display()
                        );
                        (Arc::new(tier), None)
                    } else {
                        (mem, None)
                    }
                }
            };
            // Primary-side replication: bind the shipping listener and hook
            // it under the group-commit WAL mutex *before* serving starts,
            // so no committed batch can slip past the shipper unseen.
            let replication = match (&cfg.replicate_listen, &persist) {
                (Some(addr), Some(p)) => {
                    let faults = membig::replication::FaultPlan::from_env()?;
                    let repl = membig::replication::ReplState::primary();
                    let (shipper, ship_addr) = membig::replication::ship::Shipper::listen(
                        addr,
                        p.dir().to_path_buf(),
                        p.wal_tip(),
                        repl.clone(),
                        p.health_handle(),
                        faults,
                    )
                    .map_err(|e| format!("--replicate-listen {addr}: {e}"))?;
                    p.set_commit_sink(shipper.clone());
                    println!("replicating on {ship_addr}");
                    Some((shipper, repl))
                }
                _ => None,
            };
            let engine = start_analytics(&cfg, args.get("backend"))?;
            let mut server_cfg = ServerConfig::default();
            if cfg.server_workers > 0 {
                server_cfg.workers = cfg.server_workers;
            }
            server_cfg.max_conns = cfg.server_max_conns;
            server_cfg.reactors = cfg.server_reactors;
            if cfg.server_write_buf_kb > 0 {
                server_cfg.write_buf_cap = cfg.server_write_buf_kb << 10;
            }
            let reactors_shown = if server_cfg.reactors == 0 {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            } else {
                server_cfg.reactors
            };
            println!(
                "serving {} records on {} (analytics: {}; reactors: {}; blocking workers: {}; \
                 max conns: {}; write buf: {} KiB; durability: {})",
                commas(store.len() as u64),
                cfg.bind,
                engine.as_deref().map(AnalyticsService::backend_name).unwrap_or("disabled"),
                reactors_shown,
                server_cfg.workers,
                server_cfg.max_conns,
                server_cfg.write_buf_cap >> 10,
                if persist.is_some() { "on" } else { "off" }
            );
            let mut server =
                Server::with_persistence(store, engine, server_cfg, persist.clone());
            if let Some((_, repl)) = &replication {
                server.set_replication(repl.clone());
            }
            let handle = server.spawn(&cfg.bind).map_err(|e| e.to_string())?;
            println!("listening on {} — Ctrl-C to stop", handle.addr);
            let seal = match replication {
                Some((shipper, _)) => ReplSeal::Primary(shipper),
                None => ReplSeal::None,
            };
            run_until_shutdown(handle, persist, seal)
        }
        "info" => {
            println!("membig {}", env!("CARGO_PKG_VERSION"));
            println!("cores: {}", std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0));
            println!("threads: {}  shards: {}", cfg.threads, cfg.shards);
            println!("disk model: {:?}", cfg.disk);
            println!("data dir: {}", cfg.data_dir.display());
            println!("artifacts: {}", cfg.artifacts_dir.display());
            #[cfg(feature = "pjrt")]
            match membig::runtime::AnalyticsEngine::load_lazy(&cfg.artifacts_dir) {
                Ok(e) => println!("analytics: pjrt available ({})", e.platform()),
                Err(e) => println!("analytics: pjrt unavailable ({e}); reference backend active"),
            }
            #[cfg(not(feature = "pjrt"))]
            println!("analytics: reference (pure Rust) — rebuild with --features pjrt for XLA");
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try --help)")),
    }
}

/// `serve --processes N`: shared-nothing serving behind the same wire
/// protocol. The leader loads the table once, scatters the records to N
/// spawned worker processes (each owning a disjoint key range), and keeps
/// no store of its own — every data verb becomes an RPC to the owning
/// worker. Mutually exclusive with durability and with the memstore budget
/// (enforced by `EngineConfigBuilder::build`); ANALYTICS is answered with
/// an error since the leader holds no records.
fn serve_processes(cfg: &EngineConfig, wb: &Workbench) -> Result<(), String> {
    let records = {
        let coord = Coordinator::new(cfg.clone());
        let table = wb.ensure_table(cfg).map_err(|e| e.to_string())?;
        let store = coord.load_only(&table).map_err(|e| e.to_string())?;
        let mut records = Vec::with_capacity(store.len());
        store.for_each_shard(|_, recs| records.extend_from_slice(recs));
        records
    };
    let mut pool =
        membig::ipc::ProcessPool::spawn(cfg.server_processes).map_err(|e| e.to_string())?;
    let loaded = pool.load(&records).map_err(|e| e.to_string())?;
    drop(records);
    let serving = Arc::new(pool.into_serving());

    let mut server_cfg = ServerConfig::default();
    if cfg.server_workers > 0 {
        server_cfg.workers = cfg.server_workers;
    }
    server_cfg.max_conns = cfg.server_max_conns;
    server_cfg.reactors = cfg.server_reactors;
    if cfg.server_write_buf_kb > 0 {
        server_cfg.write_buf_cap = cfg.server_write_buf_kb << 10;
    }
    println!(
        "serving {} records on {} across {} worker process(es) (pids: {:?}; analytics: \
         disabled; blocking workers: {}; max conns: {})",
        commas(loaded),
        cfg.bind,
        cfg.server_processes,
        serving.worker_pids(),
        server_cfg.workers,
        server_cfg.max_conns,
    );
    let handle =
        Server::with_procs(serving, server_cfg).spawn(&cfg.bind).map_err(|e| e.to_string())?;
    println!("listening on {} — Ctrl-C to stop", handle.addr);
    run_until_shutdown(handle, None, ReplSeal::None)
}

/// `serve --standby-of HOST:PORT`: mirror the primary's WAL stream into a
/// local durable directory and serve reads from the applied store;
/// mutations answer `ERR readonly standby` until the failover monitor
/// promotes this process (no primary heartbeat for `--failover-after` ms).
fn serve_standby(cfg: &EngineConfig, primary: String, args: &Args) -> Result<(), String> {
    let dir = cfg
        .durable_dir
        .clone()
        .ok_or("--standby-of requires --durable-dir (checked at config build)")?;
    let faults = membig::replication::FaultPlan::from_env()?;
    let repl = membig::replication::ReplState::standby();
    let (store, persist, standby) = membig::replication::apply::start(
        membig::replication::apply::StandbyOpts {
            primary: primary.clone(),
            dir: dir.clone(),
            shards: cfg.shards,
            fsync: cfg.fsync,
            failover_after: std::time::Duration::from_millis(cfg.failover_after_ms),
            faults,
        },
        repl.clone(),
    )
    .map_err(|e| e.to_string())?;
    let engine = start_analytics(cfg, args.get("backend"))?;
    let mut server_cfg = ServerConfig::default();
    if cfg.server_workers > 0 {
        server_cfg.workers = cfg.server_workers;
    }
    server_cfg.max_conns = cfg.server_max_conns;
    server_cfg.reactors = cfg.server_reactors;
    if cfg.server_write_buf_kb > 0 {
        server_cfg.write_buf_cap = cfg.server_write_buf_kb << 10;
    }
    println!(
        "standby: mirroring {} into {} (failover after {} ms, fsync={})",
        primary,
        dir.display(),
        cfg.failover_after_ms,
        cfg.fsync
    );
    let store: Arc<dyn StorageEngine> = store;
    let mut server =
        Server::with_persistence(store, engine, server_cfg, Some(persist.clone()));
    server.set_replication(repl);
    let handle = server.spawn(&cfg.bind).map_err(|e| e.to_string())?;
    println!("listening on {} — Ctrl-C to stop", handle.addr);
    run_until_shutdown(handle, Some(persist), ReplSeal::Standby(standby))
}

/// What to seal when the serve loop drains (replication stops before the
/// final WAL sync so no frame ships after the on-disk tip is frozen).
enum ReplSeal {
    None,
    Primary(Arc<membig::replication::ship::Shipper>),
    Standby(membig::replication::apply::Standby),
}

/// Park until SIGTERM/SIGINT, then tear down in order: stop accepting,
/// seal replication, fsync the WAL, exit 0 — the graceful half of the
/// crash-safety story (`kill -9` exercises the recovery half).
fn run_until_shutdown(
    handle: membig::server::ServerHandle,
    persist: Option<Arc<Persistence>>,
    seal: ReplSeal,
) -> Result<(), String> {
    while !membig::server::shutdown_requested() {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    println!("membig: shutdown signal received — draining");
    handle.shutdown();
    match seal {
        ReplSeal::None => {}
        ReplSeal::Primary(s) => s.seal(),
        ReplSeal::Standby(s) => s.seal(),
    }
    if let Some(p) = &persist {
        p.sync().map_err(|e| format!("final WAL sync: {e}"))?;
    }
    println!("membig: clean shutdown");
    Ok(())
}

/// Fail-loud startup probes: catch an unwritable `--durable-dir`, an
/// unbindable `--replicate-listen` or an unresolvable `--standby-of` before
/// any state is built, each with a one-line actionable error. The probe
/// socket/file are released before the real resources open.
fn preflight_serve(cfg: &EngineConfig) -> Result<(), String> {
    if let Some(dir) = &cfg.durable_dir {
        std::fs::create_dir_all(dir).map_err(|e| {
            format!(
                "--durable-dir {}: cannot create: {e} (fix permissions or pick another path)",
                dir.display()
            )
        })?;
        let probe = dir.join(".membig-probe");
        std::fs::write(&probe, b"probe").map_err(|e| {
            format!(
                "--durable-dir {} is not writable: {e} (fix permissions or pick another path)",
                dir.display()
            )
        })?;
        let _ = std::fs::remove_file(&probe);
        warn_if_low_disk(dir, cfg);
    }
    if cfg.memstore_budget_mb > 0 {
        // The tier's spill directory gets the same create + write probe as
        // the durable dir: `--memstore-budget-mb` must fail loud at startup,
        // not at the first spill minutes later.
        let tier = cfg.data_dir.join("tier");
        std::fs::create_dir_all(&tier).map_err(|e| {
            format!(
                "--memstore-budget-mb: cannot create spill directory {}: {e} \
                 (fix permissions or pick another --data-dir)",
                tier.display()
            )
        })?;
        let probe = tier.join(".membig-probe");
        std::fs::write(&probe, b"probe").map_err(|e| {
            format!(
                "--memstore-budget-mb: spill directory {} is not writable: {e} \
                 (fix permissions or pick another --data-dir)",
                tier.display()
            )
        })?;
        let _ = std::fs::remove_file(&probe);
        warn_if_low_disk(&tier, cfg);
    }
    if let Some(addr) = &cfg.replicate_listen {
        // A listener that never accepted leaves no TIME_WAIT state, so the
        // real bind right after this drop cannot collide with the probe.
        std::net::TcpListener::bind(addr.as_str()).map_err(|e| {
            format!(
                "--replicate-listen {addr} is not bindable: {e} \
                 (port in use, or the interface does not exist?)"
            )
        })?;
    }
    if let Some(addr) = &cfg.standby_of {
        use std::net::ToSocketAddrs as _;
        addr.to_socket_addrs().map_err(|e| {
            format!(
                "--standby-of {addr} does not resolve: {e} \
                 (expected the primary's --replicate-listen HOST:PORT)"
            )
        })?;
    }
    Ok(())
}

/// Warn — never fail — when the filesystem under a persistent directory has
/// less free space than the server plausibly needs soon: two WAL checkpoint
/// windows (`2 × --snapshot-wal-mb`), floored at 64 MiB. Advisory only:
/// ENOSPC at run time degrades gracefully (DESIGN.md §16, surfaced by
/// `HEALTH`), but the operator should hear about it before serving starts.
/// Silently skipped where the statfs probe is unavailable.
fn warn_if_low_disk(dir: &std::path::Path, cfg: &EngineConfig) {
    let Some(free) = membig::server::free_disk_bytes(dir) else {
        return;
    };
    let wal_window = cfg.snapshot_wal_mb.saturating_mul(1 << 20).saturating_mul(2);
    let need = wal_window.max(64 << 20);
    if free < need {
        eprintln!(
            "membig: warning: {} has {} MiB free, below the {} MiB advised \
             (2x the WAL checkpoint window) — ENOSPC will pause spills/checkpoints \
             and HEALTH will report degraded",
            dir.display(),
            free >> 20,
            need >> 20
        );
    }
}

/// Resolve the `--backend` flag into a running analytics service.
/// `auto` (default) prefers PJRT when compiled in, else pure-Rust reference;
/// `off` disables the ANALYTICS verb entirely.
fn start_analytics(
    cfg: &EngineConfig,
    backend: Option<&str>,
) -> Result<Option<Arc<AnalyticsService>>, String> {
    match backend.unwrap_or("auto") {
        "off" => Ok(None),
        "reference" => AnalyticsService::start_reference().map(Arc::new).map(Some),
        "pjrt" => AnalyticsService::start(&cfg.artifacts_dir).map(Arc::new).map(Some),
        "auto" => AnalyticsService::start_auto(&cfg.artifacts_dir).map(Arc::new).map(Some),
        other => Err(format!("unknown --backend '{other}' (expected auto|reference|pjrt|off)")),
    }
}

/// Assemble the config through [`EngineConfig::builder`]: INI layer first,
/// CLI overrides on top, every invariant checked once in `build()`.
fn build_config(args: &Args) -> Result<EngineConfig, String> {
    let mut b = EngineConfig::builder();
    if let Some(path) = args.get("config") {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        b = b.apply_ini(&parse_ini(&text)?)?;
    }
    if let Some(t) = args.get_parsed::<usize>("threads").map_err(|e| e.to_string())? {
        b = b.threads(t).shards(t);
    }
    if let Some(s) = args.get_parsed::<usize>("shards").map_err(|e| e.to_string())? {
        b = b.shards(s);
    }
    if let Some(v) = args.get_parsed::<usize>("batch-size").map_err(|e| e.to_string())? {
        b = b.batch_size(v);
    }
    if let Some(d) = args.get("data-dir") {
        b = b.data_dir(d);
    }
    if let Some(d) = args.get("artifacts") {
        b = b.artifacts_dir(d);
    }
    if let Some(s) = args.get_parsed::<u64>("seed").map_err(|e| e.to_string())? {
        b = b.seed(s);
    }
    if let Some(s) = args.get_parsed::<f64>("disk-scale").map_err(|e| e.to_string())? {
        b = b.disk_scale(s);
    }
    if let Some(c) = args.get_parsed::<usize>("cache-pages").map_err(|e| e.to_string())? {
        b = b.page_cache_pages(c);
    }
    if let Some(v) = args.get("bind") {
        b = b.bind(v);
    }
    if let Some(w) = args.get_parsed::<usize>("workers").map_err(|e| e.to_string())? {
        b = b.server_workers(w);
    }
    if let Some(m) = args.get_parsed::<usize>("max-conns").map_err(|e| e.to_string())? {
        b = b.server_max_conns(m);
    }
    if let Some(r) = args.get_parsed::<usize>("reactors").map_err(|e| e.to_string())? {
        b = b.server_reactors(r);
    }
    if let Some(p) = args.get_parsed::<usize>("processes").map_err(|e| e.to_string())? {
        b = b.server_processes(p);
    }
    if let Some(w) = args.get_parsed::<usize>("write-buf-kb").map_err(|e| e.to_string())? {
        b = b.server_write_buf_kb(w);
    }
    if let Some(mb) = args.get_parsed::<u64>("memstore-budget-mb").map_err(|e| e.to_string())? {
        b = b.memstore_budget_mb(mb);
    }
    if let Some(d) = args.get("durable-dir") {
        b = b.durable_dir(if d.is_empty() { None } else { Some(PathBuf::from(d)) });
    }
    if let Some(f) = args.get_parsed::<bool>("fsync").map_err(|e| e.to_string())? {
        b = b.fsync(f);
    }
    if let Some(s) = args.get_parsed::<u64>("snapshot-every").map_err(|e| e.to_string())? {
        b = b.snapshot_every_secs(s);
    }
    if let Some(m) = args.get_parsed::<u64>("snapshot-wal-mb").map_err(|e| e.to_string())? {
        b = b.snapshot_wal_mb(m);
    }
    if let Some(a) = args.get("replicate-listen") {
        b = b.replicate_listen(if a.is_empty() { None } else { Some(a.to_string()) });
    }
    if let Some(a) = args.get("standby-of") {
        b = b.standby_of(if a.is_empty() { None } else { Some(a.to_string()) });
    }
    if let Some(ms) = args.get_parsed::<u64>("failover-after").map_err(|e| e.to_string())? {
        b = b.failover_after_ms(ms);
    }
    if args.has("writeback") {
        b = b.writeback(true);
    }
    b.build()
}

/// One Table-1 cell: run both apps over identical inputs.
fn compare_once(cfg: &EngineConfig, wb: &Workbench, updates: u64) -> Result<RunReport, String> {
    let stock = wb.ensure_stock(updates).map_err(|e| e.to_string())?;

    // Proposed.
    let coord = Coordinator::new(cfg.clone());
    let table = wb.ensure_table(cfg).map_err(|e| e.to_string())?;
    let out = coord.run_proposed(&table, &stock).map_err(|e| e.to_string())?;
    drop(table);

    // Conventional over a fresh table (same content).
    std::fs::remove_dir_all(wb.table_dir()).ok();
    let table = wb.ensure_table(cfg).map_err(|e| e.to_string())?;
    let coord2 = Coordinator::new(cfg.clone());
    let rep = coord2.run_conventional(&table, &stock).map_err(|e| e.to_string())?;

    Ok(RunReport {
        n_updates: updates,
        conventional: rep.modeled,
        conventional_wall: rep.wall,
        proposed: out.load + out.update,
    })
}
