//! The record schema from the paper (`bo_ISBN13`, `bo_price`, `bo_quantity`)
//! with a fixed-width binary encoding used by both the disk store and the
//! in-memory store.
//!
//! Prices are stored as integer cents to keep the stores byte-exact and
//! comparable across the conventional and proposed paths (float drift would
//! make verification flaky); the public API exposes `f64` dollars.

/// One inventory row. 24 bytes on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BookRecord {
    /// 13-digit ISBN as integer key (fits u64).
    pub isbn13: u64,
    /// Price in cents.
    pub price_cents: u64,
    /// Units in stock.
    pub quantity: u32,
}

pub const RECORD_BYTES: usize = 8 + 8 + 4 + 4; // isbn + price + qty + crc

impl BookRecord {
    pub fn new(isbn13: u64, price_cents: u64, quantity: u32) -> Self {
        BookRecord { isbn13, price_cents, quantity }
    }

    pub fn price_dollars(&self) -> f64 {
        self.price_cents as f64 / 100.0
    }

    /// Inventory value of this line item, in cents.
    pub fn value_cents(&self) -> u128 {
        self.price_cents as u128 * self.quantity as u128
    }

    /// Serialize to the fixed 24-byte layout (LE) with a checksum word.
    pub fn encode(&self) -> [u8; RECORD_BYTES] {
        let mut out = [0u8; RECORD_BYTES];
        out[0..8].copy_from_slice(&self.isbn13.to_le_bytes());
        out[8..16].copy_from_slice(&self.price_cents.to_le_bytes());
        out[16..20].copy_from_slice(&self.quantity.to_le_bytes());
        out[20..24].copy_from_slice(&self.checksum().to_le_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Self, DecodeError> {
        if buf.len() < RECORD_BYTES {
            return Err(DecodeError::Truncated(buf.len()));
        }
        let r = BookRecord {
            isbn13: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            price_cents: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            quantity: u32::from_le_bytes(buf[16..20].try_into().unwrap()),
        };
        let crc = u32::from_le_bytes(buf[20..24].try_into().unwrap());
        if crc != r.checksum() {
            return Err(DecodeError::BadChecksum { expected: r.checksum(), found: crc });
        }
        Ok(r)
    }

    /// FNV-1a over the payload — cheap corruption tripwire, not crypto.
    pub fn checksum(&self) -> u32 {
        let mut h: u32 = 0x811c9dc5;
        for b in self
            .isbn13
            .to_le_bytes()
            .iter()
            .chain(self.price_cents.to_le_bytes().iter())
            .chain(self.quantity.to_le_bytes().iter())
        {
            h ^= *b as u32;
            h = h.wrapping_mul(0x01000193);
        }
        h
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum DecodeError {
    Truncated(usize),
    BadChecksum { expected: u32, found: u32 },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated(n) => write!(f, "record truncated: {n} bytes"),
            DecodeError::BadChecksum { expected, found } => {
                write!(f, "record checksum mismatch (expected {expected:#x}, found {found:#x})")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

/// One `Stock.dat` entry: the new price/quantity for an ISBN.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StockUpdate {
    pub isbn13: u64,
    pub new_price_cents: u64,
    pub new_quantity: u32,
}

impl StockUpdate {
    pub fn apply_to(&self, rec: &mut BookRecord) {
        rec.price_cents = self.new_price_cents;
        rec.quantity = self.new_quantity;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let r = BookRecord::new(9_783_652_774_577, 393, 495);
        let e = r.encode();
        assert_eq!(e.len(), RECORD_BYTES);
        assert_eq!(BookRecord::decode(&e).unwrap(), r);
    }

    #[test]
    fn decode_detects_corruption() {
        let r = BookRecord::new(9_780_000_004_381, 116, 91);
        let mut e = r.encode();
        e[9] ^= 0xFF;
        match BookRecord::decode(&e) {
            Err(DecodeError::BadChecksum { .. }) => {}
            other => panic!("expected checksum error, got {other:?}"),
        }
    }

    #[test]
    fn decode_detects_truncation() {
        let r = BookRecord::new(1, 2, 3);
        let e = r.encode();
        assert_eq!(BookRecord::decode(&e[..10]), Err(DecodeError::Truncated(10)));
    }

    #[test]
    fn value_math() {
        let r = BookRecord::new(1, 250, 4); // $2.50 x 4
        assert_eq!(r.value_cents(), 1000);
        assert!((r.price_dollars() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn update_applies() {
        let mut r = BookRecord::new(7, 100, 1);
        StockUpdate { isbn13: 7, new_price_cents: 785, new_quantity: 267 }.apply_to(&mut r);
        assert_eq!(r.price_cents, 785);
        assert_eq!(r.quantity, 267);
        assert_eq!(r.isbn13, 7);
    }
}
