//! ISBN-13 generation and validation.
//!
//! An ISBN-13 is 12 digits plus a check digit: with digits d1..d13,
//! Σ d_i * w_i ≡ 0 (mod 10) where w alternates 1,3,1,3,... The paper's
//! dataset uses the 978 bookland prefix (see Figure 3 samples).

use crate::util::rng::Rng;

/// Compute the ISBN-13 check digit for the first 12 digits.
pub fn check_digit(d12: &[u8; 12]) -> u8 {
    let mut sum = 0u32;
    for (i, &d) in d12.iter().enumerate() {
        debug_assert!(d < 10);
        let w = if i % 2 == 0 { 1 } else { 3 };
        sum += d as u32 * w;
    }
    ((10 - (sum % 10)) % 10) as u8
}

/// Validate a 13-digit numeric ISBN (as integer).
pub fn is_valid(isbn: u64) -> bool {
    if isbn < 9_780_000_000_000 || isbn > 9_799_999_999_999 {
        // Bookland prefixes are 978/979; the paper uses 978.
        return false;
    }
    let mut digits = [0u8; 13];
    let mut v = isbn;
    for i in (0..13).rev() {
        digits[i] = (v % 10) as u8;
        v /= 10;
    }
    let d12: [u8; 12] = digits[..12].try_into().unwrap();
    check_digit(&d12) == digits[12]
}

/// Construct a valid ISBN-13 from a 9-digit "body" (deterministic mapping
/// used so dataset keys are unique and reproducible): 978 + body(9) + check.
pub fn from_body(body: u32) -> u64 {
    debug_assert!(body < 1_000_000_000);
    let mut d = [0u8; 12];
    d[0] = 9;
    d[1] = 7;
    d[2] = 8;
    let mut b = body as u64;
    for i in (3..12).rev() {
        d[i] = (b % 10) as u8;
        b /= 10;
    }
    let cd = check_digit(&d);
    let mut v: u64 = 0;
    for digit in d {
        v = v * 10 + digit as u64;
    }
    v * 10 + cd as u64
}

/// Random valid ISBN-13 (uniform over 10^9 bodies).
pub fn random(rng: &mut Rng) -> u64 {
    from_body(rng.gen_range(1_000_000_000) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_check_digits() {
        // 978-0-306-40615-? => 7 (canonical Wikipedia example)
        let d: [u8; 12] = [9, 7, 8, 0, 3, 0, 6, 4, 0, 6, 1, 5];
        assert_eq!(check_digit(&d), 7);
        assert!(is_valid(9_780_306_406_157));
        assert!(!is_valid(9_780_306_406_158));
    }

    #[test]
    fn from_body_always_valid_and_injective() {
        let mut seen = std::collections::HashSet::new();
        for body in (0..1_000_000u32).step_by(997) {
            let isbn = from_body(body);
            assert!(is_valid(isbn), "body={body} isbn={isbn}");
            assert!(seen.insert(isbn), "collision at body={body}");
        }
    }

    #[test]
    fn random_isbns_valid() {
        let mut rng = Rng::new(42);
        for _ in 0..1000 {
            assert!(is_valid(random(&mut rng)));
        }
    }

    #[test]
    fn rejects_non_bookland() {
        assert!(!is_valid(1_234_567_890_123));
        assert!(!is_valid(0));
    }
}
