//! Mixed-operation workload traces for the server example and ablation
//! benches — extends the paper's pure-update workload with reads and scans
//! so the one-server architecture (§4.3) can be exercised under realistic
//! request mixes.

use super::gen::DatasetSpec;
use super::record::StockUpdate;
use crate::util::rng::{Rng, Zipf};

/// One request in a trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    /// Point lookup by key.
    Get(u64),
    /// Apply a stock update.
    Update(StockUpdate),
    /// Aggregate over the whole store (total inventory value).
    Stats,
}

/// Operation mix (fractions sum to 1.0; Stats gets the remainder).
#[derive(Debug, Clone, Copy)]
pub struct Mix {
    pub get: f64,
    pub update: f64,
}

impl Mix {
    pub const READ_HEAVY: Mix = Mix { get: 0.90, update: 0.095 };
    pub const UPDATE_HEAVY: Mix = Mix { get: 0.05, update: 0.945 };
    pub const PAPER: Mix = Mix { get: 0.0, update: 1.0 };
}

/// Generate a trace of `n` ops against `spec`'s key space.
pub fn generate_trace(spec: &DatasetSpec, n: usize, mix: Mix, theta: f64, seed: u64) -> Vec<Op> {
    let mut rng = Rng::new(seed ^ 0x72ACE);
    let zipf = if theta > 0.0 { Some(Zipf::new(spec.records, theta)) } else { None };
    let pick = |rng: &mut Rng| -> u64 {
        let idx = match &zipf {
            Some(z) => z.sample(rng),
            None => rng.gen_range(spec.records),
        };
        spec.record_at(idx).isbn13
    };
    (0..n)
        .map(|_| {
            let roll = rng.next_f64();
            if roll < mix.get {
                Op::Get(pick(&mut rng))
            } else if roll < mix.get + mix.update {
                Op::Update(StockUpdate {
                    isbn13: pick(&mut rng),
                    new_price_cents: rng.gen_range(1000),
                    new_quantity: rng.gen_range(500) as u32,
                })
            } else {
                Op::Stats
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_respects_mix() {
        let spec = DatasetSpec { records: 1000, ..Default::default() };
        let trace = generate_trace(&spec, 50_000, Mix::READ_HEAVY, 0.0, 3);
        let gets = trace.iter().filter(|o| matches!(o, Op::Get(_))).count() as f64;
        let updates = trace.iter().filter(|o| matches!(o, Op::Update(_))).count() as f64;
        let stats = trace.iter().filter(|o| matches!(o, Op::Stats)).count() as f64;
        assert!((gets / 50_000.0 - 0.90).abs() < 0.02);
        assert!((updates / 50_000.0 - 0.095).abs() < 0.02);
        assert!(stats > 0.0);
    }

    #[test]
    fn paper_mix_is_all_updates() {
        let spec = DatasetSpec { records: 100, ..Default::default() };
        let trace = generate_trace(&spec, 1000, Mix::PAPER, 0.0, 3);
        assert!(trace.iter().all(|o| matches!(o, Op::Update(_))));
    }

    #[test]
    fn trace_keys_belong_to_dataset() {
        let spec = DatasetSpec { records: 500, ..Default::default() };
        let keys: std::collections::HashSet<u64> = spec.iter().map(|r| r.isbn13).collect();
        for op in generate_trace(&spec, 2000, Mix::READ_HEAVY, 0.99, 5) {
            match op {
                Op::Get(k) => assert!(keys.contains(&k)),
                Op::Update(u) => assert!(keys.contains(&u.isbn13)),
                Op::Stats => {}
            }
        }
    }
}
