//! `Stock.dat` reader/writer in the paper's exact framing:
//! `9783652774577$3.93$495$` — ISBN, price (dollars, ≤2dp), quantity,
//! each token terminated by `$`, one record per line (Figure 4).
//!
//! The reader is incremental and tolerant: malformed entries are counted and
//! skipped (the pipeline reports `parse_errors`), not fatal.

use std::io::{self, BufRead, BufWriter, Read, Write};
use std::path::Path;

use super::record::StockUpdate;

/// Write updates in paper framing. Returns bytes written.
pub fn write_stock_file(path: impl AsRef<Path>, updates: &[StockUpdate]) -> io::Result<u64> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::with_capacity(1 << 20, f);
    let mut bytes = 0u64;
    let mut line = String::with_capacity(32);
    for u in updates {
        line.clear();
        format_entry(&mut line, u);
        w.write_all(line.as_bytes())?;
        bytes += line.len() as u64;
    }
    w.flush()?;
    Ok(bytes)
}

/// Render one entry incl. trailing newline, e.g. `9783652774577$3.93$495$\n`.
pub fn format_entry(out: &mut String, u: &StockUpdate) {
    use std::fmt::Write as _;
    let dollars = u.new_price_cents / 100;
    let cents = u.new_price_cents % 100;
    if cents == 0 {
        let _ = write!(out, "{}${}${}$\n", u.isbn13, dollars, u.new_quantity);
    } else if cents % 10 == 0 {
        let _ = write!(out, "{}${}.{}${}$\n", u.isbn13, dollars, cents / 10, u.new_quantity);
    } else {
        let _ = write!(out, "{}${}.{:02}${}$\n", u.isbn13, dollars, cents, u.new_quantity);
    }
}

/// Parse one `$`-framed entry (without or with trailing newline).
pub fn parse_entry(line: &str) -> Option<StockUpdate> {
    let line = line.trim_end_matches(['\n', '\r']);
    let mut parts = line.split('$');
    let isbn: u64 = parts.next()?.parse().ok()?;
    let price = parse_price_cents(parts.next()?)?;
    let qty: u32 = parts.next()?.parse().ok()?;
    // Framing requires the trailing '$' → an empty final token.
    if parts.next() != Some("") {
        return None;
    }
    if parts.next().is_some() {
        return None;
    }
    Some(StockUpdate { isbn13: isbn, new_price_cents: price, new_quantity: qty })
}

/// `"3.93"` → 393; `"8.7"` → 870; `"12"` → 1200. Rejects >2dp and junk.
pub fn parse_price_cents(s: &str) -> Option<u64> {
    let (whole, frac) = match s.split_once('.') {
        None => (s, ""),
        Some((w, f)) => (w, f),
    };
    if whole.is_empty() || whole.bytes().any(|b| !b.is_ascii_digit()) {
        return None;
    }
    let cents_part: u64 = match frac.len() {
        0 => 0,
        1 => frac.parse::<u64>().ok()? * 10,
        2 => frac.parse::<u64>().ok()?,
        _ => return None,
    };
    let dollars: u64 = whole.parse().ok()?;
    Some(dollars * 100 + cents_part)
}

/// Streaming reader over a stock file. Yields parsed updates; malformed
/// lines increment `errors` and are skipped.
pub struct StockReader<R: Read> {
    inner: io::BufReader<R>,
    line: String,
    pub errors: u64,
    pub entries: u64,
}

impl StockReader<std::fs::File> {
    pub fn open(path: impl AsRef<Path>) -> io::Result<Self> {
        Ok(Self::new(std::fs::File::open(path)?))
    }
}

impl<R: Read> StockReader<R> {
    pub fn new(r: R) -> Self {
        StockReader {
            inner: io::BufReader::with_capacity(1 << 20, r),
            line: String::with_capacity(64),
            errors: 0,
            entries: 0,
        }
    }

    /// Read the next well-formed update, skipping malformed lines.
    pub fn next_update(&mut self) -> io::Result<Option<StockUpdate>> {
        loop {
            self.line.clear();
            let n = self.inner.read_line(&mut self.line)?;
            if n == 0 {
                return Ok(None);
            }
            if self.line.trim().is_empty() {
                continue;
            }
            match parse_entry(&self.line) {
                Some(u) => {
                    self.entries += 1;
                    return Ok(Some(u));
                }
                None => self.errors += 1,
            }
        }
    }

    /// Fill `buf` with up to `buf.capacity()` updates. Returns false at EOF.
    pub fn next_batch(&mut self, buf: &mut Vec<StockUpdate>, max: usize) -> io::Result<bool> {
        buf.clear();
        while buf.len() < max {
            match self.next_update()? {
                Some(u) => buf.push(u),
                None => return Ok(!buf.is_empty()),
            }
        }
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn u(isbn: u64, cents: u64, qty: u32) -> StockUpdate {
        StockUpdate { isbn13: isbn, new_price_cents: cents, new_quantity: qty }
    }

    #[test]
    fn paper_sample_formats() {
        // From Figure 4 of the paper.
        assert_eq!(
            parse_entry("9783652774577$3.93$495$"),
            Some(u(9_783_652_774_577, 393, 495))
        );
        assert_eq!(parse_entry("9787021212112$8.7$94$"), Some(u(9_787_021_212_112, 870, 94)));
        assert_eq!(parse_entry("9782478416305$9.69$4$"), Some(u(9_782_478_416_305, 969, 4)));
    }

    #[test]
    fn format_parse_roundtrip() {
        let cases =
            [u(9_783_652_774_577, 393, 495), u(1, 0, 0), u(42, 870, 94), u(7, 1200, 500), u(9, 5, 1)];
        for c in cases {
            let mut s = String::new();
            format_entry(&mut s, &c);
            assert_eq!(parse_entry(&s), Some(c), "entry {s:?}");
        }
    }

    #[test]
    fn price_parsing() {
        assert_eq!(parse_price_cents("3.93"), Some(393));
        assert_eq!(parse_price_cents("8.7"), Some(870));
        assert_eq!(parse_price_cents("12"), Some(1200));
        assert_eq!(parse_price_cents("0.05"), Some(5));
        assert_eq!(parse_price_cents("1.234"), None);
        assert_eq!(parse_price_cents(""), None);
        assert_eq!(parse_price_cents("x.y"), None);
        assert_eq!(parse_price_cents("3."), Some(300));
    }

    #[test]
    fn malformed_entries_rejected() {
        assert_eq!(parse_entry("no-dollars-here"), None);
        assert_eq!(parse_entry("123$4.5"), None); // missing qty + frame
        assert_eq!(parse_entry("123$4.5$6"), None); // missing trailing $
        assert_eq!(parse_entry("123$4.5$6$extra$"), None);
        assert_eq!(parse_entry("$1$2$"), None);
    }

    #[test]
    fn reader_skips_bad_lines_and_counts() {
        let data = "9783652774577$3.93$495$\ngarbage\n9787021212112$8.7$94$\n\n";
        let mut r = StockReader::new(data.as_bytes());
        let a = r.next_update().unwrap().unwrap();
        assert_eq!(a.isbn13, 9_783_652_774_577);
        let b = r.next_update().unwrap().unwrap();
        assert_eq!(b.new_price_cents, 870);
        assert!(r.next_update().unwrap().is_none());
        assert_eq!(r.errors, 1);
        assert_eq!(r.entries, 2);
    }

    #[test]
    fn batching() {
        let mut data = String::new();
        for i in 0..10 {
            format_entry(&mut data, &u(9_780_000_000_000 + i, 100 + i, i as u32));
        }
        let mut r = StockReader::new(data.as_bytes());
        let mut buf = Vec::new();
        let mut total = 0;
        while r.next_batch(&mut buf, 3).unwrap() {
            assert!(buf.len() <= 3);
            total += buf.len();
            if buf.len() < 3 {
                break;
            }
        }
        assert_eq!(total, 10);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("membig_stock_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stock.dat");
        let updates: Vec<StockUpdate> = (0..100).map(|i| u(crate::workload::isbn::from_body(i), (i as u64 * 7) % 1000, i)).collect();
        write_stock_file(&path, &updates).unwrap();
        let mut r = StockReader::open(&path).unwrap();
        let mut back = Vec::new();
        while let Some(x) = r.next_update().unwrap() {
            back.push(x);
        }
        assert_eq!(back, updates);
        assert_eq!(r.errors, 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
