//! Dataset + update-feed generators reproducing the paper's experimental
//! inputs: a 2M-row book inventory (uniform prices $0–10, quantities 0–500,
//! matching Figures 3–4's value ranges) and a stock file whose keys hit the
//! database (the paper updates *existing* records).

use super::isbn;
use super::record::{BookRecord, StockUpdate};
use crate::util::rng::{Rng, Zipf};

/// Parameters for dataset generation.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Number of inventory rows.
    pub records: u64,
    /// RNG seed (dataset is fully determined by spec).
    pub seed: u64,
    /// Max price in cents (exclusive). Paper samples show $0.31–$9.69.
    pub max_price_cents: u64,
    /// Max quantity (exclusive). Paper samples show 4–499.
    pub max_quantity: u32,
}

impl Default for DatasetSpec {
    fn default() -> Self {
        DatasetSpec { records: 2_000_000, seed: 0xB00C, max_price_cents: 1000, max_quantity: 500 }
    }
}

impl DatasetSpec {
    pub fn with_records(records: u64) -> Self {
        DatasetSpec { records, ..Default::default() }
    }

    /// The i-th record of the dataset (O(1), no state): keys are a
    /// pseudo-random permutation of ISBN bodies via an affine map over a
    /// prime modulus, so they are unique, valid, and order-scrambled.
    pub fn record_at(&self, i: u64) -> BookRecord {
        debug_assert!(i < self.records);
        // Affine permutation over Z_p restricted to the first `records`
        // values; p > 10^9 would overflow the 9-digit body, so map into
        // [0, 999_999_937) (largest prime < 10^9) and fall back to identity
        // offsets for the tiny tail that maps >= records... Simpler: use a
        // SplitMix keyed by (seed, i) and resolve collisions by salting —
        // but we need determinism AND uniqueness without a global set, so
        // we use the affine permutation over the prime and accept bodies in
        // [0, p). Uniqueness: affine maps are bijective on Z_p.
        const P: u64 = 999_999_937; // prime < 10^9
        let a = 736_338_717 % P; // fixed multiplier, coprime to P (P prime)
        let b = self.seed % P;
        let body = ((i % P).wrapping_mul(a) + b) % P;
        // For i >= P (never in practice: dataset ≤ ~10^8), offset bodies.
        let body = if i >= P { (body + i / P) % P } else { body };
        let key = isbn::from_body(body as u32);
        let mut rng = Rng::new(self.seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        BookRecord {
            isbn13: key,
            price_cents: rng.gen_range(self.max_price_cents),
            quantity: rng.gen_range(self.max_quantity as u64) as u32,
        }
    }

    /// Iterate all records in generation order.
    pub fn iter(&self) -> impl Iterator<Item = BookRecord> + '_ {
        (0..self.records).map(move |i| self.record_at(i))
    }
}

/// Materialize the whole dataset (used for loads; ~24B/record in memory).
pub fn generate_dataset(spec: &DatasetSpec) -> Vec<BookRecord> {
    spec.iter().collect()
}

/// Key-selection distribution for the update feed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KeyDist {
    /// Every key updated exactly once, in shuffled order (the paper's
    /// workload: the stock file carries fresh data for each record).
    PermuteAll,
    /// Uniform random with replacement.
    Uniform,
    /// Zipf-skewed (hot keys) — ablation beyond the paper.
    Zipf(f64),
}

/// Generate `count` stock updates against the dataset keys.
pub fn generate_stock_updates(
    spec: &DatasetSpec,
    count: u64,
    dist: KeyDist,
    seed: u64,
) -> Vec<StockUpdate> {
    let mut rng = Rng::new(seed ^ 0x57AC_F11E);
    let pick_body = |i: u64, rng: &mut Rng| -> u64 {
        match dist {
            KeyDist::PermuteAll => i % spec.records,
            KeyDist::Uniform => rng.gen_range(spec.records),
            KeyDist::Zipf(_) => unreachable!("handled below"),
        }
    };
    let mut out = Vec::with_capacity(count as usize);
    match dist {
        KeyDist::Zipf(theta) => {
            let z = Zipf::new(spec.records, theta);
            for _ in 0..count {
                let idx = z.sample(&mut rng);
                out.push(update_for(spec, idx, &mut rng));
            }
        }
        _ => {
            for i in 0..count {
                let idx = pick_body(i, &mut rng);
                out.push(update_for(spec, idx, &mut rng));
            }
        }
    }
    if dist == KeyDist::PermuteAll {
        rng.shuffle(&mut out);
    }
    out
}

fn update_for(spec: &DatasetSpec, index: u64, rng: &mut Rng) -> StockUpdate {
    let rec = spec.record_at(index);
    StockUpdate {
        isbn13: rec.isbn13,
        new_price_cents: rng.gen_range(spec.max_price_cents),
        new_quantity: rng.gen_range(spec.max_quantity as u64) as u32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_are_unique_and_valid() {
        let spec = DatasetSpec { records: 50_000, ..Default::default() };
        let mut keys = std::collections::HashSet::new();
        for r in spec.iter() {
            assert!(isbn::is_valid(r.isbn13), "invalid isbn {}", r.isbn13);
            assert!(r.price_cents < spec.max_price_cents);
            assert!(r.quantity < spec.max_quantity);
            assert!(keys.insert(r.isbn13), "duplicate key {}", r.isbn13);
        }
        assert_eq!(keys.len(), 50_000);
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = DatasetSpec { records: 1000, ..Default::default() };
        let a = generate_dataset(&spec);
        let b = generate_dataset(&spec);
        assert_eq!(a, b);
        // O(1) access agrees with iteration.
        assert_eq!(spec.record_at(577), a[577]);
    }

    #[test]
    fn different_seed_different_data() {
        let a = DatasetSpec { records: 100, seed: 1, ..Default::default() };
        let b = DatasetSpec { records: 100, seed: 2, ..Default::default() };
        assert_ne!(generate_dataset(&a), generate_dataset(&b));
    }

    #[test]
    fn permute_all_hits_every_key_once() {
        let spec = DatasetSpec { records: 5_000, ..Default::default() };
        let ups = generate_stock_updates(&spec, 5_000, KeyDist::PermuteAll, 7);
        assert_eq!(ups.len(), 5_000);
        let keys: std::collections::HashSet<u64> = ups.iter().map(|u| u.isbn13).collect();
        assert_eq!(keys.len(), 5_000, "each key exactly once");
        let dataset_keys: std::collections::HashSet<u64> =
            spec.iter().map(|r| r.isbn13).collect();
        assert_eq!(keys, dataset_keys, "updates target dataset keys");
    }

    #[test]
    fn uniform_updates_target_dataset() {
        let spec = DatasetSpec { records: 1_000, ..Default::default() };
        let dataset_keys: std::collections::HashSet<u64> =
            spec.iter().map(|r| r.isbn13).collect();
        for u in generate_stock_updates(&spec, 3_000, KeyDist::Uniform, 9) {
            assert!(dataset_keys.contains(&u.isbn13));
            assert!(u.new_price_cents < spec.max_price_cents);
        }
    }

    #[test]
    fn zipf_updates_skew() {
        let spec = DatasetSpec { records: 1_000, ..Default::default() };
        let ups = generate_stock_updates(&spec, 20_000, KeyDist::Zipf(0.99), 11);
        let mut freq = std::collections::HashMap::new();
        for u in &ups {
            *freq.entry(u.isbn13).or_insert(0u64) += 1;
        }
        let max = *freq.values().max().unwrap();
        assert!(max > 200, "hot key should dominate, max={max}");
    }
}
