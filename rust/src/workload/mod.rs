//! Workload generation: the paper's book-inventory dataset and `Stock.dat`
//! update feed, plus key-distribution and trace utilities used by benches.
//!
//! The paper's database is a single table `(bo_ISBN13, bo_price, bo_quantity)`
//! with 2M rows; the stock file holds `ISBN13$price$quantity$` entries
//! (Figures 3–4). We reproduce both formats exactly, with valid ISBN-13
//! check digits.

pub mod gen;
pub mod isbn;
pub mod record;
pub mod stockfile;
pub mod trace;

pub use gen::{DatasetSpec, generate_dataset, generate_stock_updates};
pub use record::{BookRecord, StockUpdate};
