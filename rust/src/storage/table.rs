//! `DiskTable` — the complete disk-resident table: data pagefile + hash
//! index + page cache + meta file. This is the stand-in for the paper's
//! MS-Access database: the conventional baseline runs its per-record
//! read-modify-write loop directly against this structure, and the proposed
//! method bulk-loads from it into the memstore.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::cache::{CacheStats, PageCache};
use super::index::{HashIndex, IndexError, Slot};
use super::latency::{AccessKind, DiskSim};
use super::page::SLOTS_PER_PAGE;
use super::pagefile::{PageFile, PageFileError};
use crate::workload::record::BookRecord;

#[derive(Debug)]
pub enum TableError {
    Io(std::io::Error),
    PageFile(PageFileError),
    Index(IndexError),
    Page(super::page::PageError),
    NotFound(u64),
    Duplicate(u64),
    Meta(String),
}

impl std::fmt::Display for TableError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TableError::Io(e) => write!(f, "io: {e}"),
            TableError::PageFile(e) => write!(f, "pagefile: {e}"),
            TableError::Index(e) => write!(f, "index: {e}"),
            TableError::Page(e) => write!(f, "page: {e}"),
            TableError::NotFound(k) => write!(f, "key {k} not found"),
            TableError::Duplicate(k) => write!(f, "duplicate key {k}"),
            TableError::Meta(e) => write!(f, "meta file corrupt: {e}"),
        }
    }
}

impl std::error::Error for TableError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TableError::Io(e) => Some(e),
            TableError::PageFile(e) => Some(e),
            TableError::Index(e) => Some(e),
            TableError::Page(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for TableError {
    fn from(e: std::io::Error) -> Self {
        TableError::Io(e)
    }
}

impl From<PageFileError> for TableError {
    fn from(e: PageFileError) -> Self {
        TableError::PageFile(e)
    }
}

impl From<IndexError> for TableError {
    fn from(e: IndexError) -> Self {
        TableError::Index(e)
    }
}

impl From<super::page::PageError> for TableError {
    fn from(e: super::page::PageError) -> Self {
        TableError::Page(e)
    }
}

/// Options controlling a table's physical behaviour.
#[derive(Debug, Clone)]
pub struct TableOptions {
    /// Page-cache capacity in pages.
    pub cache_pages: usize,
    /// Charge the per-op engine overhead (MS-Access tax) on keyed ops.
    pub engine_overhead: bool,
}

impl Default for TableOptions {
    fn default() -> Self {
        TableOptions { cache_pages: 256, engine_overhead: true }
    }
}

pub struct DiskTable {
    dir: PathBuf,
    cache: PageCache,
    index: HashIndex,
    sim: Arc<DiskSim>,
    opts: TableOptions,
    records: u64,
}

impl DiskTable {
    /// Bulk-create a table from records (sequential load, like building the
    /// paper's Access database once before the experiments).
    pub fn create(
        dir: impl AsRef<Path>,
        records: impl Iterator<Item = BookRecord>,
        expected: u64,
        sim: Arc<DiskSim>,
        opts: TableOptions,
    ) -> Result<Self, TableError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let data = Arc::new(PageFile::create(dir.join("data.mbt"), sim.clone())?);
        let index = HashIndex::create(dir.join("index.mbi"), expected, sim.clone())?;
        let cache = PageCache::new(data, opts.cache_pages);

        let mut count = 0u64;
        let mut cur_page: Option<u32> = None;
        for rec in records {
            let page_id = match cur_page {
                Some(id) => id,
                None => {
                    let id = cache.alloc_page()?;
                    cur_page = Some(id);
                    id
                }
            };
            let (slot, full) = cache.with_page_mut(page_id, |p| {
                let s = p.insert(&rec).expect("fresh page cannot be full");
                (s, p.is_full())
            })?;
            index.insert(rec.isbn13, Slot { page: page_id, slot: slot as u16 })?;
            if full {
                cur_page = None;
            }
            count += 1;
        }
        cache.flush()?;
        index.sync()?;

        let t = DiskTable { dir, cache, index, sim, opts, records: count };
        t.write_meta()?;
        Ok(t)
    }

    /// Open an existing table directory.
    pub fn open(
        dir: impl AsRef<Path>,
        sim: Arc<DiskSim>,
        opts: TableOptions,
    ) -> Result<Self, TableError> {
        let dir = dir.as_ref().to_path_buf();
        let meta = std::fs::read_to_string(dir.join("meta.mbm"))?;
        let mut records = None;
        let mut buckets = None;
        for line in meta.lines() {
            match line.split_once('=') {
                Some(("records", v)) => records = v.trim().parse().ok(),
                Some(("buckets", v)) => buckets = v.trim().parse().ok(),
                _ => {}
            }
        }
        let records = records.ok_or_else(|| TableError::Meta("missing records".into()))?;
        let buckets = buckets.ok_or_else(|| TableError::Meta("missing buckets".into()))?;
        let data = Arc::new(PageFile::open(dir.join("data.mbt"), sim.clone())?);
        let index = HashIndex::open(dir.join("index.mbi"), buckets, sim.clone())?;
        let cache = PageCache::new(data, opts.cache_pages);
        Ok(DiskTable { dir, cache, index, sim, opts, records })
    }

    fn write_meta(&self) -> Result<(), TableError> {
        std::fs::write(
            self.dir.join("meta.mbm"),
            format!("records={}\nbuckets={}\n", self.records, self.index.buckets()),
        )?;
        Ok(())
    }

    pub fn len(&self) -> u64 {
        self.records
    }

    pub fn is_empty(&self) -> bool {
        self.records == 0
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn sim(&self) -> &Arc<DiskSim> {
        &self.sim
    }

    fn engine_tax(&self) {
        if self.opts.engine_overhead {
            self.sim.charge(AccessKind::Overhead, 0);
        }
    }

    /// Keyed point read: index probe + data page read.
    pub fn get(&self, key: u64) -> Result<BookRecord, TableError> {
        self.engine_tax();
        let loc = self.index.get(key)?.ok_or(TableError::NotFound(key))?;
        let rec = self.cache.with_page(loc.page, |p| p.read_slot(loc.slot as usize))??;
        debug_assert_eq!(rec.isbn13, key);
        Ok(rec)
    }

    /// Keyed read-modify-write — the conventional app's inner loop.
    pub fn update(
        &self,
        key: u64,
        f: impl FnOnce(&mut BookRecord),
    ) -> Result<BookRecord, TableError> {
        self.engine_tax();
        let loc = self.index.get(key)?.ok_or(TableError::NotFound(key))?;
        let rec = self.cache.with_page_mut(loc.page, |p| -> Result<BookRecord, TableError> {
            let mut rec = p.read_slot(loc.slot as usize)?;
            f(&mut rec);
            p.overwrite_slot(loc.slot as usize, &rec)?;
            Ok(rec)
        })??;
        Ok(rec)
    }

    /// Insert a new record (appends to the last page or allocates).
    pub fn insert(&mut self, rec: BookRecord) -> Result<(), TableError> {
        self.engine_tax();
        if self.index.get(rec.isbn13)?.is_some() {
            return Err(TableError::Duplicate(rec.isbn13));
        }
        // Try the last data page; allocate a new one if absent/full.
        let n = self.cache.file().page_count();
        let target = if n > 0 {
            let last = n - 1;
            let has_room = self.cache.with_page(last, |p| !p.is_full())?;
            if has_room {
                Some(last)
            } else {
                None
            }
        } else {
            None
        };
        let page_id = match target {
            Some(id) => id,
            None => self.cache.alloc_page()?,
        };
        let slot = self
            .cache
            .with_page_mut(page_id, |p| p.insert(&rec))?
            .map_err(PageFileError::from)?;
        self.index.insert(rec.isbn13, Slot { page: page_id, slot: slot as u16 })?;
        self.records += 1;
        self.write_meta()?;
        Ok(())
    }

    /// Full sequential scan (streams pages in order — cheap on the model).
    pub fn scan(&self, mut f: impl FnMut(&BookRecord)) -> Result<u64, TableError> {
        let n = self.cache.file().page_count();
        let mut seen = 0u64;
        for id in 0..n {
            self.cache.with_page(id, |p| {
                for (_, rec) in p.records() {
                    f(&rec);
                    seen += 1;
                }
            })?;
        }
        Ok(seen)
    }

    /// Rewrite the table in page order: for each live record, `f` returns
    /// the new value (or `None` to keep it). One sequential pass, no index
    /// probes — the fast writeback path (EXPERIMENTS.md §Perf P2). Returns
    /// the number of records rewritten.
    pub fn rewrite_all(
        &self,
        mut f: impl FnMut(&BookRecord) -> Option<BookRecord>,
    ) -> Result<u64, TableError> {
        let n = self.cache.file().page_count();
        let mut written = 0u64;
        for id in 0..n {
            self.cache.with_page_mut(id, |p| -> Result<(), TableError> {
                let slots: Vec<(usize, BookRecord)> = p.records().collect();
                for (slot, rec) in slots {
                    if let Some(new) = f(&rec) {
                        debug_assert_eq!(new.isbn13, rec.isbn13, "rewrite must keep keys");
                        if new != rec {
                            p.overwrite_slot(slot, &new)?;
                        }
                        written += 1;
                    }
                }
                Ok(())
            })??;
        }
        self.flush()?;
        Ok(written)
    }

    /// Flush dirty pages + index.
    pub fn flush(&self) -> Result<(), TableError> {
        self.cache.flush()?;
        self.index.sync()?;
        Ok(())
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Expected number of data pages for `n` records.
    pub fn pages_for(n: u64) -> u64 {
        n.div_ceil(SLOTS_PER_PAGE as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::latency::DiskProfile;
    use crate::workload::gen::DatasetSpec;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("membig_table_{}", std::process::id()))
            .join(name);
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn nosim() -> Arc<DiskSim> {
        Arc::new(DiskSim::new(DiskProfile::none()))
    }

    #[test]
    fn create_get_update_scan() {
        let spec = DatasetSpec { records: 2_000, ..Default::default() };
        let t = DiskTable::create(tdir("basic"), spec.iter(), 2_000, nosim(), TableOptions::default())
            .unwrap();
        assert_eq!(t.len(), 2_000);

        let r100 = spec.record_at(100);
        assert_eq!(t.get(r100.isbn13).unwrap(), r100);

        let updated = t
            .update(r100.isbn13, |r| {
                r.price_cents = 777;
                r.quantity = 42;
            })
            .unwrap();
        assert_eq!(updated.price_cents, 777);
        assert_eq!(t.get(r100.isbn13).unwrap().quantity, 42);

        let mut count = 0u64;
        let mut value: u128 = 0;
        t.scan(|r| {
            count += 1;
            value += r.value_cents();
        })
        .unwrap();
        assert_eq!(count, 2_000);
        assert!(value > 0);
    }

    #[test]
    fn missing_key_errors() {
        let spec = DatasetSpec { records: 10, ..Default::default() };
        let t = DiskTable::create(tdir("missing"), spec.iter(), 10, nosim(), TableOptions::default())
            .unwrap();
        assert!(matches!(t.get(1234), Err(TableError::NotFound(1234))));
        assert!(matches!(t.update(1234, |_| ()), Err(TableError::NotFound(1234))));
    }

    #[test]
    fn insert_and_duplicate() {
        let spec = DatasetSpec { records: 200, ..Default::default() };
        let mut t =
            DiskTable::create(tdir("insert"), spec.iter(), 200, nosim(), TableOptions::default())
                .unwrap();
        let new = BookRecord::new(9_790_000_000_000, 999, 7);
        t.insert(new).unwrap();
        assert_eq!(t.len(), 201);
        assert_eq!(t.get(new.isbn13).unwrap(), new);
        assert!(matches!(t.insert(new), Err(TableError::Duplicate(_))));
    }

    #[test]
    fn reopen_after_flush() {
        let dir = tdir("reopen");
        let spec = DatasetSpec { records: 500, ..Default::default() };
        {
            let t = DiskTable::create(&dir, spec.iter(), 500, nosim(), TableOptions::default())
                .unwrap();
            t.update(spec.record_at(3).isbn13, |r| r.quantity = 99).unwrap();
            t.flush().unwrap();
        }
        let t = DiskTable::open(&dir, nosim(), TableOptions::default()).unwrap();
        assert_eq!(t.len(), 500);
        assert_eq!(t.get(spec.record_at(3).isbn13).unwrap().quantity, 99);
        assert_eq!(t.get(spec.record_at(499).isbn13).unwrap(), spec.record_at(499));
    }

    #[test]
    fn random_update_costs_dominate_scan_costs() {
        // The microfoundation of Table 1: keyed RMW is mechanically
        // expensive; sequential scan is cheap per record.
        let spec = DatasetSpec { records: 5_000, ..Default::default() };
        let sim = Arc::new(DiskSim::new(DiskProfile::default()));
        let t = DiskTable::create(
            tdir("costs"),
            spec.iter(),
            5_000,
            sim.clone(),
            TableOptions { cache_pages: 4, engine_overhead: true },
        )
        .unwrap();
        sim.reset();
        for i in (0..5_000).step_by(50) {
            t.update(spec.record_at(i).isbn13, |r| r.quantity ^= 1).unwrap();
        }
        let per_update = sim.modeled().as_secs_f64() / 100.0;
        sim.reset();
        t.scan(|_| {}).unwrap();
        let per_scan_rec = sim.modeled().as_secs_f64() / 5_000.0;
        assert!(
            per_update > 0.02,
            "keyed RMW should cost ≥20ms modeled, got {per_update}s"
        );
        assert!(
            per_update > 100.0 * per_scan_rec,
            "RMW {per_update}s vs scan/rec {per_scan_rec}s"
        );
    }

    #[test]
    fn pages_for_math() {
        assert_eq!(DiskTable::pages_for(0), 0);
        assert_eq!(DiskTable::pages_for(1), 1);
        assert_eq!(DiskTable::pages_for(SLOTS_PER_PAGE as u64), 1);
        assert_eq!(DiskTable::pages_for(SLOTS_PER_PAGE as u64 + 1), 2);
    }
}
