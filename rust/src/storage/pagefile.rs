//! Page-granular file I/O with positional reads/writes.
//!
//! This is the raw device layer under the page cache: it does *real* file
//! I/O (so the store is durable and restart-safe) and charges the disk
//! latency model per access. Sequential-vs-random is detected from the last
//! accessed page id, mirroring how a real head only seeks when displaced.

use std::fs::{File, OpenOptions};
use std::io;
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

use super::latency::{AccessKind, DiskSim};
use super::page::{Page, PageError, PAGE_SIZE};

#[derive(Debug)]
pub enum PageFileError {
    Io(io::Error),
    OutOfRange(u32, u32),
    Page(PageError),
}

impl std::fmt::Display for PageFileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PageFileError::Io(e) => write!(f, "io: {e}"),
            PageFileError::OutOfRange(id, n) => {
                write!(f, "page {id} out of range (file has {n} pages)")
            }
            PageFileError::Page(e) => write!(f, "page: {e}"),
        }
    }
}

impl std::error::Error for PageFileError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PageFileError::Io(e) => Some(e),
            PageFileError::Page(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for PageFileError {
    fn from(e: io::Error) -> Self {
        PageFileError::Io(e)
    }
}

impl From<PageError> for PageFileError {
    fn from(e: PageError) -> Self {
        PageFileError::Page(e)
    }
}

pub struct PageFile {
    file: File,
    pages: AtomicU32,
    last_page: AtomicU64, // u64::MAX = no history
    sim: Arc<DiskSim>,
    pub reads: AtomicU64,
    pub writes: AtomicU64,
}

impl PageFile {
    pub fn create(path: impl AsRef<Path>, sim: Arc<DiskSim>) -> Result<Self, PageFileError> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(PageFile {
            file,
            pages: AtomicU32::new(0),
            last_page: AtomicU64::new(u64::MAX),
            sim,
            reads: AtomicU64::new(0),
            writes: AtomicU64::new(0),
        })
    }

    pub fn open(path: impl AsRef<Path>, sim: Arc<DiskSim>) -> Result<Self, PageFileError> {
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        let len = file.metadata()?.len();
        let pages = (len / PAGE_SIZE as u64) as u32;
        Ok(PageFile {
            file,
            pages: AtomicU32::new(pages),
            last_page: AtomicU64::new(u64::MAX),
            sim,
            reads: AtomicU64::new(0),
            writes: AtomicU64::new(0),
        })
    }

    pub fn page_count(&self) -> u32 {
        self.pages.load(Ordering::Acquire)
    }

    /// Whether accessing `id` continues the previous access (no seek).
    fn access_kind(&self, id: u32) -> AccessKind {
        let prev = self.last_page.swap(id as u64, Ordering::Relaxed);
        if prev != u64::MAX && (id as u64 == prev + 1 || id as u64 == prev) {
            AccessKind::Sequential
        } else {
            AccessKind::Random
        }
    }

    /// Read page `id` (charges the latency model).
    pub fn read_page(&self, id: u32) -> Result<Page, PageFileError> {
        let n = self.page_count();
        if id >= n {
            return Err(PageFileError::OutOfRange(id, n));
        }
        self.sim.charge(self.access_kind(id), PAGE_SIZE);
        self.reads.fetch_add(1, Ordering::Relaxed);
        let mut buf = [0u8; PAGE_SIZE];
        self.file.read_exact_at(&mut buf, id as u64 * PAGE_SIZE as u64)?;
        Ok(Page::from_bytes(buf)?)
    }

    /// Write page `id` in place (charges the latency model).
    pub fn write_page(&self, page: &Page) -> Result<(), PageFileError> {
        let id = page.id();
        let n = self.page_count();
        if id >= n {
            return Err(PageFileError::OutOfRange(id, n));
        }
        self.sim.charge(self.access_kind(id), PAGE_SIZE);
        self.writes.fetch_add(1, Ordering::Relaxed);
        self.file.write_all_at(&page.buf[..], id as u64 * PAGE_SIZE as u64)?;
        Ok(())
    }

    /// Append a fresh page; returns its id. Appends are sequential.
    pub fn alloc_page(&self) -> Result<(u32, Page), PageFileError> {
        let id = self.pages.fetch_add(1, Ordering::AcqRel);
        let page = Page::new(id);
        self.sim.charge(AccessKind::Sequential, PAGE_SIZE);
        self.writes.fetch_add(1, Ordering::Relaxed);
        self.file.write_all_at(&page.buf[..], id as u64 * PAGE_SIZE as u64)?;
        self.last_page.store(id as u64, Ordering::Relaxed);
        Ok((id, page))
    }

    pub fn sync(&self) -> Result<(), PageFileError> {
        self.file.sync_data()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::latency::DiskProfile;
    use crate::workload::record::BookRecord;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("membig_pf_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sim() -> Arc<DiskSim> {
        Arc::new(DiskSim::new(DiskProfile::none()))
    }

    #[test]
    fn alloc_write_read_roundtrip() {
        let pf = PageFile::create(tmp("a.db"), sim()).unwrap();
        let (id0, mut p0) = pf.alloc_page().unwrap();
        assert_eq!(id0, 0);
        p0.insert(&BookRecord::new(11, 22, 33)).unwrap();
        pf.write_page(&p0).unwrap();
        let back = pf.read_page(0).unwrap();
        assert_eq!(back.read_slot(0).unwrap(), BookRecord::new(11, 22, 33));
    }

    #[test]
    fn reopen_preserves_pages() {
        let path = tmp("b.db");
        {
            let pf = PageFile::create(&path, sim()).unwrap();
            for _ in 0..5 {
                pf.alloc_page().unwrap();
            }
            pf.sync().unwrap();
        }
        let pf = PageFile::open(&path, sim()).unwrap();
        assert_eq!(pf.page_count(), 5);
        assert!(pf.read_page(4).is_ok());
    }

    #[test]
    fn out_of_range_rejected() {
        let pf = PageFile::create(tmp("c.db"), sim()).unwrap();
        assert!(matches!(pf.read_page(0), Err(PageFileError::OutOfRange(0, 0))));
    }

    #[test]
    fn latency_model_charged_random_vs_sequential() {
        let s = Arc::new(DiskSim::new(DiskProfile::default()));
        let pf = PageFile::create(tmp("d.db"), s.clone()).unwrap();
        for _ in 0..10 {
            pf.alloc_page().unwrap(); // all sequential appends
        }
        let seq_only = s.modeled();
        // 10 sequential 4KiB transfers at 150MB/s ≈ 273µs total.
        assert!(seq_only < std::time::Duration::from_millis(2), "{seq_only:?}");
        pf.read_page(9).unwrap(); // head is at 9 after append → sequential-ish
        pf.read_page(0).unwrap(); // big jump → random
        let with_random = s.modeled();
        assert!(
            with_random - seq_only > std::time::Duration::from_millis(10),
            "random access must cost ~12.7ms, delta={:?}",
            with_random - seq_only
        );
    }

    #[test]
    fn stats_counted() {
        let pf = PageFile::create(tmp("e.db"), sim()).unwrap();
        let (_, p) = pf.alloc_page().unwrap();
        pf.write_page(&p).unwrap();
        pf.read_page(0).unwrap();
        assert_eq!(pf.reads.load(Ordering::Relaxed), 1);
        assert_eq!(pf.writes.load(Ordering::Relaxed), 2); // alloc + write
    }
}
