//! The storage-engine boundary the server serves through.
//!
//! Until PR 8 every serving path named [`ShardedStore`] directly, so each
//! new storage capability (durability, multi-process, and now the
//! larger-than-RAM tier) had to thread another concrete type through
//! `server::{mod, reactor, fallback, procs}`. [`StorageEngine`] collapses
//! that plumbing into one object-safe trait: the server holds an
//! `Arc<dyn StorageEngine>` and never cares whether records live purely in
//! RAM ([`ShardedStore`]) or spill to disk runs
//! ([`TieredStore`](crate::storage::tiered::TieredStore)).
//!
//! Design notes:
//!
//! - **Object safety.** The trait is used as `Arc<dyn StorageEngine>`
//!   across reactor threads, so every method takes `&self` and
//!   [`StorageEngine::for_each_shard`] takes a `&mut dyn FnMut` instead of
//!   a generic closure.
//! - **Read-path stats stay first-class.** `STATS SERVER` reports the
//!   seqlock retry/fallback counters for *any* engine — a tiered store's
//!   hot set still reads through the PR-4 lock-free path, and regressions
//!   there must stay visible.
//! - **Engine-specific stats ride a suffix.** [`StorageEngine::stats_suffix`]
//!   defaults to empty; the tiered engine appends its `tier_*` counters so
//!   `STATS SERVER` output is byte-identical for the pure-memory engine.

use std::sync::Arc;

use crate::memstore::{ReadPathStats, ShardedStore};
use crate::metrics::HealthMetrics;
use crate::workload::record::{BookRecord, StockUpdate};

/// Uniform record-store interface for the serving paths. Implemented by
/// [`ShardedStore`] (pure memory, the paper's engine) and
/// [`TieredStore`](crate::storage::tiered::TieredStore) (memstore +
/// LSM-style disk runs).
pub trait StorageEngine: Send + Sync {
    /// Point read. May touch disk on a tiered engine — the reactor
    /// classifies GETs as blocking when [`StorageEngine::spill_enabled`].
    fn get(&self, key: u64) -> Option<BookRecord>;

    /// Batched point reads, results in input order (`MGET`).
    fn get_many(&self, keys: &[u64]) -> Vec<Option<BookRecord>>;

    /// Apply one absolute stock update; `false` = no such record (`UPDATE`).
    fn apply(&self, u: &StockUpdate) -> bool;

    /// Apply a batch; duplicates land in input order. Returns
    /// `(applied, missed)` (`MUPDATE`).
    fn apply_many(&self, ups: &[StockUpdate]) -> (u64, u64);

    /// Insert or overwrite one record (bulk load; not a wire verb).
    fn insert(&self, rec: BookRecord);

    /// Logical record count across every tier.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of RAM the engine pins (hot tier only — disk bytes are
    /// reported via [`StorageEngine::stats_suffix`]).
    fn memory_bytes(&self) -> usize;

    /// `(count, Σ price·qty)` over the logical record set (`STATS`).
    fn value_sum_cents(&self) -> (u64, u128);

    /// Number of record groups [`StorageEngine::shard_records`] exposes.
    /// A tiered engine reports one extra trailing group holding its live
    /// disk records.
    fn shard_count(&self) -> usize;

    /// Copy of group `i`'s records (one shard lock at most; the tiered
    /// engine's trailing group is a merged scan of its runs). Groups are
    /// snapshotted independently, so multi-group aggregates can skew under
    /// concurrent writes — same contract as the sharded store itself.
    fn shard_records(&self, i: usize) -> Vec<BookRecord>;

    /// Visit every logical record, grouped by shard (writeback, export,
    /// multi-process bootstrap). A tiered engine appends its live disk
    /// records as one synthetic trailing shard.
    fn for_each_shard(&self, f: &mut dyn FnMut(usize, &[BookRecord])) {
        for i in 0..self.shard_count() {
            f(i, &self.shard_records(i));
        }
    }

    /// Lock-free read-path counters of the hot tier.
    fn read_stats(&self) -> &ReadPathStats;

    /// `true` when point reads can fall through to disk — the reactor then
    /// routes GET/MGET/STATS to the blocking pool, like ANALYTICS.
    fn spill_enabled(&self) -> bool {
        false
    }

    /// Engine-specific `STATS SERVER` suffix (leading space included);
    /// empty for the pure-memory engine.
    fn stats_suffix(&self) -> String {
        String::new()
    }

    /// Storage-health block for engines with their own persistent I/O
    /// (the tiered store). `None` for pure-memory engines — the `HEALTH`
    /// verb then answers from the durability layer or a constant `ok`.
    fn health_metrics(&self) -> Option<&HealthMetrics> {
        None
    }

    /// Join a `STATS RESET` epoch: zero the engine's traffic counters
    /// (read-path retries/fallbacks, tier counters) so two measurement
    /// windows compare cleanly. State gauges stay.
    fn reset_stats_epoch(&self);
}

impl StorageEngine for ShardedStore {
    fn get(&self, key: u64) -> Option<BookRecord> {
        ShardedStore::get(self, key)
    }

    fn get_many(&self, keys: &[u64]) -> Vec<Option<BookRecord>> {
        ShardedStore::get_many(self, keys)
    }

    fn apply(&self, u: &StockUpdate) -> bool {
        ShardedStore::apply(self, u)
    }

    fn apply_many(&self, ups: &[StockUpdate]) -> (u64, u64) {
        ShardedStore::apply_many(self, ups)
    }

    fn insert(&self, rec: BookRecord) {
        ShardedStore::insert(self, rec);
    }

    fn len(&self) -> usize {
        ShardedStore::len(self)
    }

    fn memory_bytes(&self) -> usize {
        ShardedStore::memory_bytes(self)
    }

    fn value_sum_cents(&self) -> (u64, u128) {
        ShardedStore::value_sum_cents(self)
    }

    fn shard_count(&self) -> usize {
        ShardedStore::shard_count(self)
    }

    fn shard_records(&self, i: usize) -> Vec<BookRecord> {
        ShardedStore::shard_records(self, i)
    }

    fn read_stats(&self) -> &ReadPathStats {
        ShardedStore::read_stats(self)
    }

    fn reset_stats_epoch(&self) {
        self.read_stats().retries.reset();
        self.read_stats().fallbacks.reset();
    }
}

/// The one engine-construction site server code may use when it needs a
/// store it will never read (the multi-process front end proxies every
/// point verb to worker processes).
pub fn placeholder_engine() -> Arc<dyn StorageEngine> {
    Arc::new(ShardedStore::new(1, 8))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn up(k: u64, price: u64, qty: u32) -> StockUpdate {
        StockUpdate { isbn13: k, new_price_cents: price, new_quantity: qty }
    }

    #[test]
    fn sharded_store_round_trips_through_the_trait_object() {
        let engine: Arc<dyn StorageEngine> = Arc::new(ShardedStore::new(4, 64));
        for k in 1..=100u64 {
            engine.insert(BookRecord::new(k, 100 + k, k as u32));
        }
        assert_eq!(engine.len(), 100);
        assert!(!engine.is_empty());
        assert!(!engine.spill_enabled());
        assert_eq!(engine.stats_suffix(), "");
        assert!(engine.health_metrics().is_none(), "pure-memory engine has no health block");
        assert_eq!(engine.get(7).unwrap().price_cents, 107);
        assert_eq!(engine.get(101), None);

        assert!(engine.apply(&up(7, 999, 9)));
        assert!(!engine.apply(&up(500, 1, 1)));
        let (applied, missed) = engine.apply_many(&[up(1, 11, 1), up(777, 1, 1)]);
        assert_eq!((applied, missed), (1, 1));

        let got = engine.get_many(&[1, 7, 500]);
        assert_eq!(got[0].unwrap().price_cents, 11);
        assert_eq!(got[1].unwrap().price_cents, 999);
        assert_eq!(got[2], None);

        let (n, _) = engine.value_sum_cents();
        assert_eq!(n, 100);
        assert!(engine.memory_bytes() > 0);

        let mut seen = 0usize;
        engine.for_each_shard(&mut |_, recs| seen += recs.len());
        assert_eq!(seen, 100);

        engine.reset_stats_epoch();
        assert_eq!(engine.read_stats().retries.get(), 0);
    }

    #[test]
    fn placeholder_engine_is_tiny_and_empty() {
        let e = placeholder_engine();
        assert!(e.is_empty());
        assert!(!e.spill_enabled());
    }
}
