//! LRU page cache with dirty tracking (write-back) sitting between the
//! table layer and the [`PageFile`]. Capacity is small by default (the
//! paper's conventional app enjoys no large buffer pool), making the
//! conventional baseline's per-record page faults faithful.

use std::collections::HashMap;
use std::sync::Arc;
use std::sync::Mutex;

use super::page::{Page, PAGE_SIZE};
use super::pagefile::{PageFile, PageFileError};

/// Intrusive doubly-linked LRU over a slab of entries.
struct Entry {
    page_id: u32,
    page: Page,
    dirty: bool,
    prev: usize,
    next: usize,
}

const NIL: usize = usize::MAX;

pub struct PageCache {
    file: Arc<PageFile>,
    inner: Mutex<CacheInner>,
    capacity: usize,
}

struct CacheInner {
    map: HashMap<u32, usize>, // page id -> slab index
    slab: Vec<Entry>,
    free: Vec<usize>,
    head: usize, // most recently used
    tail: usize, // least recently used
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl PageCache {
    pub fn new(file: Arc<PageFile>, capacity: usize) -> Self {
        assert!(capacity > 0);
        PageCache {
            file,
            capacity,
            inner: Mutex::new(CacheInner {
                map: HashMap::with_capacity(capacity),
                slab: Vec::with_capacity(capacity),
                free: Vec::new(),
                head: NIL,
                tail: NIL,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
        }
    }

    pub fn file(&self) -> &Arc<PageFile> {
        &self.file
    }

    /// Read through the cache and apply `f` to the page.
    pub fn with_page<T>(
        &self,
        page_id: u32,
        f: impl FnOnce(&Page) -> T,
    ) -> Result<T, PageFileError> {
        let mut inner = self.inner.lock().unwrap();
        let idx = self.fault_in(&mut inner, page_id)?;
        Ok(f(&inner.slab[idx].page))
    }

    /// Mutate a page through the cache; marks it dirty (write-back).
    pub fn with_page_mut<T>(
        &self,
        page_id: u32,
        f: impl FnOnce(&mut Page) -> T,
    ) -> Result<T, PageFileError> {
        let mut inner = self.inner.lock().unwrap();
        let idx = self.fault_in(&mut inner, page_id)?;
        let e = &mut inner.slab[idx];
        e.dirty = true;
        Ok(f(&mut e.page))
    }

    /// Allocate a fresh page via the file and cache it.
    pub fn alloc_page(&self) -> Result<u32, PageFileError> {
        let (id, page) = self.file.alloc_page()?;
        let mut inner = self.inner.lock().unwrap();
        self.insert_entry(&mut inner, id, page, false)?;
        Ok(id)
    }

    /// Write all dirty pages back and sync the file.
    pub fn flush(&self) -> Result<(), PageFileError> {
        let mut inner = self.inner.lock().unwrap();
        let dirty: Vec<usize> = inner
            .map
            .values()
            .copied()
            .filter(|&i| inner.slab[i].dirty)
            .collect();
        for idx in dirty {
            self.file.write_page(&inner.slab[idx].page)?;
            inner.slab[idx].dirty = false;
        }
        self.file.sync()?;
        Ok(())
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            resident: inner.map.len(),
            capacity: self.capacity,
        }
    }

    // -- internals ---------------------------------------------------------

    fn fault_in(&self, inner: &mut CacheInner, page_id: u32) -> Result<usize, PageFileError> {
        if let Some(&idx) = inner.map.get(&page_id) {
            inner.hits += 1;
            Self::unlink(inner, idx);
            Self::push_front(inner, idx);
            return Ok(idx);
        }
        inner.misses += 1;
        let page = self.file.read_page(page_id)?;
        self.insert_entry(inner, page_id, page, false)
    }

    fn insert_entry(
        &self,
        inner: &mut CacheInner,
        page_id: u32,
        page: Page,
        dirty: bool,
    ) -> Result<usize, PageFileError> {
        if inner.map.len() >= self.capacity {
            self.evict_lru(inner)?;
        }
        let idx = match inner.free.pop() {
            Some(i) => {
                inner.slab[i] = Entry { page_id, page, dirty, prev: NIL, next: NIL };
                i
            }
            None => {
                inner.slab.push(Entry { page_id, page, dirty, prev: NIL, next: NIL });
                inner.slab.len() - 1
            }
        };
        inner.map.insert(page_id, idx);
        Self::push_front(inner, idx);
        Ok(idx)
    }

    fn evict_lru(&self, inner: &mut CacheInner) -> Result<(), PageFileError> {
        let victim = inner.tail;
        debug_assert_ne!(victim, NIL);
        if inner.slab[victim].dirty {
            self.file.write_page(&inner.slab[victim].page)?;
        }
        let pid = inner.slab[victim].page_id;
        Self::unlink(inner, victim);
        inner.map.remove(&pid);
        inner.free.push(victim);
        inner.evictions += 1;
        Ok(())
    }

    fn unlink(inner: &mut CacheInner, idx: usize) {
        let (prev, next) = (inner.slab[idx].prev, inner.slab[idx].next);
        if prev != NIL {
            inner.slab[prev].next = next;
        } else if inner.head == idx {
            inner.head = next;
        }
        if next != NIL {
            inner.slab[next].prev = prev;
        } else if inner.tail == idx {
            inner.tail = prev;
        }
        inner.slab[idx].prev = NIL;
        inner.slab[idx].next = NIL;
    }

    fn push_front(inner: &mut CacheInner, idx: usize) {
        inner.slab[idx].prev = NIL;
        inner.slab[idx].next = inner.head;
        if inner.head != NIL {
            let h = inner.head;
            inner.slab[h].prev = idx;
        }
        inner.head = idx;
        if inner.tail == NIL {
            inner.tail = idx;
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub resident: usize,
    pub capacity: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Bytes of memory a cache of `capacity` pages pins (approx).
pub fn cache_bytes(capacity: usize) -> usize {
    capacity * (PAGE_SIZE + std::mem::size_of::<Entry>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::latency::{DiskProfile, DiskSim};
    use crate::workload::record::BookRecord;

    fn setup(name: &str, cap: usize) -> PageCache {
        let dir = std::env::temp_dir().join(format!("membig_cache_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sim = Arc::new(DiskSim::new(DiskProfile::none()));
        let pf = Arc::new(PageFile::create(dir.join(name), sim).unwrap());
        PageCache::new(pf, cap)
    }

    #[test]
    fn read_through_and_hit() {
        let c = setup("rt.db", 4);
        let id = c.alloc_page().unwrap();
        c.with_page_mut(id, |p| p.insert(&BookRecord::new(1, 2, 3)).unwrap()).unwrap();
        // First read is a hit (page cached from alloc), repeated reads hit.
        for _ in 0..5 {
            let rec = c.with_page(id, |p| p.read_slot(0).unwrap()).unwrap();
            assert_eq!(rec, BookRecord::new(1, 2, 3));
        }
        let s = c.stats();
        assert_eq!(s.misses, 0);
        assert!(s.hits >= 5);
    }

    #[test]
    fn eviction_respects_capacity_and_writes_back() {
        let c = setup("ev.db", 2);
        let ids: Vec<u32> = (0..4).map(|_| c.alloc_page().unwrap()).collect();
        for (i, &id) in ids.iter().enumerate() {
            c.with_page_mut(id, |p| p.insert(&BookRecord::new(i as u64 + 1, 0, 0)).unwrap())
                .unwrap();
        }
        let s = c.stats();
        assert!(s.resident <= 2);
        assert!(s.evictions >= 2);
        // Dirty evicted pages must have been written back: read them again.
        for (i, &id) in ids.iter().enumerate() {
            let rec = c.with_page(id, |p| p.read_slot(0).unwrap()).unwrap();
            assert_eq!(rec.isbn13, i as u64 + 1);
        }
    }

    #[test]
    fn lru_order_keeps_hot_page() {
        let c = setup("lru.db", 2);
        let a = c.alloc_page().unwrap();
        let b = c.alloc_page().unwrap();
        // Touch `a` so `b` is LRU, then fault a third page: `b` must go.
        c.with_page(a, |_| ()).unwrap();
        let d = c.alloc_page().unwrap();
        let before = c.stats().misses;
        c.with_page(a, |_| ()).unwrap(); // hit
        c.with_page(d, |_| ()).unwrap(); // hit
        assert_eq!(c.stats().misses, before);
        c.with_page(b, |_| ()).unwrap(); // miss: was evicted
        assert_eq!(c.stats().misses, before + 1);
    }

    #[test]
    fn flush_persists_dirty_pages() {
        let dir = std::env::temp_dir().join(format!("membig_cache_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fl.db");
        let sim = Arc::new(DiskSim::new(DiskProfile::none()));
        {
            let pf = Arc::new(PageFile::create(&path, sim.clone()).unwrap());
            let c = PageCache::new(pf, 8);
            let id = c.alloc_page().unwrap();
            c.with_page_mut(id, |p| p.insert(&BookRecord::new(42, 7, 9)).unwrap()).unwrap();
            c.flush().unwrap();
        }
        let pf = Arc::new(PageFile::open(&path, sim).unwrap());
        let page = pf.read_page(0).unwrap();
        assert_eq!(page.read_slot(0).unwrap(), BookRecord::new(42, 7, 9));
    }

    #[test]
    fn hit_rate_math() {
        let s = CacheStats { hits: 75, misses: 25, evictions: 0, resident: 1, capacity: 1 };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }
}
