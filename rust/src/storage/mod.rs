//! Disk-backed storage: the conventional baseline store and, since PR 8,
//! the serving engine's larger-than-RAM tier.
//!
//! Two distinct disk subsystems live here:
//!
//! - **The conventional baseline** (`page`/`pagefile`/`index`/`cache`/
//!   `table`/`latency`) — the substrate the paper compares against (an
//!   MS-Access database on a SATA HDD). The store is real: fixed-slot
//!   pages in a data file, an on-disk hash index with overflow chains, and
//!   an LRU page cache. What is *simulated* is the mechanical latency of a
//!   spinning disk ([`latency::DiskProfile`]) — the testbed has no HDD,
//!   and per DESIGN.md §2 the conventional app's cost is dominated by
//!   per-record random I/O. Every uncached page touch charges the model
//!   (and optionally sleeps a scaled-down delay), and the full-scale
//!   modeled time is reported alongside wall-clock so Table 1 can be
//!   regenerated at any `--disk-scale`.
//! - **The serving tier** (`engine`/`tiered`) — the [`StorageEngine`]
//!   boundary the server routes through, and the [`tiered::TieredStore`]
//!   implementation that spills cold shards to immutable disk runs when
//!   the memstore exceeds `--memstore-budget-mb` (DESIGN.md §14).

pub mod cache;
pub mod engine;
pub mod index;
pub mod latency;
pub mod page;
pub mod pagefile;
pub mod table;
pub mod tiered;

pub use engine::StorageEngine;
pub use latency::{DiskProfile, DiskSim};
pub use table::DiskTable;
pub use tiered::{TierError, TieredOptions, TieredStore};
