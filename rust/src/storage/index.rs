//! On-disk static hash index: key → (data page, slot).
//!
//! Layout: the index file holds `buckets` primary pages (page b = bucket b)
//! plus overflow pages appended at the end and chained via a `next` pointer
//! in the page header. Each entry is 16 bytes: key(8) page(4) slot(2)
//! flags(2). This mirrors how a desktop DB engine (the paper's MS Access)
//! resolves a keyed lookup with one or more index page touches before the
//! data page touch — each touch charges the disk latency model.
//!
//! Index page layout (little-endian):
//! ```text
//! [0..4)  magic 0x4D494458 ("MIDX")
//! [4..8)  next overflow page id (u32::MAX = none)
//! [8..12) entry count
//! [12..16) reserved
//! [16..)  entries
//! ```

use std::fs::{File, OpenOptions};
use std::io;
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

use super::latency::{AccessKind, DiskSim};
use super::page::PAGE_SIZE;

const IDX_MAGIC: u32 = 0x4D49_4458;
const HEADER: usize = 16;
const ENTRY_BYTES: usize = 16;
pub const ENTRIES_PER_PAGE: usize = (PAGE_SIZE - HEADER) / ENTRY_BYTES; // 255
const NO_PAGE: u32 = u32::MAX;

#[derive(Debug)]
pub enum IndexError {
    Io(io::Error),
    BadMagic(u32, u32),
    Full,
}

impl std::fmt::Display for IndexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IndexError::Io(e) => write!(f, "io: {e}"),
            IndexError::BadMagic(m, p) => write!(f, "bad index magic {m:#x} at page {p}"),
            IndexError::Full => write!(f, "index full: bucket chain exhausted"),
        }
    }
}

impl std::error::Error for IndexError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            IndexError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for IndexError {
    fn from(e: io::Error) -> Self {
        IndexError::Io(e)
    }
}

/// Location of a record in the data file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slot {
    pub page: u32,
    pub slot: u16,
}

pub struct HashIndex {
    file: File,
    buckets: u32,
    pages: AtomicU32,
    sim: Arc<DiskSim>,
    pub page_reads: AtomicU64,
    pub page_writes: AtomicU64,
}

/// 64-bit fibonacci/multiply-xor hash — same family the memstore uses, so
/// collision behaviour is comparable across the two stores.
#[inline]
pub fn hash_key(key: u64) -> u64 {
    let mut h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^= h >> 32;
    h = h.wrapping_mul(0xD6E8_FEB8_6659_FD93);
    h ^ (h >> 32)
}

struct IdxPage {
    buf: Box<[u8; PAGE_SIZE]>,
}

impl IdxPage {
    fn new() -> Self {
        let mut buf = Box::new([0u8; PAGE_SIZE]);
        buf[0..4].copy_from_slice(&IDX_MAGIC.to_le_bytes());
        buf[4..8].copy_from_slice(&NO_PAGE.to_le_bytes());
        IdxPage { buf }
    }

    fn next(&self) -> u32 {
        u32::from_le_bytes(self.buf[4..8].try_into().unwrap())
    }

    fn set_next(&mut self, n: u32) {
        self.buf[4..8].copy_from_slice(&n.to_le_bytes());
    }

    fn count(&self) -> u32 {
        u32::from_le_bytes(self.buf[8..12].try_into().unwrap())
    }

    fn set_count(&mut self, c: u32) {
        self.buf[8..12].copy_from_slice(&c.to_le_bytes());
    }

    fn entry(&self, i: usize) -> (u64, Slot) {
        let off = HEADER + i * ENTRY_BYTES;
        let key = u64::from_le_bytes(self.buf[off..off + 8].try_into().unwrap());
        let page = u32::from_le_bytes(self.buf[off + 8..off + 12].try_into().unwrap());
        let slot = u16::from_le_bytes(self.buf[off + 12..off + 14].try_into().unwrap());
        (key, Slot { page, slot })
    }

    fn set_entry(&mut self, i: usize, key: u64, loc: Slot) {
        let off = HEADER + i * ENTRY_BYTES;
        self.buf[off..off + 8].copy_from_slice(&key.to_le_bytes());
        self.buf[off + 8..off + 12].copy_from_slice(&loc.page.to_le_bytes());
        self.buf[off + 12..off + 14].copy_from_slice(&loc.slot.to_le_bytes());
        self.buf[off + 14..off + 16].copy_from_slice(&1u16.to_le_bytes());
    }
}

impl HashIndex {
    /// Create an index sized for `expected` keys at ~70% target load.
    pub fn create(
        path: impl AsRef<Path>,
        expected: u64,
        sim: Arc<DiskSim>,
    ) -> Result<Self, IndexError> {
        let buckets =
            ((expected as f64 / (ENTRIES_PER_PAGE as f64 * 0.7)).ceil() as u32).max(1);
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        // Pre-extend with empty bucket pages (sequential write).
        let empty = IdxPage::new();
        for b in 0..buckets {
            file.write_all_at(&empty.buf[..], b as u64 * PAGE_SIZE as u64)?;
        }
        sim.charge(AccessKind::Sequential, buckets as usize * PAGE_SIZE);
        Ok(HashIndex {
            file,
            buckets,
            pages: AtomicU32::new(buckets),
            sim,
            page_reads: AtomicU64::new(0),
            page_writes: AtomicU64::new(0),
        })
    }

    /// Open an existing index; `buckets` must match creation time (stored by
    /// the table's meta file).
    pub fn open(path: impl AsRef<Path>, buckets: u32, sim: Arc<DiskSim>) -> Result<Self, IndexError> {
        let file = OpenOptions::new().read(true).write(true).open(path)?;
        let len = file.metadata()?.len();
        Ok(HashIndex {
            file,
            buckets,
            pages: AtomicU32::new((len / PAGE_SIZE as u64) as u32),
            sim,
            page_reads: AtomicU64::new(0),
            page_writes: AtomicU64::new(0),
        })
    }

    pub fn buckets(&self) -> u32 {
        self.buckets
    }

    fn read_idx_page(&self, id: u32) -> Result<IdxPage, IndexError> {
        self.sim.charge(AccessKind::Random, PAGE_SIZE);
        self.page_reads.fetch_add(1, Ordering::Relaxed);
        let mut p = IdxPage::new();
        self.file.read_exact_at(&mut p.buf[..], id as u64 * PAGE_SIZE as u64)?;
        let magic = u32::from_le_bytes(p.buf[0..4].try_into().unwrap());
        if magic != IDX_MAGIC {
            return Err(IndexError::BadMagic(magic, id));
        }
        Ok(p)
    }

    fn write_idx_page(&self, id: u32, p: &IdxPage) -> Result<(), IndexError> {
        self.sim.charge(AccessKind::Random, PAGE_SIZE);
        self.page_writes.fetch_add(1, Ordering::Relaxed);
        self.file.write_all_at(&p.buf[..], id as u64 * PAGE_SIZE as u64)?;
        Ok(())
    }

    /// Look up a key; returns its data-file location. Charges one index page
    /// read per chain hop.
    pub fn get(&self, key: u64) -> Result<Option<Slot>, IndexError> {
        let mut page_id = (hash_key(key) % self.buckets as u64) as u32;
        loop {
            let p = self.read_idx_page(page_id)?;
            for i in 0..p.count() as usize {
                let (k, loc) = p.entry(i);
                if k == key {
                    return Ok(Some(loc));
                }
            }
            match p.next() {
                NO_PAGE => return Ok(None),
                n => page_id = n,
            }
        }
    }

    /// Insert a (key → slot) mapping; appends overflow pages as needed.
    pub fn insert(&self, key: u64, loc: Slot) -> Result<(), IndexError> {
        let mut page_id = (hash_key(key) % self.buckets as u64) as u32;
        loop {
            let mut p = self.read_idx_page(page_id)?;
            let count = p.count() as usize;
            if count < ENTRIES_PER_PAGE {
                p.set_entry(count, key, loc);
                p.set_count(count as u32 + 1);
                self.write_idx_page(page_id, &p)?;
                return Ok(());
            }
            match p.next() {
                NO_PAGE => {
                    // Append an overflow page and link it.
                    let new_id = self.pages.fetch_add(1, Ordering::AcqRel);
                    let mut np = IdxPage::new();
                    np.set_entry(0, key, loc);
                    np.set_count(1);
                    self.write_idx_page(new_id, &np)?;
                    p.set_next(new_id);
                    self.write_idx_page(page_id, &p)?;
                    return Ok(());
                }
                n => page_id = n,
            }
        }
    }

    /// Mean chain length (diagnostics for benches).
    pub fn chain_stats(&self) -> Result<(f64, u32), IndexError> {
        let mut total_pages = 0u64;
        let mut max_chain = 0u32;
        for b in 0..self.buckets {
            let mut len = 1u32;
            let mut p = self.read_idx_page(b)?;
            while p.next() != NO_PAGE {
                len += 1;
                p = self.read_idx_page(p.next())?;
            }
            total_pages += len as u64;
            max_chain = max_chain.max(len);
        }
        Ok((total_pages as f64 / self.buckets as f64, max_chain))
    }

    pub fn sync(&self) -> Result<(), IndexError> {
        self.file.sync_data()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::latency::DiskProfile;

    fn setup(name: &str, expected: u64) -> HashIndex {
        let dir = std::env::temp_dir().join(format!("membig_idx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sim = Arc::new(DiskSim::new(DiskProfile::none()));
        HashIndex::create(dir.join(name), expected, sim).unwrap()
    }

    #[test]
    fn insert_get_roundtrip() {
        let idx = setup("a.idx", 1000);
        for k in 0..1000u64 {
            idx.insert(k * 7 + 1, Slot { page: (k / 100) as u32, slot: (k % 100) as u16 })
                .unwrap();
        }
        for k in 0..1000u64 {
            let loc = idx.get(k * 7 + 1).unwrap().unwrap();
            assert_eq!(loc, Slot { page: (k / 100) as u32, slot: (k % 100) as u16 });
        }
        assert_eq!(idx.get(999_999).unwrap(), None);
    }

    #[test]
    fn overflow_chains_work() {
        // Force overflow: expected=1 → 1 bucket; insert far more than one
        // page holds.
        let idx = setup("b.idx", 1);
        assert_eq!(idx.buckets(), 1);
        let n = ENTRIES_PER_PAGE as u64 * 3 + 10;
        for k in 0..n {
            idx.insert(k, Slot { page: 0, slot: k as u16 }).unwrap();
        }
        for k in (0..n).step_by(37) {
            assert_eq!(idx.get(k).unwrap(), Some(Slot { page: 0, slot: k as u16 }));
        }
        let (mean, max) = idx.chain_stats().unwrap();
        assert!(max >= 4, "expected ≥4-page chain, got {max}");
        assert!(mean >= 4.0);
    }

    #[test]
    fn sizing_keeps_chains_short() {
        let idx = setup("c.idx", 50_000);
        for k in 0..50_000u64 {
            idx.insert(hash_key(k) | 1, Slot { page: 0, slot: 0 }).unwrap();
        }
        let (mean, max) = idx.chain_stats().unwrap();
        assert!(mean < 1.5, "mean chain {mean}");
        assert!(max <= 3, "max chain {max}");
    }

    #[test]
    fn reopen_preserves_entries() {
        let dir = std::env::temp_dir().join(format!("membig_idx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("d.idx");
        let sim = Arc::new(DiskSim::new(DiskProfile::none()));
        let buckets;
        {
            let idx = HashIndex::create(&path, 500, sim.clone()).unwrap();
            buckets = idx.buckets();
            for k in 0..500u64 {
                idx.insert(k, Slot { page: 1, slot: k as u16 }).unwrap();
            }
            idx.sync().unwrap();
        }
        let idx = HashIndex::open(&path, buckets, sim).unwrap();
        assert_eq!(idx.get(250).unwrap(), Some(Slot { page: 1, slot: 250 }));
    }

    #[test]
    fn lookups_charge_latency() {
        let dir = std::env::temp_dir().join(format!("membig_idx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sim = Arc::new(DiskSim::new(DiskProfile::default()));
        let idx = HashIndex::create(dir.join("e.idx"), 100, sim.clone()).unwrap();
        idx.insert(42, Slot { page: 0, slot: 0 }).unwrap();
        let before = sim.modeled();
        idx.get(42).unwrap();
        let delta = sim.modeled() - before;
        assert!(delta >= std::time::Duration::from_millis(10), "index read must seek: {delta:?}");
    }
}
