//! Larger-than-RAM tier: LSM-style spill + compaction under the memstore.
//!
//! The paper's engine caps the dataset at RAM. [`TieredStore`] lifts that
//! cap behind the [`StorageEngine`] boundary: a [`ShardedStore`] holds the
//! hot set on the PR-4 seqlock read path, and when resident records exceed
//! the configured budget, whole *cold shards* spill into SSTable-style
//! immutable runs on disk. Point reads fall through
//! `memstore → block cache → disk runs (newest-first)`; a background
//! compactor merges runs and garbage-collects dead versions.
//!
//! ## On-disk format
//!
//! Each run `run-<seq>.run` is a sorted, immutable file reusing the
//! snapshot layer's framing discipline: a fixed header, a bloom filter,
//! then `count` records in ascending key order, each encoded with the
//! per-record CRC of [`BookRecord::encode`] (`workload::record`) — the
//! same 24-byte frame the WAL and snapshots use, so a torn or bit-flipped
//! record can never decode.
//!
//! ```text
//! offset  size  field
//! 0       4     magic "MRUN"
//! 4       4     version (u32 LE) = 1
//! 8       8     record count (u64 LE)
//! 16      8     min key
//! 24      8     max key
//! 32      8     bloom filter length in u64 words
//! 40      8     reserved (zero)
//! 48      ..    bloom words, then count × 24-byte CRC-framed records
//! ```
//!
//! ## Run-set manifest
//!
//! The live run set is published through `RUNS.json` with the same
//! tmp + `sync_data` + rename + directory-fsync protocol as the
//! durability layer's `MANIFEST.json`: a crash between writing a run file
//! and publishing the manifest leaves an unlisted file that the next
//! [`TieredStore::open`] garbage-collects; a published manifest always
//! names fully-synced runs, so records served from disk survive a kill
//! (`tests/tiered_kill.rs`).
//!
//! ## Eviction policy
//!
//! Per-shard heat counters (bumped on every routed read) pick the
//! *coldest non-empty shard*; its records are written to a new run while
//! the shard's write guard is held (writers to that one shard stall for
//! the spill, hot shards and lock-free readers elsewhere are untouched),
//! then removed from the memstore. Heat ages by halving on every spill.
//! The budget is enforced on *resident records* (budget bytes ÷ ~32 B of
//! bucket cost per record): the memstore's bucket arrays themselves are
//! hysteretic (they never shrink), so byte-exact accounting against
//! `memory_bytes()` would spill forever.
//!
//! ## Writes to spilled keys
//!
//! `UPDATE`/`MUPDATE` on a key that only lives on disk promotes it: the
//! record is read from the runs, the absolute update applied, and the
//! result inserted back into the memstore (write-back). Newest-first read
//! order makes the disk version stale immediately; compaction drops it.
//!
//! ## WAL interaction
//!
//! The tier is deliberately **mutually exclusive with durability**
//! (`EngineConfig` validation rejects `--durable-dir` + a non-zero
//! budget): the WAL replays into the memstore, and evicting a WAL-covered
//! record would require snapshot-before-evict bookkeeping the tier does
//! not yet have. The run set is still crash-safe as a *cache of the
//! authoritative table* — spilled records survive via the manifest — but
//! un-spilled memstore writes die with the process, exactly like the
//! paper's pure-memory engine. See DESIGN.md §14.

use std::collections::HashMap;
use std::fs::File;
use std::io::{self, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::memstore::ShardedStore;
use crate::metrics::{HealthMetrics, TieredMetrics};
use crate::storage::index::hash_key;
use crate::util::iofault;
use crate::util::json::{self, Json};
use crate::workload::record::{BookRecord, StockUpdate, RECORD_BYTES};

const RUN_MAGIC: &[u8; 4] = b"MRUN";
const RUN_VERSION: u32 = 1;
const RUN_HEADER_BYTES: u64 = 48;
const RUNS_MANIFEST: &str = "RUNS.json";

/// Fault-injection surfaces (`MEMBIG_IO_FAULTS`, DESIGN.md §16).
const RUN_WRITE_SURFACE: &str = "run-write";
const RUN_READ_SURFACE: &str = "run-read";
const RUNS_SURFACE: &str = "runs";

/// How long spills stay paused after a spill failure (ENOSPC or any
/// other write error) before the next mutation retries. During the pause
/// the store serves resident records + existing runs normally; only
/// eviction is held back (`health_tier_spill_stopped`).
const SPILL_RETRY_MS: u64 = 500;

/// Block size of the read-through cache over run files. Records never
/// span more than two blocks (24 B frames, 4 KiB blocks).
const BLOCK_BYTES: u64 = 4096;

/// Bloom sizing: ~10 bits per key, two probes (≈1% false positives).
const BLOOM_BITS_PER_KEY: u64 = 10;

/// Approximate resident RAM per memstore record: a 24-byte bucket slot at
/// 7/8 max load, rounded up for growth slack. Converts the byte budget
/// into the record budget eviction enforces.
const RESIDENT_RECORD_BYTES: u64 = 32;

/// Tunables for [`TieredStore::open`].
#[derive(Debug, Clone)]
pub struct TieredOptions {
    /// Memstore budget in bytes; eviction keeps resident records under
    /// `budget_bytes / 32`.
    pub budget_bytes: u64,
    /// Hot-tier shard count (same meaning as [`ShardedStore::new`]).
    pub shards: usize,
    /// Per-shard capacity hint for the hot tier.
    pub capacity_hint: usize,
    /// Block-cache capacity in 4 KiB blocks.
    pub cache_blocks: usize,
    /// Background compaction triggers at this many runs; `0` disables the
    /// compactor thread (tests drive [`TieredStore::compact_now`]).
    pub compact_at: usize,
}

impl Default for TieredOptions {
    fn default() -> Self {
        TieredOptions {
            budget_bytes: 64 << 20,
            shards: 8,
            capacity_hint: 1024,
            cache_blocks: 256,
            compact_at: 4,
        }
    }
}

/// Errors opening or maintaining the tier directory.
#[derive(Debug)]
pub enum TierError {
    Io(io::Error),
    /// A manifest-listed run failed to load (bad magic/version/size).
    Corrupt(String),
}

impl std::fmt::Display for TierError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TierError::Io(e) => write!(f, "io: {e}"),
            TierError::Corrupt(e) => write!(f, "corrupt tier dir: {e}"),
        }
    }
}

impl std::error::Error for TierError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TierError::Io(e) => Some(e),
            TierError::Corrupt(_) => None,
        }
    }
}

impl From<io::Error> for TierError {
    fn from(e: io::Error) -> Self {
        TierError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// Bloom filter
// ---------------------------------------------------------------------------

/// Fixed-size double-probe bloom over a run's key set. Both probes derive
/// from the one `hash_key` call the read path already makes.
struct Bloom {
    words: Vec<u64>,
}

impl Bloom {
    fn bits(&self) -> u64 {
        self.words.len() as u64 * 64
    }

    fn probes(&self, key: u64) -> (u64, u64) {
        let h = hash_key(key);
        let mask = self.bits() - 1; // bits is a power of two
        (h & mask, h.rotate_right(23) & mask)
    }

    fn build(keys: impl Iterator<Item = u64>, count: u64) -> Bloom {
        let bits = (count.max(1) * BLOOM_BITS_PER_KEY).next_power_of_two().max(64);
        let mut b = Bloom { words: vec![0u64; (bits / 64) as usize] };
        for k in keys {
            let (p1, p2) = b.probes(k);
            b.words[(p1 / 64) as usize] |= 1 << (p1 % 64);
            b.words[(p2 / 64) as usize] |= 1 << (p2 % 64);
        }
        b
    }

    fn maybe_contains(&self, key: u64) -> bool {
        let (p1, p2) = self.probes(key);
        self.words[(p1 / 64) as usize] & (1 << (p1 % 64)) != 0
            && self.words[(p2 / 64) as usize] & (1 << (p2 % 64)) != 0
    }
}

// ---------------------------------------------------------------------------
// Immutable runs
// ---------------------------------------------------------------------------

/// One immutable sorted run on disk. `file` is only used on block-cache
/// misses; the header metadata (key range + bloom) lets point reads skip
/// runs that cannot hold the key without touching the file at all.
pub(crate) struct Run {
    seq: u64,
    path: PathBuf,
    file: Mutex<File>,
    count: u64,
    min_key: u64,
    max_key: u64,
    bloom: Bloom,
    /// Total file size in bytes (disk-usage gauge).
    bytes: u64,
    /// Offset of the record region.
    records_off: u64,
    /// Set after a read I/O error (not a CRC skip): the run is excluded
    /// from point reads and compaction inputs, but stays listed in the
    /// manifest and on disk — the error may be transient, and a restart
    /// re-probes the file (DESIGN.md §16).
    quarantined: AtomicBool,
}

fn run_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("run-{seq}.run"))
}

fn run_file_name(seq: u64) -> String {
    format!("run-{seq}.run")
}

fn parse_run_seq(name: &str) -> Option<u64> {
    name.strip_prefix("run-")?.strip_suffix(".run")?.parse().ok()
}

/// Write `recs` (ascending key order, unique keys) as `run-<seq>.run`
/// under `dir`: tmp file, `sync_data`, rename, then re-open *and
/// validate* the published file before handing it back. The caller
/// publishes the manifest afterwards; a crash in between leaves an
/// unlisted file that `open` garbage-collects. A failed (or torn —
/// caught by the validation) write removes the tmp immediately and
/// never reaches the manifest.
fn write_run(dir: &Path, seq: u64, recs: &[BookRecord]) -> Result<Run, TierError> {
    debug_assert!(recs.windows(2).all(|w| w[0].isbn13 < w[1].isbn13));
    let count = recs.len() as u64;
    let bloom = Bloom::build(recs.iter().map(|r| r.isbn13), count);
    let min_key = recs.first().map(|r| r.isbn13).unwrap_or(0);
    let max_key = recs.last().map(|r| r.isbn13).unwrap_or(0);

    // Header + bloom in one buffer, records in another: two large writes
    // instead of thousands of tiny ones, and two deterministic fault
    // ordinals per run for the `faultcheck` sweep.
    let mut head = Vec::with_capacity(RUN_HEADER_BYTES as usize + bloom.words.len() * 8);
    head.extend_from_slice(RUN_MAGIC);
    head.extend_from_slice(&RUN_VERSION.to_le_bytes());
    head.extend_from_slice(&count.to_le_bytes());
    head.extend_from_slice(&min_key.to_le_bytes());
    head.extend_from_slice(&max_key.to_le_bytes());
    head.extend_from_slice(&(bloom.words.len() as u64).to_le_bytes());
    head.extend_from_slice(&0u64.to_le_bytes()); // reserved
    for w in &bloom.words {
        head.extend_from_slice(&w.to_le_bytes());
    }
    let mut body = Vec::with_capacity(recs.len() * RECORD_BYTES);
    for r in recs {
        body.extend_from_slice(&r.encode());
    }

    let final_path = run_path(dir, seq);
    let tmp = final_path.with_extension("run.tmp");
    let publish = (|| -> io::Result<()> {
        iofault::fail_point(RUN_WRITE_SURFACE)?;
        let mut f = File::create(&tmp)?;
        iofault::write_all(RUN_WRITE_SURFACE, &mut f, &head)?;
        iofault::write_all(RUN_WRITE_SURFACE, &mut f, &body)?;
        iofault::sync_data(RUN_WRITE_SURFACE, &f)?;
        drop(f);
        iofault::rename(RUN_WRITE_SURFACE, &tmp, &final_path)
    })();
    if let Err(e) = publish {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    // Validate what actually landed on disk (size check against the
    // header) instead of trusting our own metadata: a torn write that
    // reported success must fail *here*, before the manifest ever lists
    // the file — the stray is unlisted and GC'd on the next open.
    Run::open(final_path)
}

impl Run {
    /// Open and validate an existing run file: magic, version, and an
    /// exact-size check against the header (truncation guard, mirroring
    /// `durability::snapshot::load_snapshot`). Record payloads are
    /// validated lazily by their per-record CRC on every read.
    fn open(path: PathBuf) -> Result<Run, TierError> {
        let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        let seq = parse_run_seq(&name)
            .ok_or_else(|| TierError::Corrupt(format!("bad run file name: {name}")))?;
        iofault::fail_point(RUN_READ_SURFACE)?;
        let mut file = File::open(&path)?;
        let mut header = [0u8; RUN_HEADER_BYTES as usize];
        file.read_exact(&mut header).map_err(|_| {
            TierError::Corrupt(format!("{name}: shorter than the {RUN_HEADER_BYTES}-byte header"))
        })?;
        if &header[0..4] != RUN_MAGIC {
            return Err(TierError::Corrupt(format!("{name}: bad magic")));
        }
        let version = u32::from_le_bytes(header[4..8].try_into().unwrap_or([0; 4]));
        if version != RUN_VERSION {
            return Err(TierError::Corrupt(format!("{name}: unsupported version {version}")));
        }
        let count = u64::from_le_bytes(header[8..16].try_into().unwrap_or([0; 8]));
        let min_key = u64::from_le_bytes(header[16..24].try_into().unwrap_or([0; 8]));
        let max_key = u64::from_le_bytes(header[24..32].try_into().unwrap_or([0; 8]));
        let bloom_words = u64::from_le_bytes(header[32..40].try_into().unwrap_or([0; 8]));
        // `Bloom::probes` masks with `bits - 1`, so a run with records must
        // carry a power-of-two bloom (`Bloom::build` always writes one);
        // accepting bloom_words == 0 here would underflow the mask and
        // panic on the first lookup.
        if count > 0 && !bloom_words.is_power_of_two() {
            return Err(TierError::Corrupt(format!(
                "{name}: bloom size {bloom_words} words (want a nonzero power of two)"
            )));
        }
        let records_off = bloom_words
            .checked_mul(8)
            .and_then(|b| b.checked_add(RUN_HEADER_BYTES))
            .ok_or_else(|| TierError::Corrupt(format!("{name}: bloom size overflows")))?;
        let expect = count
            .checked_mul(RECORD_BYTES as u64)
            .and_then(|r| r.checked_add(records_off))
            .ok_or_else(|| TierError::Corrupt(format!("{name}: record count overflows")))?;
        let actual = file.metadata()?.len();
        if actual != expect {
            return Err(TierError::Corrupt(format!(
                "{name}: {actual} bytes on disk, header implies {expect}"
            )));
        }
        let mut words = vec![0u64; bloom_words as usize];
        let mut buf = vec![0u8; (bloom_words * 8) as usize];
        file.read_exact(&mut buf)
            .map_err(|_| TierError::Corrupt(format!("{name}: bloom region truncated")))?;
        for (i, w) in words.iter_mut().enumerate() {
            *w = u64::from_le_bytes(buf[i * 8..i * 8 + 8].try_into().unwrap_or([0; 8]));
        }
        Ok(Run {
            seq,
            path,
            file: Mutex::new(file),
            count,
            min_key,
            max_key,
            bloom: Bloom { words },
            bytes: expect,
            records_off,
            quarantined: AtomicBool::new(false),
        })
    }

    /// Read one 4 KiB-aligned block of the record region from disk.
    fn read_block(&self, block: u64) -> io::Result<Vec<u8>> {
        let region = self.count * RECORD_BYTES as u64;
        let start = block * BLOCK_BYTES;
        let len = BLOCK_BYTES.min(region.saturating_sub(start));
        let mut buf = vec![0u8; len as usize];
        // lint:allow(hot-path-panic): a poisoned file mutex means another
        // reader panicked mid-seek; the run is unusable either way.
        let mut f = self.file.lock().unwrap();
        f.seek(SeekFrom::Start(self.records_off + start))?;
        iofault::read_exact(RUN_READ_SURFACE, &mut *f, &mut buf)?;
        Ok(buf)
    }
}

// ---------------------------------------------------------------------------
// Block cache
// ---------------------------------------------------------------------------

/// Read-through LRU block cache shared by every run of one store —
/// the tier's analogue of `storage::cache::PageCache`, but read-only over
/// immutable run files (no dirty tracking, no write-back). Keys are
/// `(run seq, block index)`; run seqs are never reused, so a compacted
/// run's stale blocks simply age out.
struct BlockCache {
    cap: usize,
    inner: Mutex<BlockCacheInner>,
}

struct BlockCacheInner {
    tick: u64,
    map: HashMap<(u64, u64), (u64, Vec<u8>)>,
}

impl BlockCache {
    fn new(cap: usize) -> BlockCache {
        BlockCache {
            cap: cap.max(1),
            inner: Mutex::new(BlockCacheInner { tick: 0, map: HashMap::new() }),
        }
    }

    /// Copy `out.len()` bytes starting at `rel_off` of `run`'s record
    /// region through the cache (a 24-byte frame can straddle two blocks).
    fn read_into(
        &self,
        run: &Run,
        rel_off: u64,
        out: &mut [u8],
        m: &TieredMetrics,
    ) -> io::Result<()> {
        let mut done = 0usize;
        while done < out.len() {
            let abs = rel_off + done as u64;
            let block = abs / BLOCK_BYTES;
            let within = (abs % BLOCK_BYTES) as usize;
            let key = (run.seq, block);
            let mut copied = false;
            {
                // lint:allow(hot-path-panic): cache-mutex poisoning is
                // unrecoverable; propagating it would just move the panic.
                let mut g = self.inner.lock().unwrap();
                g.tick += 1;
                let tick = g.tick;
                if let Some(entry) = g.map.get_mut(&key) {
                    entry.0 = tick;
                    let take = (out.len() - done).min(entry.1.len() - within);
                    out[done..done + take].copy_from_slice(&entry.1[within..within + take]);
                    done += take;
                    copied = true;
                    m.cache_hits.inc();
                }
            }
            if copied {
                continue;
            }
            // Miss: read outside the lock (concurrent misses may duplicate
            // the read — benign for immutable files), then insert.
            m.cache_misses.inc();
            let data = run.read_block(block)?;
            let take = (out.len() - done).min(data.len().saturating_sub(within));
            if take == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "run block shorter than the header-implied record region",
                ));
            }
            out[done..done + take].copy_from_slice(&data[within..within + take]);
            done += take;
            // lint:allow(hot-path-panic): same cache-mutex poisoning case.
            let mut g = self.inner.lock().unwrap();
            g.tick += 1;
            let tick = g.tick;
            if g.map.len() >= self.cap && !g.map.contains_key(&key) {
                if let Some(&victim) = g.map.iter().min_by_key(|(_, v)| v.0).map(|(k, _)| k) {
                    g.map.remove(&victim);
                    m.cache_evictions.inc();
                }
            }
            g.map.insert(key, (tick, data));
        }
        Ok(())
    }
}

impl Run {
    /// Point lookup via binary search over the sorted record region.
    /// `Ok(None)` = key not in this run; `Err` = I/O failure or a record
    /// that failed its CRC (callers count it and fall through to older
    /// runs rather than serving a torn frame).
    fn get(
        &self,
        key: u64,
        cache: &BlockCache,
        m: &TieredMetrics,
    ) -> Result<Option<BookRecord>, TierError> {
        if self.count == 0 || key < self.min_key || key > self.max_key {
            return Ok(None);
        }
        if !self.bloom.maybe_contains(key) {
            return Ok(None);
        }
        let mut lo = 0u64;
        let mut hi = self.count;
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            let rec = self.read_record(mid, cache, m)?;
            if rec.isbn13 < key {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        if lo < self.count {
            let rec = self.read_record(lo, cache, m)?;
            if rec.isbn13 == key {
                return Ok(Some(rec));
            }
        }
        Ok(None)
    }

    fn read_record(
        &self,
        i: u64,
        cache: &BlockCache,
        m: &TieredMetrics,
    ) -> Result<BookRecord, TierError> {
        let mut buf = [0u8; RECORD_BYTES];
        cache.read_into(self, i * RECORD_BYTES as u64, &mut buf, m)?;
        BookRecord::decode(&buf).map_err(|e| {
            m.corrupt_records.inc();
            TierError::Corrupt(format!("{}: record {i}: {e:?}", self.path.display()))
        })
    }
}

// ---------------------------------------------------------------------------
// Run-set manifest
// ---------------------------------------------------------------------------

/// Atomically publish `RUNS.json` (tmp + `sync_data` + rename + directory
/// fsync — the durability layer's manifest protocol). Lists the run set
/// newest-first; every listed file is fully synced before this runs.
fn write_runs_manifest(dir: &Path, next_seq: u64, runs: &[Arc<Run>]) -> io::Result<()> {
    let j = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("next_seq", Json::num(next_seq as f64)),
        (
            "runs",
            Json::arr(runs.iter().map(|r| Json::str(run_file_name(r.seq))).collect()),
        ),
    ]);
    let tmp = dir.join("RUNS.json.tmp");
    let publish = (|| -> io::Result<()> {
        iofault::fail_point(RUNS_SURFACE)?;
        let mut f = File::create(&tmp)?;
        iofault::write_all(RUNS_SURFACE, &mut f, j.to_string_pretty().as_bytes())?;
        iofault::write_all(RUNS_SURFACE, &mut f, b"\n")?;
        iofault::sync_data(RUNS_SURFACE, &f)?;
        drop(f);
        iofault::rename(RUNS_SURFACE, &tmp, &dir.join(RUNS_MANIFEST))
    })();
    if let Err(e) = publish {
        // Never leave the tmp for a later GC sweep to find.
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all(); // directory entry durability (best effort)
    }
    Ok(())
}

/// `(next_seq, run file names newest-first)`, or `None` when absent or
/// unparseable (an empty tier).
fn read_runs_manifest(dir: &Path) -> Option<(u64, Vec<String>)> {
    let text = std::fs::read_to_string(dir.join(RUNS_MANIFEST)).ok()?;
    let j = json::parse(&text).ok()?;
    let next = j.get("next_seq")?.as_f64()?;
    if !next.is_finite() || next < 0.0 {
        return None;
    }
    let names = j
        .get("runs")?
        .as_arr()?
        .iter()
        .map(|r| r.as_str().map(|s| s.to_string()))
        .collect::<Option<Vec<_>>>()?;
    Some((next as u64, names))
}

// ---------------------------------------------------------------------------
// The tiered store
// ---------------------------------------------------------------------------

struct TieredShared {
    mem: ShardedStore,
    dir: PathBuf,
    /// Eviction threshold in resident records (`budget_bytes / 32`).
    budget_records: u64,
    /// Records currently resident in the memstore (maintained by every
    /// mutation path; cheaper than `mem.len()`'s per-shard lock sweep).
    resident: AtomicU64,
    /// Per-shard read heat; coldest shard spills first, halved on spill.
    heat: Vec<AtomicU64>,
    /// Live run set, newest-first. Readers clone the `Arc` and search
    /// without any lock held; writers swap in a new list after the
    /// manifest is published.
    runs: Mutex<Arc<Vec<Arc<Run>>>>,
    next_seq: AtomicU64,
    /// Serializes the structural writers (spill, compaction, flush) so the
    /// newest-first invariant of run seqs can never interleave.
    tier_lock: Mutex<()>,
    cache: BlockCache,
    compact_at: usize,
    metrics: TieredMetrics,
    /// Storage-health block (`HEALTH` verb, `health_*` stats) — the tier
    /// is mutually exclusive with `durability::Persistence`, so it owns
    /// the server's one health instance when configured.
    health: Arc<HealthMetrics>,
    /// Earliest instant the next spill attempt is allowed after a spill
    /// failure (`None` = spills healthy). Guards the degraded-mode pause;
    /// read before taking `tier_lock`.
    spill_retry: Mutex<Option<Instant>>,
    stop: AtomicBool,
}

/// Memstore + disk-run store behind the [`StorageEngine`] API. Construct
/// with [`TieredStore::open`] (recovers the run set from `RUNS.json`) or
/// [`TieredStore::open_clean`] (wipes the tier directory first — the serve
/// path, where the authoritative table is reloaded anyway).
///
/// [`StorageEngine`]: crate::storage::engine::StorageEngine
pub struct TieredStore {
    shared: Arc<TieredShared>,
    compactor: Option<std::thread::JoinHandle<()>>,
}

impl TieredStore {
    pub fn open(dir: impl AsRef<Path>, opts: TieredOptions) -> Result<TieredStore, TierError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;

        let manifest = read_runs_manifest(&dir);
        let manifest_torn = manifest.is_none() && dir.join(RUNS_MANIFEST).exists();
        let (next_seq, listed, runs) = if let Some((next, names)) = manifest {
            // Normal path: every manifest-listed run must load — the
            // publish protocol only ever lists fully-synced, validated
            // files, so a failure here is real damage worth refusing on.
            let mut runs: Vec<Arc<Run>> = Vec::with_capacity(names.len());
            for name in &names {
                runs.push(Arc::new(Run::open(dir.join(name))?));
            }
            (next, names, runs)
        } else if manifest_torn {
            // RUNS.json exists but does not parse (torn write, external
            // damage). The manifest is a hint, not the data: fall back to
            // a directory scan, keep every run that validates, skip+GC
            // the rest, and rewrite the manifest — mirroring how the
            // durability layer survives a corrupt MANIFEST.json.
            eprintln!("membig: RUNS.json unreadable; rebuilding the run set from a directory scan");
            let mut found: Vec<(u64, String)> = match std::fs::read_dir(&dir) {
                Ok(rd) => rd
                    .flatten()
                    .filter_map(|e| {
                        let name = e.file_name().to_string_lossy().into_owned();
                        parse_run_seq(&name).map(|seq| (seq, name))
                    })
                    .collect(),
                Err(_) => Vec::new(),
            };
            found.sort_unstable_by(|a, b| b.0.cmp(&a.0)); // newest-first
            let next = found.first().map(|(s, _)| s + 1).unwrap_or(0);
            let mut names = Vec::with_capacity(found.len());
            let mut runs: Vec<Arc<Run>> = Vec::with_capacity(found.len());
            for (_, name) in found {
                match Run::open(dir.join(&name)) {
                    Ok(r) => {
                        runs.push(Arc::new(r));
                        names.push(name);
                    }
                    Err(e) => {
                        eprintln!("membig: dropping unloadable run {name} during rebuild: {e}");
                        let _ = std::fs::remove_file(dir.join(&name));
                    }
                }
            }
            write_runs_manifest(&dir, next, &runs)?;
            (next, names, runs)
        } else {
            (0, Vec::new(), Vec::new())
        };
        // GC files the manifest does not own: runs written but never
        // published (crash mid-spill), stale tmp files, compacted inputs.
        if let Ok(rd) = std::fs::read_dir(&dir) {
            for e in rd.flatten() {
                let name = e.file_name().to_string_lossy().into_owned();
                let unlisted = parse_run_seq(&name).is_some() && !listed.contains(&name);
                if unlisted || name.ends_with(".tmp") {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }

        let shards = opts.shards.max(1);
        let shared = Arc::new(TieredShared {
            mem: ShardedStore::new(shards, opts.capacity_hint),
            dir,
            budget_records: (opts.budget_bytes / RESIDENT_RECORD_BYTES).max(1),
            resident: AtomicU64::new(0),
            heat: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            runs: Mutex::new(Arc::new(runs)),
            next_seq: AtomicU64::new(next_seq),
            tier_lock: Mutex::new(()),
            cache: BlockCache::new(opts.cache_blocks),
            compact_at: opts.compact_at,
            metrics: TieredMetrics::new(),
            health: Arc::new(HealthMetrics::new()),
            spill_retry: Mutex::new(None),
            stop: AtomicBool::new(false),
        });
        shared.publish_gauges(&shared.runs_snapshot());
        let compactor = spawn_compactor(shared.clone());
        Ok(TieredStore { shared, compactor })
    }

    /// [`TieredStore::open`] after wiping the tier directory — for serving
    /// paths that reload the authoritative dataset at startup and must not
    /// resurrect runs of a previous process.
    pub fn open_clean(
        dir: impl AsRef<Path>,
        opts: TieredOptions,
    ) -> Result<TieredStore, TierError> {
        let _ = std::fs::remove_dir_all(dir.as_ref());
        Self::open(dir, opts)
    }

    /// Tier metrics (also rendered into `STATS SERVER` via
    /// `StorageEngine::stats_suffix`).
    pub fn tiered_metrics(&self) -> &TieredMetrics {
        &self.shared.metrics
    }

    /// Storage-health block for this store (`HEALTH` verb, `health_*`
    /// stats keys).
    pub fn health(&self) -> &HealthMetrics {
        &self.shared.health
    }

    /// Current number of live runs.
    pub fn run_count(&self) -> usize {
        self.shared.runs_snapshot().len()
    }

    /// Bytes across all live run files.
    pub fn disk_bytes(&self) -> u64 {
        self.shared.runs_snapshot().iter().map(|r| r.bytes).sum()
    }

    /// Records currently resident in the hot tier.
    pub fn resident_records(&self) -> u64 {
        self.shared.resident.load(Ordering::Relaxed)
    }

    /// Spill every non-empty shard to disk (tests and benches: force every
    /// record onto the fallthrough path).
    pub fn flush(&self) -> Result<(), TierError> {
        self.shared.flush()
    }

    /// Merge every run into one and drop dead versions, synchronously.
    /// Returns `false` when there was nothing to compact (fewer than two
    /// runs). The background compactor uses the same serialized path.
    pub fn compact_now(&self) -> Result<bool, TierError> {
        self.shared.compact()
    }
}

impl Drop for TieredStore {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        if let Some(j) = self.compactor.take() {
            let _ = j.join();
        }
    }
}

impl TieredShared {
    fn runs_snapshot(&self) -> Arc<Vec<Arc<Run>>> {
        // lint:allow(hot-path-panic): runs-mutex poisoning is unrecoverable.
        self.runs.lock().unwrap().clone()
    }

    /// Current run set minus quarantined runs — what scans may touch
    /// (the point-read path does its own skip).
    fn readable_runs(&self) -> Vec<Arc<Run>> {
        self.runs_snapshot()
            .iter()
            .filter(|r| !r.quarantined.load(Ordering::Relaxed))
            .cloned()
            .collect()
    }

    fn publish_gauges(&self, runs: &[Arc<Run>]) {
        self.metrics.runs.set(runs.len() as i64);
        let bytes: u64 = runs.iter().map(|r| r.bytes).sum();
        self.metrics.disk_bytes.set(bytes.min(i64::MAX as u64) as i64);
        self.metrics
            .resident_records
            .set(self.resident.load(Ordering::Relaxed).min(i64::MAX as u64) as i64);
        let q = runs.iter().filter(|r| r.quarantined.load(Ordering::Relaxed)).count();
        self.metrics.quarantined.set(q as i64);
    }

    /// Point read through the tiers: memstore, then runs newest-first
    /// (key-range + bloom skips, block cache under each probe).
    fn get(&self, key: u64) -> Option<BookRecord> {
        self.heat[self.mem.route(key)].fetch_add(1, Ordering::Relaxed);
        if let Some(r) = self.mem.get(key) {
            self.metrics.mem_hits.inc();
            return Some(r);
        }
        self.fallthrough_get(key)
    }

    /// Search the disk runs only, newest-first. No miss accounting — the
    /// callers decide what a miss means (see [`TieredShared::fallthrough_get`]).
    fn disk_get(&self, key: u64) -> Option<BookRecord> {
        let runs = self.runs_snapshot();
        for run in runs.iter() {
            if run.quarantined.load(Ordering::Relaxed) {
                continue;
            }
            match run.get(key, &self.cache, &self.metrics) {
                Ok(Some(r)) => {
                    self.metrics.disk_hits.inc();
                    return Some(r);
                }
                Ok(None) => {}
                // A CRC-invalid frame must never be served, but the rest of
                // the run is fine — skip just the probe and fall through to
                // older runs for a (stale but valid) version.
                Err(TierError::Corrupt(_)) => self.metrics.disk_errors.inc(),
                // An I/O error (EIO, truncation behind our back) condemns
                // the whole file: quarantine the run so reads stop paying
                // for it, keep its bytes on disk — the error may be
                // transient, and a restart re-probes it (DESIGN.md §16).
                Err(TierError::Io(e)) => {
                    self.metrics.disk_errors.inc();
                    if !run.quarantined.swap(true, Ordering::Relaxed) {
                        self.health.tier_errors.inc();
                        self.publish_gauges(&runs);
                        eprintln!(
                            "membig: quarantining run {} after a read error \
                             (serving older versions): {e}",
                            run.path.display()
                        );
                    }
                }
            }
        }
        None
    }

    /// Disk fallthrough for a key the caller just missed in the memstore:
    /// runs newest-first, then the memstore *again*. The trailing re-check
    /// closes a read race — between the memstore miss and the runs
    /// snapshot, a concurrent write-back promotion can move the key's only
    /// live version back into the memstore and a compaction can then GC
    /// the mem-shadowed disk version; without the re-check a key that
    /// logically existed throughout would read as absent.
    fn fallthrough_get(&self, key: u64) -> Option<BookRecord> {
        if let Some(r) = self.disk_get(key) {
            return Some(r);
        }
        match self.mem.get(key) {
            Some(r) => {
                self.metrics.mem_hits.inc();
                Some(r)
            }
            None => {
                self.metrics.misses.inc();
                None
            }
        }
    }

    fn insert(&self, rec: BookRecord) {
        if self.mem.insert(rec).is_none() {
            self.resident.fetch_add(1, Ordering::Relaxed);
        }
        self.maybe_spill();
    }

    /// Absolute update with write-back promotion: a key found only on disk
    /// is read, updated, and re-inserted into the memstore; the disk
    /// version becomes a dead version for the compactor.
    fn apply(&self, u: &StockUpdate) -> bool {
        if self.mem.apply(u) {
            return true;
        }
        match self.fallthrough_get(u.isbn13) {
            Some(mut r) => {
                u.apply_to(&mut r);
                self.metrics.promotions.inc();
                self.insert(r);
                true
            }
            None => false,
        }
    }

    fn get_many(&self, keys: &[u64]) -> Vec<Option<BookRecord>> {
        for &k in keys {
            self.heat[self.mem.route(k)].fetch_add(1, Ordering::Relaxed);
        }
        let mut out = self.mem.get_many(keys);
        for (i, slot) in out.iter_mut().enumerate() {
            match slot {
                Some(_) => self.metrics.mem_hits.inc(),
                None => *slot = self.fallthrough_get(keys[i]),
            }
        }
        out
    }

    /// Batch update: the memstore's shard-affine bulk path first, then a
    /// per-key promotion pass for exactly the updates it did not apply
    /// (the bulk pass reports per-update outcomes — re-probing `mem.get`
    /// here instead would race with a concurrent spill and double-count).
    /// Input-order last-writer-wins holds across the promotion boundary:
    /// duplicates of a promoted key re-apply in order after the first
    /// promotion.
    fn apply_many(&self, ups: &[StockUpdate]) -> (u64, u64) {
        let mut done = vec![false; ups.len()];
        let (mut applied, bulk_missed) = self.mem.apply_many_tracked(ups, |i| done[i] = true);
        let mut missed = 0u64;
        if bulk_missed > 0 {
            let mut promoted = std::collections::HashSet::new();
            let mut absent = std::collections::HashSet::new();
            for (i, u) in ups.iter().enumerate() {
                if done[i] {
                    continue; // served by the bulk pass
                }
                let k = u.isbn13;
                if promoted.contains(&k) && self.mem.apply(u) {
                    applied += 1;
                    continue;
                }
                if absent.contains(&k) {
                    missed += 1;
                    continue;
                }
                match self.fallthrough_get(k) {
                    Some(mut r) => {
                        u.apply_to(&mut r);
                        self.metrics.promotions.inc();
                        if self.mem.insert(r).is_none() {
                            self.resident.fetch_add(1, Ordering::Relaxed);
                        }
                        promoted.insert(k);
                        applied += 1;
                    }
                    None => {
                        absent.insert(k);
                        missed += 1;
                    }
                }
            }
        }
        self.maybe_spill();
        (applied, missed)
    }

    /// Enforce the resident-record budget: spill coldest shards until
    /// under budget (or nothing spillable remains). A spill failure leaves
    /// the records safely in RAM — over budget, never lossy — and flips
    /// the degraded `tier_spill_stopped` flag: mutations and reads keep
    /// working against resident records + existing runs, and the next
    /// mutation after [`SPILL_RETRY_MS`] retries the spill (an ENOSPC
    /// disk usually stays full for a while; hammering it on every insert
    /// would turn one failure into a log storm).
    fn maybe_spill(&self) {
        while self.resident.load(Ordering::Relaxed) > self.budget_records {
            if self.health.tier_spill_stopped.get() != 0 {
                // lint:allow(hot-path-panic): retry-mutex poisoning is
                // unrecoverable.
                let retry_at = *self.spill_retry.lock().unwrap();
                if let Some(t) = retry_at {
                    if Instant::now() < t {
                        return; // paused; stay over budget until the window closes
                    }
                }
            }
            // lint:allow(hot-path-panic): tier-lock poisoning is unrecoverable.
            let _serialize = self.tier_lock.lock().unwrap();
            if self.resident.load(Ordering::Relaxed) <= self.budget_records {
                return; // another writer spilled while we waited
            }
            match self.spill_coldest() {
                Ok(true) => {}
                Ok(false) => return, // nothing left to spill
                Err(e) => {
                    self.metrics.spill_errors.inc();
                    self.health.tier_errors.inc();
                    self.health.tier_spill_stopped.set(1);
                    // lint:allow(hot-path-panic): retry-mutex poisoning is
                    // unrecoverable.
                    *self.spill_retry.lock().unwrap() =
                        Some(Instant::now() + Duration::from_millis(SPILL_RETRY_MS));
                    eprintln!(
                        "membig: tier spill failed (records stay in RAM; spills paused \
                         {SPILL_RETRY_MS} ms): {e}"
                    );
                    return;
                }
            }
        }
    }

    /// Pick the coldest non-empty shard and spill it. Caller holds
    /// `tier_lock`.
    fn spill_coldest(&self) -> Result<bool, TierError> {
        let sizes = self.mem.shard_sizes();
        let mut pick: Option<(usize, u64, usize)> = None; // (shard, heat, len)
        for (i, &len) in sizes.iter().enumerate() {
            if len == 0 {
                continue;
            }
            let h = self.heat[i].load(Ordering::Relaxed);
            let better = match pick {
                None => true,
                // Colder wins; equal heat → the bigger shard frees more.
                Some((_, ph, plen)) => h < ph || (h == ph && len > plen),
            };
            if better {
                pick = Some((i, h, len));
            }
        }
        let Some((shard, _, _)) = pick else {
            return Ok(false);
        };
        self.spill_shard(shard)?;
        // Age the heat so one hot burst does not pin a shard forever.
        for h in &self.heat {
            h.store(h.load(Ordering::Relaxed) / 2, Ordering::Relaxed);
        }
        self.heat[shard].store(0, Ordering::Relaxed);
        Ok(true)
    }

    /// Write shard `i`'s records to a new run and remove them from the
    /// memstore. The shard's write guard is held across the file write:
    /// writers to this (cold) shard stall for the spill; every other shard
    /// and all lock-free readers elsewhere proceed. Publish order — run
    /// file synced, run list + manifest, then memstore removal — means a
    /// reader that misses the memstore always finds the new run in its
    /// snapshot.
    fn spill_shard(&self, i: usize) -> Result<usize, TierError> {
        let mut guard = self.mem.shard(i);
        let mut recs: Vec<BookRecord> = guard.iter().collect();
        if recs.is_empty() {
            return Ok(0);
        }
        recs.sort_unstable_by_key(|r| r.isbn13);
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let run = Arc::new(write_run(&self.dir, seq, &recs)?);
        {
            // lint:allow(hot-path-panic): runs-mutex poisoning is unrecoverable.
            let mut runs = self.runs.lock().unwrap();
            let mut v: Vec<Arc<Run>> = Vec::with_capacity(runs.len() + 1);
            v.push(run);
            v.extend(runs.iter().cloned());
            let v = Arc::new(v);
            write_runs_manifest(&self.dir, self.next_seq.load(Ordering::Relaxed), &v)?;
            *runs = v;
        }
        for r in &recs {
            guard.remove(r.isbn13);
        }
        drop(guard);
        self.resident.fetch_sub(recs.len() as u64, Ordering::Relaxed);
        self.metrics.spills.inc();
        self.metrics.spilled_records.add(recs.len() as u64);
        // A successful spill ends the degraded pause (disk came back).
        if self.health.tier_spill_stopped.get() != 0 {
            self.health.tier_spill_stopped.set(0);
            // lint:allow(hot-path-panic): retry-mutex poisoning is unrecoverable.
            *self.spill_retry.lock().unwrap() = None;
            eprintln!("membig: tier spill recovered; degraded mode cleared");
        }
        self.publish_gauges(&self.runs_snapshot());
        Ok(recs.len())
    }

    fn flush(&self) -> Result<(), TierError> {
        // lint:allow(hot-path-panic): tier-lock poisoning is unrecoverable.
        let _serialize = self.tier_lock.lock().unwrap();
        for i in 0..self.mem.shard_count() {
            self.spill_shard(i)?;
        }
        Ok(())
    }

    /// Merge every run into one, keeping only the newest disk version of
    /// each key and dropping versions shadowed by the memstore (dead-
    /// version GC — a memstore record is always at least as new as any
    /// disk version of its key, and eviction is serialized with this path
    /// by `tier_lock`). Old run files are unlinked after the new manifest
    /// is live; a crash in between leaves them unlisted for `open`'s GC.
    ///
    /// Any read I/O error aborts the whole compaction *before* the new
    /// manifest is published or any input is unlinked: the runs are the
    /// sole copy of their records (durability is mutually exclusive with
    /// the tier), so publishing a partial merge would silently lose every
    /// record the interrupted scan never reached.
    fn compact(&self) -> Result<bool, TierError> {
        // lint:allow(hot-path-panic): tier-lock poisoning is unrecoverable.
        let _serialize = self.tier_lock.lock().unwrap();
        let old = self.runs_snapshot();
        // Quarantined runs are excluded from the merge inputs (their
        // records cannot be read) but stay listed in the new manifest and
        // keep their files: the read path already skips them, and a
        // restart re-probes them. Merging fewer than two readable runs is
        // pointless.
        let (readable, quarantined): (Vec<Arc<Run>>, Vec<Arc<Run>>) = old
            .iter()
            .cloned()
            .partition(|r| !r.quarantined.load(Ordering::Relaxed));
        if readable.len() < 2 {
            return Ok(false);
        }
        let mut merged: Vec<BookRecord> = Vec::new();
        self.merge_live(&readable, &mut |r| merged.push(r))?;
        let mut v: Vec<Arc<Run>> = Vec::with_capacity(1 + quarantined.len());
        if !merged.is_empty() {
            let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
            v.push(Arc::new(write_run(&self.dir, seq, &merged)?));
        }
        // The merged run carries the highest seq, so listing the
        // quarantined survivors after it preserves newest-first order —
        // and preserves what reads already serve: a key whose newest
        // version sits in a quarantined run was *already* answered from
        // an older run, which is exactly the version the merge kept.
        v.extend(quarantined);
        let new_list = Arc::new(v);
        {
            // lint:allow(hot-path-panic): runs-mutex poisoning is unrecoverable.
            let mut runs = self.runs.lock().unwrap();
            write_runs_manifest(&self.dir, self.next_seq.load(Ordering::Relaxed), &new_list)?;
            *runs = new_list;
        }
        for r in readable.iter() {
            let _ = std::fs::remove_file(&r.path); // best effort; open() GCs
        }
        self.metrics.compactions.inc();
        self.publish_gauges(&self.runs_snapshot());
        Ok(true)
    }

    /// K-way merge over `runs` (newest-first), emitting the newest disk
    /// version of each key that is *not* shadowed by the memstore, in
    /// ascending key order. CRC-corrupt frames are counted and skipped
    /// (they can never be served, and an older run's version of the same
    /// key then wins — matching the read path's fallthrough); an I/O error
    /// aborts the merge so `compact` never publishes a partial result.
    fn merge_live(
        &self,
        runs: &[Arc<Run>],
        f: &mut dyn FnMut(BookRecord),
    ) -> Result<(), TierError> {
        struct Cursor<'a> {
            run: &'a Run,
            idx: u64,
            cur: Option<BookRecord>,
        }
        fn advance(
            c: &mut Cursor<'_>,
            cache: &BlockCache,
            m: &TieredMetrics,
        ) -> Result<(), TierError> {
            c.cur = None;
            while c.idx < c.run.count {
                let i = c.idx;
                c.idx += 1;
                match c.run.read_record(i, cache, m) {
                    Ok(rec) => {
                        c.cur = Some(rec);
                        return Ok(());
                    }
                    Err(e @ TierError::Io(_)) => {
                        // The unreachable tail of this run may hold the
                        // sole copy of still-live keys — the caller must
                        // not treat this merge as complete.
                        m.disk_errors.inc();
                        return Err(e);
                    }
                    Err(TierError::Corrupt(_)) => continue, // counted; skip frame
                }
            }
            Ok(())
        }
        let mut cursors: Vec<Cursor<'_>> = runs
            .iter()
            .map(|r| Cursor { run: r, idx: 0, cur: None })
            .collect();
        for c in cursors.iter_mut() {
            advance(c, &self.cache, &self.metrics)?;
        }
        loop {
            let Some(min_key) =
                cursors.iter().filter_map(|c| c.cur.map(|r| r.isbn13)).min()
            else {
                break;
            };
            // Newest-first list order: the first cursor at min_key wins.
            let mut emit: Option<BookRecord> = None;
            for c in cursors.iter_mut() {
                if c.cur.map(|r| r.isbn13) == Some(min_key) {
                    if emit.is_none() {
                        emit = c.cur;
                    }
                    advance(c, &self.cache, &self.metrics)?;
                }
            }
            if let Some(rec) = emit {
                if self.mem.get(rec.isbn13).is_none() {
                    f(rec);
                }
            }
        }
        Ok(())
    }

    /// `(count, Σ price·qty)` over the logical record set: the memstore
    /// plus every live (unshadowed) disk record. O(dataset) with disk
    /// reads — STATS-class, never on the point-read path. Best-effort on
    /// an I/O error: the aggregate covers what was readable (unlike
    /// `compact`, nothing is deleted based on it).
    fn value_sum_cents(&self) -> (u64, u128) {
        let (mut n, mut sum) = self.mem.value_sum_cents();
        let runs = self.readable_runs();
        let _ = self.merge_live(&runs, &mut |r| {
            n += 1;
            sum += r.value_cents();
        });
        (n, sum)
    }

    fn len(&self) -> usize {
        let mut n = self.mem.len();
        let runs = self.readable_runs();
        let _ = self.merge_live(&runs, &mut |_| n += 1);
        n
    }
}

/// Background compactor: ticks every ~100 ms and merges once the run
/// count reaches `compact_at`. Not spawned when disabled (`compact_at ==
/// 0`); `compact_now` still works.
fn spawn_compactor(shared: Arc<TieredShared>) -> Option<std::thread::JoinHandle<()>> {
    if shared.compact_at == 0 {
        return None;
    }
    std::thread::Builder::new()
        .name("membig-compactor".into())
        .spawn(move || loop {
            for _ in 0..5 {
                if shared.stop.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            // Quarantined runs cannot be merged — counting them would spin
            // the compactor against a merge that always declines.
            let due = shared.readable_runs().len() >= shared.compact_at;
            if due {
                if let Err(e) = shared.compact() {
                    // Not fatal: the pre-compaction run set stays live.
                    shared.metrics.disk_errors.inc();
                    eprintln!("membig: background compaction failed (run set unchanged): {e}");
                }
            }
        })
        .ok()
}

impl crate::storage::engine::StorageEngine for TieredStore {
    fn get(&self, key: u64) -> Option<BookRecord> {
        self.shared.get(key)
    }

    fn get_many(&self, keys: &[u64]) -> Vec<Option<BookRecord>> {
        self.shared.get_many(keys)
    }

    fn apply(&self, u: &StockUpdate) -> bool {
        self.shared.apply(u)
    }

    fn apply_many(&self, ups: &[StockUpdate]) -> (u64, u64) {
        self.shared.apply_many(ups)
    }

    fn insert(&self, rec: BookRecord) {
        self.shared.insert(rec);
    }

    fn len(&self) -> usize {
        self.shared.len()
    }

    fn memory_bytes(&self) -> usize {
        self.shared.mem.memory_bytes()
    }

    fn value_sum_cents(&self) -> (u64, u128) {
        self.shared.value_sum_cents()
    }

    fn shard_count(&self) -> usize {
        // The hot-tier shards plus one trailing group of live disk records.
        self.shared.mem.shard_count() + 1
    }

    fn shard_records(&self, i: usize) -> Vec<BookRecord> {
        if i < self.shared.mem.shard_count() {
            return self.shared.mem.shard_records(i);
        }
        let runs = self.shared.readable_runs();
        let mut disk: Vec<BookRecord> = Vec::new();
        // Best-effort on I/O error: exports see what was readable.
        let _ = self.shared.merge_live(&runs, &mut |r| disk.push(r));
        disk
    }

    fn read_stats(&self) -> &crate::memstore::ReadPathStats {
        self.shared.mem.read_stats()
    }

    fn spill_enabled(&self) -> bool {
        true
    }

    fn stats_suffix(&self) -> String {
        let mut s = self.shared.metrics.stats_suffix();
        s.push_str(&self.shared.health.stats_suffix());
        s
    }

    fn health_metrics(&self) -> Option<&HealthMetrics> {
        Some(&self.shared.health)
    }

    fn reset_stats_epoch(&self) {
        let rs = self.shared.mem.read_stats();
        rs.retries.reset();
        rs.fallbacks.reset();
        self.shared.metrics.reset_epoch_counters();
        self.shared.health.reset_epoch_counters();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::engine::StorageEngine;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("membig_tiered_{}", std::process::id()))
            .join(name);
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn opts(budget_records: u64) -> TieredOptions {
        TieredOptions {
            budget_bytes: budget_records * RESIDENT_RECORD_BYTES,
            shards: 4,
            capacity_hint: 64,
            cache_blocks: 8,
            compact_at: 0, // tests drive compaction explicitly
        }
    }

    fn up(k: u64, price: u64, qty: u32) -> StockUpdate {
        StockUpdate { isbn13: k, new_price_cents: price, new_quantity: qty }
    }

    #[test]
    fn run_roundtrip_with_metadata_skips() {
        let dir = tdir("run_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let recs: Vec<BookRecord> =
            (1..=500u64).map(|k| BookRecord::new(k * 3, 100 + k, k as u32)).collect();
        let m = TieredMetrics::new();
        let cache = BlockCache::new(4);
        let run = write_run(&dir, 7, &recs).unwrap();
        assert_eq!(run.count, 500);
        assert_eq!((run.min_key, run.max_key), (3, 1500));
        for k in (1..=500u64).step_by(17) {
            assert_eq!(run.get(k * 3, &cache, &m).unwrap().unwrap(), recs[k as usize - 1]);
        }
        // Out-of-range and bloom-rejected keys never touch the file.
        let misses_before = m.cache_misses.get();
        assert_eq!(run.get(2000 * 3, &cache, &m).unwrap(), None);
        assert_eq!(m.cache_misses.get(), misses_before, "range skip must not read");
        // In-range absent key: bloom may pass, lookup still misses.
        assert_eq!(run.get(4, &cache, &m).unwrap(), None);
        // Reopen from disk and read again.
        let reopened = Run::open(run_path(&dir, 7)).unwrap();
        assert_eq!(reopened.get(9, &cache, &m).unwrap().unwrap(), recs[2]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_open_rejects_truncation_and_bad_magic() {
        let dir = tdir("run_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let recs: Vec<BookRecord> = (1..=100u64).map(|k| BookRecord::new(k, 1, 1)).collect();
        write_run(&dir, 1, &recs).unwrap();
        let p = run_path(&dir, 1);
        let len = std::fs::metadata(&p).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(len - 10).unwrap();
        drop(f);
        assert!(matches!(Run::open(p.clone()), Err(TierError::Corrupt(_))));

        std::fs::write(&p, b"NOPE").unwrap();
        assert!(matches!(Run::open(p), Err(TierError::Corrupt(_))));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_open_rejects_zero_bloom_words() {
        let dir = tdir("run_bloom0");
        std::fs::create_dir_all(&dir).unwrap();
        let recs: Vec<BookRecord> = (1..=10u64).map(|k| BookRecord::new(k, 1, 1)).collect();
        let run = write_run(&dir, 5, &recs).unwrap();
        let p = run_path(&dir, 5);
        // Craft a header claiming bloom_words = 0 with the bloom region
        // excised so the file-size check still passes; before the bloom
        // validation this underflowed the probe mask and panicked on the
        // first lookup.
        let data = std::fs::read(&p).unwrap();
        let mut crafted = Vec::new();
        crafted.extend_from_slice(&data[..32]);
        crafted.extend_from_slice(&0u64.to_le_bytes());
        crafted.extend_from_slice(&data[40..48]);
        crafted.extend_from_slice(&data[run.records_off as usize..]);
        std::fs::write(&p, crafted).unwrap();
        assert!(matches!(Run::open(p), Err(TierError::Corrupt(_))));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_record_is_skipped_not_served() {
        let dir = tdir("run_crc");
        std::fs::create_dir_all(&dir).unwrap();
        let recs: Vec<BookRecord> = (1..=50u64).map(|k| BookRecord::new(k, 100, 1)).collect();
        let run = write_run(&dir, 3, &recs).unwrap();
        // Flip a payload bit of record 10 (key 11) on disk.
        let off = run.records_off + 10 * RECORD_BYTES as u64 + 9;
        let mut data = std::fs::read(&run.path).unwrap();
        data[off as usize] ^= 0x40;
        std::fs::write(&run.path, &data).unwrap();
        let reopened = Run::open(run_path(&dir, 3)).unwrap();
        let m = TieredMetrics::new();
        let cache = BlockCache::new(4);
        assert!(reopened.get(11, &cache, &m).is_err(), "torn frame must not decode");
        assert_eq!(m.corrupt_records.get(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn over_budget_load_spills_and_every_key_reads_back() {
        let dir = tdir("spill");
        let store = TieredStore::open_clean(&dir, opts(100)).unwrap();
        for k in 1..=1000u64 {
            StorageEngine::insert(&store, BookRecord::new(k, 100 + k, k as u32));
        }
        assert!(store.run_count() > 0, "over-budget load must spill runs");
        assert!(store.resident_records() <= 100);
        assert!(store.disk_bytes() > 0);
        assert!(store.tiered_metrics().spills.get() > 0);
        for k in 1..=1000u64 {
            let r = StorageEngine::get(&store, k).unwrap_or_else(|| panic!("lost key {k}"));
            assert_eq!((r.price_cents, r.quantity), (100 + k, k as u32), "key {k}");
        }
        assert!(store.tiered_metrics().disk_hits.get() > 0, "some reads must come from runs");
        assert_eq!(StorageEngine::len(&store), 1000);
        let (n, sum) = StorageEngine::value_sum_cents(&store);
        assert_eq!(n, 1000);
        let naive: u128 = (1..=1000u64).map(|k| (100 + k) as u128 * k as u128).sum();
        assert_eq!(sum, naive);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn update_of_spilled_key_promotes_and_wins() {
        let dir = tdir("promote");
        let store = TieredStore::open_clean(&dir, opts(10_000)).unwrap();
        for k in 1..=200u64 {
            StorageEngine::insert(&store, BookRecord::new(k, 1, 1));
        }
        store.flush().unwrap();
        assert_eq!(store.resident_records(), 0);
        assert!(StorageEngine::apply(&store, &up(42, 999, 9)));
        assert_eq!(store.tiered_metrics().promotions.get(), 1);
        let r = StorageEngine::get(&store, 42).unwrap();
        assert_eq!((r.price_cents, r.quantity), (999, 9), "promoted value shadows the run");
        assert!(!StorageEngine::apply(&store, &up(9999, 1, 1)), "absent key still misses");
        // Batch with duplicates across the promotion boundary.
        let (applied, missed) = StorageEngine::apply_many(
            &store,
            &[up(7, 10, 1), up(7, 20, 2), up(12345, 1, 1)],
        );
        assert_eq!((applied, missed), (2, 1));
        let r = StorageEngine::get(&store, 7).unwrap();
        assert_eq!((r.price_cents, r.quantity), (20, 2), "last duplicate wins after promotion");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_merges_runs_and_drops_dead_versions() {
        let dir = tdir("compact");
        let store = TieredStore::open_clean(&dir, opts(10_000)).unwrap();
        for k in 1..=300u64 {
            StorageEngine::insert(&store, BookRecord::new(k, 1, 1));
        }
        store.flush().unwrap();
        let runs_before = store.run_count();
        assert!(runs_before >= 2, "per-shard flush writes one run per shard");
        // Churn: promote a third of the keys (their run versions go dead),
        // then spill again so the dead versions coexist with newer ones.
        for k in (1..=300u64).step_by(3) {
            assert!(StorageEngine::apply(&store, &up(k, 777, 7)));
        }
        store.flush().unwrap();
        let bytes_before = store.disk_bytes();
        assert!(store.run_count() > runs_before);

        assert!(store.compact_now().unwrap());
        assert_eq!(store.run_count(), 1, "compaction must merge to a single run");
        assert!(store.disk_bytes() < bytes_before, "dead versions must be GC'd");
        assert_eq!(store.tiered_metrics().compactions.get(), 1);
        for k in 1..=300u64 {
            let want = if k % 3 == 1 { 777 } else { 1 };
            assert_eq!(StorageEngine::get(&store, k).unwrap().price_cents, want, "key {k}");
        }
        assert_eq!(StorageEngine::len(&store), 300);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_aborts_on_read_error_without_dropping_inputs() {
        let dir = tdir("compact_abort");
        let store = TieredStore::open_clean(&dir, opts(10_000)).unwrap();
        for k in 1..=200u64 {
            StorageEngine::insert(&store, BookRecord::new(k, k, 1));
        }
        store.flush().unwrap();
        assert!(store.run_count() >= 2);
        let list_runs = || {
            let mut v: Vec<String> = std::fs::read_dir(&dir)
                .unwrap()
                .flatten()
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| parse_run_seq(n).is_some())
                .collect();
            v.sort();
            v
        };
        let before = list_runs();
        let manifest_before = std::fs::read_to_string(dir.join(RUNS_MANIFEST)).unwrap();
        // Truncate one run behind the store's back: its record region
        // becomes unreadable (I/O error, not a CRC skip). The runs are the
        // sole copy of their records, so the merge must abort rather than
        // publish a partial result and unlink the inputs.
        let victim = dir.join(&before[0]);
        std::fs::OpenOptions::new()
            .write(true)
            .open(&victim)
            .unwrap()
            .set_len(RUN_HEADER_BYTES)
            .unwrap();
        let res = store.compact_now();
        assert!(matches!(&res, Err(TierError::Io(_))), "partial merge must abort: {res:?}");
        assert_eq!(list_runs(), before, "no input run may be unlinked");
        assert_eq!(
            std::fs::read_to_string(dir.join(RUNS_MANIFEST)).unwrap(),
            manifest_before,
            "manifest must not be republished"
        );
        assert_eq!(store.run_count(), before.len());
        assert_eq!(store.tiered_metrics().compactions.get(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fallthrough_recheck_serves_key_resident_in_memstore() {
        let dir = tdir("race_recheck");
        let store = TieredStore::open_clean(&dir, opts(10_000)).unwrap();
        StorageEngine::insert(&store, BookRecord::new(42, 7, 7));
        // Simulate the promotion/compaction read race: the reader has
        // already missed the memstore; by fallthrough time the key lives
        // there again (write-back promotion) and no disk version remains
        // (compaction GC'd the mem-shadowed copy). The trailing re-check
        // must serve it instead of declaring a miss.
        let r = store
            .shared
            .fallthrough_get(42)
            .expect("re-check must serve the memstore-resident key");
        assert_eq!((r.price_cents, r.quantity), (7, 7));
        assert_eq!(store.tiered_metrics().misses.get(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn background_compactor_reduces_run_count() {
        let dir = tdir("bg_compact");
        let mut o = opts(10_000);
        o.compact_at = 3;
        let store = TieredStore::open_clean(&dir, o).unwrap();
        for k in 1..=100u64 {
            StorageEngine::insert(&store, BookRecord::new(k, 5, 5));
        }
        store.flush().unwrap();
        assert!(store.run_count() >= 3);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while store.run_count() > 1 {
            assert!(std::time::Instant::now() < deadline, "compactor never merged");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(store.tiered_metrics().compactions.get() >= 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unreadable_run_is_quarantined_and_older_versions_serve() {
        let dir = tdir("quarantine");
        let store = TieredStore::open_clean(&dir, opts(10_000)).unwrap();
        for k in 1..=100u64 {
            StorageEngine::insert(&store, BookRecord::new(k, 1, 1));
        }
        store.flush().unwrap();
        // Promote every key (new version in mem) and spill again: newer
        // runs now shadow the originals.
        for k in 1..=100u64 {
            assert!(StorageEngine::apply(&store, &up(k, 2, 2)));
        }
        store.flush().unwrap();
        // Truncate the newest run behind the store's back: its record
        // region becomes unreadable (I/O error, not a CRC skip). Cache
        // misses on it must quarantine the run and fall through to the
        // older (stale but valid) version instead of failing the GET.
        let newest = store.shared.runs_snapshot()[0].clone();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&newest.path)
            .unwrap()
            .set_len(RUN_HEADER_BYTES)
            .unwrap();
        let mut stale = 0u64;
        for k in 1..=100u64 {
            let r = StorageEngine::get(&store, k).unwrap_or_else(|| panic!("lost key {k}"));
            assert!(r.price_cents == 1 || r.price_cents == 2, "key {k} must stay valid");
            if r.price_cents == 1 {
                stale += 1;
            }
        }
        assert!(stale > 0, "keys in the truncated run must fall back to the old version");
        assert!(newest.quarantined.load(Ordering::Relaxed));
        assert_eq!(store.tiered_metrics().quarantined.get(), 1);
        assert!(store.health().tier_errors.get() >= 1);
        assert!(newest.path.exists(), "quarantine must never delete the file");
        // Second pass never re-probes the quarantined run.
        let errs = store.tiered_metrics().disk_errors.get();
        for k in 1..=100u64 {
            StorageEngine::get(&store, k);
        }
        assert_eq!(store.tiered_metrics().disk_errors.get(), errs, "quarantined run re-probed");
        // Compaction merges the readable runs, keeps the quarantined one
        // listed and on disk, and answers reads identically.
        assert!(store.compact_now().unwrap());
        assert!(newest.path.exists(), "compaction must not unlink a quarantined run");
        let listed = read_runs_manifest(&dir).unwrap().1;
        let qname = newest.path.file_name().unwrap().to_string_lossy().into_owned();
        assert!(listed.contains(&qname), "quarantined run must stay in the manifest");
        for k in 1..=100u64 {
            let r = StorageEngine::get(&store, k).unwrap_or_else(|| panic!("lost key {k}"));
            assert!(r.price_cents == 1 || r.price_cents == 2, "key {k} post-compaction");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spill_failure_enters_and_exits_degraded_mode() {
        let dir = tdir("degraded");
        let store = TieredStore::open_clean(&dir, opts(50)).unwrap();
        for k in 1..=40u64 {
            StorageEngine::insert(&store, BookRecord::new(k, 7, 7));
        }
        assert_eq!(store.health().health_line(), "ok");
        // Yank the tier directory: the next over-budget spill fails at
        // `File::create` — same degradation path as a full disk.
        std::fs::remove_dir_all(&dir).unwrap();
        for k in 41..=200u64 {
            StorageEngine::insert(&store, BookRecord::new(k, 7, 7));
        }
        assert_eq!(store.health().tier_spill_stopped.get(), 1);
        assert!(store.health().tier_errors.get() >= 1);
        assert_eq!(store.health().health_line(), "degraded: tier-spill-stopped");
        // Degraded, not dead: reads and mutations keep working against
        // the resident set.
        assert_eq!(StorageEngine::get(&store, 10).unwrap().price_cents, 7);
        assert!(StorageEngine::apply(&store, &up(10, 99, 9)));
        assert_eq!(StorageEngine::get(&store, 10).unwrap().price_cents, 99);
        // Disk comes back; after the retry window the next mutation's
        // spill succeeds and clears the flag.
        std::fs::create_dir_all(&dir).unwrap();
        std::thread::sleep(Duration::from_millis(SPILL_RETRY_MS + 100));
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            StorageEngine::insert(&store, BookRecord::new(100_000, 1, 1));
            if store.health().tier_spill_stopped.get() == 0 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "degraded mode never cleared");
            std::thread::sleep(Duration::from_millis(50));
        }
        assert_eq!(store.health().health_line(), "ok");
        assert!(store.run_count() > 0, "recovered spill must publish a run");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_runs_manifest_rebuilds_from_directory_scan() {
        let dir = tdir("torn_manifest");
        {
            let store = TieredStore::open_clean(&dir, opts(10_000)).unwrap();
            for k in 1..=150u64 {
                StorageEngine::insert(&store, BookRecord::new(k, 5 * k, 5));
            }
            store.flush().unwrap();
            assert!(store.run_count() >= 1);
        }
        // Tear the manifest (half a JSON document): the run files are the
        // data; the manifest is a hint and must be rebuilt, not trusted
        // into wiping the tier.
        let text = std::fs::read_to_string(dir.join(RUNS_MANIFEST)).unwrap();
        std::fs::write(dir.join(RUNS_MANIFEST), &text.as_bytes()[..text.len() / 2]).unwrap();

        let store = TieredStore::open(&dir, opts(10_000)).unwrap();
        for k in 1..=150u64 {
            assert_eq!(
                StorageEngine::get(&store, k).unwrap().price_cents,
                5 * k,
                "key {k} must survive the torn manifest"
            );
        }
        assert!(
            read_runs_manifest(&dir).is_some(),
            "manifest must be rewritten after the rebuild"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reopen_recovers_runs_from_manifest_and_gcs_strays() {
        let dir = tdir("reopen");
        {
            let store = TieredStore::open_clean(&dir, opts(10_000)).unwrap();
            for k in 1..=150u64 {
                StorageEngine::insert(&store, BookRecord::new(k, 2 * k, 2));
            }
            store.flush().unwrap();
            assert!(store.run_count() >= 1);
        }
        // Simulate a crash mid-spill: an orphan run file the manifest
        // never published, plus a stale tmp.
        std::fs::write(dir.join("run-999.run"), b"garbage").unwrap();
        std::fs::write(dir.join("RUNS.json.tmp"), b"{").unwrap();

        let store = TieredStore::open(&dir, opts(10_000)).unwrap();
        assert!(!dir.join("run-999.run").exists(), "unlisted run must be GC'd");
        assert!(!dir.join("RUNS.json.tmp").exists(), "stale tmp must be GC'd");
        assert_eq!(store.resident_records(), 0, "reopen starts with a cold memstore");
        for k in 1..=150u64 {
            assert_eq!(
                StorageEngine::get(&store, k).unwrap().price_cents,
                2 * k,
                "key {k} must survive via the run manifest"
            );
        }
        assert_eq!(StorageEngine::len(&store), 150);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stats_suffix_and_reset_epoch() {
        let dir = tdir("stats");
        let store = TieredStore::open_clean(&dir, opts(50)).unwrap();
        for k in 1..=400u64 {
            StorageEngine::insert(&store, BookRecord::new(k, 1, 1));
        }
        for k in 1..=400u64 {
            StorageEngine::get(&store, k);
        }
        let s = StorageEngine::stats_suffix(&store);
        assert!(s.starts_with(" tier_spills="), "suffix must lead with a space: {s:?}");
        assert!(s.contains(" tier_runs="));
        assert!(s.contains(" tier_disk_bytes="));
        assert!(s.contains(" tier_cache_hit_rate="));
        assert!(StorageEngine::spill_enabled(&store));
        StorageEngine::reset_stats_epoch(&store);
        assert_eq!(store.tiered_metrics().mem_hits.get(), 0);
        assert_eq!(store.tiered_metrics().disk_hits.get(), 0);
        assert!(store.tiered_metrics().runs.get() > 0, "gauges survive the epoch reset");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn for_each_shard_visits_memstore_and_disk_records_once() {
        let dir = tdir("fes");
        let store = TieredStore::open_clean(&dir, opts(10_000)).unwrap();
        for k in 1..=100u64 {
            StorageEngine::insert(&store, BookRecord::new(k, 3, 3));
        }
        store.flush().unwrap();
        for k in 101..=160u64 {
            StorageEngine::insert(&store, BookRecord::new(k, 3, 3));
        }
        // Promote one spilled key back so it exists in mem AND on disk.
        assert!(StorageEngine::apply(&store, &up(50, 9, 9)));
        let mut keys: Vec<u64> = Vec::new();
        StorageEngine::for_each_shard(&store, &mut |_, recs| {
            keys.extend(recs.iter().map(|r| r.isbn13));
        });
        keys.sort_unstable();
        let expect: Vec<u64> = (1..=160).collect();
        assert_eq!(keys, expect, "each logical record exactly once");
        std::fs::remove_dir_all(&dir).ok();
    }
}
