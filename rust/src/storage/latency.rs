//! HDD mechanical-latency model.
//!
//! Calibration (DESIGN.md §4): a 7200rpm SATA disk ~ 8.5ms average seek +
//! 4.17ms average rotational delay (half a revolution) + sequential transfer
//! at ~150MB/s; plus a per-operation CPU/interpreter overhead term modelling
//! the paper's MS-Access stack. The paper itself quotes ~10ms disk latency
//! vs ~10ns RAM (§5 reason 1); at these defaults one record's
//! read-modify-write lands at ~40–60ms, matching Table 1's conventional
//! column (~61.7ms/record at 2M records).
//!
//! `scale` shrinks *sleeping* so benches finish in minutes; modeled time is
//! always accumulated at full scale and reported separately.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Parameters of the simulated disk (all tunable via config / CLI).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiskProfile {
    /// Average seek time, milliseconds.
    pub avg_seek_ms: f64,
    /// Average rotational delay (half revolution), milliseconds.
    pub rotational_ms: f64,
    /// Sequential transfer rate, MB/s.
    pub transfer_mb_s: f64,
    /// Per-operation CPU/db-engine overhead, milliseconds (MS-Access tax).
    pub cpu_overhead_ms: f64,
    /// Fraction of the modeled delay actually slept (0 = don't sleep,
    /// 1 = real time). Modeled time is unaffected.
    pub scale: f64,
}

impl Default for DiskProfile {
    fn default() -> Self {
        // 7200rpm SATA (paper's 1TB non-SSD disk) + DB-engine overhead.
        DiskProfile {
            avg_seek_ms: 8.5,
            rotational_ms: 4.17,
            transfer_mb_s: 150.0,
            cpu_overhead_ms: 5.0,
            scale: 0.0,
        }
    }
}

impl DiskProfile {
    /// An SSD-ish profile for ablations (no mechanical delay, 500MB/s).
    pub fn ssd() -> Self {
        DiskProfile {
            avg_seek_ms: 0.05,
            rotational_ms: 0.0,
            transfer_mb_s: 500.0,
            cpu_overhead_ms: 0.02,
            scale: 0.0,
        }
    }

    /// Zero-latency profile (pure functional testing).
    pub fn none() -> Self {
        DiskProfile {
            avg_seek_ms: 0.0,
            rotational_ms: 0.0,
            transfer_mb_s: f64::INFINITY,
            cpu_overhead_ms: 0.0,
            scale: 0.0,
        }
    }

    pub fn with_scale(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    /// Modeled cost of one *random* access transferring `bytes`.
    pub fn random_access_ns(&self, bytes: usize) -> u64 {
        let transfer_ms = if self.transfer_mb_s.is_finite() && self.transfer_mb_s > 0.0 {
            bytes as f64 / (self.transfer_mb_s * 1e6) * 1e3
        } else {
            0.0
        };
        ((self.avg_seek_ms + self.rotational_ms + transfer_ms) * 1e6) as u64
    }

    /// Modeled cost of a *sequential* access (no seek, no rotation —
    /// streaming reads after the head is positioned).
    pub fn sequential_access_ns(&self, bytes: usize) -> u64 {
        let transfer_ms = if self.transfer_mb_s.is_finite() && self.transfer_mb_s > 0.0 {
            bytes as f64 / (self.transfer_mb_s * 1e6) * 1e3
        } else {
            0.0
        };
        (transfer_ms * 1e6) as u64
    }

    /// Modeled per-op engine overhead.
    pub fn overhead_ns(&self) -> u64 {
        (self.cpu_overhead_ms * 1e6) as u64
    }
}

/// Accumulating simulator: charges modeled time, optionally sleeps
/// `scale × delay`. Thread-safe; shared by all accessors of one store.
#[derive(Debug)]
pub struct DiskSim {
    pub profile: DiskProfile,
    modeled_ns: AtomicU64,
    ops: AtomicU64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    Random,
    Sequential,
    /// Engine/interpreter overhead only (no head movement).
    Overhead,
}

impl DiskSim {
    pub fn new(profile: DiskProfile) -> Self {
        DiskSim { profile, modeled_ns: AtomicU64::new(0), ops: AtomicU64::new(0) }
    }

    /// Charge one access of `bytes` and (optionally) sleep the scaled delay.
    pub fn charge(&self, kind: AccessKind, bytes: usize) {
        let ns = match kind {
            AccessKind::Random => self.profile.random_access_ns(bytes),
            AccessKind::Sequential => self.profile.sequential_access_ns(bytes),
            AccessKind::Overhead => self.profile.overhead_ns(),
        };
        self.modeled_ns.fetch_add(ns, Ordering::Relaxed);
        self.ops.fetch_add(1, Ordering::Relaxed);
        let sleep_ns = (ns as f64 * self.profile.scale) as u64;
        if sleep_ns > 0 {
            precise_sleep(Duration::from_nanos(sleep_ns));
        }
    }

    /// Total modeled (full-scale) time so far.
    pub fn modeled(&self) -> Duration {
        Duration::from_nanos(self.modeled_ns.load(Ordering::Relaxed))
    }

    pub fn ops(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.modeled_ns.store(0, Ordering::Relaxed);
        self.ops.store(0, Ordering::Relaxed);
    }
}

/// Sleep that stays accurate below OS timer granularity: coarse sleep for
/// the bulk, spin for the last stretch. Benches that scale delays down to
/// tens of microseconds need this.
pub fn precise_sleep(d: Duration) {
    let start = Instant::now();
    if d > Duration::from_micros(200) {
        std::thread::sleep(d - Duration::from_micros(100));
    }
    while start.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_profile_matches_calibration_band() {
        let p = DiskProfile::default();
        // One 4KiB random access ≈ 8.5 + 4.17 + ~0.027 ms.
        let ns = p.random_access_ns(4096);
        assert!((12.0e6..13.5e6).contains(&(ns as f64)), "ns={ns}");
        // A record RMW (index read + data read + data write + overhead)
        // should land in the paper's ~40-60ms band.
        let rmw = 3 * ns + p.overhead_ns();
        assert!((40.0e6..62.0e6).contains(&(rmw as f64)), "rmw={rmw}");
    }

    #[test]
    fn sequential_is_cheaper_than_random() {
        let p = DiskProfile::default();
        assert!(p.sequential_access_ns(4096) < p.random_access_ns(4096) / 100);
    }

    #[test]
    fn none_profile_is_free() {
        let p = DiskProfile::none();
        assert_eq!(p.random_access_ns(1 << 20), 0);
        assert_eq!(p.overhead_ns(), 0);
    }

    #[test]
    fn sim_accumulates_without_sleeping_at_scale_zero() {
        let sim = DiskSim::new(DiskProfile::default()); // scale = 0
        let t0 = Instant::now();
        for _ in 0..1000 {
            sim.charge(AccessKind::Random, 4096);
        }
        assert!(t0.elapsed() < Duration::from_millis(200), "must not sleep at scale 0");
        assert_eq!(sim.ops(), 1000);
        // 1000 * ~12.7ms ≈ 12.7s modeled.
        let m = sim.modeled().as_secs_f64();
        assert!((12.0..14.0).contains(&m), "modeled={m}");
    }

    #[test]
    fn sim_sleeps_scaled() {
        let p = DiskProfile::default().with_scale(0.001); // 12.7µs per access
        let sim = DiskSim::new(p);
        let t0 = Instant::now();
        for _ in 0..100 {
            sim.charge(AccessKind::Random, 4096);
        }
        let el = t0.elapsed();
        // ≥ 100 × 12.7µs ≈ 1.27ms, and well under full scale.
        assert!(el >= Duration::from_micros(1200), "slept only {el:?}");
        assert!(el < Duration::from_millis(500));
    }

    #[test]
    fn precise_sleep_accuracy() {
        for target_us in [50u64, 500, 2000] {
            let d = Duration::from_micros(target_us);
            let t0 = Instant::now();
            precise_sleep(d);
            let el = t0.elapsed();
            assert!(el >= d, "undersleep {el:?} < {d:?}");
            assert!(el < d + Duration::from_millis(2), "oversleep {el:?} vs {d:?}");
        }
    }

    #[test]
    fn concurrent_charges_sum() {
        let sim = DiskSim::new(DiskProfile::default());
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..250 {
                        sim.charge(AccessKind::Overhead, 0);
                    }
                });
            }
        });
        assert_eq!(sim.ops(), 1000);
        let expect = 1000 * DiskProfile::default().overhead_ns();
        assert_eq!(sim.modeled(), Duration::from_nanos(expect));
    }
}
