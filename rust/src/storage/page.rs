//! Fixed-slot data pages.
//!
//! Records are fixed-width ([`RECORD_BYTES`]), so a page is a small header
//! plus `SLOTS_PER_PAGE` record slots and an occupancy bitmap — simpler and
//! denser than a general slotted page, and exactly what a static inventory
//! table needs.
//!
//! Layout (little-endian):
//! ```text
//! [0..4)   magic 0x4D504147 ("MPAG")
//! [4..8)   page id
//! [8..12)  record count
//! [12..16) reserved
//! [16..16+ceil(SLOTS/8))  occupancy bitmap
//! [DATA_OFF..)            slots
//! ```

use crate::workload::record::{BookRecord, DecodeError, RECORD_BYTES};

pub const PAGE_SIZE: usize = 4096;
pub const PAGE_MAGIC: u32 = 0x4D50_4147;
const HEADER: usize = 16;
/// Solve slots so header + bitmap + slots*RECORD_BYTES <= PAGE_SIZE.
pub const SLOTS_PER_PAGE: usize = (PAGE_SIZE - HEADER - 24) / RECORD_BYTES; // 169
const BITMAP_OFF: usize = HEADER;
const BITMAP_BYTES: usize = SLOTS_PER_PAGE.div_ceil(8);
const DATA_OFF: usize = BITMAP_OFF + BITMAP_BYTES;

const _: () = assert!(DATA_OFF + SLOTS_PER_PAGE * RECORD_BYTES <= PAGE_SIZE);

#[derive(Debug, PartialEq, Eq)]
pub enum PageError {
    BadMagic(u32),
    SlotRange(usize),
    Empty(usize),
    Occupied(usize),
    Full,
    Decode(DecodeError),
}

impl std::fmt::Display for PageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PageError::BadMagic(m) => write!(f, "bad page magic {m:#x}"),
            PageError::SlotRange(s) => write!(f, "slot {s} out of range (max {SLOTS_PER_PAGE})"),
            PageError::Empty(s) => write!(f, "slot {s} is empty"),
            PageError::Occupied(s) => write!(f, "slot {s} is occupied"),
            PageError::Full => write!(f, "page full"),
            PageError::Decode(e) => write!(f, "record decode: {e}"),
        }
    }
}

impl std::error::Error for PageError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PageError::Decode(e) => Some(e),
            _ => None,
        }
    }
}

impl From<DecodeError> for PageError {
    fn from(e: DecodeError) -> Self {
        PageError::Decode(e)
    }
}

/// In-memory view over one page buffer.
pub struct Page {
    pub buf: Box<[u8; PAGE_SIZE]>,
}

impl Page {
    /// Fresh empty page with the given id.
    pub fn new(id: u32) -> Self {
        let mut buf = Box::new([0u8; PAGE_SIZE]);
        buf[0..4].copy_from_slice(&PAGE_MAGIC.to_le_bytes());
        buf[4..8].copy_from_slice(&id.to_le_bytes());
        Page { buf }
    }

    /// Wrap an existing buffer, validating the magic.
    pub fn from_bytes(bytes: [u8; PAGE_SIZE]) -> Result<Self, PageError> {
        let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        if magic != PAGE_MAGIC {
            return Err(PageError::BadMagic(magic));
        }
        Ok(Page { buf: Box::new(bytes) })
    }

    pub fn id(&self) -> u32 {
        u32::from_le_bytes(self.buf[4..8].try_into().unwrap())
    }

    pub fn count(&self) -> u32 {
        u32::from_le_bytes(self.buf[8..12].try_into().unwrap())
    }

    fn set_count(&mut self, c: u32) {
        self.buf[8..12].copy_from_slice(&c.to_le_bytes());
    }

    #[inline]
    pub fn is_occupied(&self, slot: usize) -> bool {
        debug_assert!(slot < SLOTS_PER_PAGE);
        self.buf[BITMAP_OFF + slot / 8] & (1 << (slot % 8)) != 0
    }

    fn set_occupied(&mut self, slot: usize, on: bool) {
        let byte = &mut self.buf[BITMAP_OFF + slot / 8];
        if on {
            *byte |= 1 << (slot % 8);
        } else {
            *byte &= !(1 << (slot % 8));
        }
    }

    fn slot_range(slot: usize) -> std::ops::Range<usize> {
        let off = DATA_OFF + slot * RECORD_BYTES;
        off..off + RECORD_BYTES
    }

    /// Insert into the first free slot; returns the slot index.
    pub fn insert(&mut self, rec: &BookRecord) -> Result<usize, PageError> {
        for slot in 0..SLOTS_PER_PAGE {
            if !self.is_occupied(slot) {
                self.write_slot(slot, rec)?;
                return Ok(slot);
            }
        }
        Err(PageError::Full)
    }

    /// Write a specific (empty) slot.
    pub fn write_slot(&mut self, slot: usize, rec: &BookRecord) -> Result<(), PageError> {
        if slot >= SLOTS_PER_PAGE {
            return Err(PageError::SlotRange(slot));
        }
        if self.is_occupied(slot) {
            return Err(PageError::Occupied(slot));
        }
        self.buf[Self::slot_range(slot)].copy_from_slice(&rec.encode());
        self.set_occupied(slot, true);
        self.set_count(self.count() + 1);
        Ok(())
    }

    /// Overwrite an occupied slot in place (the update path).
    pub fn overwrite_slot(&mut self, slot: usize, rec: &BookRecord) -> Result<(), PageError> {
        if slot >= SLOTS_PER_PAGE {
            return Err(PageError::SlotRange(slot));
        }
        if !self.is_occupied(slot) {
            return Err(PageError::Empty(slot));
        }
        self.buf[Self::slot_range(slot)].copy_from_slice(&rec.encode());
        Ok(())
    }

    pub fn read_slot(&self, slot: usize) -> Result<BookRecord, PageError> {
        if slot >= SLOTS_PER_PAGE {
            return Err(PageError::SlotRange(slot));
        }
        if !self.is_occupied(slot) {
            return Err(PageError::Empty(slot));
        }
        Ok(BookRecord::decode(&self.buf[Self::slot_range(slot)])?)
    }

    pub fn delete_slot(&mut self, slot: usize) -> Result<(), PageError> {
        if slot >= SLOTS_PER_PAGE {
            return Err(PageError::SlotRange(slot));
        }
        if !self.is_occupied(slot) {
            return Err(PageError::Empty(slot));
        }
        self.set_occupied(slot, false);
        self.set_count(self.count() - 1);
        Ok(())
    }

    pub fn is_full(&self) -> bool {
        self.count() as usize >= SLOTS_PER_PAGE
    }

    /// Iterate occupied slots.
    pub fn records(&self) -> impl Iterator<Item = (usize, BookRecord)> + '_ {
        (0..SLOTS_PER_PAGE).filter_map(move |s| self.read_slot(s).ok().map(|r| (s, r)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: u64) -> BookRecord {
        BookRecord::new(9_780_000_000_000 + i, i * 3, i as u32)
    }

    #[test]
    fn slots_per_page_sane() {
        assert!(SLOTS_PER_PAGE >= 150, "density too low: {SLOTS_PER_PAGE}");
        assert!(DATA_OFF + SLOTS_PER_PAGE * RECORD_BYTES <= PAGE_SIZE);
    }

    #[test]
    fn insert_read_roundtrip() {
        let mut p = Page::new(3);
        assert_eq!(p.id(), 3);
        let s0 = p.insert(&rec(1)).unwrap();
        let s1 = p.insert(&rec(2)).unwrap();
        assert_ne!(s0, s1);
        assert_eq!(p.read_slot(s0).unwrap(), rec(1));
        assert_eq!(p.read_slot(s1).unwrap(), rec(2));
        assert_eq!(p.count(), 2);
    }

    #[test]
    fn fills_to_capacity_then_errors() {
        let mut p = Page::new(0);
        for i in 0..SLOTS_PER_PAGE as u64 {
            p.insert(&rec(i)).unwrap();
        }
        assert!(p.is_full());
        assert_eq!(p.insert(&rec(999)), Err(PageError::Full));
        assert_eq!(p.count() as usize, SLOTS_PER_PAGE);
    }

    #[test]
    fn overwrite_updates_in_place() {
        let mut p = Page::new(0);
        let s = p.insert(&rec(5)).unwrap();
        p.overwrite_slot(s, &rec(6)).unwrap();
        assert_eq!(p.read_slot(s).unwrap(), rec(6));
        assert_eq!(p.count(), 1);
        assert_eq!(p.overwrite_slot(s + 1, &rec(7)), Err(PageError::Empty(s + 1)));
    }

    #[test]
    fn delete_frees_slot_for_reuse() {
        let mut p = Page::new(0);
        let s = p.insert(&rec(1)).unwrap();
        p.delete_slot(s).unwrap();
        assert_eq!(p.count(), 0);
        assert_eq!(p.read_slot(s), Err(PageError::Empty(s)));
        let s2 = p.insert(&rec(2)).unwrap();
        assert_eq!(s2, s, "first-fit reuses the freed slot");
    }

    #[test]
    fn serialization_roundtrip_via_bytes() {
        let mut p = Page::new(9);
        for i in 0..10 {
            p.insert(&rec(i)).unwrap();
        }
        let bytes = *p.buf;
        let q = Page::from_bytes(bytes).unwrap();
        assert_eq!(q.id(), 9);
        assert_eq!(q.count(), 10);
        let got: Vec<_> = q.records().map(|(_, r)| r).collect();
        assert_eq!(got, (0..10).map(rec).collect::<Vec<_>>());
    }

    #[test]
    fn bad_magic_rejected() {
        let bytes = [0u8; PAGE_SIZE];
        assert!(matches!(Page::from_bytes(bytes), Err(PageError::BadMagic(0))));
    }

    #[test]
    fn slot_bounds_checked() {
        let p = Page::new(0);
        assert_eq!(p.read_slot(SLOTS_PER_PAGE), Err(PageError::SlotRange(SLOTS_PER_PAGE)));
    }
}
