//! Binary store snapshots: a sequential dump of all records, with a header
//! carrying count + checksum. Loading a snapshot is one streaming read —
//! the fast path for the proposed method's "load prior to processing" step
//! (see the recovery ablation bench).
//!
//! Layout: `MSNP` magic, version u32, record count u64, FNV-64 of the
//! payload, then `count` encoded records (24B each).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;
use std::sync::Arc;

use crate::memstore::ShardedStore;
use crate::util::iofault;
use crate::workload::record::{BookRecord, RECORD_BYTES};

const MAGIC: &[u8; 4] = b"MSNP";
const VERSION: u32 = 1;

/// Fault-injection surface for snapshot writes and loads
/// (`MEMBIG_IO_FAULTS`, DESIGN.md §16).
const SURFACE: &str = "snap";

#[derive(Debug)]
pub enum SnapshotError {
    Io(std::io::Error),
    BadMagic,
    BadVersion(u32),
    BadChecksum,
    Truncated { expected: u64, got: u64 },
    Record(u64, crate::workload::record::DecodeError),
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "io: {e}"),
            SnapshotError::BadMagic => write!(f, "bad snapshot magic"),
            SnapshotError::BadVersion(v) => write!(f, "unsupported snapshot version {v}"),
            SnapshotError::BadChecksum => write!(f, "snapshot checksum mismatch"),
            SnapshotError::Truncated { expected, got } => {
                write!(f, "snapshot truncated: expected {expected} records, read {got}")
            }
            SnapshotError::Record(i, e) => write!(f, "record decode at index {i}: {e}"),
        }
    }
}

impl std::error::Error for SnapshotError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SnapshotError::Io(e) => Some(e),
            SnapshotError::Record(_, e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> Self {
        SnapshotError::Io(e)
    }
}

fn fnv64(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Write the full store to `path`. Returns records written.
///
/// Publish is tmp + fsync + rename; any failure removes the tmp file
/// immediately (best effort — the recovery `*.tmp` GC sweep is the
/// backstop) so an aborted snapshot never leaves an orphan waiting.
pub fn write_snapshot(store: &ShardedStore, path: impl AsRef<Path>) -> Result<u64, SnapshotError> {
    let tmp = path.as_ref().with_extension("tmp");
    let res = write_snapshot_inner(store, path.as_ref(), &tmp);
    if res.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    res
}

fn write_snapshot_inner(
    store: &ShardedStore,
    path: &Path,
    tmp: &Path,
) -> Result<u64, SnapshotError> {
    iofault::fail_point(SURFACE)?;
    let mut out = BufWriter::with_capacity(1 << 20, std::fs::File::create(tmp)?);

    // First pass: collect per-shard to compute count + checksum while
    // streaming records to disk after the header is known. We buffer the
    // header space and patch it at the end instead of two passes.
    out.write_all(MAGIC)?;
    out.write_all(&VERSION.to_le_bytes())?;
    out.write_all(&0u64.to_le_bytes())?; // count placeholder
    out.write_all(&0u64.to_le_bytes())?; // checksum placeholder

    let mut count = 0u64;
    let mut checksum = FNV_SEED;
    // `for_each_shard` copies one shard out under its own lock and hands it
    // over lock-free — a live store keeps serving the other shards while
    // this loop streams to disk (the snapshotter's iteration hook).
    let mut io_err: Option<std::io::Error> = None;
    store.for_each_shard(|_, recs| {
        if io_err.is_some() {
            return;
        }
        for rec in recs {
            let enc = rec.encode();
            checksum = fnv64(checksum, &enc);
            if let Err(e) = iofault::write_all(SURFACE, &mut out, &enc) {
                io_err = Some(e);
                return;
            }
            count += 1;
        }
    });
    if let Some(e) = io_err {
        return Err(e.into());
    }
    out.flush()?;
    let file = out.into_inner().map_err(|e| SnapshotError::Io(e.into_error()))?;
    // Patch header.
    iofault::write_all_at(SURFACE, &file, &count.to_le_bytes(), 8)?;
    iofault::write_all_at(SURFACE, &file, &checksum.to_le_bytes(), 16)?;
    iofault::sync_data(SURFACE, &file)?;
    drop(file);
    iofault::rename(SURFACE, tmp, path)?; // atomic publish
    Ok(count)
}

/// Stream `path` and check magic, version, count-vs-size, per-record
/// decodability and the payload checksum — everything [`load_snapshot`]
/// checks — without building a store.
///
/// The checkpoint path runs this on the image it just published *before*
/// the manifest points at it and GC deletes the previous generation: a
/// torn write can report success with only half the bytes on disk, and
/// that must fail here, while the older chain still exists, not at the
/// next recovery. Reads here are deliberately not routed through the
/// fault shim — read-side validation is the detector, not the surface
/// under test (same policy as `WalReader`).
pub fn verify_snapshot(path: impl AsRef<Path>) -> Result<u64, SnapshotError> {
    let mut input = BufReader::with_capacity(1 << 20, std::fs::File::open(path.as_ref())?);
    let mut header = [0u8; 24];
    input.read_exact(&mut header)?;
    if &header[0..4] != MAGIC {
        return Err(SnapshotError::BadMagic);
    }
    let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(SnapshotError::BadVersion(version));
    }
    let expected = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let want_sum = u64::from_le_bytes(header[16..24].try_into().unwrap());
    let payload = std::fs::metadata(path.as_ref())?.len().saturating_sub(24);
    if payload != expected.saturating_mul(RECORD_BYTES as u64) {
        return Err(SnapshotError::Truncated { expected, got: payload / RECORD_BYTES as u64 });
    }
    let mut buf = [0u8; RECORD_BYTES];
    let mut checksum = FNV_SEED;
    let mut got = 0u64;
    while got < expected {
        if let Err(e) = input.read_exact(&mut buf) {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                return Err(SnapshotError::Truncated { expected, got });
            }
            return Err(e.into());
        }
        checksum = fnv64(checksum, &buf);
        BookRecord::decode(&buf).map_err(|e| SnapshotError::Record(got, e))?;
        got += 1;
    }
    if checksum != want_sum {
        return Err(SnapshotError::BadChecksum);
    }
    Ok(expected)
}

/// Load a snapshot into a fresh store with `shards` shards.
pub fn load_snapshot(
    path: impl AsRef<Path>,
    shards: usize,
) -> Result<Arc<ShardedStore>, SnapshotError> {
    iofault::fail_point(SURFACE)?;
    let mut input = BufReader::with_capacity(1 << 20, std::fs::File::open(path.as_ref())?);
    let mut header = [0u8; 24];
    iofault::read_exact(SURFACE, &mut input, &mut header)?;
    if &header[0..4] != MAGIC {
        return Err(SnapshotError::BadMagic);
    }
    let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(SnapshotError::BadVersion(version));
    }
    let expected = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let want_sum = u64::from_le_bytes(header[16..24].try_into().unwrap());

    // Guard the pre-allocation against a corrupted count field: the file
    // size must carry exactly `expected` records. (Found by the
    // prop_durability corruption sweep — a bit-flip in the header count
    // previously drove a multi-petabyte allocation.)
    let payload = std::fs::metadata(path.as_ref())?.len().saturating_sub(24);
    if payload != expected.saturating_mul(RECORD_BYTES as u64) {
        return Err(SnapshotError::Truncated {
            expected,
            got: payload / RECORD_BYTES as u64,
        });
    }

    let store =
        Arc::new(ShardedStore::new(shards, ((expected as usize / shards) + 1).next_power_of_two()));
    let mut buf = [0u8; RECORD_BYTES];
    let mut checksum = FNV_SEED;
    let mut got = 0u64;
    while got < expected {
        if let Err(e) = iofault::read_exact(SURFACE, &mut input, &mut buf) {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                return Err(SnapshotError::Truncated { expected, got });
            }
            return Err(e.into());
        }
        checksum = fnv64(checksum, &buf);
        let rec = BookRecord::decode(&buf).map_err(|e| SnapshotError::Record(got, e))?;
        store.insert(rec);
        got += 1;
    }
    if checksum != want_sum {
        return Err(SnapshotError::BadChecksum);
    }
    Ok(store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::DatasetSpec;

    fn tpath(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("membig_snapf_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    fn filled(n: u64) -> ShardedStore {
        let spec = DatasetSpec { records: n, ..Default::default() };
        let s = ShardedStore::new(4, 1 << 12);
        for r in spec.iter() {
            s.insert(r);
        }
        s
    }

    #[test]
    fn roundtrip_identical_state() {
        let store = filled(10_000);
        let path = tpath("rt.snap");
        let written = write_snapshot(&store, &path).unwrap();
        assert_eq!(written, 10_000);
        let loaded = load_snapshot(&path, 8).unwrap(); // different shard count is fine
        assert_eq!(loaded.len(), 10_000);
        assert_eq!(loaded.value_sum_cents(), store.value_sum_cents());
        // Spot-check records.
        let spec = DatasetSpec { records: 10_000, ..Default::default() };
        for i in (0..10_000).step_by(977) {
            let r = spec.record_at(i);
            assert_eq!(loaded.get(r.isbn13), Some(r));
        }
    }

    #[test]
    fn verify_matches_load_on_good_and_torn_images() {
        let store = filled(800);
        let path = tpath("verify.snap");
        write_snapshot(&store, &path).unwrap();
        assert_eq!(verify_snapshot(&path).unwrap(), 800);
        // A torn publish (success reported, tail bytes missing) must fail
        // verification exactly like it fails a load.
        let len = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - len / 2).unwrap();
        drop(f);
        assert!(verify_snapshot(&path).is_err());
        assert!(load_snapshot(&path, 4).is_err());
    }

    #[test]
    fn detects_truncation() {
        let store = filled(500);
        let path = tpath("trunc.snap");
        write_snapshot(&store, &path).unwrap();
        let len = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 100).unwrap();
        drop(f);
        assert!(matches!(
            load_snapshot(&path, 4),
            Err(SnapshotError::Truncated { .. }) | Err(SnapshotError::Record(_, _))
        ));
    }

    #[test]
    fn detects_corruption() {
        let store = filled(500);
        let path = tpath("corr.snap");
        write_snapshot(&store, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = 24 + bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_snapshot(&path, 4).is_err());
    }

    #[test]
    fn rejects_wrong_magic_and_version() {
        let path = tpath("magic.snap");
        std::fs::write(&path, b"NOPE____________________").unwrap();
        assert!(matches!(load_snapshot(&path, 2), Err(SnapshotError::BadMagic)));
        let store = filled(10);
        write_snapshot(&store, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 99; // version
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(load_snapshot(&path, 2), Err(SnapshotError::BadVersion(99))));
    }

    #[test]
    fn empty_store_snapshots() {
        let store = ShardedStore::new(2, 16);
        let path = tpath("empty.snap");
        assert_eq!(write_snapshot(&store, &path).unwrap(), 0);
        let loaded = load_snapshot(&path, 2).unwrap();
        assert!(loaded.is_empty());
    }
}
