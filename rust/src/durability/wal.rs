//! Write-ahead log of stock updates.
//!
//! Frame layout (little-endian):
//! ```text
//! [0..8)   isbn13
//! [8..16)  new_price_cents
//! [16..20) new_quantity
//! [20..24) crc32c-style FNV check of the first 20 bytes
//! ```
//! A torn final frame (crash mid-write) is detected by length/CRC and
//! dropped; everything before it replays. `append_batch` + explicit
//! `sync()` gives group commit: the pipeline syncs once per batch, not per
//! record, keeping the hot path sequential-write fast.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use crate::util::iofault;
use crate::workload::record::StockUpdate;

/// Fault-injection surface for WAL appends, syncs and opens
/// (`MEMBIG_IO_FAULTS`, DESIGN.md §16).
const SURFACE: &str = "wal";

const FRAME: usize = 24;

/// Exact on-disk size of one WAL frame — exported so the persistence layer
/// and the crash-point property tests can reason about byte offsets.
pub const FRAME_BYTES: usize = FRAME;

fn frame_crc(buf: &[u8; FRAME]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in &buf[..20] {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

fn encode(u: &StockUpdate) -> [u8; FRAME] {
    let mut b = [0u8; FRAME];
    b[0..8].copy_from_slice(&u.isbn13.to_le_bytes());
    b[8..16].copy_from_slice(&u.new_price_cents.to_le_bytes());
    b[16..20].copy_from_slice(&u.new_quantity.to_le_bytes());
    let crc = frame_crc(&b);
    b[20..24].copy_from_slice(&crc.to_le_bytes());
    b
}

fn decode(b: &[u8; FRAME]) -> Option<StockUpdate> {
    let crc = u32::from_le_bytes(b[20..24].try_into().unwrap());
    if crc != frame_crc(b) {
        return None;
    }
    Some(StockUpdate {
        isbn13: u64::from_le_bytes(b[0..8].try_into().unwrap()),
        new_price_cents: u64::from_le_bytes(b[8..16].try_into().unwrap()),
        new_quantity: u32::from_le_bytes(b[16..20].try_into().unwrap()),
    })
}

/// Encode one update as its on-disk/on-wire WAL frame. The replication
/// layer ships frames in exactly this format, so the standby's stream
/// decoder and crash recovery share one codec (and one CRC).
pub fn encode_frame(u: &StockUpdate) -> [u8; FRAME_BYTES] {
    encode(u)
}

/// Decode one WAL frame; `None` on CRC mismatch (torn/corrupt).
pub fn decode_frame(b: &[u8; FRAME_BYTES]) -> Option<StockUpdate> {
    decode(b)
}

/// Appender. One per process; the pipeline's reader thread owns it.
///
/// The writer is an `Option` so [`Wal::discard_and_trim`] can dismantle a
/// poisoned buffer *infallibly* (taking it apart via `into_parts`, never
/// via `Drop`, which would flush it). `None` only after a failed rollback;
/// every other method then reports the WAL as dismantled instead of
/// touching the file.
pub struct Wal {
    out: Option<BufWriter<File>>,
    appended: u64,
}

impl Wal {
    /// Open for append (created if missing).
    pub fn open(path: impl AsRef<Path>) -> std::io::Result<Self> {
        iofault::fail_point(SURFACE)?;
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Wal { out: Some(BufWriter::with_capacity(1 << 20, f)), appended: 0 })
    }

    fn writer(&mut self) -> std::io::Result<&mut BufWriter<File>> {
        self.out
            .as_mut()
            .ok_or_else(|| std::io::Error::other("WAL writer dismantled by a failed rollback"))
    }

    pub fn append(&mut self, u: &StockUpdate) -> std::io::Result<()> {
        iofault::write_all(SURFACE, self.writer()?, &encode(u))?;
        self.appended += 1;
        Ok(())
    }

    pub fn append_batch(&mut self, us: &[StockUpdate]) -> std::io::Result<()> {
        for u in us {
            self.append(u)?;
        }
        Ok(())
    }

    /// Group commit: flush + fsync.
    pub fn sync(&mut self) -> std::io::Result<()> {
        let w = self.writer()?;
        w.flush()?;
        iofault::sync_data(SURFACE, w.get_ref())
    }

    /// Push buffered frames to the kernel without the fsync. Data written
    /// here survives a process kill (the OS has it) but not power loss —
    /// the persistence layer uses this as its `fsync = false` mode.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.writer()?.flush()
    }

    /// Crash-consistency repair after a failed append: throw away every
    /// buffered-but-unwritten byte, trim the file back to `keep_bytes` —
    /// frames of the failed batch may have spilled to disk when the buffer
    /// filled — and resume appending on the same descriptor (`O_APPEND`
    /// sticks to the fd, so later writes land at the trimmed end).
    ///
    /// The buffer is discarded *before* anything fallible runs: even if the
    /// trim fails, no later flush — explicit or `Drop` — can write the
    /// abandoned frames. On trim failure the `Wal` stays dismantled (every
    /// operation errors) rather than risk extending a bad segment.
    /// Requires `keep_bytes <=` the current file length, which holds
    /// whenever callers flush after every successful append run.
    pub fn discard_and_trim(&mut self, keep_bytes: u64) -> std::io::Result<()> {
        let (file, _discarded_buffer) = self
            .out
            .take()
            .ok_or_else(|| std::io::Error::other("WAL writer already dismantled"))?
            .into_parts();
        file.set_len(keep_bytes)?;
        file.sync_all()?;
        self.out = Some(BufWriter::with_capacity(1 << 20, file));
        Ok(())
    }

    pub fn appended(&self) -> u64 {
        self.appended
    }
}

/// Replayer. Stops cleanly at a torn/corrupt tail.
pub struct WalReader {
    input: std::io::BufReader<File>,
    pub replayed: u64,
    pub torn_tail: bool,
}

impl WalReader {
    pub fn open(path: impl AsRef<Path>) -> std::io::Result<Self> {
        Ok(WalReader {
            input: std::io::BufReader::with_capacity(1 << 20, File::open(path)?),
            replayed: 0,
            torn_tail: false,
        })
    }

    /// Next valid frame; `None` at EOF or first corruption.
    pub fn next_frame(&mut self) -> std::io::Result<Option<StockUpdate>> {
        let mut buf = [0u8; FRAME];
        let mut read = 0;
        while read < FRAME {
            let n = self.input.read(&mut buf[read..])?;
            if n == 0 {
                if read > 0 {
                    self.torn_tail = true; // partial frame at EOF
                }
                return Ok(None);
            }
            read += n;
        }
        match decode(&buf) {
            Some(u) => {
                self.replayed += 1;
                Ok(Some(u))
            }
            None => {
                self.torn_tail = true;
                Ok(None)
            }
        }
    }

    /// Replay everything into `apply`; returns (replayed, torn_tail).
    pub fn replay(
        mut self,
        mut apply: impl FnMut(&StockUpdate),
    ) -> std::io::Result<(u64, bool)> {
        while let Some(u) = self.next_frame()? {
            apply(&u);
        }
        Ok((self.replayed, self.torn_tail))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memstore::ShardedStore;
    use crate::util::rng::Rng;
    use crate::workload::record::BookRecord;

    fn tpath(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("membig_wal_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join(name);
        std::fs::remove_file(&p).ok();
        p
    }

    fn arb_updates(n: usize, seed: u64) -> Vec<StockUpdate> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| StockUpdate {
                isbn13: rng.next_u64() | 1,
                new_price_cents: rng.gen_range(100_000),
                new_quantity: rng.next_u32() % 10_000,
            })
            .collect()
    }

    #[test]
    fn roundtrip() {
        let path = tpath("rt.wal");
        let ups = arb_updates(5_000, 1);
        {
            let mut w = Wal::open(&path).unwrap();
            w.append_batch(&ups).unwrap();
            w.sync().unwrap();
            assert_eq!(w.appended(), 5_000);
        }
        let mut got = Vec::new();
        let (n, torn) = WalReader::open(&path).unwrap().replay(|u| got.push(*u)).unwrap();
        assert_eq!(n, 5_000);
        assert!(!torn);
        assert_eq!(got, ups);
    }

    #[test]
    fn torn_tail_detected_and_prefix_replays() {
        let path = tpath("torn.wal");
        let ups = arb_updates(100, 2);
        {
            let mut w = Wal::open(&path).unwrap();
            w.append_batch(&ups).unwrap();
            w.sync().unwrap();
        }
        // Truncate mid-frame (simulate crash during the 81st frame).
        let full = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - (FRAME as u64 * 20) - 7).unwrap();
        drop(f);

        let mut got = Vec::new();
        let (n, torn) = WalReader::open(&path).unwrap().replay(|u| got.push(*u)).unwrap();
        assert_eq!(n, 79, "79 whole frames survive the truncation");
        assert!(torn);
        assert_eq!(&got[..], &ups[..79]);
    }

    #[test]
    fn corrupt_middle_frame_stops_replay() {
        let path = tpath("corrupt.wal");
        let ups = arb_updates(50, 3);
        {
            let mut w = Wal::open(&path).unwrap();
            w.append_batch(&ups).unwrap();
            w.sync().unwrap();
        }
        // Flip a byte inside frame 10.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[10 * FRAME + 3] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let (n, torn) = WalReader::open(&path).unwrap().replay(|_| {}).unwrap();
        assert_eq!(n, 10);
        assert!(torn);
    }

    #[test]
    fn crash_recovery_reconstructs_store() {
        // snapshot-less recovery: base store + WAL replay ≡ final store.
        let path = tpath("recover.wal");
        let store = ShardedStore::new(4, 1024);
        for k in 1..=1_000u64 {
            store.insert(BookRecord::new(k, 100, 1));
        }
        let ups: Vec<StockUpdate> = (1..=1_000u64)
            .map(|k| StockUpdate { isbn13: k, new_price_cents: k * 2, new_quantity: 7 })
            .collect();
        {
            let mut w = Wal::open(&path).unwrap();
            for u in &ups {
                w.append(u).unwrap();
                store.apply(u);
            }
            w.sync().unwrap();
        }
        let expected = store.value_sum_cents();

        // "Restart": rebuild base then replay the log.
        let recovered = ShardedStore::new(4, 1024);
        for k in 1..=1_000u64 {
            recovered.insert(BookRecord::new(k, 100, 1));
        }
        let (n, torn) =
            WalReader::open(&path).unwrap().replay(|u| {
                recovered.apply(u);
            }).unwrap();
        assert_eq!(n, 1_000);
        assert!(!torn);
        assert_eq!(recovered.value_sum_cents(), expected);
    }

    #[test]
    fn discard_and_trim_drops_unflushed_frames_and_stays_appendable() {
        let path = tpath("discard.wal");
        let ups = arb_updates(30, 9);
        let mut w = Wal::open(&path).unwrap();
        w.append_batch(&ups[..10]).unwrap();
        w.sync().unwrap();
        // Buffered-only frames (never flushed) simulate a failed commit.
        w.append_batch(&ups[10..20]).unwrap();
        w.discard_and_trim(10 * FRAME as u64).unwrap();
        // Post-repair appends extend the trimmed log cleanly.
        w.append_batch(&ups[20..30]).unwrap();
        w.sync().unwrap();
        drop(w);

        let mut got = Vec::new();
        let (n, torn) = WalReader::open(&path).unwrap().replay(|u| got.push(*u)).unwrap();
        assert_eq!(n, 20);
        assert!(!torn);
        assert_eq!(&got[..10], &ups[..10]);
        assert_eq!(&got[10..], &ups[20..30], "discarded frames must never resurface");
    }

    #[test]
    fn empty_wal_replays_nothing() {
        let path = tpath("empty.wal");
        Wal::open(&path).unwrap().sync().unwrap();
        let (n, torn) = WalReader::open(&path).unwrap().replay(|_| {}).unwrap();
        assert_eq!(n, 0);
        assert!(!torn);
    }
}
