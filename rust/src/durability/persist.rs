//! The live persistence layer: WAL group commit + background checkpoints +
//! manifest-driven crash recovery, behind the serving path.
//!
//! The paper's engine loads data into RAM "prior to processing" and writes
//! results back only at the end — everything in between dies with the
//! process. [`Persistence`] closes that gap for the one-server front end:
//!
//! - **Commit path.** Every acknowledged mutation is appended to the
//!   current WAL segment *and* applied to the [`ShardedStore`] under one
//!   mutex, so replay order per key always matches apply order. A request
//!   batch (`MUPDATE`, `BATCH`) costs **one** `sync()` — group commit —
//!   and with `fsync = false` the sync degrades to a kernel flush (survives
//!   `SIGKILL`, not power loss).
//! - **Checkpoints.** A snapshotter thread rotates the WAL (new generation
//!   `g+1` opened, old segment fully synced), streams the store to
//!   `store-<g+1>.snap` one shard lock at a time
//!   ([`ShardedStore::for_each_shard`]), atomically publishes
//!   `MANIFEST.json`, then garbage-collects superseded generations.
//!   Mutations racing the snapshot may appear in both the snapshot and
//!   `wal-<g+1>` — harmless, because stock updates are absolute
//!   (replay is idempotent) and WAL order matches apply order.
//! - **Recovery.** [`Persistence::open`] picks the newest loadable
//!   snapshot (manifest first, then a directory scan — so a corrupt or
//!   missing manifest degrades, never bricks), replays the WAL chain
//!   `wal-g, wal-g+1, ...` over it, drops a torn final frame (per-frame
//!   CRC), trims the live segment to its valid prefix and appends from
//!   there. A crash at *any* point — mid-append, mid-rotation,
//!   mid-manifest — recovers to a prefix-consistent state containing every
//!   synced write.

use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::memstore::ShardedStore;
use crate::metrics::{DurabilityMetrics, HealthMetrics};
use crate::util::iofault;
use crate::util::json::{self, Json};
use crate::workload::record::StockUpdate;

use super::snapshot::{load_snapshot, verify_snapshot, write_snapshot, SnapshotError};
use super::wal::{Wal, WalReader, FRAME_BYTES};

const MANIFEST: &str = "MANIFEST.json";

/// Fault-injection surface for `MANIFEST.json` publishes.
const MANIFEST_SURFACE: &str = "manifest";

/// Fault-injection surface shared with `durability::snapshot` — the
/// rebase path writes a snapshot image by hand.
const SNAP_SURFACE: &str = "snap";

/// First retry delay after a failed background checkpoint.
const SNAP_BACKOFF_BASE_MS: u64 = 500;

/// Ceiling for the checkpoint retry delay (capped exponential).
const SNAP_BACKOFF_CAP_MS: u64 = 30_000;

/// Retry delay after `failures` consecutive failed background
/// checkpoints: `500ms * 2^failures`, capped at 30s. Deterministic (no
/// jitter) — a single snapshotter thread has nothing to de-synchronize
/// from, and the fault sweep wants reproducible timing.
fn snapshot_backoff_delay(failures: u32) -> Duration {
    let exp = failures.min(6);
    Duration::from_millis((SNAP_BACKOFF_BASE_MS << exp).min(SNAP_BACKOFF_CAP_MS))
}

/// Tunables for the persistence layer.
#[derive(Debug, Clone)]
pub struct DurabilityOptions {
    /// `true`: every group commit fsyncs (survives power loss). `false`:
    /// group commits flush to the kernel only (survives process death,
    /// ~disk-write-free hot path); checkpoints still fsync.
    pub fsync: bool,
    /// Checkpoint at least this often. Zero disables the time trigger.
    pub snapshot_every: Duration,
    /// Checkpoint when the current WAL segment exceeds this many bytes.
    /// Zero disables the size trigger.
    pub snapshot_wal_bytes: u64,
}

impl Default for DurabilityOptions {
    fn default() -> Self {
        DurabilityOptions {
            fsync: true,
            snapshot_every: Duration::from_secs(60),
            snapshot_wal_bytes: 64 << 20,
        }
    }
}

#[derive(Debug)]
pub enum DurabilityError {
    Io(std::io::Error),
    Snapshot(SnapshotError),
    /// No recoverable state and the seed loader failed (or refused to run).
    Seed(String),
    /// Directory contents are beyond repair (e.g. WAL segments with no
    /// loadable snapshot at all).
    Corrupt(String),
}

impl std::fmt::Display for DurabilityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DurabilityError::Io(e) => write!(f, "io: {e}"),
            DurabilityError::Snapshot(e) => write!(f, "snapshot: {e}"),
            DurabilityError::Seed(e) => write!(f, "seed: {e}"),
            DurabilityError::Corrupt(e) => write!(f, "unrecoverable data dir: {e}"),
        }
    }
}

impl std::error::Error for DurabilityError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DurabilityError::Io(e) => Some(e),
            DurabilityError::Snapshot(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for DurabilityError {
    fn from(e: std::io::Error) -> Self {
        DurabilityError::Io(e)
    }
}

impl From<SnapshotError> for DurabilityError {
    fn from(e: SnapshotError) -> Self {
        DurabilityError::Snapshot(e)
    }
}

/// What [`Persistence::open`] found on disk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RecoveryReport {
    /// `true`: the directory was empty and was initialized from the seed.
    pub fresh: bool,
    /// Generation of the snapshot the store was rebuilt from.
    pub snapshot_generation: u64,
    /// Records loaded from that snapshot.
    pub snapshot_records: u64,
    /// Generation of the live WAL segment appends continue into.
    pub wal_generation: u64,
    /// WAL frames replayed across the whole chain.
    pub wal_frames: u64,
    /// Number of WAL segments replayed.
    pub chain: usize,
    /// A torn/corrupt frame was hit and the suffix from it on was dropped.
    pub torn_tail: bool,
}

/// Result of one checkpoint.
#[derive(Debug, Clone)]
pub struct CheckpointStats {
    pub generation: u64,
    pub records: u64,
    pub elapsed: Duration,
}

/// Observer of the WAL commit path, called with the wal mutex held so
/// observation order is exactly append order. The replication shipper
/// implements this; both hooks MUST be non-blocking (bounded-queue push or
/// atomic watermark update) — anything slower would serialize behind group
/// commit and stall every mutation.
pub trait CommitSink: Send + Sync {
    /// `ups` was appended to segment `generation` starting at byte
    /// `start_offset` and is at least kernel-flushed (fsynced when
    /// `sync_now` held).
    fn frames_committed(&self, generation: u64, start_offset: u64, ups: &[StockUpdate]);
    /// A checkpoint rotated the WAL; appends continue in `new_generation`
    /// at offset 0.
    fn generation_rotated(&self, new_generation: u64);
}

struct WalState {
    wal: Wal,
    /// Generation of the segment `wal` appends to.
    generation: u64,
    /// Bytes in the current segment (drives the size trigger). Because
    /// every successful commit flushes, this always equals the on-disk
    /// segment length — the rollback boundary after a failed append.
    wal_bytes: u64,
    /// Frames appended since the last group sync.
    unsynced: bool,
    /// Set when a failed append could not be rolled back: the segment may
    /// hold frames of a mutation that was reported ERR, so accepting more
    /// writes would let them resurface at replay. All further commits are
    /// refused; a restart recovers cleanly.
    poisoned: bool,
}

struct Shared {
    dir: PathBuf,
    opts: DurabilityOptions,
    store: Arc<ShardedStore>,
    wal: Mutex<WalState>,
    /// `true` when the size trigger fired; consumed by the snapshotter.
    snap_signal: Mutex<bool>,
    wake: Condvar,
    stop: AtomicBool,
    /// Serializes `checkpoint_now` against the background snapshotter.
    checkpoint_lock: Mutex<()>,
    metrics: DurabilityMetrics,
    /// Storage-health block (`HEALTH` verb, `health_*` stats). `Arc` so
    /// the replication shipper can count its disk errors into the same
    /// instance the server renders.
    health: Arc<HealthMetrics>,
    /// Optional commit observer (the replication shipper). Installed once
    /// before serving starts; read under the wal lock so notification
    /// order ≡ WAL order.
    sink: Mutex<Option<Arc<dyn CommitSink>>>,
}

/// Live persistence handle. Dropping it stops the snapshotter and performs
/// a final WAL sync; the on-disk state then recovers byte-exactly.
pub struct Persistence {
    shared: Arc<Shared>,
    snapshotter: Option<std::thread::JoinHandle<()>>,
}

pub(crate) fn snap_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("store-{generation}.snap"))
}

pub(crate) fn wal_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("wal-{generation}.log"))
}

fn parse_gen(name: &str, prefix: &str, suffix: &str) -> Option<u64> {
    name.strip_prefix(prefix)?.strip_suffix(suffix)?.parse().ok()
}

/// Generations with a snapshot file present, newest first.
pub(crate) fn scan_snapshot_gens(dir: &Path) -> Vec<u64> {
    let mut gens: Vec<u64> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .flatten()
            .filter_map(|e| parse_gen(&e.file_name().to_string_lossy(), "store-", ".snap"))
            .collect(),
        Err(_) => Vec::new(),
    };
    gens.sort_unstable();
    gens.dedup();
    gens.reverse();
    gens
}

fn any_wal_segment(dir: &Path) -> bool {
    match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .flatten()
            .any(|e| parse_gen(&e.file_name().to_string_lossy(), "wal-", ".log").is_some()),
        Err(_) => false,
    }
}

fn read_manifest(dir: &Path) -> Option<u64> {
    let text = std::fs::read_to_string(dir.join(MANIFEST)).ok()?;
    let j = json::parse(&text).ok()?;
    let g = j.get("generation")?.as_f64()?;
    if !g.is_finite() || g < 0.0 {
        return None;
    }
    Some(g as u64)
}

/// Atomically publish `MANIFEST.json` for `generation` (tmp + fsync +
/// rename + directory fsync). The manifest is a hint — recovery survives
/// it being stale, missing or corrupt — so it is always safe to rewrite.
fn write_manifest(dir: &Path, generation: u64) -> Result<(), DurabilityError> {
    let j = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("generation", Json::num(generation as f64)),
        ("snapshot", Json::str(format!("store-{generation}.snap"))),
        ("wal", Json::str(format!("wal-{generation}.log"))),
    ]);
    let tmp = dir.join("MANIFEST.json.tmp");
    let publish = (|| -> std::io::Result<()> {
        iofault::fail_point(MANIFEST_SURFACE)?;
        let mut f = File::create(&tmp)?;
        iofault::write_all(MANIFEST_SURFACE, &mut f, j.to_string_pretty().as_bytes())?;
        iofault::write_all(MANIFEST_SURFACE, &mut f, b"\n")?;
        iofault::sync_data(MANIFEST_SURFACE, &f)?;
        drop(f);
        iofault::rename(MANIFEST_SURFACE, &tmp, &dir.join(MANIFEST))
    })();
    if let Err(e) = publish {
        // A failed publish must not leave the tmp for the GC sweep to
        // find later (best effort; the sweep is the backstop).
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all(); // directory entry durability (best effort)
    }
    Ok(())
}

/// Delete snapshot/WAL generations strictly below `keep`, plus stray tmp
/// files. Best effort: a leftover file only wastes space, never blocks
/// recovery.
fn gc_below(dir: &Path, keep: u64) {
    gc_where(dir, |g| g < keep);
}

/// Delete generations strictly above `keep` — used after a mid-chain tear
/// so a later recovery cannot resurrect segments past the dropped suffix.
fn gc_above(dir: &Path, keep: u64) {
    gc_where(dir, |g| g > keep);
}

fn gc_where(dir: &Path, cond: impl Fn(u64) -> bool) {
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        Err(_) => return,
    };
    for e in rd.flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        let gen = parse_gen(&name, "store-", ".snap")
            .or_else(|| parse_gen(&name, "wal-", ".log"));
        let stale_tmp = name.ends_with(".tmp");
        if stale_tmp || gen.map(&cond).unwrap_or(false) {
            let _ = std::fs::remove_file(e.path());
        }
    }
}

impl Persistence {
    /// Open `dir`: recover the store from the newest consistent
    /// `snapshot + WAL chain` if one exists, otherwise initialize the
    /// directory from `seed` (generation-0 snapshot + empty WAL). Returns
    /// the live store, the persistence handle (snapshotter running), and a
    /// report of what was recovered.
    ///
    /// `shards` sizes the recovered store; `seed` runs only for a fresh
    /// directory.
    pub fn open(
        dir: impl AsRef<Path>,
        opts: DurabilityOptions,
        shards: usize,
        seed: impl FnOnce() -> Result<Arc<ShardedStore>, String>,
    ) -> Result<(Arc<ShardedStore>, Persistence, RecoveryReport), DurabilityError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;

        // Candidate snapshot generations, newest first. A complete snapshot
        // is self-validating (checksum + record count), so newest-first is
        // safe even when the manifest lags a crash-interrupted checkpoint.
        let mut candidates = scan_snapshot_gens(&dir);
        if let Some(g) = read_manifest(&dir) {
            if !candidates.contains(&g) {
                candidates.push(g);
                candidates.sort_unstable();
                candidates.reverse();
            }
        }

        if candidates.is_empty() {
            if any_wal_segment(&dir) {
                return Err(DurabilityError::Corrupt(
                    "WAL segments present but no snapshot to replay them over".into(),
                ));
            }
            return Self::init_fresh(dir, opts, seed);
        }

        let mut last_err: Option<DurabilityError> = None;
        for &g in &candidates {
            let store = match load_snapshot(snap_path(&dir, g), shards) {
                Ok(s) => s,
                Err(e) => {
                    last_err = Some(e.into());
                    continue;
                }
            };
            let snapshot_records = store.len() as u64;

            // Replay the WAL chain g, g+1, ... — segments past g exist when
            // a crash interrupted a checkpoint between rotation and
            // manifest publication.
            let mut frames = 0u64;
            let mut last_file_frames = 0u64;
            let mut chain = 0usize;
            let mut torn = false;
            let mut wal_gen = g;
            let mut k = g;
            while wal_path(&dir, k).exists() {
                let (n, t) =
                    WalReader::open(wal_path(&dir, k))?.replay(|u| {
                        store.apply(u);
                    })?;
                frames += n;
                last_file_frames = n;
                chain += 1;
                wal_gen = k;
                if t {
                    torn = true;
                    break; // prefix consistency: drop everything after the tear
                }
                k += 1;
            }

            if chain > 0 {
                // Trim the live segment to its valid prefix so appends
                // extend a clean log (a torn tail would otherwise hide
                // every later frame from the next replay).
                let live = wal_path(&dir, wal_gen);
                let valid = last_file_frames * FRAME_BYTES as u64;
                let f = std::fs::OpenOptions::new().write(true).open(&live)?;
                if f.metadata()?.len() != valid {
                    f.set_len(valid)?;
                    f.sync_all()?;
                }
            }
            // Segments past a mid-chain tear (rare: external damage to a
            // fully-synced segment) must not resurface next recovery.
            gc_above(&dir, wal_gen);
            // Re-point the manifest at what we actually recovered from.
            write_manifest(&dir, g)?;

            let wal = Wal::open(wal_path(&dir, wal_gen))?;
            let wal_bytes = last_file_frames * FRAME_BYTES as u64;
            let persist =
                Self::start(dir.clone(), opts.clone(), store.clone(), wal_gen, wal, wal_bytes);
            let report = RecoveryReport {
                fresh: false,
                snapshot_generation: g,
                snapshot_records,
                wal_generation: wal_gen,
                wal_frames: frames,
                chain,
                torn_tail: torn,
            };
            return Ok((store, persist, report));
        }
        Err(last_err
            .unwrap_or_else(|| DurabilityError::Corrupt("no loadable snapshot".into())))
    }

    fn init_fresh(
        dir: PathBuf,
        opts: DurabilityOptions,
        seed: impl FnOnce() -> Result<Arc<ShardedStore>, String>,
    ) -> Result<(Arc<ShardedStore>, Persistence, RecoveryReport), DurabilityError> {
        let store = seed().map_err(DurabilityError::Seed)?;
        let records = write_snapshot(&store, snap_path(&dir, 0))?;
        let wal = Wal::open(wal_path(&dir, 0))?;
        write_manifest(&dir, 0)?;
        let persist = Self::start(dir, opts, store.clone(), 0, wal, 0);
        let report = RecoveryReport {
            fresh: true,
            snapshot_generation: 0,
            snapshot_records: records,
            wal_generation: 0,
            wal_frames: 0,
            chain: 0,
            torn_tail: false,
        };
        Ok((store, persist, report))
    }

    fn start(
        dir: PathBuf,
        opts: DurabilityOptions,
        store: Arc<ShardedStore>,
        generation: u64,
        wal: Wal,
        wal_bytes: u64,
    ) -> Persistence {
        let shared = Arc::new(Shared {
            dir,
            opts,
            store,
            wal: Mutex::new(WalState {
                wal,
                generation,
                wal_bytes,
                unsynced: false,
                poisoned: false,
            }),
            snap_signal: Mutex::new(false),
            wake: Condvar::new(),
            stop: AtomicBool::new(false),
            checkpoint_lock: Mutex::new(()),
            metrics: DurabilityMetrics::new(),
            health: Arc::new(HealthMetrics::new()),
            sink: Mutex::new(None),
        });
        shared.metrics.generation.set(generation as i64);
        let snapshotter = spawn_snapshotter(shared.clone());
        Persistence { shared, snapshotter }
    }

    /// Log + apply + (optionally) group-sync one update. With
    /// `sync_now = false` the frame reaches the kernel but the fsync is
    /// deferred to a later [`Persistence::sync`] — the BATCH path, where
    /// the whole group is acknowledged by one socket write.
    pub fn apply_update(&self, u: &StockUpdate, sync_now: bool) -> std::io::Result<bool> {
        let (applied, _) = self.commit(std::slice::from_ref(u), sync_now)?;
        Ok(applied == 1)
    }

    /// Log + apply a batch with **one** sync — group commit, mirroring the
    /// shard-affine `ShardedStore::apply_many` it wraps. (The store's
    /// seqlock write windows live *inside* this commit path's mutex, so
    /// WAL append order ≡ apply order still holds; lock-free readers are
    /// unaffected by either lock.)
    pub fn apply_many(&self, ups: &[StockUpdate], sync_now: bool) -> std::io::Result<(u64, u64)> {
        self.commit(ups, sync_now)
    }

    fn commit(&self, ups: &[StockUpdate], sync_now: bool) -> std::io::Result<(u64, u64)> {
        if ups.is_empty() {
            return Ok((0, 0));
        }
        let sh = &*self.shared;
        let bytes = (ups.len() * FRAME_BYTES) as u64;
        // Append *then* apply under one lock: replay order per key can
        // never diverge from apply order, and a snapshot taken after a
        // rotation (same lock) always covers the whole prior segment.
        let mut g = sh.wal.lock().unwrap();
        if g.poisoned {
            return Err(std::io::Error::other(
                "WAL poisoned by an unrecoverable append failure; restart to recover",
            ));
        }
        // Log first, make it durable second, apply to the store LAST — so
        // any failure before the apply can be rolled back and reported ERR
        // with the store untouched: an ERR response always means "nothing
        // changed, retry safely".
        let mut logged = g.wal.append_batch(ups);
        let mut fsync_failed = false;
        if logged.is_ok() {
            g.unsynced = true;
            logged = if sync_now {
                let r = sync_locked(sh, &mut g);
                fsync_failed = r.is_err() && sh.opts.fsync;
                r
            } else {
                // Flush even without the group sync: the kernel gets the
                // frames (SIGKILL-safe before the deferred sync lands), and
                // the buffer-always-empty invariant keeps `wal_bytes` ==
                // file length — the rollback boundary below.
                g.wal.flush()
            };
        }
        if let Err(e) = logged {
            sh.health.wal_errors.inc();
            if fsync_failed {
                // fsyncgate: after a failed fsync the kernel may have
                // dropped dirty pages while marking them clean, so no
                // in-process repair (including a re-tried fsync in
                // discard_and_trim) can re-establish what is durable.
                // Crash-restart semantics: refuse everything until a
                // restart replays what actually reached the disk.
                g.poisoned = true;
                sh.health.wal_failstop.set(1);
                eprintln!(
                    "membig: WAL fsync failed; refusing further writes until restart: {e}"
                );
            } else {
                // Write-level failure — durability was never claimed for
                // these frames, so the segment can be repaired in place:
                // discard the write buffer and trim back to the last
                // committed length.
                let committed = g.wal_bytes;
                match g.wal.discard_and_trim(committed) {
                    Ok(()) => g.unsynced = false, // trim fsynced the survivors
                    Err(repair) => {
                        g.poisoned = true;
                        sh.health.wal_failstop.set(1);
                        eprintln!(
                            "membig: WAL rollback after failed commit also failed \
                             ({repair}); refusing further writes until restart"
                        );
                    }
                }
            }
            return Err(e);
        }
        let res = sh.store.apply_many(ups);
        let start_offset = g.wal_bytes;
        g.wal_bytes += bytes;
        sh.metrics.wal_appends.add(ups.len() as u64);
        sh.metrics.wal_bytes.add(bytes);
        // Ship hook: still under the wal lock, so standbys observe commits
        // in exactly WAL order. The sink is a bounded non-blocking push — a
        // slow standby overflows its queue (and later re-syncs from a
        // snapshot) instead of stalling group commit here.
        if let Some(sink) = sh.sink.lock().unwrap().clone() {
            sink.frames_committed(g.generation, start_offset, ups);
        }
        let over = sh.opts.snapshot_wal_bytes > 0 && g.wal_bytes >= sh.opts.snapshot_wal_bytes;
        drop(g);
        if over {
            *sh.snap_signal.lock().unwrap() = true;
            sh.wake.notify_all();
        }
        Ok(res)
    }

    /// Group sync: make every frame appended so far durable (fsync, or
    /// kernel flush when `fsync = false`). No-op when nothing is pending.
    ///
    /// A failure here poisons the WAL: the pending frames are already
    /// applied to the store (deferred BATCH commits), so they cannot be
    /// rolled back, and letting later commits append — and get
    /// acknowledged — after a non-durable hole would let a crash drop
    /// acked writes as part of the hole's torn tail.
    pub fn sync(&self) -> std::io::Result<()> {
        let sh = &*self.shared;
        let mut g = sh.wal.lock().unwrap();
        let r = sync_locked(sh, &mut g);
        if let Err(ref e) = r {
            if !g.poisoned {
                g.poisoned = true;
                sh.health.wal_errors.inc();
                sh.health.wal_failstop.set(1);
                eprintln!(
                    "membig: WAL group sync failed; refusing further writes until restart: {e}"
                );
            }
        }
        r
    }

    /// Run a checkpoint synchronously (tests, shutdown hooks). The
    /// background snapshotter uses the same serialized path.
    pub fn checkpoint_now(&self) -> Result<CheckpointStats, DurabilityError> {
        self.shared.checkpoint()
    }

    pub fn metrics(&self) -> &DurabilityMetrics {
        &self.shared.metrics
    }

    /// Storage-health block for this instance (`HEALTH` verb,
    /// `health_*` stats keys).
    pub fn health(&self) -> &HealthMetrics {
        &self.shared.health
    }

    /// Shared handle to the health block, for subsystems that outlive a
    /// borrow (the replication shipper's listener threads).
    pub fn health_handle(&self) -> Arc<HealthMetrics> {
        self.shared.health.clone()
    }

    /// `STATS SERVER` suffix for the persistence layer.
    pub fn stats_suffix(&self) -> String {
        self.shared.metrics.stats_suffix()
    }

    /// Generation of the WAL segment currently receiving appends.
    pub fn wal_generation(&self) -> u64 {
        self.shared.wal.lock().unwrap().generation
    }

    /// Install the commit observer (the replication shipper). Install once,
    /// before the server starts taking traffic; hooks run under the wal
    /// lock and must never block (see [`CommitSink`]).
    pub fn set_commit_sink(&self, sink: Arc<dyn CommitSink>) {
        *self.shared.sink.lock().unwrap() = Some(sink);
    }

    /// `(generation, byte offset)` of the next WAL append — the resume
    /// position a standby reports on (re)connect.
    pub fn wal_tip(&self) -> (u64, u64) {
        let g = self.shared.wal.lock().unwrap();
        (g.generation, g.wal_bytes)
    }

    /// The durable directory this instance persists into.
    pub fn dir(&self) -> &Path {
        &self.shared.dir
    }

    /// Standby re-sync: replace this node's durable state with the
    /// primary's snapshot image at `generation` and re-point the live WAL
    /// at `wal-<generation>.log`, offset 0 — the shipped stream resumes
    /// from exactly there. Used when the stream cannot resume from our
    /// local (generation, offset): fresh bootstrap, falling behind the
    /// primary's GC floor after a ship-queue overflow, or a divergent
    /// history after the primary itself crash-recovered. The image is
    /// validated (checksum + record count) *before* any live state
    /// changes; its records are then upserted into the live store — the
    /// workload never deletes keys, so overwrite converges on the
    /// primary's image. Returns records loaded.
    pub fn rebase_to_snapshot(
        &self,
        generation: u64,
        snap: &[u8],
        shards: usize,
    ) -> Result<u64, DurabilityError> {
        let sh = &*self.shared;
        let _serialize = sh.checkpoint_lock.lock().unwrap();
        // Publish the snapshot file (tmp + fsync + rename), then validate
        // it by loading into a scratch store.
        let path = snap_path(&sh.dir, generation);
        // `.tmp` suffix so a crash mid-rebase leaves an orphan the normal
        // GC sweep already cleans up; a *failed* publish removes it
        // immediately instead of waiting for the next sweep.
        let tmp = path.with_extension("tmp");
        let publish = (|| -> std::io::Result<()> {
            iofault::fail_point(SNAP_SURFACE)?;
            let mut f = File::create(&tmp)?;
            iofault::write_all(SNAP_SURFACE, &mut f, snap)?;
            iofault::sync_data(SNAP_SURFACE, &f)?;
            drop(f);
            iofault::rename(SNAP_SURFACE, &tmp, &path)
        })();
        if let Err(e) = publish {
            let _ = std::fs::remove_file(&tmp);
            sh.health.snapshot_errors.inc();
            return Err(e.into());
        }
        // Validate before any live state changes: a torn or corrupt
        // image must leave the old store + WAL fully intact. Take the
        // bad file back out immediately — recovery must never have to
        // consider a generation that was published but failed to load.
        let incoming = match load_snapshot(&path, shards) {
            Ok(s) => s,
            Err(e) => {
                let _ = std::fs::remove_file(&path);
                sh.health.snapshot_errors.inc();
                return Err(e.into());
            }
        };
        let records = incoming.len() as u64;
        {
            let mut g = sh.wal.lock().unwrap();
            if g.poisoned {
                return Err(DurabilityError::Io(std::io::Error::other(
                    "WAL poisoned; restart before re-syncing",
                )));
            }
            // Fresh segment for the new generation: whatever local frames
            // existed are superseded by the snapshot image.
            let live = wal_path(&sh.dir, generation);
            let _ = std::fs::remove_file(&live);
            g.wal = Wal::open(&live)?;
            g.generation = generation;
            g.wal_bytes = 0;
            g.unsynced = false;
            // Upsert under the wal lock — same ordering discipline as the
            // commit path, so a racing reader never sees post-rebase
            // frames applied before the base image.
            incoming.for_each_shard(|_, recs| {
                for r in recs {
                    sh.store.insert(*r);
                }
            });
        }
        write_manifest(&sh.dir, generation)?;
        gc_below(&sh.dir, generation);
        gc_above(&sh.dir, generation);
        sh.metrics.generation.set(generation as i64);
        Ok(records)
    }
}

fn sync_locked(sh: &Shared, g: &mut WalState) -> std::io::Result<()> {
    if g.poisoned {
        // Never flush a poisoned buffer — it may hold frames of an ERR'd
        // mutation that a replay must not see.
        return Err(std::io::Error::other(
            "WAL poisoned by an unrecoverable append failure; restart to recover",
        ));
    }
    if !g.unsynced {
        return Ok(());
    }
    if sh.opts.fsync {
        g.wal.sync()?;
    } else {
        g.wal.flush()?;
    }
    g.unsynced = false;
    sh.metrics.wal_syncs.inc();
    Ok(())
}

impl Drop for Persistence {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.wake.notify_all();
        if let Some(j) = self.snapshotter.take() {
            let _ = j.join();
        }
        // Final sync: a graceful shutdown loses nothing even with
        // `fsync = false` (cheap — once per process lifetime). A poisoned
        // buffer must stay unwritten.
        if let Ok(mut g) = self.shared.wal.lock() {
            if !g.poisoned {
                let _ = g.wal.sync();
            }
        }
    }
}

impl Shared {
    /// One checkpoint: rotate the WAL, snapshot the store, publish the
    /// manifest, GC superseded generations.
    fn checkpoint(&self) -> Result<CheckpointStats, DurabilityError> {
        let _serialize = self.checkpoint_lock.lock().unwrap();
        let t0 = Instant::now();
        let new_gen = {
            let mut g = self.wal.lock().unwrap();
            if g.poisoned {
                return Err(DurabilityError::Io(std::io::Error::other(
                    "WAL poisoned; checkpoint would persist frames of an ERR'd mutation",
                )));
            }
            // Everything in the old segment is durable before the rotation:
            // from here on, snapshot + wal-<new_gen> alone must reconstruct
            // the state. fsyncgate applies here exactly as in the commit
            // path: a failed fsync may have silently dropped dirty pages,
            // so retrying the checkpoint later and trusting a second sync
            // of the same frames would build a snapshot chain on top of a
            // non-durable hole. Fail-stop the WAL instead.
            if let Err(e) = g.wal.sync() {
                g.poisoned = true;
                self.health.wal_errors.inc();
                self.health.wal_failstop.set(1);
                eprintln!(
                    "membig: WAL sync during checkpoint failed; refusing further writes \
                     until restart: {e}"
                );
                return Err(e.into());
            }
            g.unsynced = false;
            let new_gen = g.generation + 1;
            g.wal = Wal::open(wal_path(&self.dir, new_gen))?;
            g.generation = new_gen;
            g.wal_bytes = 0;
            // Rotation notice under the same lock: the shipper learns of
            // the generation bump before any frame of the new segment.
            if let Some(sink) = self.sink.lock().unwrap().clone() {
                sink.generation_rotated(new_gen);
            }
            new_gen
        };
        // Stream the store without the WAL lock — commits keep flowing into
        // the new segment while this runs; racing updates may land in both
        // the snapshot and the segment, which replay tolerates (absolute
        // values, apply order preserved).
        let records = write_snapshot(&self.store, snap_path(&self.dir, new_gen))?;
        // A torn write can report success with half the bytes on disk.
        // Verify the published image while generation `new_gen - 1` and
        // its WAL chain still exist — the manifest must never point at
        // (nor GC run toward) a snapshot that cannot load.
        if let Err(e) = verify_snapshot(snap_path(&self.dir, new_gen)) {
            let _ = std::fs::remove_file(snap_path(&self.dir, new_gen));
            return Err(e.into());
        }
        write_manifest(&self.dir, new_gen)?;
        gc_below(&self.dir, new_gen);
        let elapsed = t0.elapsed();
        self.metrics.snapshots.inc();
        self.metrics.snapshot_last_ms.set(elapsed.as_millis().min(i64::MAX as u128) as i64);
        self.metrics.snapshot_last_records.set(records.min(i64::MAX as u64) as i64);
        self.metrics.generation.set(new_gen as i64);
        Ok(CheckpointStats { generation: new_gen, records, elapsed })
    }
}

/// Background checkpoint thread: ticks every 200 ms, fires on the size
/// signal from the commit path or the elapsed-time trigger. Not spawned
/// when both triggers are disabled (`checkpoint_now` still works).
fn spawn_snapshotter(shared: Arc<Shared>) -> Option<std::thread::JoinHandle<()>> {
    if shared.opts.snapshot_every.is_zero() && shared.opts.snapshot_wal_bytes == 0 {
        return None;
    }
    let handle = std::thread::Builder::new()
        .name("membig-snapshot".into())
        .spawn(move || {
            let mut last = Instant::now();
            // Degraded-mode state: consecutive checkpoint failures and the
            // earliest instant a retry is allowed (capped exponential
            // backoff — an out-of-space disk gets seconds to recover
            // instead of a 200 ms hammer; see DESIGN.md §16).
            let mut failures = 0u32;
            let mut retry_at = Instant::now();
            loop {
                let due_size = {
                    let guard = shared.snap_signal.lock().unwrap();
                    let (mut guard, _) = shared
                        .wake
                        .wait_timeout(guard, Duration::from_millis(200))
                        .unwrap();
                    std::mem::take(&mut *guard)
                };
                if shared.stop.load(Ordering::Acquire) {
                    return;
                }
                let every = shared.opts.snapshot_every;
                let due_time = !every.is_zero() && last.elapsed() >= every;
                if !(due_size || due_time) {
                    continue;
                }
                if failures > 0 && Instant::now() < retry_at {
                    // Holding back. The size trigger was consumed above —
                    // re-assert it so the pressure that fired it is not
                    // forgotten once the backoff window closes.
                    if due_size {
                        *shared.snap_signal.lock().unwrap() = true;
                    }
                    continue;
                }
                match shared.checkpoint() {
                    Ok(_) => {
                        if failures > 0 {
                            failures = 0;
                            shared.health.snapshot_backoff.set(0);
                            eprintln!("membig: background checkpoint recovered; backoff cleared");
                        }
                    }
                    Err(e) => {
                        self_heal_note(&e);
                        shared.metrics.snapshot_errors.inc();
                        shared.health.snapshot_errors.inc();
                        shared.health.snapshot_backoff.set(1);
                        retry_at = Instant::now() + snapshot_backoff_delay(failures);
                        failures = failures.saturating_add(1);
                    }
                }
                last = Instant::now();
            }
        })
        .expect("spawn membig-snapshot thread");
    Some(handle)
}

fn self_heal_note(e: &DurabilityError) {
    // A failed checkpoint is not fatal: the previous snapshot plus a longer
    // WAL chain still recovers. Surface it and keep serving.
    eprintln!("membig: background checkpoint failed (state remains recoverable): {e}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::record::BookRecord;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("membig_persist_{}", std::process::id()))
            .join(name);
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn opts_manual() -> DurabilityOptions {
        // No background triggers: tests drive checkpoints explicitly.
        DurabilityOptions {
            fsync: false,
            snapshot_every: Duration::ZERO,
            snapshot_wal_bytes: 0,
        }
    }

    fn seeded(n: u64) -> impl FnOnce() -> Result<Arc<ShardedStore>, String> {
        move || {
            let s = ShardedStore::new(4, 256);
            for k in 1..=n {
                s.insert(BookRecord::new(k, 100, 1));
            }
            Ok(Arc::new(s))
        }
    }

    fn no_seed() -> impl FnOnce() -> Result<Arc<ShardedStore>, String> {
        || Err("seed must not run on recovery".into())
    }

    fn up(k: u64, price: u64, qty: u32) -> StockUpdate {
        StockUpdate { isbn13: k, new_price_cents: price, new_quantity: qty }
    }

    #[test]
    fn fresh_init_then_reopen_replays_all_commits() {
        let dir = tdir("fresh");
        let (store, persist, rep) =
            Persistence::open(&dir, opts_manual(), 4, seeded(100)).unwrap();
        assert!(rep.fresh);
        assert_eq!(rep.snapshot_records, 100);
        assert_eq!(persist.wal_generation(), 0);

        assert!(persist.apply_update(&up(1, 500, 5), true).unwrap());
        assert!(!persist.apply_update(&up(9_999, 1, 1), true).unwrap(), "miss is logged too");
        let (applied, missed) =
            persist.apply_many(&[up(2, 600, 6), up(3, 700, 7), up(8_888, 1, 1)], true).unwrap();
        assert_eq!((applied, missed), (2, 1));
        // Deferred group: two appends, one sync.
        persist.apply_update(&up(4, 800, 8), false).unwrap();
        persist.apply_update(&up(5, 900, 9), false).unwrap();
        persist.sync().unwrap();
        assert_eq!(persist.metrics().wal_appends.get(), 7);
        assert_eq!(store.get(4).unwrap().price_cents, 800);
        drop(persist);
        drop(store);

        let (store, persist, rep) =
            Persistence::open(&dir, opts_manual(), 8, no_seed()).unwrap();
        assert!(!rep.fresh);
        assert_eq!(rep.snapshot_generation, 0);
        assert_eq!(rep.wal_generation, 0);
        assert_eq!(rep.wal_frames, 7);
        assert_eq!(rep.chain, 1);
        assert!(!rep.torn_tail);
        assert_eq!(store.len(), 100);
        for (k, price, qty) in [(1, 500, 5u32), (2, 600, 6), (3, 700, 7), (4, 800, 8), (5, 900, 9)]
        {
            let r = store.get(k).unwrap();
            assert_eq!((r.price_cents, r.quantity), (price, qty), "key {k}");
        }
        assert_eq!(store.get(50).unwrap().price_cents, 100, "untouched key unchanged");
        drop(persist);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_rotates_gcs_and_recovers_from_new_generation() {
        let dir = tdir("rotate");
        let (store, persist, _) =
            Persistence::open(&dir, opts_manual(), 4, seeded(50)).unwrap();
        let phase1: Vec<StockUpdate> = (1..=50).map(|k| up(k, 1_000 + k, 2)).collect();
        persist.apply_many(&phase1, true).unwrap();

        let stats = persist.checkpoint_now().unwrap();
        assert_eq!(stats.generation, 1);
        assert_eq!(stats.records, 50);
        assert_eq!(persist.wal_generation(), 1);
        assert!(snap_path(&dir, 1).exists());
        assert!(wal_path(&dir, 1).exists());
        assert!(!snap_path(&dir, 0).exists(), "old snapshot GC'd");
        assert!(!wal_path(&dir, 0).exists(), "old WAL GC'd");
        assert_eq!(read_manifest(&dir), Some(1));
        assert_eq!(persist.metrics().snapshots.get(), 1);
        assert_eq!(persist.metrics().generation.get(), 1);

        // Post-checkpoint tail lands in wal-1.
        persist.apply_many(&[up(7, 77_777, 7), up(8, 88_888, 8)], true).unwrap();
        drop(persist);
        drop(store);

        let (store, persist, rep) =
            Persistence::open(&dir, opts_manual(), 4, no_seed()).unwrap();
        assert_eq!(rep.snapshot_generation, 1);
        assert_eq!(rep.wal_generation, 1);
        assert_eq!(rep.wal_frames, 2);
        assert_eq!(store.get(7).unwrap().price_cents, 77_777);
        assert_eq!(store.get(8).unwrap().quantity, 8);
        assert_eq!(store.get(9).unwrap().price_cents, 1_009, "phase-1 value via snapshot");
        drop(persist);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_live_tail_is_dropped_trimmed_and_appendable() {
        let dir = tdir("torn");
        let (_, persist, _) = Persistence::open(&dir, opts_manual(), 4, seeded(20)).unwrap();
        for k in 1..=10u64 {
            persist.apply_update(&up(k, 2_000 + k, 3), true).unwrap();
        }
        drop(persist);

        // Crash mid-frame: cut 7 bytes into the 9th frame.
        let live = wal_path(&dir, 0);
        let full = std::fs::metadata(&live).unwrap().len();
        assert_eq!(full, 10 * FRAME_BYTES as u64);
        let f = std::fs::OpenOptions::new().write(true).open(&live).unwrap();
        f.set_len(8 * FRAME_BYTES as u64 + 7).unwrap();
        drop(f);

        let (store, persist, rep) = Persistence::open(&dir, opts_manual(), 4, no_seed()).unwrap();
        assert!(rep.torn_tail);
        assert_eq!(rep.wal_frames, 8);
        assert_eq!(store.get(8).unwrap().price_cents, 2_008);
        assert_eq!(store.get(9).unwrap().price_cents, 100, "torn frame dropped");
        assert_eq!(
            std::fs::metadata(&live).unwrap().len(),
            8 * FRAME_BYTES as u64,
            "live WAL trimmed to its valid prefix"
        );

        // Appends after the trim must survive another restart.
        persist.apply_update(&up(15, 42_000, 4), true).unwrap();
        drop(persist);
        let (store, persist, rep) = Persistence::open(&dir, opts_manual(), 4, no_seed()).unwrap();
        assert!(!rep.torn_tail);
        assert_eq!(rep.wal_frames, 9);
        assert_eq!(store.get(15).unwrap().price_cents, 42_000);
        assert_eq!(store.get(8).unwrap().price_cents, 2_008);
        drop(persist);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recovery_without_manifest_scans_for_newest_snapshot() {
        let dir = tdir("noman");
        let (_, persist, _) = Persistence::open(&dir, opts_manual(), 4, seeded(30)).unwrap();
        persist.apply_many(&(1..=30).map(|k| up(k, 3_000 + k, 1)).collect::<Vec<_>>(), true)
            .unwrap();
        persist.checkpoint_now().unwrap();
        persist.apply_update(&up(5, 55_555, 5), true).unwrap();
        drop(persist);

        std::fs::remove_file(dir.join(MANIFEST)).unwrap();
        let (store, persist, rep) = Persistence::open(&dir, opts_manual(), 4, no_seed()).unwrap();
        assert_eq!(rep.snapshot_generation, 1);
        assert_eq!(rep.wal_frames, 1);
        assert_eq!(store.get(5).unwrap().price_cents, 55_555);
        assert_eq!(store.get(6).unwrap().price_cents, 3_006);
        assert_eq!(read_manifest(&dir), Some(1), "manifest rewritten after recovery");
        drop(persist);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crash_between_rotation_and_manifest_replays_the_chain() {
        // Hand-build the on-disk layout a crash between WAL rotation and
        // manifest publication leaves behind: manifest + snapshot at gen 5,
        // plus wal-5 AND wal-6 (the freshly rotated segment).
        let dir = tdir("chain");
        std::fs::create_dir_all(&dir).unwrap();
        let base = ShardedStore::new(4, 64);
        for k in 1..=40u64 {
            base.insert(BookRecord::new(k, 100, 1));
        }
        write_snapshot(&base, snap_path(&dir, 5)).unwrap();
        write_manifest(&dir, 5).unwrap();
        {
            let mut w = Wal::open(wal_path(&dir, 5)).unwrap();
            w.append_batch(&(1..=20).map(|k| up(k, 5_000 + k, 2)).collect::<Vec<_>>()).unwrap();
            w.sync().unwrap();
        }
        {
            let mut w = Wal::open(wal_path(&dir, 6)).unwrap();
            w.append_batch(&[up(1, 60_001, 6), up(21, 60_021, 6)]).unwrap();
            w.sync().unwrap();
        }

        let (store, persist, rep) = Persistence::open(&dir, opts_manual(), 4, no_seed()).unwrap();
        assert_eq!(rep.snapshot_generation, 5);
        assert_eq!(rep.wal_generation, 6, "appends continue into the newest segment");
        assert_eq!(rep.chain, 2);
        assert_eq!(rep.wal_frames, 22);
        assert_eq!(store.get(1).unwrap().price_cents, 60_001, "wal-6 wins over wal-5");
        assert_eq!(store.get(20).unwrap().price_cents, 5_020);
        assert_eq!(store.get(21).unwrap().price_cents, 60_021);
        assert_eq!(store.get(22).unwrap().price_cents, 100);
        // The next checkpoint moves past the whole chain.
        persist.checkpoint_now().unwrap();
        assert_eq!(persist.wal_generation(), 7);
        assert!(!wal_path(&dir, 5).exists());
        assert!(!wal_path(&dir, 6).exists());
        drop(persist);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_trigger_checkpoints_in_background() {
        let dir = tdir("sizetrig");
        let opts = DurabilityOptions {
            fsync: false,
            snapshot_every: Duration::ZERO,
            snapshot_wal_bytes: 10 * FRAME_BYTES as u64,
        };
        let (_, persist, _) = Persistence::open(&dir, opts, 4, seeded(20)).unwrap();
        persist
            .apply_many(&(1..=20).map(|k| up(k, 4_000 + k, 4)).collect::<Vec<_>>(), true)
            .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while persist.metrics().snapshots.get() == 0 {
            assert!(Instant::now() < deadline, "background size-triggered checkpoint never ran");
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(persist.wal_generation() >= 1);
        drop(persist);
        let (store, persist, rep) = Persistence::open(
            &dir,
            DurabilityOptions { snapshot_wal_bytes: 0, ..opts_manual() },
            4,
            no_seed(),
        )
        .unwrap();
        assert!(rep.snapshot_generation >= 1);
        assert_eq!(store.get(20).unwrap().price_cents, 4_020);
        drop(persist);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn backoff_delay_doubles_and_caps() {
        assert_eq!(snapshot_backoff_delay(0), Duration::from_millis(500));
        assert_eq!(snapshot_backoff_delay(1), Duration::from_millis(1_000));
        assert_eq!(snapshot_backoff_delay(3), Duration::from_millis(4_000));
        // Capped: the exponent clamps at 6 and the product at 30 s.
        assert_eq!(snapshot_backoff_delay(6), Duration::from_millis(30_000));
        assert_eq!(snapshot_backoff_delay(60), Duration::from_millis(30_000));
        let mut prev = Duration::ZERO;
        for f in 0..12 {
            let d = snapshot_backoff_delay(f);
            assert!(d >= prev, "delay must be monotone");
            prev = d;
        }
    }

    #[test]
    fn empty_commit_is_a_noop() {
        let dir = tdir("empty");
        let (_, persist, _) = Persistence::open(&dir, opts_manual(), 2, seeded(1)).unwrap();
        assert_eq!(persist.apply_many(&[], true).unwrap(), (0, 0));
        assert_eq!(persist.metrics().wal_appends.get(), 0);
        persist.sync().unwrap();
        assert_eq!(persist.metrics().wal_syncs.get(), 0, "no pending frames, no sync");
        drop(persist);
        std::fs::remove_dir_all(&dir).ok();
    }
}
