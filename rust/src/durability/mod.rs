//! Durability for the memory store: write-ahead log + binary snapshots.
//!
//! The paper loads the database into RAM "prior to processing" and writes
//! results back at the end; anything in between dies with the process. A
//! production one-server deployment needs better:
//!
//! - [`wal`] — an append-only, CRC-framed write-ahead log of applied
//!   updates. Replaying `snapshot + WAL suffix` reconstructs the exact
//!   store state after a crash.
//! - [`snapshot`] — compact binary checkpoints of the full store. Loading
//!   a snapshot is a sequential read of 24-byte records — far cheaper than
//!   re-scanning the paged disk table (see the `recovery` rows of the
//!   ablations bench).
//! - [`persist`] — the live layer tying both together behind the server:
//!   group-committed WAL appends on the mutation path, a background
//!   snapshotter with generation-numbered checkpoints + manifest, and
//!   crash recovery that replays `snapshot + WAL chain` to the exact
//!   pre-crash (synced) state. See `DESIGN.md` §9.

pub mod persist;
pub mod snapshot;
pub mod wal;

pub use persist::{
    CheckpointStats, CommitSink, DurabilityError, DurabilityOptions, Persistence, RecoveryReport,
};
pub use snapshot::{load_snapshot, verify_snapshot, write_snapshot};
pub use wal::{decode_frame, encode_frame, Wal, WalReader, FRAME_BYTES};
