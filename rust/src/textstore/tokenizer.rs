//! Minimal text tokenizer: ASCII-lowercased alphanumeric runs, short/stop
//! words dropped. Deliberately simple — the contribution under test is the
//! memory/parallelism architecture, not linguistics.

/// Words excluded from the index (tiny closed-class set).
pub const STOPWORDS: &[&str] =
    &["the", "a", "an", "and", "or", "of", "to", "in", "is", "it", "on", "for", "with", "as"];

fn is_stopword(w: &str) -> bool {
    STOPWORDS.contains(&w)
}

/// Tokenize into lowercase terms, skipping stopwords and 1-char tokens.
/// Allocation-conscious: yields borrowed slices of an internal lowercase
/// buffer via a callback to keep the indexing hot loop copy-light.
pub fn tokenize_into(text: &str, mut emit: impl FnMut(&str)) {
    let mut word = String::with_capacity(16);
    for c in text.chars() {
        if c.is_ascii_alphanumeric() {
            word.push(c.to_ascii_lowercase());
        } else if !word.is_empty() {
            if word.len() > 1 && !is_stopword(&word) {
                emit(&word);
            }
            word.clear();
        }
    }
    if word.len() > 1 && !is_stopword(&word) {
        emit(&word);
    }
}

/// Convenience: collect tokens into a Vec (tests / small call sites).
pub fn tokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    tokenize_into(text, |w| out.push(w.to_string()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_splitting_and_lowering() {
        assert_eq!(tokenize("Hello, World! HELLO?"), vec!["hello", "world", "hello"]);
    }

    #[test]
    fn stopwords_and_singles_dropped() {
        assert_eq!(tokenize("the cat and a dog in x"), vec!["cat", "dog"]);
    }

    #[test]
    fn alphanumerics_kept_together() {
        assert_eq!(tokenize("isbn13 978-0306406157"), vec!["isbn13", "978", "0306406157"]);
    }

    #[test]
    fn empty_and_punctuation_only() {
        assert!(tokenize("").is_empty());
        assert!(tokenize("!!! ... ???").is_empty());
    }

    #[test]
    fn trailing_word_emitted() {
        assert_eq!(tokenize("big data"), vec!["big", "data"]);
    }
}
