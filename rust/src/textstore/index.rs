//! In-memory inverted index with parallel construction.
//!
//! Build: documents are partitioned across `threads` workers; each worker
//! builds a *local* index (term → postings), then the leader merges — the
//! shared-memory analogue of map/reduce, with zero synchronization during
//! the map phase (paper §4.2 applied to text).
//!
//! Query: conjunctive (AND) term queries with tf scoring, top-k by score.

use std::collections::HashMap;

use super::corpus::Document;
use super::tokenizer::tokenize_into;
use crate::util::split_ranges;

/// Posting: (doc id, term frequency).
pub type Posting = (u64, u32);

#[derive(Default)]
pub struct InvertedIndex {
    terms: HashMap<String, Vec<Posting>>,
    pub docs: u64,
}

impl InvertedIndex {
    /// Single-threaded build (baseline for the scaling ablation).
    pub fn build(docs: &[Document]) -> Self {
        let mut idx = InvertedIndex::default();
        for d in docs {
            idx.add_document(d);
        }
        idx.finalize();
        idx
    }

    /// Parallel build: map (local indexes) + reduce (merge).
    pub fn build_parallel(docs: &[Document], threads: usize) -> Self {
        assert!(threads > 0);
        if threads == 1 || docs.len() < 2 {
            return Self::build(docs);
        }
        let ranges = split_ranges(docs.len(), threads);
        let locals: Vec<InvertedIndex> = std::thread::scope(|scope| {
            let handles: Vec<_> = ranges
                .into_iter()
                .map(|r| {
                    let slice = &docs[r];
                    scope.spawn(move || {
                        let mut local = InvertedIndex::default();
                        for d in slice {
                            local.add_document(d);
                        }
                        local
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("indexer panicked")).collect()
        });
        let mut merged = InvertedIndex::default();
        for local in locals {
            merged.docs += local.docs;
            for (term, mut postings) in local.terms {
                merged.terms.entry(term).or_default().append(&mut postings);
            }
        }
        merged.finalize();
        merged
    }

    fn add_document(&mut self, doc: &Document) {
        // Aggregate term frequencies within the document first.
        let mut tf: HashMap<String, u32> = HashMap::new();
        tokenize_into(&doc.text, |w| {
            *tf.entry(w.to_string()).or_insert(0) += 1;
        });
        for (term, count) in tf {
            self.terms.entry(term).or_default().push((doc.id, count));
        }
        self.docs += 1;
    }

    /// Sort postings by doc id (required by the intersection) — called once
    /// after build/merge.
    fn finalize(&mut self) {
        for postings in self.terms.values_mut() {
            postings.sort_unstable_by_key(|&(id, _)| id);
        }
    }

    pub fn term_count(&self) -> usize {
        self.terms.len()
    }

    pub fn postings(&self, term: &str) -> Option<&[Posting]> {
        self.terms.get(term).map(|v| v.as_slice())
    }

    /// Conjunctive query: documents containing *all* terms, scored by
    /// summed tf, top-k by (score desc, id asc).
    pub fn search(&self, query: &str, k: usize) -> Vec<(u64, u32)> {
        let mut terms = Vec::new();
        tokenize_into(query, |w| terms.push(w.to_string()));
        if terms.is_empty() {
            return Vec::new();
        }
        terms.sort();
        terms.dedup();
        // Gather posting lists; a missing term → empty result.
        let mut lists: Vec<&[Posting]> = Vec::with_capacity(terms.len());
        for t in &terms {
            match self.postings(t) {
                Some(p) => lists.push(p),
                None => return Vec::new(),
            }
        }
        // Intersect starting from the rarest list.
        lists.sort_by_key(|l| l.len());
        let mut acc: Vec<(u64, u32)> = lists[0].to_vec();
        for list in &lists[1..] {
            let mut out = Vec::with_capacity(acc.len().min(list.len()));
            let (mut i, mut j) = (0usize, 0usize);
            while i < acc.len() && j < list.len() {
                match acc[i].0.cmp(&list[j].0) {
                    std::cmp::Ordering::Less => i += 1,
                    std::cmp::Ordering::Greater => j += 1,
                    std::cmp::Ordering::Equal => {
                        out.push((acc[i].0, acc[i].1 + list[j].1));
                        i += 1;
                        j += 1;
                    }
                }
            }
            acc = out;
            if acc.is_empty() {
                return acc;
            }
        }
        acc.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        acc.truncate(k);
        acc
    }

    /// Approximate resident bytes.
    pub fn memory_bytes(&self) -> usize {
        self.terms
            .iter()
            .map(|(t, p)| t.len() + 48 + p.len() * std::mem::size_of::<Posting>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::textstore::corpus::CorpusSpec;

    fn doc(id: u64, text: &str) -> Document {
        Document { id, text: text.to_string() }
    }

    #[test]
    fn search_single_term() {
        let idx = InvertedIndex::build(&[
            doc(1, "big data computation"),
            doc(2, "small data"),
            doc(3, "big big big ideas"),
        ]);
        let hits = idx.search("big", 10);
        assert_eq!(hits, vec![(3, 3), (1, 1)], "tf-ordered");
    }

    #[test]
    fn search_conjunction() {
        let idx = InvertedIndex::build(&[
            doc(1, "memory based processing"),
            doc(2, "memory leaks"),
            doc(3, "stream processing memory pool"),
        ]);
        let hits = idx.search("memory processing", 10);
        assert_eq!(hits.iter().map(|h| h.0).collect::<Vec<_>>(), vec![1, 3]);
        assert!(idx.search("memory nonexistentterm", 10).is_empty());
        assert!(idx.search("", 10).is_empty());
    }

    #[test]
    fn top_k_truncation() {
        let docs: Vec<Document> = (0..50).map(|i| doc(i, "common term here")).collect();
        let idx = InvertedIndex::build(&docs);
        assert_eq!(idx.search("common", 5).len(), 5);
    }

    #[test]
    fn parallel_build_equals_serial() {
        let spec = CorpusSpec { docs: 2_000, ..Default::default() };
        let docs = crate::textstore::generate_corpus(&spec);
        let serial = InvertedIndex::build(&docs);
        for threads in [2usize, 3, 8] {
            let par = InvertedIndex::build_parallel(&docs, threads);
            assert_eq!(par.docs, serial.docs);
            assert_eq!(par.term_count(), serial.term_count(), "threads={threads}");
            // Identical results for a few probe queries.
            for q in ["t0", "t1 t2", "t5 t10 t0", "t999"] {
                assert_eq!(par.search(q, 20), serial.search(q, 20), "query {q:?}");
            }
        }
    }

    #[test]
    fn postings_sorted_by_doc_id() {
        let spec = CorpusSpec { docs: 500, ..Default::default() };
        let docs = crate::textstore::generate_corpus(&spec);
        let idx = InvertedIndex::build_parallel(&docs, 4);
        let p = idx.postings("t0").expect("t0 is the hottest term");
        assert!(p.windows(2).all(|w| w[0].0 < w[1].0), "postings must be sorted");
    }

    #[test]
    fn stopwords_not_indexed() {
        let idx = InvertedIndex::build(&[doc(1, "the cat and the hat")]);
        assert!(idx.postings("the").is_none());
        assert!(idx.postings("cat").is_some());
    }
}
