//! Unstructured-data extension (paper §7: "support not only relational
//! databases but also unstructured data such as text and web documents").
//!
//! The same memory-based multi-processing method applied to text: documents
//! are tokenized and indexed into an **in-memory inverted index**, built in
//! parallel with one indexer thread per core (local index per worker →
//! leader merge, the map/reduce shape the paper positions itself against),
//! then queried at RAM latency. The disk-based baseline — re-scanning the
//! corpus per query, as the conventional app re-reads the database per
//! update — is in [`scan`], and the `textsearch` bench reproduces the
//! Table-1 *shape* on this workload.

pub mod corpus;
pub mod index;
pub mod scan;
pub mod tokenizer;

pub use corpus::{generate_corpus, CorpusSpec, Document};
pub use index::InvertedIndex;
pub use tokenizer::tokenize;
