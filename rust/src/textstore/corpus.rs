//! Synthetic web-document corpus: zipf-distributed vocabulary (like real
//! text), deterministic from a spec, with a line-oriented on-disk format
//! (`id<TAB>text`) for the disk-scan baseline.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::util::rng::{Rng, Zipf};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Document {
    pub id: u64,
    pub text: String,
}

#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub docs: u64,
    /// Vocabulary size; term `t<k>` has zipf rank k.
    pub vocab: u64,
    /// Words per document (uniform in [min, max)).
    pub min_words: usize,
    pub max_words: usize,
    /// Zipf skew of term frequencies (≈1.0 for natural text).
    pub theta: f64,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec { docs: 10_000, vocab: 20_000, min_words: 30, max_words: 200, theta: 1.07, seed: 7 }
    }
}

impl CorpusSpec {
    /// Deterministic O(1)-seekable document generator.
    pub fn document_at(&self, i: u64) -> Document {
        debug_assert!(i < self.docs);
        let mut rng = Rng::new(self.seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let zipf = Zipf::new(self.vocab, self.theta);
        let n_words = rng.range_usize(self.min_words, self.max_words);
        let mut text = String::with_capacity(n_words * 7);
        for w in 0..n_words {
            if w > 0 {
                text.push(' ');
            }
            let term = zipf.sample(&mut rng);
            text.push_str("t");
            text.push_str(&term.to_string());
        }
        Document { id: i, text }
    }

    pub fn iter(&self) -> impl Iterator<Item = Document> + '_ {
        (0..self.docs).map(move |i| self.document_at(i))
    }
}

pub fn generate_corpus(spec: &CorpusSpec) -> Vec<Document> {
    spec.iter().collect()
}

/// Write corpus to disk (`id<TAB>text\n` per doc). Returns bytes written.
pub fn write_corpus(path: impl AsRef<Path>, spec: &CorpusSpec) -> std::io::Result<u64> {
    let mut out = BufWriter::with_capacity(1 << 20, std::fs::File::create(path)?);
    let mut bytes = 0u64;
    for doc in spec.iter() {
        let line = format!("{}\t{}\n", doc.id, doc.text);
        out.write_all(line.as_bytes())?;
        bytes += line.len() as u64;
    }
    out.flush()?;
    Ok(bytes)
}

/// Stream documents back from disk.
pub fn read_corpus(
    path: impl AsRef<Path>,
    mut f: impl FnMut(Document),
) -> std::io::Result<u64> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::with_capacity(1 << 20, file);
    let mut n = 0u64;
    for line in reader.lines() {
        let line = line?;
        if let Some((id, text)) = line.split_once('\t') {
            if let Ok(id) = id.parse() {
                f(Document { id, text: text.to_string() });
                n += 1;
            }
        }
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sized() {
        let spec = CorpusSpec { docs: 100, ..Default::default() };
        let a = generate_corpus(&spec);
        let b = generate_corpus(&spec);
        assert_eq!(a, b);
        assert_eq!(a.len(), 100);
        for d in &a {
            let words = d.text.split(' ').count();
            assert!((spec.min_words..spec.max_words).contains(&words));
        }
        assert_eq!(spec.document_at(42), a[42]);
    }

    #[test]
    fn zipf_vocabulary_head_heavy() {
        let spec = CorpusSpec { docs: 500, ..Default::default() };
        let mut head = 0u64;
        let mut total = 0u64;
        for d in spec.iter() {
            for w in d.text.split(' ') {
                total += 1;
                if w == "t0" || w == "t1" || w == "t2" {
                    head += 1;
                }
            }
        }
        assert!(
            head as f64 > total as f64 * 0.05,
            "top-3 terms should carry a visible share: {head}/{total}"
        );
    }

    #[test]
    fn disk_roundtrip() {
        let spec = CorpusSpec { docs: 200, ..Default::default() };
        let path = std::env::temp_dir().join(format!("membig_corpus_{}.tsv", std::process::id()));
        write_corpus(&path, &spec).unwrap();
        let mut back = Vec::new();
        let n = read_corpus(&path, |d| back.push(d)).unwrap();
        assert_eq!(n, 200);
        assert_eq!(back, generate_corpus(&spec));
        std::fs::remove_file(&path).ok();
    }
}
