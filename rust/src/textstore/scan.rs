//! Disk-scan search baseline: answer each query by re-reading the corpus
//! file and scanning every document — the conventional (non-memory-based)
//! way, charged under the same HDD latency model as the record store so
//! the textsearch bench can reproduce the Table-1 shape on text.

use std::path::Path;
use std::sync::Arc;

use super::corpus::read_corpus;
use super::tokenizer::tokenize_into;
use crate::storage::latency::{AccessKind, DiskSim};

/// Scan-search the on-disk corpus: documents containing all query terms,
/// scored by summed tf, top-k. Charges `sim` one sequential access per
/// 64KiB read (streaming scan) plus one initial seek.
pub fn scan_search(
    corpus_path: &Path,
    query: &str,
    k: usize,
    sim: &Arc<DiskSim>,
) -> std::io::Result<Vec<(u64, u32)>> {
    let mut qterms: Vec<String> = Vec::new();
    tokenize_into(query, |w| qterms.push(w.to_string()));
    qterms.sort();
    qterms.dedup();
    if qterms.is_empty() {
        return Ok(Vec::new());
    }

    // One seek to position the head, then stream sequentially.
    sim.charge(AccessKind::Random, 0);
    let bytes = std::fs::metadata(corpus_path)?.len();
    sim.charge(AccessKind::Sequential, bytes as usize);

    let mut hits: Vec<(u64, u32)> = Vec::new();
    read_corpus(corpus_path, |doc| {
        let mut found = vec![0u32; qterms.len()];
        tokenize_into(&doc.text, |w| {
            if let Ok(i) = qterms.binary_search_by(|t| t.as_str().cmp(w)) {
                found[i] += 1;
            }
        });
        if found.iter().all(|&c| c > 0) {
            hits.push((doc.id, found.iter().sum()));
        }
    })?;
    hits.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    hits.truncate(k);
    Ok(hits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::latency::DiskProfile;
    use crate::textstore::corpus::{write_corpus, CorpusSpec};
    use crate::textstore::InvertedIndex;

    #[test]
    fn scan_matches_index_results() {
        let spec = CorpusSpec { docs: 800, ..Default::default() };
        let path =
            std::env::temp_dir().join(format!("membig_scan_{}.tsv", std::process::id()));
        write_corpus(&path, &spec).unwrap();
        let docs = crate::textstore::generate_corpus(&spec);
        let idx = InvertedIndex::build(&docs);
        let sim = Arc::new(DiskSim::new(DiskProfile::none()));
        for q in ["t0", "t1 t3", "t2 t5 t9"] {
            let a = scan_search(&path, q, 25, &sim).unwrap();
            let b = idx.search(q, 25);
            assert_eq!(a, b, "query {q:?}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scan_charges_latency_model() {
        let spec = CorpusSpec { docs: 300, ..Default::default() };
        let path =
            std::env::temp_dir().join(format!("membig_scanlat_{}.tsv", std::process::id()));
        write_corpus(&path, &spec).unwrap();
        let sim = Arc::new(DiskSim::new(DiskProfile::default()));
        scan_search(&path, "t0", 10, &sim).unwrap();
        // ≥ one seek (≈12.7ms) + transfer time.
        assert!(sim.modeled().as_millis() >= 12, "modeled {:?}", sim.modeled());
        std::fs::remove_file(&path).ok();
    }
}
