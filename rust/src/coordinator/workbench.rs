//! Workbench: prepares the §5 experiment inputs — the book-inventory
//! database (DiskTable) and the `Stock.dat` feed — in a directory, reusing
//! them across runs when the spec hasn't changed (like `make artifacts`).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::CoordinatorError;
use crate::config::EngineConfig;
use crate::storage::latency::DiskSim;
use crate::storage::table::{DiskTable, TableOptions};
use crate::workload::gen::{generate_stock_updates, DatasetSpec, KeyDist};
use crate::workload::stockfile::write_stock_file;

pub struct Workbench {
    pub dir: PathBuf,
    pub spec: DatasetSpec,
}

impl Workbench {
    pub fn new(dir: impl AsRef<Path>, spec: DatasetSpec) -> Self {
        Workbench { dir: dir.as_ref().to_path_buf(), spec }
    }

    pub fn table_dir(&self) -> PathBuf {
        self.dir.join(format!("table_{}_{}", self.spec.records, self.spec.seed))
    }

    pub fn stock_path(&self, updates: u64) -> PathBuf {
        self.dir.join(format!("stock_{}_{}_{}.dat", self.spec.records, updates, self.spec.seed))
    }

    /// Build (or reuse) the disk table. Building happens with a free latency
    /// model — the paper's DB exists before the experiment starts; only the
    /// measured runs pay mechanical costs.
    pub fn ensure_table(&self, cfg: &EngineConfig) -> Result<DiskTable, CoordinatorError> {
        let dir = self.table_dir();
        let opts = TableOptions { cache_pages: cfg.page_cache_pages, engine_overhead: true };
        let sim = Arc::new(DiskSim::new(cfg.disk));
        if dir.join("meta.mbm").exists() {
            let t = DiskTable::open(&dir, sim.clone(), opts.clone())?;
            if t.len() == self.spec.records {
                return Ok(t);
            }
            // Spec changed → rebuild.
            drop(t);
            std::fs::remove_dir_all(&dir)?;
        }
        let build_sim = Arc::new(DiskSim::new(crate::storage::latency::DiskProfile::none()));
        let _ = DiskTable::create(
            &dir,
            self.spec.iter(),
            self.spec.records,
            build_sim,
            opts.clone(),
        )?;
        // Reopen under the *experiment's* latency model.
        Ok(DiskTable::open(&dir, sim, opts)?)
    }

    /// Build (or reuse) a stock file with `updates` entries.
    pub fn ensure_stock(&self, updates: u64) -> Result<PathBuf, CoordinatorError> {
        let path = self.stock_path(updates);
        if !path.exists() {
            std::fs::create_dir_all(&self.dir)?;
            let dist =
                if updates <= self.spec.records { KeyDist::PermuteAll } else { KeyDist::Uniform };
            let ups = generate_stock_updates(&self.spec, updates, dist, self.spec.seed);
            write_stock_file(&path, &ups)?;
        }
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Coordinator;

    fn bench_dir(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("membig_wb_{}_{}", std::process::id(), name))
    }

    fn cfg(dir: &Path) -> EngineConfig {
        let mut c = EngineConfig::default();
        c.data_dir = dir.to_path_buf();
        c.shards = 4;
        c.threads = 4;
        c.disk.scale = 0.0;
        c
    }

    #[test]
    fn ensure_table_builds_then_reuses() {
        let dir = bench_dir("reuse");
        std::fs::remove_dir_all(&dir).ok();
        let spec = DatasetSpec { records: 1_000, ..Default::default() };
        let wb = Workbench::new(&dir, spec.clone());
        let c = cfg(&dir);
        let t1 = wb.ensure_table(&c).unwrap();
        assert_eq!(t1.len(), 1_000);
        drop(t1);
        // Second call must open, not rebuild (same meta).
        let t2 = wb.ensure_table(&c).unwrap();
        assert_eq!(t2.len(), 1_000);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ensure_stock_is_idempotent() {
        let dir = bench_dir("stock");
        std::fs::remove_dir_all(&dir).ok();
        let spec = DatasetSpec { records: 500, ..Default::default() };
        let wb = Workbench::new(&dir, spec);
        let p1 = wb.ensure_stock(500).unwrap();
        let bytes1 = std::fs::metadata(&p1).unwrap().len();
        let p2 = wb.ensure_stock(500).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(std::fs::metadata(&p2).unwrap().len(), bytes1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn end_to_end_proposed_vs_conventional_small() {
        // A miniature Table-1 cell: both apps over the same inputs agree on
        // the final database state.
        let dir = bench_dir("e2e");
        std::fs::remove_dir_all(&dir).ok();
        let spec = DatasetSpec { records: 2_000, ..Default::default() };
        let wb = Workbench::new(&dir, spec.clone());
        let mut c = cfg(&dir);
        c.writeback = true;

        let stock = wb.ensure_stock(2_000).unwrap();

        // Proposed run.
        let coord = Coordinator::new(c.clone());
        let table = wb.ensure_table(&c).unwrap();
        let out = coord.run_proposed(&table, &stock).unwrap();
        assert_eq!(out.stream.updates_applied, 2_000);
        assert_eq!(out.written_back, 2_000);
        let (_, proposed_value) = out.store.value_sum_cents();
        drop(table);

        // Conventional run over a *fresh* copy of the table.
        std::fs::remove_dir_all(wb.table_dir()).unwrap();
        let table = wb.ensure_table(&c).unwrap();
        let coord2 = Coordinator::new(c);
        let rep = coord2.run_conventional(&table, &stock).unwrap();
        assert_eq!(rep.updates_applied, 2_000);
        let mut conv_value: u128 = 0;
        table.scan(|r| conv_value += r.value_cents()).unwrap();

        assert_eq!(proposed_value, conv_value, "both apps must produce identical state");
        std::fs::remove_dir_all(&dir).ok();
    }
}
