//! The leader: wires config → workload → storage → memstore → pipeline →
//! analytics → writeback, with per-phase timing. `run_proposed` is the
//! paper's second application; `run_conventional` the first. `Workbench`
//! prepares the experiment inputs (database + Stock.dat) the way §5 does.

pub mod report;
pub mod workbench;

pub use report::{ProposedOutcome, RunReport};
pub use workbench::Workbench;

use std::path::Path;
use std::sync::Arc;

use crate::baseline::conventional::{run_conventional_stream, ConventionalReport};
use crate::config::EngineConfig;
use crate::memstore::snapshot::{load_store, verify_against_table, writeback};
use crate::memstore::ShardedStore;
use crate::metrics::EngineMetrics;
use crate::pipeline::executor::{run_streaming_update, PipelineError};
use crate::storage::table::{DiskTable, TableError, TableOptions};

#[derive(Debug)]
pub enum CoordinatorError {
    Table(TableError),
    Pipeline(PipelineError),
    Io(std::io::Error),
    Verification(u64),
}

impl std::fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoordinatorError::Table(e) => write!(f, "table: {e}"),
            CoordinatorError::Pipeline(e) => write!(f, "pipeline: {e}"),
            CoordinatorError::Io(e) => write!(f, "io: {e}"),
            CoordinatorError::Verification(n) => {
                write!(f, "verification failed: {n} records diverge between store and table")
            }
        }
    }
}

impl std::error::Error for CoordinatorError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CoordinatorError::Table(e) => Some(e),
            CoordinatorError::Pipeline(e) => Some(e),
            CoordinatorError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<TableError> for CoordinatorError {
    fn from(e: TableError) -> Self {
        CoordinatorError::Table(e)
    }
}

impl From<PipelineError> for CoordinatorError {
    fn from(e: PipelineError) -> Self {
        CoordinatorError::Pipeline(e)
    }
}

impl From<std::io::Error> for CoordinatorError {
    fn from(e: std::io::Error) -> Self {
        CoordinatorError::Io(e)
    }
}

/// Orchestrates one run of either application over prepared inputs.
pub struct Coordinator {
    pub cfg: EngineConfig,
    pub metrics: Arc<EngineMetrics>,
}

impl Coordinator {
    pub fn new(cfg: EngineConfig) -> Self {
        Coordinator { cfg, metrics: Arc::new(EngineMetrics::new()) }
    }

    fn table_opts(&self) -> TableOptions {
        TableOptions { cache_pages: self.cfg.page_cache_pages, engine_overhead: true }
    }

    /// Open the experiment's disk table.
    pub fn open_table(&self, dir: &Path) -> Result<DiskTable, CoordinatorError> {
        let sim = Arc::new(crate::storage::latency::DiskSim::new(self.cfg.disk));
        Ok(DiskTable::open(dir, sim, self.table_opts())?)
    }

    /// The paper's proposed application: load → parallel streaming update →
    /// (optional) writeback → verify.
    pub fn run_proposed(
        &self,
        table: &DiskTable,
        stock_path: &Path,
    ) -> Result<ProposedOutcome, CoordinatorError> {
        let m = &self.metrics;

        // Phase 1: load the database into sharded RAM tables (§4.1).
        let store = m.phases.time("load", || load_store(table, self.cfg.shards, m))?;

        // Phase 2: multi-threaded shared-memory update (§4.2).
        let stream = run_streaming_update(
            &store,
            stock_path,
            self.cfg.batch_size,
            self.cfg.channel_depth,
            m,
        )?;

        // Phase 3: optional writeback + verification.
        let mut written = 0;
        if self.cfg.writeback {
            written = m.phases.time("writeback", || writeback(&store, table, m))?;
            let diverged = verify_against_table(&store, table)?;
            if diverged > 0 {
                return Err(CoordinatorError::Verification(diverged));
            }
        }

        let (count, value_cents) = store.value_sum_cents();
        Ok(ProposedOutcome {
            store,
            stream,
            records: count,
            inventory_value_cents: value_cents,
            written_back: written,
            load: m.phases.get("load").unwrap_or_default(),
            update: m.phases.get("update_stream").unwrap_or_default(),
            writeback: m.phases.get("writeback").unwrap_or_default(),
        })
    }

    /// The paper's conventional application.
    pub fn run_conventional(
        &self,
        table: &DiskTable,
        stock_path: &Path,
    ) -> Result<ConventionalReport, CoordinatorError> {
        Ok(run_conventional_stream(table, stock_path, &self.metrics)?)
    }

    /// Load-only (for servers/analytics without an update feed).
    pub fn load_only(&self, table: &DiskTable) -> Result<Arc<ShardedStore>, CoordinatorError> {
        Ok(self.metrics.phases.time("load", || load_store(table, self.cfg.shards, &self.metrics))?)
    }
}
