//! Run outcomes and rendering — the numbers Table 1 / Figure 6 are made of.

use std::sync::Arc;
use std::time::Duration;

use crate::memstore::ShardedStore;
use crate::pipeline::executor::StreamReport;
use crate::util::fmt::{commas, human_duration, paper_hms};
use crate::util::json::Json;

/// Result of a proposed-method run.
pub struct ProposedOutcome {
    /// The live store (kept for analytics / serving after the run).
    pub store: Arc<ShardedStore>,
    pub stream: StreamReport,
    pub records: u64,
    pub inventory_value_cents: u128,
    pub written_back: u64,
    pub load: Duration,
    pub update: Duration,
    pub writeback: Duration,
}

impl ProposedOutcome {
    pub fn total(&self) -> Duration {
        self.load + self.update + self.writeback
    }
}

/// A paper-style side-by-side row (one N of Table 1).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub n_updates: u64,
    /// Conventional: modeled full-scale mechanical time.
    pub conventional: Duration,
    /// Conventional wall time actually waited (scaled sleeps).
    pub conventional_wall: Duration,
    /// Proposed: wall time (it really runs at full speed).
    pub proposed: Duration,
}

impl RunReport {
    pub fn speedup(&self) -> f64 {
        let p = self.proposed.as_secs_f64();
        if p <= 0.0 {
            f64::INFINITY
        } else {
            self.conventional.as_secs_f64() / p
        }
    }

    /// Render like the paper's Table 1 (plus the speedup column the paper
    /// leaves implicit).
    pub fn render_row(&self) -> String {
        format!(
            "| {:>9} | {:>12} | {:>10} | {:>9.0}x |",
            commas(self.n_updates),
            paper_hms(self.conventional),
            human_duration(self.proposed),
            self.speedup(),
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_updates", Json::num(self.n_updates as f64)),
            ("conventional_modeled_s", Json::num(self.conventional.as_secs_f64())),
            ("conventional_wall_s", Json::num(self.conventional_wall.as_secs_f64())),
            ("proposed_s", Json::num(self.proposed.as_secs_f64())),
            ("speedup", Json::num(self.speedup())),
        ])
    }
}

/// Render a whole Table 1.
pub fn render_table1(rows: &[RunReport]) -> String {
    let mut s = String::new();
    s.push_str("| # updates |  conventional |  proposed  |  speedup  |\n");
    s.push_str("|-----------|---------------|------------|-----------|\n");
    for r in rows {
        s.push_str(&r.render_row());
        s.push('\n');
    }
    s
}

/// ASCII histogram of Table 1 (Figure 6 equivalent): log-scaled bars.
pub fn render_figure6(rows: &[RunReport]) -> String {
    let mut s = String::from("Figure 6 — execution time (log scale, s)\n");
    let max = rows
        .iter()
        .map(|r| r.conventional.as_secs_f64())
        .fold(1.0f64, f64::max)
        .log10();
    for r in rows {
        let conv = r.conventional.as_secs_f64().max(1e-3);
        let prop = r.proposed.as_secs_f64().max(1e-3);
        let bar = |v: f64| -> String {
            let w = ((v.log10() + 3.0) / (max + 3.0) * 50.0).max(0.0) as usize;
            "#".repeat(w.max(1))
        };
        s.push_str(&format!("{:>9}  conv |{:<50}| {:.1}s\n", commas(r.n_updates), bar(conv), conv));
        s.push_str(&format!("{:>9}  prop |{:<50}| {:.3}s\n", "", bar(prop), prop));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(n: u64, conv_s: u64, prop_ms: u64) -> RunReport {
        RunReport {
            n_updates: n,
            conventional: Duration::from_secs(conv_s),
            conventional_wall: Duration::from_millis(conv_s),
            proposed: Duration::from_millis(prop_ms),
        }
    }

    #[test]
    fn speedup_math() {
        let r = row(100_000, 6602, 4_000); // paper: 1h50m02s vs 4s
        assert!((r.speedup() - 1650.5).abs() < 1.0);
    }

    #[test]
    fn table_renders_all_rows() {
        let rows =
            vec![row(100_000, 6602, 4000), row(500_000, 29535, 6000), row(2_000_000, 123471, 63000)];
        let t = render_table1(&rows);
        assert_eq!(t.lines().count(), 5);
        assert!(t.contains("100,000"));
        assert!(t.contains("34h 17m 51s"), "paper's 2M conventional row:\n{t}");
    }

    #[test]
    fn figure6_renders_bars() {
        let rows = vec![row(100_000, 6602, 4000), row(2_000_000, 123471, 63000)];
        let f = render_figure6(&rows);
        assert!(f.contains("conv |#"));
        assert!(f.contains("prop |#"));
        // Conventional bar must be longer than proposed bar for same N.
        let lines: Vec<&str> = f.lines().collect();
        let conv_len = lines[1].matches('#').count();
        let prop_len = lines[2].matches('#').count();
        assert!(conv_len > prop_len);
    }

    #[test]
    fn json_row() {
        let j = row(500_000, 29535, 6000).to_json();
        assert_eq!(j.get("n_updates").unwrap().as_f64().unwrap(), 500_000.0);
        assert!(j.get("speedup").unwrap().as_f64().unwrap() > 4000.0);
    }
}
