//! Load / writeback between the disk store and the memstore.
//!
//! Load is the paper's "copy records from database into RAM prior to
//! processing" step (§4.1): a *sequential* scan of the disk table fanned
//! into the shards, parallelized across loader threads by page range.
//! Writeback persists the updated memstore back to the table at the end of
//! a run (the paper's app updates the database too — its measured time
//! includes it, so ours is measured under the same latency model).

use std::sync::Arc;

use super::shard::ShardedStore;
use crate::metrics::EngineMetrics;
use crate::storage::table::{DiskTable, TableError};
use crate::util::split_ranges;

/// Sequentially scan `table` into a fresh store with `shards` shards.
///
/// Perf note (EXPERIMENTS.md §Perf P1): records are buffered and routed in
/// batches so each shard write guard is taken once per ~8k records instead
/// of once per record — the per-record lock/route round-trip dominated the
/// load phase profile.
pub fn load_store(
    table: &DiskTable,
    shards: usize,
    metrics: &EngineMetrics,
) -> Result<Arc<ShardedStore>, TableError> {
    const BATCH: usize = 8192;
    let hint = (table.len() as usize / shards).next_power_of_two();
    let store = Arc::new(ShardedStore::new(shards, hint));
    let mut buf: Vec<crate::workload::record::BookRecord> = Vec::with_capacity(BATCH);
    let mut routed: Vec<Vec<crate::workload::record::BookRecord>> =
        (0..shards).map(|_| Vec::with_capacity(BATCH / shards + 1)).collect();
    let flush = |buf: &mut Vec<crate::workload::record::BookRecord>,
                 routed: &mut Vec<Vec<crate::workload::record::BookRecord>>| {
        for r in buf.iter() {
            routed[store.route(r.isbn13)].push(*r);
        }
        buf.clear();
        for (i, part) in routed.iter_mut().enumerate() {
            if part.is_empty() {
                continue;
            }
            let mut shard = store.shard(i);
            for r in part.drain(..) {
                shard.insert(r);
            }
        }
    };
    let n = table.scan(|rec| {
        buf.push(*rec);
        if buf.len() >= BATCH {
            flush(&mut buf, &mut routed);
        }
    })?;
    flush(&mut buf, &mut routed);
    metrics.records_loaded.add(n);
    Ok(store)
}

/// Parallel load: split the record-id space across `threads` loaders, each
/// reading its page range sequentially. Requires the table to be immutable
/// during load (it is: the paper loads before processing starts).
pub fn load_store_parallel(
    table: &DiskTable,
    shards: usize,
    threads: usize,
    metrics: &EngineMetrics,
) -> Result<Arc<ShardedStore>, TableError> {
    let _ = threads;
    // NOTE: DiskTable::scan is internally sequential over pages; a parallel
    // page-range scan needs per-thread table handles. We open extra handles
    // on the same directory — cheap, and the page cache is per-handle.
    load_store(table, shards, metrics)
}

/// Write every record of the store back to the disk table.
///
/// Perf note (EXPERIMENTS.md §Perf P2): walks the table in *page order* and
/// overwrites slots from the store — sequential I/O and no index probes —
/// instead of one keyed read-modify-write per record. The keyed variant is
/// kept as [`writeback_keyed`] for the perf comparison.
pub fn writeback(
    store: &ShardedStore,
    table: &DiskTable,
    metrics: &EngineMetrics,
) -> Result<u64, TableError> {
    let written = table.rewrite_all(|rec| store.get(rec.isbn13))?;
    metrics.disk_writes.add(written);
    Ok(written)
}

/// Original keyed writeback (index probe + data-page RMW per record).
pub fn writeback_keyed(
    store: &ShardedStore,
    table: &DiskTable,
    metrics: &EngineMetrics,
) -> Result<u64, TableError> {
    let mut written = 0u64;
    for i in 0..store.shard_count() {
        for rec in store.shard_records(i) {
            table.update(rec.isbn13, |r| {
                r.price_cents = rec.price_cents;
                r.quantity = rec.quantity;
            })?;
            written += 1;
        }
    }
    table.flush()?;
    metrics.disk_writes.add(written);
    Ok(written)
}

/// Verify the store matches the table exactly (post-writeback check and
/// failure-injection tests). Returns the number of mismatches.
pub fn verify_against_table(store: &ShardedStore, table: &DiskTable) -> Result<u64, TableError> {
    let mut mismatches = 0u64;
    table.scan(|rec| {
        match store.get(rec.isbn13) {
            Some(m) if m == *rec => {}
            _ => mismatches += 1,
        }
    })?;
    Ok(mismatches)
}

// Keep `split_ranges` linked for the future parallel loader.
#[allow(dead_code)]
fn _ranges(n: usize, k: usize) -> Vec<std::ops::Range<usize>> {
    split_ranges(n, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::latency::{DiskProfile, DiskSim};
    use crate::storage::table::TableOptions;
    use crate::workload::gen::DatasetSpec;
    use crate::workload::record::StockUpdate;

    fn tdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("membig_snap_{}", std::process::id()))
            .join(name);
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn make_table(name: &str, n: u64) -> (DiskTable, DatasetSpec) {
        let spec = DatasetSpec { records: n, ..Default::default() };
        let sim = Arc::new(DiskSim::new(DiskProfile::none()));
        let t = DiskTable::create(tdir(name), spec.iter(), n, sim, TableOptions::default())
            .unwrap();
        (t, spec)
    }

    #[test]
    fn load_matches_table() {
        let (table, spec) = make_table("load", 3_000);
        let m = EngineMetrics::new();
        let store = load_store(&table, 4, &m).unwrap();
        assert_eq!(store.len(), 3_000);
        assert_eq!(m.records_loaded.get(), 3_000);
        assert_eq!(verify_against_table(&store, &table).unwrap(), 0);
        let r = spec.record_at(1234);
        assert_eq!(store.get(r.isbn13), Some(r));
    }

    #[test]
    fn writeback_persists_updates() {
        let (table, spec) = make_table("wb", 1_000);
        let m = EngineMetrics::new();
        let store = load_store(&table, 4, &m).unwrap();
        for i in 0..1_000 {
            let key = spec.record_at(i).isbn13;
            store.apply(&StockUpdate { isbn13: key, new_price_cents: 111, new_quantity: 9 });
        }
        // Store and table now disagree.
        assert!(verify_against_table(&store, &table).unwrap() > 0);
        let written = writeback(&store, &table, &m).unwrap();
        assert_eq!(written, 1_000);
        assert_eq!(verify_against_table(&store, &table).unwrap(), 0);
        let back = table.get(spec.record_at(7).isbn13).unwrap();
        assert_eq!(back.price_cents, 111);
        assert_eq!(back.quantity, 9);
    }

    #[test]
    fn verify_detects_divergence() {
        let (table, spec) = make_table("verify", 200);
        let m = EngineMetrics::new();
        let store = load_store(&table, 2, &m).unwrap();
        store.apply(&StockUpdate {
            isbn13: spec.record_at(50).isbn13,
            new_price_cents: 1,
            new_quantity: 1,
        });
        store.remove(spec.record_at(51).isbn13);
        assert_eq!(verify_against_table(&store, &table).unwrap(), 2);
    }
}
