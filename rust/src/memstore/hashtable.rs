//! Robin-Hood open-addressing hash table specialised for `u64 → BookRecord`.
//!
//! This is the paper's "special Hash Table data structure" (§4.1) built from
//! scratch rather than taken from the standard library:
//! - open addressing with linear probing and robin-hood displacement keeps
//!   probe sequences short and cache-friendly at high load factors;
//! - keys are ISBN-13 integers (never 0), so 0 doubles as the empty marker
//!   and the table stores no separate occupancy metadata;
//! - power-of-two capacity → mask instead of modulo on the hot path;
//! - the record payload is stored inline (24B), one cache line covers a
//!   probe step.
//!
//! Not thread-safe by design: the sharded store gives each worker thread
//! exclusive ownership of its table, which is exactly the paper's
//! shared-memory-without-locks architecture.

use crate::storage::index::hash_key;
use crate::workload::record::BookRecord;

const EMPTY: u64 = 0;

#[derive(Clone)]
struct Bucket {
    key: u64, // 0 = empty
    price_cents: u64,
    quantity: u32,
}

impl Bucket {
    const VACANT: Bucket = Bucket { key: EMPTY, price_cents: 0, quantity: 0 };

    #[inline]
    fn record(&self) -> BookRecord {
        BookRecord { isbn13: self.key, price_cents: self.price_cents, quantity: self.quantity }
    }
}

pub struct HashTable {
    buckets: Vec<Bucket>,
    mask: usize,
    len: usize,
    /// Grow when len exceeds this (87.5% load factor).
    grow_at: usize,
    /// Probe-length statistics for Figure-1-style diagnostics.
    max_probe: usize,
}

impl HashTable {
    /// Max load factor numerator/denominator: 7/8.
    const LOAD_NUM: usize = 7;
    const LOAD_DEN: usize = 8;

    pub fn new() -> Self {
        Self::with_capacity(16)
    }

    /// Capacity hint in *records*; the table sizes itself so that `hint`
    /// records fit without growing.
    pub fn with_capacity(hint: usize) -> Self {
        let cap = (hint.max(8) * Self::LOAD_DEN / Self::LOAD_NUM + 1).next_power_of_two();
        HashTable {
            buckets: vec![Bucket::VACANT; cap],
            mask: cap - 1,
            len: 0,
            grow_at: cap * Self::LOAD_NUM / Self::LOAD_DEN,
            max_probe: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.buckets.len()
    }

    /// Longest probe sequence seen during inserts (diagnostics).
    pub fn max_probe(&self) -> usize {
        self.max_probe
    }

    /// Bytes of heap this table pins.
    pub fn memory_bytes(&self) -> usize {
        self.buckets.len() * std::mem::size_of::<Bucket>()
    }

    #[inline]
    fn slot_of(&self, key: u64) -> usize {
        (hash_key(key) as usize) & self.mask
    }

    /// Probe distance of the key found at `idx` from its home slot.
    #[inline]
    fn distance(&self, idx: usize, key: u64) -> usize {
        let home = self.slot_of(key);
        idx.wrapping_sub(home) & self.mask
    }

    /// Insert or overwrite. Returns the previous record for the key, if any.
    pub fn insert(&mut self, rec: BookRecord) -> Option<BookRecord> {
        assert_ne!(rec.isbn13, EMPTY, "key 0 is reserved as the empty marker");
        if self.len >= self.grow_at {
            self.grow();
        }
        let mut idx = self.slot_of(rec.isbn13);
        let mut cur =
            Bucket { key: rec.isbn13, price_cents: rec.price_cents, quantity: rec.quantity };
        let mut dist = 0usize;
        loop {
            let b = &mut self.buckets[idx];
            if b.key == EMPTY {
                *b = cur;
                self.len += 1;
                self.max_probe = self.max_probe.max(dist);
                return None;
            }
            if b.key == cur.key {
                let prev = b.record();
                *b = cur;
                return Some(prev);
            }
            // Robin hood: displace richer residents.
            let their_dist = self.distance(idx, self.buckets[idx].key);
            if their_dist < dist {
                std::mem::swap(&mut self.buckets[idx], &mut cur);
                self.max_probe = self.max_probe.max(dist);
                dist = their_dist;
            }
            idx = (idx + 1) & self.mask;
            dist += 1;
        }
    }

    /// Point lookup.
    #[inline]
    pub fn get(&self, key: u64) -> Option<BookRecord> {
        let mut idx = self.slot_of(key);
        let mut dist = 0usize;
        loop {
            let b = &self.buckets[idx];
            if b.key == key {
                return Some(b.record());
            }
            if b.key == EMPTY {
                return None;
            }
            // Robin-hood invariant: once we've probed further than the
            // resident's own distance, the key cannot be present.
            if self.distance(idx, b.key) < dist {
                return None;
            }
            idx = (idx + 1) & self.mask;
            dist += 1;
        }
    }

    /// In-place update through a closure; returns false if the key is absent.
    /// This is the hot path of the proposed method: one probe, one write,
    /// no allocation.
    #[inline]
    pub fn update(&mut self, key: u64, f: impl FnOnce(&mut BookRecord)) -> bool {
        let mut idx = self.slot_of(key);
        let mut dist = 0usize;
        loop {
            let b = &self.buckets[idx];
            if b.key == key {
                let mut rec = b.record();
                f(&mut rec);
                debug_assert_eq!(rec.isbn13, key, "update must not change the key");
                let b = &mut self.buckets[idx];
                b.price_cents = rec.price_cents;
                b.quantity = rec.quantity;
                return true;
            }
            if b.key == EMPTY || self.distance(idx, b.key) < dist {
                return false;
            }
            idx = (idx + 1) & self.mask;
            dist += 1;
        }
    }

    /// Remove a key (backward-shift deletion keeps probe chains tight).
    pub fn remove(&mut self, key: u64) -> Option<BookRecord> {
        let mut idx = self.slot_of(key);
        let mut dist = 0usize;
        loop {
            let b = &self.buckets[idx];
            if b.key == key {
                let prev = b.record();
                // Backward shift: pull successors left until an empty slot
                // or a resident at home position.
                let mut cur = idx;
                loop {
                    let next = (cur + 1) & self.mask;
                    let nb = self.buckets[next].clone();
                    if nb.key == EMPTY || self.distance(next, nb.key) == 0 {
                        self.buckets[cur] = Bucket::VACANT;
                        break;
                    }
                    self.buckets[cur] = nb;
                    cur = next;
                }
                self.len -= 1;
                return Some(prev);
            }
            if b.key == EMPTY || self.distance(idx, b.key) < dist {
                return None;
            }
            idx = (idx + 1) & self.mask;
            dist += 1;
        }
    }

    /// Iterate all records (arbitrary order).
    pub fn iter(&self) -> impl Iterator<Item = BookRecord> + '_ {
        self.buckets.iter().filter(|b| b.key != EMPTY).map(|b| b.record())
    }

    /// Fold the table into (count, Σ price·qty cents) without materializing.
    pub fn value_sum_cents(&self) -> (u64, u128) {
        let mut n = 0u64;
        let mut sum = 0u128;
        for b in &self.buckets {
            if b.key != EMPTY {
                n += 1;
                sum += b.price_cents as u128 * b.quantity as u128;
            }
        }
        (n, sum)
    }

    fn grow(&mut self) {
        let new_cap = self.buckets.len() * 2;
        let old = std::mem::replace(&mut self.buckets, vec![Bucket::VACANT; new_cap]);
        self.mask = new_cap - 1;
        self.grow_at = new_cap * Self::LOAD_NUM / Self::LOAD_DEN;
        self.len = 0;
        self.max_probe = 0;
        for b in old {
            if b.key != EMPTY {
                self.insert(b.record());
            }
        }
    }
}

impl Default for HashTable {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rec(k: u64) -> BookRecord {
        BookRecord::new(k, k % 1000, (k % 500) as u32)
    }

    #[test]
    fn insert_get_update_remove() {
        let mut t = HashTable::new();
        assert!(t.is_empty());
        assert_eq!(t.insert(rec(42)), None);
        assert_eq!(t.get(42), Some(rec(42)));
        assert_eq!(t.get(43), None);
        assert!(t.update(42, |r| r.quantity = 7));
        assert_eq!(t.get(42).unwrap().quantity, 7);
        assert!(!t.update(43, |r| r.quantity = 7));
        let removed = t.remove(42).unwrap();
        assert_eq!(removed.quantity, 7);
        assert_eq!(t.get(42), None);
        assert!(t.is_empty());
    }

    #[test]
    fn insert_overwrites_and_returns_prev() {
        let mut t = HashTable::new();
        t.insert(BookRecord::new(5, 100, 1));
        let prev = t.insert(BookRecord::new(5, 200, 2)).unwrap();
        assert_eq!(prev.price_cents, 100);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(5).unwrap().price_cents, 200);
    }

    #[test]
    fn grows_past_initial_capacity() {
        let mut t = HashTable::with_capacity(8);
        let initial_cap = t.capacity();
        for k in 1..=10_000u64 {
            t.insert(rec(k));
        }
        assert_eq!(t.len(), 10_000);
        assert!(t.capacity() > initial_cap);
        for k in 1..=10_000u64 {
            assert_eq!(t.get(k), Some(rec(k)), "lost key {k} after growth");
        }
    }

    #[test]
    fn with_capacity_avoids_growth() {
        let mut t = HashTable::with_capacity(10_000);
        let cap = t.capacity();
        for k in 1..=10_000u64 {
            t.insert(rec(k));
        }
        assert_eq!(t.capacity(), cap, "should not grow when sized upfront");
    }

    #[test]
    fn dense_adversarial_keys() {
        // Sequential keys stress the mixer; probe lengths must stay sane.
        let mut t = HashTable::with_capacity(100_000);
        for k in 1..=100_000u64 {
            t.insert(rec(k));
        }
        assert!(t.max_probe() < 32, "max probe {} too long", t.max_probe());
    }

    #[test]
    fn matches_std_hashmap_reference() {
        // Randomized differential test vs std::HashMap.
        let mut rng = Rng::new(2024);
        let mut ours = HashTable::new();
        let mut reference = std::collections::HashMap::new();
        for _ in 0..50_000 {
            let key = rng.gen_range(2_000) + 1;
            match rng.gen_range(4) {
                0 => {
                    let r = rec(key * 31);
                    assert_eq!(
                        ours.insert(BookRecord::new(key, r.price_cents, r.quantity)),
                        reference
                            .insert(key, (r.price_cents, r.quantity))
                            .map(|(p, q)| BookRecord::new(key, p, q))
                    );
                }
                1 => {
                    assert_eq!(
                        ours.get(key),
                        reference.get(&key).map(|&(p, q)| BookRecord::new(key, p, q))
                    );
                }
                2 => {
                    let updated = ours.update(key, |r| r.quantity += 1);
                    let ref_updated = reference.get_mut(&key).map(|v| v.1 += 1).is_some();
                    assert_eq!(updated, ref_updated);
                }
                _ => {
                    assert_eq!(
                        ours.remove(key),
                        reference.remove(&key).map(|(p, q)| BookRecord::new(key, p, q))
                    );
                }
            }
            assert_eq!(ours.len(), reference.len());
        }
    }

    #[test]
    fn iteration_sees_exactly_live_records() {
        let mut t = HashTable::new();
        for k in 1..=500u64 {
            t.insert(rec(k));
        }
        for k in (1..=500u64).step_by(2) {
            t.remove(k);
        }
        let mut keys: Vec<u64> = t.iter().map(|r| r.isbn13).collect();
        keys.sort_unstable();
        let expect: Vec<u64> = (1..=500).filter(|k| k % 2 == 0).collect();
        assert_eq!(keys, expect);
    }

    #[test]
    fn value_sum_matches_naive() {
        let mut t = HashTable::new();
        let mut naive: u128 = 0;
        for k in 1..=1000u64 {
            let r = rec(k);
            naive += r.value_cents();
            t.insert(r);
        }
        let (n, sum) = t.value_sum_cents();
        assert_eq!(n, 1000);
        assert_eq!(sum, naive);
    }

    #[test]
    #[should_panic(expected = "key 0 is reserved")]
    fn zero_key_rejected() {
        HashTable::new().insert(BookRecord::new(0, 1, 1));
    }

    #[test]
    fn memory_accounting() {
        let t = HashTable::with_capacity(1 << 16);
        // 24-byte buckets (u64,u64,u32 + padding) → cap * 24.
        assert_eq!(t.memory_bytes(), t.capacity() * std::mem::size_of::<Bucket>());
        assert!(t.memory_bytes() >= (1 << 16) * 24);
    }
}
