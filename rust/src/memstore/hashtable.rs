//! Robin-Hood open-addressing hash table specialised for `u64 → BookRecord`.
//!
//! This is the paper's "special Hash Table data structure" (§4.1) built from
//! scratch rather than taken from the standard library:
//! - open addressing with linear probing and robin-hood displacement keeps
//!   probe sequences short and cache-friendly at high load factors;
//! - keys are ISBN-13 integers (never 0), so 0 doubles as the empty marker
//!   and the table stores no separate occupancy metadata;
//! - power-of-two capacity → mask instead of modulo on the hot path;
//! - the record payload is stored inline (24B), one cache line covers a
//!   probe step.
//!
//! Concurrency: mutations still require `&mut self` (the sharded store
//! serializes writers per shard), but every slot field is an atomic so the
//! bucket array can additionally be **probed lock-free** while a writer
//! mutates it. A lock-free probe may observe torn records or mid-displacement
//! states — it is only meaningful under the shard's seqlock protocol
//! (`memstore::shard`), which detects any concurrent write and retries the
//! read. The live bucket array is published to readers as a raw [`Buckets`]
//! pointer; arrays replaced by growth are parked in `retired` (never freed
//! before the table drops) so a reader holding a stale pointer dereferences
//! valid — merely outdated — memory and fails seqlock validation instead of
//! faulting. Retired arrays sum to less than one live array (capacities are
//! a geometric series), so the worst-case overhead is < 2× bucket memory.
//!
//! Correctness tooling (DESIGN.md §13): this file is one of the three
//! modules whitelisted for `unsafe` by `cargo xtask lint`; the Miri CI lane
//! runs these tests (interpreter-sized N, see the test-mod `n()` helper) to
//! check the raw-pointer publication and retired-array lifetimes against
//! the real aliasing model, and `debug_assertions` builds verify mask/slots
//! self-consistency and retired-array distinctness at the window edges.

// Whitelisted exception to the crate-root `#![deny(unsafe_code)]`.
#![allow(unsafe_code)]

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use crate::storage::index::hash_key;
use crate::workload::record::BookRecord;

const EMPTY: u64 = 0;

/// Plain bucket value used by writers for local manipulation (loads,
/// robin-hood displacement) before storing back into the atomic slots.
#[derive(Clone, Copy)]
struct Bucket {
    key: u64, // 0 = empty
    price_cents: u64,
    quantity: u32,
}

impl Bucket {
    const VACANT: Bucket = Bucket { key: EMPTY, price_cents: 0, quantity: 0 };

    #[inline]
    fn record(&self) -> BookRecord {
        BookRecord { isbn13: self.key, price_cents: self.price_cents, quantity: self.quantity }
    }
}

/// One slot of the table. All fields are atomics so concurrent lock-free
/// readers never race a writer on non-atomic memory (no UB); a multi-field
/// read can still be torn, which the shard seqlock detects and retries.
/// Same 24-byte footprint as the plain layout — one cache line per probe.
struct AtomicBucket {
    key: AtomicU64,
    price_cents: AtomicU64,
    quantity: AtomicU32,
}

impl AtomicBucket {
    fn vacant() -> Self {
        AtomicBucket {
            key: AtomicU64::new(EMPTY),
            price_cents: AtomicU64::new(0),
            quantity: AtomicU32::new(0),
        }
    }

    /// Relaxed is sufficient everywhere: writers are serialized by the shard
    /// mutex (they read their own writes), and cross-thread visibility for
    /// readers is established by the seqlock's acquire/release edges.
    #[inline]
    fn load(&self) -> Bucket {
        Bucket {
            key: self.key.load(Ordering::Relaxed),
            price_cents: self.price_cents.load(Ordering::Relaxed),
            quantity: self.quantity.load(Ordering::Relaxed),
        }
    }

    #[inline]
    fn store(&self, b: Bucket) {
        self.key.store(b.key, Ordering::Relaxed);
        self.price_cents.store(b.price_cents, Ordering::Relaxed);
        self.quantity.store(b.quantity, Ordering::Relaxed);
    }
}

/// A bucket array plus its mask, self-contained so a reader that obtained a
/// (possibly stale) `*const Buckets` can probe without touching any other
/// table state — mask and slots can never be observed out of sync.
pub(crate) struct Buckets {
    mask: usize,
    slots: Box<[AtomicBucket]>,
}

impl Buckets {
    fn alloc(cap: usize) -> Box<Buckets> {
        debug_assert!(cap.is_power_of_two());
        Box::new(Buckets {
            mask: cap - 1,
            slots: (0..cap).map(|_| AtomicBucket::vacant()).collect(),
        })
    }

    #[inline]
    fn capacity(&self) -> usize {
        self.slots.len()
    }

    #[inline]
    fn slot_of(&self, hash: u64) -> usize {
        (hash as usize) & self.mask
    }

    /// Probe distance of `key` found at `idx` from its home slot.
    #[inline]
    fn distance(&self, idx: usize, key: u64) -> usize {
        let home = self.slot_of(hash_key(key));
        idx.wrapping_sub(home) & self.mask
    }

    /// Lock-free point probe with the key's hash precomputed. With no
    /// concurrent writer this is exactly the sequential robin-hood lookup
    /// (early exit on empty slot or a poorer resident). Racing a writer it
    /// may return a torn record or a false miss — callers MUST discard the
    /// result unless their seqlock validation succeeds. The loop is bounded
    /// by capacity so a torn probe chain can never spin forever.
    pub(crate) fn probe(&self, key: u64, hash: u64) -> Option<BookRecord> {
        let mut idx = self.slot_of(hash);
        let mut dist = 0usize;
        for _ in 0..=self.mask {
            let slot = &self.slots[idx];
            let k = slot.key.load(Ordering::Relaxed);
            if k == key {
                return Some(BookRecord {
                    isbn13: key,
                    price_cents: slot.price_cents.load(Ordering::Relaxed),
                    quantity: slot.quantity.load(Ordering::Relaxed),
                });
            }
            if k == EMPTY || self.distance(idx, k) < dist {
                return None;
            }
            idx = (idx + 1) & self.mask;
            dist += 1;
        }
        None
    }

    /// Debug-build self-consistency check for readers holding a raw
    /// `Buckets` view: the mask must describe exactly the slot array it
    /// was allocated with. A mismatch means a torn or dangling view — the
    /// seqlock can mask the symptom (failed validation) but never the
    /// cause, so assert loudly here.
    #[inline]
    pub(crate) fn debug_check(&self) {
        debug_assert!(self.slots.len().is_power_of_two());
        debug_assert_eq!(self.mask, self.slots.len() - 1, "bucket mask out of sync with slots");
    }
}

pub struct HashTable {
    /// The live bucket array, held as a raw pointer (`Box::into_raw` at
    /// allocation) rather than a `Box`: readers probe this allocation
    /// through raw pointers published by the shard, and a `Box` *value*
    /// being moved (`mem::replace` in `grow`, pushing onto `retired`)
    /// would re-assert its uniqueness and invalidate those derived
    /// pointers under Rust's aliasing model. Raw from birth, the pointer
    /// carries no uniqueness claim; the heap address is stable across
    /// moves of the `HashTable` itself.
    live: *mut Buckets,
    /// Arrays replaced by `grow`, kept allocated until `Drop` so stale
    /// reader views stay dereferenceable (see module docs).
    retired: Vec<*mut Buckets>,
    len: usize,
    /// Grow when len exceeds this (87.5% load factor).
    grow_at: usize,
    /// Probe-length statistics for Figure-1-style diagnostics.
    max_probe: usize,
}

// SAFETY: the raw pointers are uniquely owned by this table (created by
// `Box::into_raw`, freed only in `Drop`), and everything reachable through
// them is atomics — `&HashTable` exposes only `&Buckets` (Sync) views, and
// moving the table between threads moves plain pointer values.
unsafe impl Send for HashTable {}
unsafe impl Sync for HashTable {}

impl Drop for HashTable {
    fn drop(&mut self) {
        // Retired-array liveness: every pointer freed below must be
        // distinct, or one of the `Box::from_raw` calls is a double free.
        #[cfg(debug_assertions)]
        {
            let mut addrs: Vec<usize> = self.retired.iter().map(|&p| p as usize).collect();
            addrs.push(self.live as usize);
            addrs.sort_unstable();
            addrs.dedup();
            assert_eq!(
                addrs.len(),
                self.retired.len() + 1,
                "duplicate bucket-array pointer at Drop: double free"
            );
        }
        // SAFETY: `live` and every entry of `retired` came from
        // `Box::into_raw(Buckets::alloc(..))`, are distinct, and are freed
        // exactly once, here. `&mut self` proves no reader can exist (all
        // reader paths borrow the owning store).
        unsafe {
            drop(Box::from_raw(self.live));
            for p in self.retired.drain(..) {
                drop(Box::from_raw(p));
            }
        }
    }
}

impl HashTable {
    /// Max load factor numerator/denominator: 7/8.
    const LOAD_NUM: usize = 7;
    const LOAD_DEN: usize = 8;

    pub fn new() -> Self {
        Self::with_capacity(16)
    }

    /// Capacity hint in *records*; the table sizes itself so that `hint`
    /// records fit without growing.
    pub fn with_capacity(hint: usize) -> Self {
        let cap = (hint.max(8) * Self::LOAD_DEN / Self::LOAD_NUM + 1).next_power_of_two();
        HashTable {
            live: Box::into_raw(Buckets::alloc(cap)),
            retired: Vec::new(),
            len: 0,
            grow_at: cap * Self::LOAD_NUM / Self::LOAD_DEN,
            max_probe: 0,
        }
    }

    /// The live bucket array. The borrow is expression-scoped in practice
    /// (each call re-derives from the raw pointer), so writer methods can
    /// interleave these reads with `self.len`/`self.max_probe` updates.
    #[inline]
    fn live(&self) -> &Buckets {
        // SAFETY: `live` always points to an allocation from
        // `Buckets::alloc`, freed only in `Drop`.
        unsafe { &*self.live }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.live().capacity()
    }

    /// Longest probe sequence seen during inserts (diagnostics).
    pub fn max_probe(&self) -> usize {
        self.max_probe
    }

    /// Bytes of heap this table pins — live buckets plus the retired arrays
    /// kept alive for lock-free readers.
    pub fn memory_bytes(&self) -> usize {
        // SAFETY: retired pointers stay valid until `Drop` (see `live()`).
        let retired: usize =
            self.retired.iter().map(|&p| unsafe { &*p }.capacity()).sum();
        (self.live().capacity() + retired) * std::mem::size_of::<AtomicBucket>()
    }

    /// Raw pointer to the live bucket array, published by the sharded store
    /// to lock-free readers. Stays valid until the table is dropped (growth
    /// retires, never frees, old arrays).
    pub(crate) fn buckets_ptr(&self) -> *const Buckets {
        self.live
    }

    /// Insert or overwrite. Returns the previous record for the key, if any.
    pub fn insert(&mut self, rec: BookRecord) -> Option<BookRecord> {
        self.insert_hashed(rec, hash_key(rec.isbn13))
    }

    /// [`insert`](Self::insert) with the key's hash precomputed — batch
    /// callers hash once and share the value with shard routing.
    pub fn insert_hashed(&mut self, rec: BookRecord, hash: u64) -> Option<BookRecord> {
        assert_ne!(rec.isbn13, EMPTY, "key 0 is reserved as the empty marker");
        if self.len >= self.grow_at {
            self.grow();
        }
        let cur = Bucket { key: rec.isbn13, price_cents: rec.price_cents, quantity: rec.quantity };
        self.insert_at(cur, hash)
    }

    /// Robin-hood insertion into the live array; never grows (callers size
    /// first). `hash` must be `hash_key(cur.key)`.
    fn insert_at(&mut self, mut cur: Bucket, hash: u64) -> Option<BookRecord> {
        let mut idx = self.live().slot_of(hash);
        let mut dist = 0usize;
        loop {
            let b = self.live().slots[idx].load();
            if b.key == EMPTY {
                self.live().slots[idx].store(cur);
                self.len += 1;
                self.max_probe = self.max_probe.max(dist);
                return None;
            }
            if b.key == cur.key {
                self.live().slots[idx].store(cur);
                return Some(b.record());
            }
            // Robin hood: displace richer residents.
            let their_dist = self.live().distance(idx, b.key);
            if their_dist < dist {
                self.live().slots[idx].store(cur);
                cur = b;
                self.max_probe = self.max_probe.max(dist);
                dist = their_dist;
            }
            idx = (idx + 1) & self.live().mask;
            dist += 1;
        }
    }

    /// Point lookup.
    #[inline]
    pub fn get(&self, key: u64) -> Option<BookRecord> {
        self.get_hashed(key, hash_key(key))
    }

    /// [`get`](Self::get) with the key's hash precomputed. With exclusive
    /// access the optimistic probe *is* the sequential lookup — same probe
    /// sequence, same early exits.
    #[inline]
    pub fn get_hashed(&self, key: u64, hash: u64) -> Option<BookRecord> {
        self.live().probe(key, hash)
    }

    /// In-place update through a closure; returns false if the key is absent.
    /// This is the hot path of the proposed method: one probe, one write,
    /// no allocation.
    #[inline]
    pub fn update(&mut self, key: u64, f: impl FnOnce(&mut BookRecord)) -> bool {
        self.update_hashed(key, hash_key(key), f)
    }

    /// [`update`](Self::update) with the key's hash precomputed.
    #[inline]
    pub fn update_hashed(&mut self, key: u64, hash: u64, f: impl FnOnce(&mut BookRecord)) -> bool {
        let mut idx = self.live().slot_of(hash);
        let mut dist = 0usize;
        loop {
            let b = self.live().slots[idx].load();
            if b.key == key {
                let mut rec = b.record();
                f(&mut rec);
                debug_assert_eq!(rec.isbn13, key, "update must not change the key");
                let slot = &self.live().slots[idx];
                slot.price_cents.store(rec.price_cents, Ordering::Relaxed);
                slot.quantity.store(rec.quantity, Ordering::Relaxed);
                return true;
            }
            if b.key == EMPTY || self.live().distance(idx, b.key) < dist {
                return false;
            }
            idx = (idx + 1) & self.live().mask;
            dist += 1;
        }
    }

    /// Remove a key (backward-shift deletion keeps probe chains tight).
    pub fn remove(&mut self, key: u64) -> Option<BookRecord> {
        self.remove_hashed(key, hash_key(key))
    }

    /// [`remove`](Self::remove) with the key's hash precomputed.
    pub fn remove_hashed(&mut self, key: u64, hash: u64) -> Option<BookRecord> {
        let mut idx = self.live().slot_of(hash);
        let mut dist = 0usize;
        loop {
            let b = self.live().slots[idx].load();
            if b.key == key {
                // Backward shift: pull successors left until an empty slot
                // or a resident at home position.
                let mut cur = idx;
                loop {
                    let next = (cur + 1) & self.live().mask;
                    let nb = self.live().slots[next].load();
                    if nb.key == EMPTY || self.live().distance(next, nb.key) == 0 {
                        self.live().slots[cur].store(Bucket::VACANT);
                        break;
                    }
                    self.live().slots[cur].store(nb);
                    cur = next;
                }
                self.len -= 1;
                return Some(b.record());
            }
            if b.key == EMPTY || self.live().distance(idx, b.key) < dist {
                return None;
            }
            idx = (idx + 1) & self.live().mask;
            dist += 1;
        }
    }

    /// Iterate all records (arbitrary order).
    pub fn iter(&self) -> impl Iterator<Item = BookRecord> + '_ {
        self.live().slots.iter().map(|s| s.load()).filter(|b| b.key != EMPTY).map(|b| b.record())
    }

    /// Fold the table into (count, Σ price·qty cents) without materializing.
    pub fn value_sum_cents(&self) -> (u64, u128) {
        let mut n = 0u64;
        let mut sum = 0u128;
        for s in &self.live().slots {
            let b = s.load();
            if b.key != EMPTY {
                n += 1;
                sum += b.price_cents as u128 * b.quantity as u128;
            }
        }
        (n, sum)
    }

    fn grow(&mut self) {
        let new_cap = self.live().capacity() * 2;
        let old = std::mem::replace(&mut self.live, Box::into_raw(Buckets::alloc(new_cap)));
        self.grow_at = new_cap * Self::LOAD_NUM / Self::LOAD_DEN;
        self.len = 0;
        self.max_probe = 0;
        // SAFETY: `old` is the just-retired array; it stays allocated until
        // `Drop`. Only raw-pointer *values* move below, so pointers readers
        // derived from the published address remain valid.
        let old_ref: &Buckets = unsafe { &*old };
        for slot in old_ref.slots.iter() {
            let b = slot.load();
            if b.key != EMPTY {
                self.insert_at(b, hash_key(b.key));
            }
        }
        // Park, don't free: a lock-free reader may still hold a pointer to
        // this array; it will fail seqlock validation and re-probe the new
        // one, but the memory must outlive the table.
        self.retired.push(old);
        // Retired-array liveness: the live array must never appear in the
        // retired list, or Drop would free it twice.
        debug_assert!(
            !self.retired.iter().any(|&p| std::ptr::eq(p, self.live)),
            "live bucket array also parked as retired"
        );
    }
}

impl Default for HashTable {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rec(k: u64) -> BookRecord {
        BookRecord::new(k, k % 1000, (k % 500) as u32)
    }

    /// Miri runs the same tests with interpreter-sized inputs: the raw
    /// pointer/aliasing checks don't need native-scale N.
    fn n(native: u64, miri: u64) -> u64 {
        if cfg!(miri) {
            miri
        } else {
            native
        }
    }

    #[test]
    fn insert_get_update_remove() {
        let mut t = HashTable::new();
        assert!(t.is_empty());
        assert_eq!(t.insert(rec(42)), None);
        assert_eq!(t.get(42), Some(rec(42)));
        assert_eq!(t.get(43), None);
        assert!(t.update(42, |r| r.quantity = 7));
        assert_eq!(t.get(42).unwrap().quantity, 7);
        assert!(!t.update(43, |r| r.quantity = 7));
        let removed = t.remove(42).unwrap();
        assert_eq!(removed.quantity, 7);
        assert_eq!(t.get(42), None);
        assert!(t.is_empty());
    }

    #[test]
    fn insert_overwrites_and_returns_prev() {
        let mut t = HashTable::new();
        t.insert(BookRecord::new(5, 100, 1));
        let prev = t.insert(BookRecord::new(5, 200, 2)).unwrap();
        assert_eq!(prev.price_cents, 100);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(5).unwrap().price_cents, 200);
    }

    #[test]
    fn grows_past_initial_capacity() {
        let count = n(10_000, 600);
        let mut t = HashTable::with_capacity(8);
        let initial_cap = t.capacity();
        for k in 1..=count {
            t.insert(rec(k));
        }
        assert_eq!(t.len() as u64, count);
        assert!(t.capacity() > initial_cap);
        for k in 1..=count {
            assert_eq!(t.get(k), Some(rec(k)), "lost key {k} after growth");
        }
    }

    #[test]
    fn with_capacity_avoids_growth() {
        let count = n(10_000, 500);
        let mut t = HashTable::with_capacity(count as usize);
        let cap = t.capacity();
        for k in 1..=count {
            t.insert(rec(k));
        }
        assert_eq!(t.capacity(), cap, "should not grow when sized upfront");
    }

    #[test]
    fn dense_adversarial_keys() {
        // Sequential keys stress the mixer; probe lengths must stay sane.
        let count = n(100_000, 2_000);
        let mut t = HashTable::with_capacity(count as usize);
        for k in 1..=count {
            t.insert(rec(k));
        }
        assert!(t.max_probe() < 32, "max probe {} too long", t.max_probe());
    }

    #[test]
    fn matches_std_hashmap_reference() {
        // Randomized differential test vs std::HashMap.
        let mut rng = Rng::new(2024);
        let mut ours = HashTable::new();
        let mut reference = std::collections::HashMap::new();
        for _ in 0..n(50_000, 1_000) {
            let key = rng.gen_range(2_000) + 1;
            match rng.gen_range(4) {
                0 => {
                    let r = rec(key * 31);
                    assert_eq!(
                        ours.insert(BookRecord::new(key, r.price_cents, r.quantity)),
                        reference
                            .insert(key, (r.price_cents, r.quantity))
                            .map(|(p, q)| BookRecord::new(key, p, q))
                    );
                }
                1 => {
                    assert_eq!(
                        ours.get(key),
                        reference.get(&key).map(|&(p, q)| BookRecord::new(key, p, q))
                    );
                }
                2 => {
                    let updated = ours.update(key, |r| r.quantity += 1);
                    let ref_updated = reference.get_mut(&key).map(|v| v.1 += 1).is_some();
                    assert_eq!(updated, ref_updated);
                }
                _ => {
                    assert_eq!(
                        ours.remove(key),
                        reference.remove(&key).map(|(p, q)| BookRecord::new(key, p, q))
                    );
                }
            }
            assert_eq!(ours.len(), reference.len());
        }
    }

    #[test]
    fn iteration_sees_exactly_live_records() {
        let mut t = HashTable::new();
        for k in 1..=500u64 {
            t.insert(rec(k));
        }
        for k in (1..=500u64).step_by(2) {
            t.remove(k);
        }
        let mut keys: Vec<u64> = t.iter().map(|r| r.isbn13).collect();
        keys.sort_unstable();
        let expect: Vec<u64> = (1..=500).filter(|k| k % 2 == 0).collect();
        assert_eq!(keys, expect);
    }

    #[test]
    fn value_sum_matches_naive() {
        let mut t = HashTable::new();
        let mut naive: u128 = 0;
        for k in 1..=1000u64 {
            let r = rec(k);
            naive += r.value_cents();
            t.insert(r);
        }
        let (n, sum) = t.value_sum_cents();
        assert_eq!(n, 1000);
        assert_eq!(sum, naive);
    }

    #[test]
    #[should_panic(expected = "key 0 is reserved")]
    fn zero_key_rejected() {
        HashTable::new().insert(BookRecord::new(0, 1, 1));
    }

    #[test]
    fn memory_accounting() {
        let hint = if cfg!(miri) { 1 << 12 } else { 1 << 16 };
        let t = HashTable::with_capacity(hint);
        // 24-byte slots (AtomicU64 ×2 + AtomicU32 + padding) → cap * 24.
        assert_eq!(t.memory_bytes(), t.capacity() * std::mem::size_of::<AtomicBucket>());
        assert!(t.memory_bytes() >= hint * 24);
    }

    #[test]
    fn retired_arrays_are_accounted_and_bounded() {
        let mut t = HashTable::with_capacity(8);
        for k in 1..=n(5_000, 1_000) {
            t.insert(rec(k));
        }
        let live = t.capacity() * std::mem::size_of::<AtomicBucket>();
        let total = t.memory_bytes();
        assert!(total > live, "growth must leave retired arrays accounted");
        // Geometric series: everything retired sums to < one live array.
        assert!(total < 2 * live, "retired overhead must stay under 1× live ({total} vs {live})");
    }

    #[test]
    fn hashed_variants_match_plain_calls() {
        let mut t = HashTable::with_capacity(64);
        for k in 1..=200u64 {
            let h = hash_key(k);
            assert_eq!(t.insert_hashed(rec(k), h), None);
            assert_eq!(t.get_hashed(k, h), Some(rec(k)));
            assert_eq!(t.get(k), t.get_hashed(k, h));
        }
        let h7 = hash_key(7);
        assert!(t.update_hashed(7, h7, |r| r.quantity = 99));
        assert_eq!(t.get(7).unwrap().quantity, 99);
        assert_eq!(t.remove_hashed(7, h7).unwrap().quantity, 99);
        assert_eq!(t.get(7), None);
        assert!(!t.update_hashed(7, h7, |r| r.quantity = 1));
    }

    #[test]
    fn probe_is_bounded_even_on_absent_keys() {
        let mut t = HashTable::with_capacity(64);
        for k in 1..=50u64 {
            t.insert(rec(k));
        }
        // Misses terminate via the robin-hood early exit / capacity bound.
        for k in 10_001..=10_200u64 {
            assert_eq!(t.get(k), None);
        }
    }
}
