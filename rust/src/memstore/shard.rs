//! Sharded in-memory store: `n` independent [`HashTable`]s, keys routed by
//! hash. Shard count is fixed at construction (paper: one shard per core),
//! so routing is a pure function and workers never contend.
//!
//! Concurrency model (paper §4: workers read the memory-resident table "in
//! a concurrent fashion"):
//!
//! - **Writers** stay serialized per shard by a mutex, exactly as before —
//!   the durability layer depends on WAL replay order ≡ apply order, and a
//!   single writer per shard keeps that guarantee trivially. Every write
//!   window is bracketed by a **seqlock**: the shard's version counter goes
//!   odd on entry and even on exit ([`ShardWriteGuard`]).
//! - **Readers** (`get` / `get_many`) are lock-free: snapshot the version,
//!   probe the atomic bucket array through the published view pointer, then
//!   validate the version. An odd snapshot or a changed version means a
//!   writer raced the probe — the result is discarded and the read retried.
//!   After [`READ_RETRIES`] failed attempts the reader falls back to the
//!   shard mutex so a write-heavy shard cannot starve its readers; retry and
//!   fallback totals are exported via [`ReadPathStats`].
//!
//! Hashing: `route()` uses the *upper* hash bits, the in-table slot the
//! lower bits, so one `hash_key` call per key serves both — the batch paths
//! hash each key exactly once (`route_hashed` + `*_hashed` table calls).
//!
//! Correctness tooling (DESIGN.md §13): this file is one of the three
//! modules whitelisted for `unsafe` by `cargo xtask lint`; the seqlock
//! windows carry `racecheck` perturbation points so the TSan lane drives
//! threads through them, and `debug_assertions` builds check version
//! parity and view/mask self-consistency at every window edge.

// Whitelisted exception to the crate-root `#![deny(unsafe_code)]`.
#![allow(unsafe_code)]

use std::ops::Deref;
use std::sync::atomic::{fence, AtomicPtr, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use super::hashtable::{Buckets, HashTable};
use crate::metrics::Counter;
use crate::storage::index::hash_key;
use crate::util::racecheck;
use crate::workload::record::{BookRecord, StockUpdate};

/// Optimistic attempts before a reader gives up on the lock-free path and
/// takes the shard mutex. Small: each retry is only worth it while the
/// writer's window is shorter than a mutex round-trip.
const READ_RETRIES: usize = 8;

/// Keys validated under one version snapshot in `get_many`. A whole huge
/// MGET group under a single snapshot would make its probe window so long
/// that any write traffic forces every attempt to fail and be redone —
/// chunking bounds the work a failed validation can discard.
const READ_GROUP_CHUNK: usize = 256;

/// Lock-free read-path counters (shared across all shards of a store).
/// `retries` counts discarded optimistic attempts (a writer raced the
/// probe); `fallbacks` counts reads that exhausted their retries and went
/// through the mutex. Both are zero on an uncontended store.
#[derive(Default)]
pub struct ReadPathStats {
    pub retries: Counter,
    pub fallbacks: Counter,
}

/// One shard: a writer-serialized table plus the seqlock state that lets
/// readers probe it without the lock. Cache-line aligned so one shard's
/// version bumps never invalidate the line holding a *neighbouring*
/// shard's seqlock state in the `Vec<Shard>` — cross-shard coherence
/// traffic is exactly what the lock-free read path exists to eliminate.
#[repr(align(64))]
struct Shard {
    /// Seqlock version: even = stable, odd = a writer is inside its window.
    seq: AtomicU64,
    /// Published pointer to the table's live bucket array. May briefly lag
    /// behind a growth (readers then probe the retired array, which stays
    /// allocated — see `hashtable` module docs — and fail validation).
    view: AtomicPtr<Buckets>,
    table: Mutex<HashTable>,
}

impl Shard {
    fn new(capacity_hint: usize) -> Self {
        let table = HashTable::with_capacity(capacity_hint);
        let view = AtomicPtr::new(table.buckets_ptr() as *mut Buckets);
        Shard { seq: AtomicU64::new(0), view, table: Mutex::new(table) }
    }

    /// Start an optimistic read: `Some(stamp)` when the shard is stable,
    /// `None` while a writer is inside its window.
    #[inline]
    fn read_begin(&self) -> Option<u64> {
        let stamp = self.seq.load(Ordering::Acquire);
        if stamp & 1 == 0 {
            Some(stamp)
        } else {
            None
        }
    }

    /// True iff no writer ran since `read_begin` returned `stamp` — the
    /// probed data was a consistent snapshot. The acquire fence orders the
    /// data loads before this version re-check (Boehm's seqlock recipe).
    #[inline]
    fn read_validate(&self, stamp: u64) -> bool {
        fence(Ordering::Acquire);
        self.seq.load(Ordering::Relaxed) == stamp
    }

    /// Enter a write window: take the writer mutex, flip the version odd.
    fn write(&self) -> ShardWriteGuard<'_> {
        let table = self.table.lock().unwrap();
        // Odd flip, then a release fence *before* the window's relaxed slot
        // stores (crossbeam's SeqLock recipe): the fence pairs with the
        // reader's acquire fence in `read_validate`, so any reader that
        // observed one of this window's stores must also observe the odd
        // version on its re-check — without the fence, weakly-ordered
        // hardware could publish a slot store ahead of the flip and let a
        // torn read validate. (Mutual exclusion itself comes from the
        // mutex; Relaxed is enough for the counter bump.)
        let prev = self.seq.fetch_add(1, Ordering::Relaxed);
        debug_assert_eq!(
            prev & 1,
            0,
            "seqlock version was odd under a fresh mutex hold: unbalanced write window"
        );
        fence(Ordering::Release);
        // Widen the odd-version window: readers racing this writer must
        // observe the odd flip, retry, and eventually take the mutex.
        racecheck::perturb("seqlock.write.enter");
        ShardWriteGuard { shard: self, table }
    }

    /// Read-only access under the mutex (fallback path, snapshots,
    /// aggregation). Does not touch the version: lock-free readers proceed
    /// concurrently, other writers block.
    fn read(&self) -> MutexGuard<'_, HashTable> {
        self.table.lock().unwrap()
    }
}

/// The two ways a validated read can see a shard's data: the lock-free
/// published bucket array, or the table under the mutex (fallback). One
/// closure in [`ShardedStore::read_shard`] serves both, so the read
/// protocol exists in exactly one place and the paths cannot diverge.
enum ReadView<'a> {
    Optimistic(&'a Buckets),
    Locked(&'a HashTable),
}

impl ReadView<'_> {
    #[inline]
    fn get(&self, key: u64, hash: u64) -> Option<BookRecord> {
        match self {
            ReadView::Optimistic(b) => b.probe(key, hash),
            ReadView::Locked(t) => t.get_hashed(key, hash),
        }
    }
}

/// Exclusive write access to one shard's table. Holds the shard mutex and
/// keeps the seqlock version odd for its whole lifetime, so lock-free
/// readers retry (and eventually queue on the mutex) instead of observing
/// torn state. On drop it republishes the bucket-array view (growth may
/// have moved it), flips the version even, then releases the mutex.
///
/// Mutation goes through the forwarding methods below — deliberately NOT
/// `DerefMut`: `&mut HashTable` would let safe code *replace* the table
/// (`mem::replace`, `*guard = ...`), dropping bucket arrays that
/// concurrent lock-free readers may still be probing. Shared `Deref` for
/// the read API is fine; nothing reachable through `&HashTable` can free
/// the arrays.
pub struct ShardWriteGuard<'a> {
    shard: &'a Shard,
    table: MutexGuard<'a, HashTable>,
}

impl Deref for ShardWriteGuard<'_> {
    type Target = HashTable;

    fn deref(&self) -> &HashTable {
        &self.table
    }
}

impl ShardWriteGuard<'_> {
    pub fn insert(&mut self, rec: BookRecord) -> Option<BookRecord> {
        self.table.insert(rec)
    }

    pub fn insert_hashed(&mut self, rec: BookRecord, hash: u64) -> Option<BookRecord> {
        self.table.insert_hashed(rec, hash)
    }

    pub fn update(&mut self, key: u64, f: impl FnOnce(&mut BookRecord)) -> bool {
        self.table.update(key, f)
    }

    pub fn update_hashed(&mut self, key: u64, hash: u64, f: impl FnOnce(&mut BookRecord)) -> bool {
        self.table.update_hashed(key, hash, f)
    }

    pub fn remove(&mut self, key: u64) -> Option<BookRecord> {
        self.table.remove(key)
    }

    pub fn remove_hashed(&mut self, key: u64, hash: u64) -> Option<BookRecord> {
        self.table.remove_hashed(key, hash)
    }
}

impl Drop for ShardWriteGuard<'_> {
    fn drop(&mut self) {
        // Window between the last slot store and the view republish: stale
        // readers probing the pre-growth array must keep failing validation.
        racecheck::perturb("seqlock.write.republish");
        self.shard.view.store(self.table.buckets_ptr() as *mut Buckets, Ordering::Release);
        // Window between republish and the even flip: a reader can now see
        // the *new* array under a still-odd version and must retry.
        racecheck::perturb("seqlock.write.exit");
        let prev = self.shard.seq.fetch_add(1, Ordering::Release);
        debug_assert_eq!(prev & 1, 1, "closing a write window whose version was already even");
        // The MutexGuard field drops after this body: the even version is
        // published before the next writer can enter.
    }
}

pub struct ShardedStore {
    shards: Vec<Shard>,
    /// Bit mask when shard count is a power of two, else None → modulo.
    mask: Option<u64>,
    read_stats: ReadPathStats,
}

impl ShardedStore {
    pub fn new(shards: usize, capacity_hint_per_shard: usize) -> Self {
        assert!(shards > 0);
        let mask = if shards.is_power_of_two() { Some(shards as u64 - 1) } else { None };
        ShardedStore {
            shards: (0..shards).map(|_| Shard::new(capacity_hint_per_shard)).collect(),
            mask,
            read_stats: ReadPathStats::default(),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Lock-free read-path counters (seqlock retries / mutex fallbacks).
    pub fn read_stats(&self) -> &ReadPathStats {
        &self.read_stats
    }

    /// Which shard owns `key`. Uses the *upper* hash bits so shard routing
    /// stays independent of the in-table slot choice (lower bits).
    #[inline]
    pub fn route(&self, key: u64) -> usize {
        self.route_hashed(hash_key(key))
    }

    /// [`route`](Self::route) with `hash_key(key)` precomputed, so callers
    /// that also probe the table hash each key exactly once per operation.
    #[inline]
    pub fn route_hashed(&self, hash: u64) -> usize {
        let h = hash >> 32;
        match self.mask {
            Some(m) => (h & m) as usize,
            None => (h % self.shards.len() as u64) as usize,
        }
    }

    /// Exclusive write access to one shard (shard-affine workers, bulk
    /// load). The guard keeps the shard's seqlock odd for its lifetime —
    /// take it only to mutate; use the read APIs for lookups.
    pub fn shard(&self, i: usize) -> ShardWriteGuard<'_> {
        self.shards[i].write()
    }

    pub fn insert(&self, rec: BookRecord) -> Option<BookRecord> {
        let h = hash_key(rec.isbn13);
        self.shards[self.route_hashed(h)].write().insert_hashed(rec, h)
    }

    /// Lock-free point read (seqlock-validated; mutex fallback after
    /// [`READ_RETRIES`] raced attempts).
    pub fn get(&self, key: u64) -> Option<BookRecord> {
        let h = hash_key(key);
        let s = self.route_hashed(h);
        self.read_shard(s, |v| v.get(key, h))
    }

    /// The one copy of the seqlock read protocol, shared by `get` and
    /// `get_many`: `read` runs against the published bucket array
    /// ([`ReadView::Optimistic`]) and its result counts only if the
    /// version validates; after [`READ_RETRIES`] raced attempts it runs
    /// once more under the shard mutex ([`ReadView::Locked`]). `read` may
    /// execute several times — each run must fully overwrite anything it
    /// writes, since a raced attempt's output is discarded or overwritten
    /// by the next attempt.
    fn read_shard<T>(&self, s: usize, mut read: impl FnMut(ReadView<'_>) -> T) -> T {
        let shard = &self.shards[s];
        for _ in 0..READ_RETRIES {
            if let Some(stamp) = shard.read_begin() {
                // SAFETY: `view` points at the live or a retired bucket
                // array of this shard's table; both stay allocated until
                // the store drops, which requires exclusive access — no
                // reader can coexist with the deallocation. (The write
                // guard exposes no way for safe code to replace the table,
                // so no other path can free the arrays early.)
                let buckets = unsafe { &*shard.view.load(Ordering::Acquire) };
                buckets.debug_check();
                let out = read(ReadView::Optimistic(buckets));
                // Widen the probe→validate gap: a racing writer must be
                // caught by the version re-check, never by luck of timing.
                racecheck::perturb("seqlock.read.validate");
                if shard.read_validate(stamp) {
                    return out;
                }
            }
            self.read_stats.retries.inc();
            std::hint::spin_loop();
        }
        self.read_stats.fallbacks.inc();
        read(ReadView::Locked(&*shard.read()))
    }

    pub fn update(&self, key: u64, f: impl FnOnce(&mut BookRecord)) -> bool {
        let h = hash_key(key);
        self.shards[self.route_hashed(h)].write().update_hashed(key, h, f)
    }

    pub fn apply(&self, u: &StockUpdate) -> bool {
        self.update(u.isbn13, |r| u.apply_to(r))
    }

    pub fn remove(&self, key: u64) -> Option<BookRecord> {
        let h = hash_key(key);
        self.shards[self.route_hashed(h)].write().remove_hashed(key, h)
    }

    /// Batched point reads: pre-route every key (hashing each exactly
    /// once), then read each touched shard's group lock-free in chunks of
    /// [`READ_GROUP_CHUNK`] keys per seqlock snapshot, with the shard
    /// mutex as the contended-chunk fallback. Per-record consistency only
    /// (like sequential `get` calls); results come back in input order.
    pub fn get_many(&self, keys: &[u64]) -> Vec<Option<BookRecord>> {
        let hashes: Vec<u64> = keys.iter().map(|&k| hash_key(k)).collect();
        let mut out = vec![None; keys.len()];
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, &h) in hashes.iter().enumerate() {
            by_shard[self.route_hashed(h)].push(i);
        }
        for (s, idxs) in by_shard.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            // One version snapshot/validation per chunk of the per-shard
            // key group. The closure writes straight into the pre-sized
            // output (no per-attempt allocation); a raced attempt's slots
            // are simply overwritten by the retry.
            for chunk in idxs.chunks(READ_GROUP_CHUNK) {
                self.read_shard(s, |v| {
                    for &i in chunk {
                        out[i] = v.get(keys[i], hashes[i]);
                    }
                });
            }
        }
        out
    }

    /// Batched updates with one write window per touched shard.
    /// Duplicate keys within a batch apply in input order (same shard ⇒
    /// ascending index). Returns `(applied, missed)`.
    pub fn apply_many(&self, ups: &[StockUpdate]) -> (u64, u64) {
        self.apply_many_tracked(ups, |_| {})
    }

    /// [`ShardedStore::apply_many`] that also reports the input index of
    /// every update it applies. The tiered store's promotion pass needs
    /// exact per-update outcomes: re-probing `get` after the fact would
    /// race with a concurrent spill (applied key evicted in between reads
    /// as a miss) and double-count. The no-op closure in `apply_many`
    /// compiles away.
    pub fn apply_many_tracked(
        &self,
        ups: &[StockUpdate],
        mut on_applied: impl FnMut(usize),
    ) -> (u64, u64) {
        let hashes: Vec<u64> = ups.iter().map(|u| hash_key(u.isbn13)).collect();
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, &h) in hashes.iter().enumerate() {
            by_shard[self.route_hashed(h)].push(i);
        }
        let (mut applied, mut missed) = (0u64, 0u64);
        for (s, idxs) in by_shard.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let mut shard = self.shards[s].write();
            for &i in idxs {
                let u = &ups[i];
                if shard.update_hashed(u.isbn13, hashes[i], |r| u.apply_to(r)) {
                    applied += 1;
                    on_applied(i);
                } else {
                    missed += 1;
                }
            }
        }
        (applied, missed)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn memory_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.read().memory_bytes()).sum()
    }

    /// (count, Σ price·qty) across all shards.
    pub fn value_sum_cents(&self) -> (u64, u128) {
        let mut n = 0;
        let mut sum = 0;
        for s in &self.shards {
            let (sn, ss) = s.read().value_sum_cents();
            n += sn;
            sum += ss;
        }
        (n, sum)
    }

    /// Snapshot all records of one shard (for writeback / analytics export).
    /// Takes the mutex read-side only — concurrent lock-free readers are
    /// unaffected while a shard is being exported.
    pub fn shard_records(&self, i: usize) -> Vec<BookRecord> {
        self.shards[i].read().iter().collect()
    }

    /// Per-shard record counts — balance diagnostics for benches.
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.read().len()).collect()
    }

    /// Iteration hook for checkpointing: visit every record shard by shard.
    /// Each shard's records are copied out under that shard's lock alone —
    /// the store never holds more than one lock, so a snapshot streaming
    /// gigabytes to disk stalls at most one shard at a time while live
    /// traffic proceeds on the others (lock-free readers aren't stalled at
    /// all). The view is per-shard-consistent, not globally consistent; the
    /// durability layer recovers exactness by replaying the WAL segment
    /// opened before the snapshot began.
    pub fn for_each_shard(&self, mut f: impl FnMut(usize, &[BookRecord])) {
        for i in 0..self.shards.len() {
            let recs = self.shard_records(i);
            f(i, &recs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::DatasetSpec;

    /// Miri runs the same tests with interpreter-sized inputs: Miri's
    /// aliasing/atomics model is what we're after, not throughput.
    fn n(native: u64, miri: u64) -> u64 {
        if cfg!(miri) {
            miri
        } else {
            native
        }
    }

    #[test]
    fn routing_is_stable_and_in_range() {
        let s = ShardedStore::new(12, 16);
        for k in 1..n(10_000, 500) {
            let r = s.route(k);
            assert!(r < 12);
            assert_eq!(r, s.route(k), "routing must be deterministic");
            assert_eq!(r, s.route_hashed(hash_key(k)), "route and route_hashed must agree");
        }
    }

    #[test]
    fn insert_get_across_shards() {
        let records = n(5_000, 400);
        let s = ShardedStore::new(8, 16);
        let spec = DatasetSpec { records, ..Default::default() };
        for r in spec.iter() {
            s.insert(r);
        }
        assert_eq!(s.len() as u64, records);
        for i in (0..records).step_by(97) {
            let r = spec.record_at(i);
            assert_eq!(s.get(r.isbn13), Some(r));
        }
        // No writer raced these reads: the optimistic path never fell back.
        assert_eq!(s.read_stats().fallbacks.get(), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical balance needs large N; nothing unsafe exercised")]
    fn shards_balanced_within_20_percent() {
        let s = ShardedStore::new(8, 1 << 12);
        let spec = DatasetSpec { records: 80_000, ..Default::default() };
        for r in spec.iter() {
            s.insert(r);
        }
        let sizes = s.shard_sizes();
        let mean = 80_000.0 / 8.0;
        for (i, &sz) in sizes.iter().enumerate() {
            assert!(
                (sz as f64 - mean).abs() / mean < 0.2,
                "shard {i} unbalanced: {sz} vs mean {mean}"
            );
        }
    }

    #[test]
    fn apply_stock_update() {
        let s = ShardedStore::new(4, 16);
        s.insert(BookRecord::new(123, 100, 1));
        let u = StockUpdate { isbn13: 123, new_price_cents: 393, new_quantity: 495 };
        assert!(s.apply(&u));
        assert_eq!(s.get(123).unwrap().price_cents, 393);
        assert!(!s.apply(&StockUpdate { isbn13: 999, new_price_cents: 1, new_quantity: 1 }));
    }

    #[test]
    fn concurrent_shard_affine_updates() {
        // The paper's topology: each worker updates only its own shard.
        let records = n(40_000, 1_000);
        let spec = DatasetSpec { records, ..Default::default() };
        let s = ShardedStore::new(4, 1 << 14);
        for r in spec.iter() {
            s.insert(r);
        }
        // Pre-route updates per shard.
        let mut per_shard: Vec<Vec<StockUpdate>> = vec![Vec::new(); 4];
        for r in spec.iter() {
            per_shard[s.route(r.isbn13)].push(StockUpdate {
                isbn13: r.isbn13,
                new_price_cents: 555,
                new_quantity: 5,
            });
        }
        std::thread::scope(|scope| {
            for (i, ups) in per_shard.iter().enumerate() {
                let s = &s;
                scope.spawn(move || {
                    let mut shard = s.shard(i);
                    for u in ups {
                        assert!(shard.update(u.isbn13, |r| u.apply_to(r)));
                    }
                });
            }
        });
        let (count, sum) = s.value_sum_cents();
        assert_eq!(count, records);
        assert_eq!(sum, u128::from(records) * 555 * 5);
    }

    #[test]
    fn non_power_of_two_shards() {
        let records = n(1_000, 300);
        let s = ShardedStore::new(12, 16);
        let spec = DatasetSpec { records, ..Default::default() };
        for r in spec.iter() {
            s.insert(r);
        }
        assert_eq!(s.len() as u64, records);
        assert_eq!(s.shard_sizes().iter().sum::<usize>() as u64, records);
    }

    #[test]
    fn get_many_matches_sequential_gets_in_order() {
        let s = ShardedStore::new(8, 1 << 10);
        let spec = DatasetSpec { records: n(2_000, 300), ..Default::default() };
        for r in spec.iter() {
            s.insert(r);
        }
        let mut keys: Vec<u64> = (0..n(500, 100)).map(|i| spec.record_at(i).isbn13).collect();
        keys.push(42); // guaranteed miss
        keys.push(spec.record_at(0).isbn13); // duplicate key
        let batch = s.get_many(&keys);
        assert_eq!(batch.len(), keys.len());
        for (i, k) in keys.iter().enumerate() {
            assert_eq!(batch[i], s.get(*k), "index {i} key {k}");
        }
    }

    #[test]
    fn apply_many_counts_and_matches_sequential() {
        let s = ShardedStore::new(4, 1 << 10);
        for k in 1..=100u64 {
            s.insert(BookRecord::new(k, 1, 1));
        }
        let mut ups: Vec<StockUpdate> = (1..=100u64)
            .map(|k| StockUpdate { isbn13: k, new_price_cents: k * 10, new_quantity: k as u32 })
            .collect();
        ups.push(StockUpdate { isbn13: 9999, new_price_cents: 1, new_quantity: 1 }); // miss
        // Duplicate key: later entry must win (input order within a batch).
        ups.push(StockUpdate { isbn13: 7, new_price_cents: 777, new_quantity: 7 });
        let (applied, missed) = s.apply_many(&ups);
        assert_eq!(applied, 101);
        assert_eq!(missed, 1);
        assert_eq!(s.get(7).unwrap().price_cents, 777);
        assert_eq!(s.get(50).unwrap().price_cents, 500);
        assert_eq!(s.len(), 100);
    }

    #[test]
    fn apply_many_tracked_reports_exact_applied_indices() {
        let s = ShardedStore::new(4, 1 << 10);
        for k in 1..=10u64 {
            s.insert(BookRecord::new(k, 1, 1));
        }
        let mk = |k: u64| StockUpdate { isbn13: k, new_price_cents: 5, new_quantity: 5 };
        let ups = [mk(1), mk(999), mk(2), mk(999), mk(1)];
        let mut done = [false; 5];
        let (applied, missed) = s.apply_many_tracked(&ups, |i| done[i] = true);
        assert_eq!((applied, missed), (3, 2));
        assert_eq!(done, [true, false, true, false, true]);
    }

    #[test]
    fn for_each_shard_visits_every_record_exactly_once() {
        let records = n(3_000, 400);
        let s = ShardedStore::new(5, 64);
        let spec = DatasetSpec { records, ..Default::default() };
        for r in spec.iter() {
            s.insert(r);
        }
        let mut seen = std::collections::BTreeSet::new();
        let mut shards_visited = 0;
        s.for_each_shard(|i, recs| {
            shards_visited += 1;
            for r in recs {
                assert_eq!(s.route(r.isbn13), i, "record reported under a foreign shard");
                assert!(seen.insert(r.isbn13), "duplicate key {}", r.isbn13);
            }
        });
        assert_eq!(shards_visited, 5);
        assert_eq!(seen.len() as u64, records);
    }

    #[test]
    fn value_sum_aggregates_all_shards() {
        let s = ShardedStore::new(3, 16);
        s.insert(BookRecord::new(1, 100, 2)); // 200
        s.insert(BookRecord::new(2, 300, 3)); // 900
        s.insert(BookRecord::new(3, 50, 4)); // 200
        let (n, sum) = s.value_sum_cents();
        assert_eq!(n, 3);
        assert_eq!(sum, 1300);
    }

    #[test]
    fn reads_survive_growth_under_a_write_guard() {
        // A write guard that grows the table republishes the view on drop;
        // reads before, during (fallback) and after agree.
        let s = ShardedStore::new(1, 8);
        for k in 1..=6u64 {
            s.insert(BookRecord::new(k, k * 10, 1));
        }
        {
            let mut g = s.shard(0);
            for k in 7..=500u64 {
                g.insert(BookRecord::new(k, k * 10, 1));
            }
        }
        for k in 1..=500u64 {
            assert_eq!(s.get(k).unwrap().price_cents, k * 10, "key {k} lost across growth");
        }
    }
}
