//! Sharded in-memory store: `n` independent [`HashTable`]s, keys routed by
//! hash. Shard count is fixed at construction (paper: one shard per core),
//! so routing is a pure function and workers never contend.
//!
//! Concurrency model: each shard is wrapped in a `Mutex` so the store is
//! usable from any topology, but the pipeline's shard-affine workers take
//! each mutex uncontended (one worker ↔ one shard) — the lock is a safety
//! net, not a synchronization point. `route()` is exposed so callers can
//! partition work *before* touching the store, which is the paper's design.

use std::sync::Mutex;

use super::hashtable::HashTable;
use crate::storage::index::hash_key;
use crate::workload::record::{BookRecord, StockUpdate};

pub struct ShardedStore {
    shards: Vec<Mutex<HashTable>>,
    /// Bit mask when shard count is a power of two, else None → modulo.
    mask: Option<u64>,
}

impl ShardedStore {
    pub fn new(shards: usize, capacity_hint_per_shard: usize) -> Self {
        assert!(shards > 0);
        let mask = if shards.is_power_of_two() { Some(shards as u64 - 1) } else { None };
        ShardedStore {
            shards: (0..shards)
                .map(|_| Mutex::new(HashTable::with_capacity(capacity_hint_per_shard)))
                .collect(),
            mask,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard owns `key`. Uses the *upper* hash bits so shard routing
    /// stays independent of the in-table slot choice (lower bits).
    #[inline]
    pub fn route(&self, key: u64) -> usize {
        let h = hash_key(key) >> 32;
        match self.mask {
            Some(m) => (h & m) as usize,
            None => (h % self.shards.len() as u64) as usize,
        }
    }

    /// Exclusive access to one shard (used by shard-affine workers).
    pub fn shard(&self, i: usize) -> std::sync::MutexGuard<'_, HashTable> {
        self.shards[i].lock().unwrap()
    }

    pub fn insert(&self, rec: BookRecord) -> Option<BookRecord> {
        self.shard(self.route(rec.isbn13)).insert(rec)
    }

    pub fn get(&self, key: u64) -> Option<BookRecord> {
        self.shard(self.route(key)).get(key)
    }

    pub fn update(&self, key: u64, f: impl FnOnce(&mut BookRecord)) -> bool {
        self.shard(self.route(key)).update(key, f)
    }

    pub fn apply(&self, u: &StockUpdate) -> bool {
        self.update(u.isbn13, |r| u.apply_to(r))
    }

    pub fn remove(&self, key: u64) -> Option<BookRecord> {
        self.shard(self.route(key)).remove(key)
    }

    /// Batched point reads: pre-route every key, then take each touched
    /// shard lock exactly once (shard-affine dispatch, paper §4.2).
    /// Results come back in input order.
    pub fn get_many(&self, keys: &[u64]) -> Vec<Option<BookRecord>> {
        let mut out = vec![None; keys.len()];
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, &k) in keys.iter().enumerate() {
            by_shard[self.route(k)].push(i);
        }
        for (s, idxs) in by_shard.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let shard = self.shard(s);
            for &i in idxs {
                out[i] = shard.get(keys[i]);
            }
        }
        out
    }

    /// Batched updates with one lock acquisition per touched shard.
    /// Duplicate keys within a batch apply in input order (same shard ⇒
    /// ascending index). Returns `(applied, missed)`.
    pub fn apply_many(&self, ups: &[StockUpdate]) -> (u64, u64) {
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, u) in ups.iter().enumerate() {
            by_shard[self.route(u.isbn13)].push(i);
        }
        let (mut applied, mut missed) = (0u64, 0u64);
        for (s, idxs) in by_shard.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let mut shard = self.shard(s);
            for &i in idxs {
                let u = &ups[i];
                if shard.update(u.isbn13, |r| u.apply_to(r)) {
                    applied += 1;
                } else {
                    missed += 1;
                }
            }
        }
        (applied, missed)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn memory_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().memory_bytes()).sum()
    }

    /// (count, Σ price·qty) across all shards.
    pub fn value_sum_cents(&self) -> (u64, u128) {
        let mut n = 0;
        let mut sum = 0;
        for s in &self.shards {
            let (sn, ss) = s.lock().unwrap().value_sum_cents();
            n += sn;
            sum += ss;
        }
        (n, sum)
    }

    /// Snapshot all records of one shard (for writeback / analytics export).
    pub fn shard_records(&self, i: usize) -> Vec<BookRecord> {
        self.shard(i).iter().collect()
    }

    /// Per-shard record counts — balance diagnostics for benches.
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.lock().unwrap().len()).collect()
    }

    /// Iteration hook for checkpointing: visit every record shard by shard.
    /// Each shard's records are copied out under that shard's lock alone —
    /// the store never holds more than one lock, so a snapshot streaming
    /// gigabytes to disk stalls at most one shard at a time while live
    /// traffic proceeds on the others. The view is per-shard-consistent,
    /// not globally consistent; the durability layer recovers exactness by
    /// replaying the WAL segment opened before the snapshot began.
    pub fn for_each_shard(&self, mut f: impl FnMut(usize, &[BookRecord])) {
        for i in 0..self.shards.len() {
            let recs = self.shard_records(i);
            f(i, &recs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::DatasetSpec;

    #[test]
    fn routing_is_stable_and_in_range() {
        let s = ShardedStore::new(12, 16);
        for k in 1..10_000u64 {
            let r = s.route(k);
            assert!(r < 12);
            assert_eq!(r, s.route(k), "routing must be deterministic");
        }
    }

    #[test]
    fn insert_get_across_shards() {
        let s = ShardedStore::new(8, 16);
        let spec = DatasetSpec { records: 5_000, ..Default::default() };
        for r in spec.iter() {
            s.insert(r);
        }
        assert_eq!(s.len(), 5_000);
        for i in (0..5_000).step_by(97) {
            let r = spec.record_at(i);
            assert_eq!(s.get(r.isbn13), Some(r));
        }
    }

    #[test]
    fn shards_balanced_within_20_percent() {
        let s = ShardedStore::new(8, 1 << 12);
        let spec = DatasetSpec { records: 80_000, ..Default::default() };
        for r in spec.iter() {
            s.insert(r);
        }
        let sizes = s.shard_sizes();
        let mean = 80_000.0 / 8.0;
        for (i, &sz) in sizes.iter().enumerate() {
            assert!(
                (sz as f64 - mean).abs() / mean < 0.2,
                "shard {i} unbalanced: {sz} vs mean {mean}"
            );
        }
    }

    #[test]
    fn apply_stock_update() {
        let s = ShardedStore::new(4, 16);
        s.insert(BookRecord::new(123, 100, 1));
        let u = StockUpdate { isbn13: 123, new_price_cents: 393, new_quantity: 495 };
        assert!(s.apply(&u));
        assert_eq!(s.get(123).unwrap().price_cents, 393);
        assert!(!s.apply(&StockUpdate { isbn13: 999, new_price_cents: 1, new_quantity: 1 }));
    }

    #[test]
    fn concurrent_shard_affine_updates() {
        // The paper's topology: each worker updates only its own shard.
        let spec = DatasetSpec { records: 40_000, ..Default::default() };
        let s = ShardedStore::new(4, 1 << 14);
        for r in spec.iter() {
            s.insert(r);
        }
        // Pre-route updates per shard.
        let mut per_shard: Vec<Vec<StockUpdate>> = vec![Vec::new(); 4];
        for r in spec.iter() {
            per_shard[s.route(r.isbn13)].push(StockUpdate {
                isbn13: r.isbn13,
                new_price_cents: 555,
                new_quantity: 5,
            });
        }
        std::thread::scope(|scope| {
            for (i, ups) in per_shard.iter().enumerate() {
                let s = &s;
                scope.spawn(move || {
                    let mut shard = s.shard(i);
                    for u in ups {
                        assert!(shard.update(u.isbn13, |r| u.apply_to(r)));
                    }
                });
            }
        });
        let (n, sum) = s.value_sum_cents();
        assert_eq!(n, 40_000);
        assert_eq!(sum, 40_000u128 * 555 * 5);
    }

    #[test]
    fn non_power_of_two_shards() {
        let s = ShardedStore::new(12, 16);
        let spec = DatasetSpec { records: 1_000, ..Default::default() };
        for r in spec.iter() {
            s.insert(r);
        }
        assert_eq!(s.len(), 1_000);
        assert_eq!(s.shard_sizes().iter().sum::<usize>(), 1_000);
    }

    #[test]
    fn get_many_matches_sequential_gets_in_order() {
        let s = ShardedStore::new(8, 1 << 10);
        let spec = DatasetSpec { records: 2_000, ..Default::default() };
        for r in spec.iter() {
            s.insert(r);
        }
        let mut keys: Vec<u64> = (0..500).map(|i| spec.record_at(i).isbn13).collect();
        keys.push(42); // guaranteed miss
        keys.push(spec.record_at(0).isbn13); // duplicate key
        let batch = s.get_many(&keys);
        assert_eq!(batch.len(), keys.len());
        for (i, k) in keys.iter().enumerate() {
            assert_eq!(batch[i], s.get(*k), "index {i} key {k}");
        }
    }

    #[test]
    fn apply_many_counts_and_matches_sequential() {
        let s = ShardedStore::new(4, 1 << 10);
        for k in 1..=100u64 {
            s.insert(BookRecord::new(k, 1, 1));
        }
        let mut ups: Vec<StockUpdate> = (1..=100u64)
            .map(|k| StockUpdate { isbn13: k, new_price_cents: k * 10, new_quantity: k as u32 })
            .collect();
        ups.push(StockUpdate { isbn13: 9999, new_price_cents: 1, new_quantity: 1 }); // miss
        // Duplicate key: later entry must win (input order within a batch).
        ups.push(StockUpdate { isbn13: 7, new_price_cents: 777, new_quantity: 7 });
        let (applied, missed) = s.apply_many(&ups);
        assert_eq!(applied, 101);
        assert_eq!(missed, 1);
        assert_eq!(s.get(7).unwrap().price_cents, 777);
        assert_eq!(s.get(50).unwrap().price_cents, 500);
        assert_eq!(s.len(), 100);
    }

    #[test]
    fn for_each_shard_visits_every_record_exactly_once() {
        let s = ShardedStore::new(5, 64);
        let spec = DatasetSpec { records: 3_000, ..Default::default() };
        for r in spec.iter() {
            s.insert(r);
        }
        let mut seen = std::collections::BTreeSet::new();
        let mut shards_visited = 0;
        s.for_each_shard(|i, recs| {
            shards_visited += 1;
            for r in recs {
                assert_eq!(s.route(r.isbn13), i, "record reported under a foreign shard");
                assert!(seen.insert(r.isbn13), "duplicate key {}", r.isbn13);
            }
        });
        assert_eq!(shards_visited, 5);
        assert_eq!(seen.len(), 3_000);
    }

    #[test]
    fn value_sum_aggregates_all_shards() {
        let s = ShardedStore::new(3, 16);
        s.insert(BookRecord::new(1, 100, 2)); // 200
        s.insert(BookRecord::new(2, 300, 3)); // 900
        s.insert(BookRecord::new(3, 50, 4)); // 200
        let (n, sum) = s.value_sum_cents();
        assert_eq!(n, 3);
        assert_eq!(sum, 1300);
    }
}
