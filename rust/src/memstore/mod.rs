//! The paper's memory layer (§4.1–4.2): records live in purpose-built
//! open-addressing hash tables in RAM, sharded one-table-per-thread
//! (`T = {(t1,h1), (t2,h2), …, (tn,hn)}`), loaded once from the disk store
//! and updated in parallel with zero cross-shard synchronization. Point
//! reads are **lock-free** (per-shard seqlock; see [`shard`]): writers stay
//! mutex-serialized per shard, readers validate an optimistic probe against
//! the shard's version counter and retry instead of locking.

pub mod hashtable;
pub mod shard;
pub mod snapshot;

pub use hashtable::HashTable;
pub use shard::{ReadPathStats, ShardWriteGuard, ShardedStore};
