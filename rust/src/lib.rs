//! # membig — memory-based multi-processing engine for big-data computation
//!
//! A production-shaped reproduction of Bassil (2019), *"Memory-Based
//! Multi-Processing Method For Big Data Computation"*: load a disk-resident
//! table into sharded in-memory hash tables, apply a bulk update feed with
//! one worker thread per core over shared memory, on a single server — and
//! compare against the conventional disk-based per-record path.
//!
//! ## Layering
//! - **L3 (this crate)** — coordinator, sharded memstore, streaming pipeline,
//!   disk-store substrate with an HDD latency model, metrics, CLI, server.
//! - **L2 (JAX, build-time)** — the analytics compute graph, AOT-lowered to
//!   HLO text in `artifacts/` by `python/compile/aot.py`.
//! - **L1 (Pallas, build-time)** — the tiled masked-update + partial-reduce
//!   kernel called by L2 (interpret mode for CPU PJRT).
//!
//! Python never runs on the request path: with the `pjrt` cargo feature,
//! [`runtime`] loads the artifacts through the PJRT C API (`xla` crate) and
//! executes them from Rust. The **default build is std-only**: analytics is
//! served by the pure-Rust reference backend ([`runtime::reference`]), so a
//! fresh checkout builds and tests green with no artifacts and no XLA.
//!
//! See `DESIGN.md` (repo root) for the full system inventory and the
//! experiment index, and `EXPERIMENTS.md` for paper-vs-measured results.

// Correctness wall (DESIGN.md §13): `unsafe` is confined to the three
// whitelisted modules — `memstore/hashtable.rs`, `memstore/shard.rs`,
// `server/sys.rs` — each of which opens with `#![allow(unsafe_code)]`.
// Everything else is denied here, every unsafe fn body must re-assert its
// own obligations, and `cargo xtask lint` additionally enforces a
// `// SAFETY:` comment on every unsafe block.
#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod baseline;
pub mod config;
pub mod ipc;
pub mod coordinator;
pub mod durability;
pub mod memstore;
pub mod metrics;
pub mod pipeline;
pub mod replication;
pub mod runtime;
pub mod server;
pub mod storage;
pub mod textstore;
pub mod util;
pub mod workload;
