//! Leader side: spawn N worker processes, shard records/updates across
//! them by the same hash routing as the in-process store, and drive the
//! workload over Unix sockets.

use std::io::{BufReader, BufWriter, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::process::{Child, Command};

use super::proto::{join_u128, ProtoError, Request, Response};
use crate::storage::index::hash_key;
use crate::workload::record::{BookRecord, StockUpdate};

#[derive(Debug)]
pub enum IpcError {
    Io(std::io::Error),
    Proto(ProtoError),
    Unexpected(usize, Response),
    WorkerDied(usize),
}

impl std::fmt::Display for IpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IpcError::Io(e) => write!(f, "io: {e}"),
            IpcError::Proto(e) => write!(f, "proto: {e}"),
            IpcError::Unexpected(w, resp) => {
                write!(f, "worker {w} sent unexpected response: {resp:?}")
            }
            IpcError::WorkerDied(w) => write!(f, "worker {w} exited abnormally"),
        }
    }
}

impl std::error::Error for IpcError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            IpcError::Io(e) => Some(e),
            IpcError::Proto(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for IpcError {
    fn from(e: std::io::Error) -> Self {
        IpcError::Io(e)
    }
}

impl From<ProtoError> for IpcError {
    fn from(e: ProtoError) -> Self {
        IpcError::Proto(e)
    }
}

struct WorkerConn {
    child: Option<Child>,
    reader: BufReader<UnixStream>,
    writer: BufWriter<UnixStream>,
}

/// A pool of worker processes, one hash-table shard each.
pub struct ProcessPool {
    workers: Vec<WorkerConn>,
    socket_dir: PathBuf,
}

impl ProcessPool {
    /// Spawn `n` worker processes by self-exec'ing the current binary with
    /// the hidden `ipc-worker` subcommand.
    pub fn spawn(n: usize) -> Result<Self, IpcError> {
        Self::spawn_with_exe(n, std::env::current_exe()?)
    }

    /// Spawn with an explicit worker binary (integration tests pass
    /// `env!("CARGO_BIN_EXE_membig")`; production uses `spawn`).
    pub fn spawn_with_exe(n: usize, exe: PathBuf) -> Result<Self, IpcError> {
        assert!(n > 0);
        // Fork-bomb guard: a worker process must never spawn its own pool.
        if std::env::var_os("MEMBIG_IPC_CHILD").is_some() {
            return Err(IpcError::Io(std::io::Error::other(
                "refusing to spawn a process pool from inside an ipc worker",
            )));
        }
        let socket_dir = std::env::temp_dir()
            .join(format!("membig_ipc_{}_{:x}", std::process::id(), hash_key(n as u64)));
        std::fs::create_dir_all(&socket_dir)?;
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let sock_path = socket_dir.join(format!("worker_{i}.sock"));
            std::fs::remove_file(&sock_path).ok();
            let listener = UnixListener::bind(&sock_path)?;
            let child = Command::new(&exe)
                .arg("ipc-worker")
                .arg("--socket")
                .arg(&sock_path)
                .env("MEMBIG_IPC_CHILD", "1")
                .spawn()?;
            let (stream, _) = listener.accept()?;
            workers.push(WorkerConn {
                child: Some(child),
                reader: BufReader::with_capacity(1 << 20, stream.try_clone()?),
                writer: BufWriter::with_capacity(1 << 20, stream),
            });
        }
        Ok(ProcessPool { workers, socket_dir })
    }

    /// In-process pool for tests: workers are threads serving socketpairs,
    /// exercising the identical protocol path without process spawn.
    pub fn spawn_in_process(n: usize) -> Result<Self, IpcError> {
        assert!(n > 0);
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let (leader_sock, worker_sock) = UnixStream::pair()?;
            std::thread::spawn(move || {
                let r = worker_sock.try_clone().expect("clone");
                let _ = super::worker::serve(r, worker_sock);
            });
            workers.push(WorkerConn {
                child: None,
                reader: BufReader::with_capacity(1 << 20, leader_sock.try_clone()?),
                writer: BufWriter::with_capacity(1 << 20, leader_sock),
            });
        }
        Ok(ProcessPool { workers, socket_dir: std::env::temp_dir() })
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    #[inline]
    pub fn route(&self, key: u64) -> usize {
        ((hash_key(key) >> 32) % self.workers.len() as u64) as usize
    }

    fn call(&mut self, worker: usize, req: &Request) -> Result<Response, IpcError> {
        let w = &mut self.workers[worker];
        req.write_to(&mut w.writer)?;
        w.writer.flush()?;
        Ok(Response::read_from(&mut w.reader)?)
    }

    /// Shard and load records; returns total loaded.
    pub fn load(&mut self, records: &[BookRecord]) -> Result<u64, IpcError> {
        let n = self.workers.len();
        let mut parts: Vec<Vec<BookRecord>> = vec![Vec::new(); n];
        for r in records {
            parts[self.route(r.isbn13)].push(*r);
        }
        // Send all, then collect all (one in-flight request per worker).
        for (i, part) in parts.iter().enumerate() {
            let w = &mut self.workers[i];
            Request::Load(part.clone()).write_to(&mut w.writer)?;
            w.writer.flush()?;
        }
        let mut total = 0;
        for i in 0..n {
            match Response::read_from(&mut self.workers[i].reader)? {
                Response::Loaded(k) => total += k,
                other => return Err(IpcError::Unexpected(i, other)),
            }
        }
        Ok(total)
    }

    /// Shard and apply updates in parallel across processes; returns
    /// (applied, missing).
    pub fn update(&mut self, updates: &[StockUpdate]) -> Result<(u64, u64), IpcError> {
        let n = self.workers.len();
        let mut parts: Vec<Vec<StockUpdate>> = vec![Vec::new(); n];
        for u in updates {
            parts[self.route(u.isbn13)].push(*u);
        }
        for (i, part) in parts.iter().enumerate() {
            let w = &mut self.workers[i];
            Request::Update(part.clone()).write_to(&mut w.writer)?;
            w.writer.flush()?;
        }
        let (mut applied, mut missing) = (0, 0);
        for i in 0..n {
            match Response::read_from(&mut self.workers[i].reader)? {
                Response::Applied { applied: a, missing: m } => {
                    applied += a;
                    missing += m;
                }
                other => return Err(IpcError::Unexpected(i, other)),
            }
        }
        Ok((applied, missing))
    }

    /// Aggregate stats across all workers.
    pub fn stats(&mut self) -> Result<(u64, u128), IpcError> {
        let n = self.workers.len();
        for i in 0..n {
            let w = &mut self.workers[i];
            Request::Stats.write_to(&mut w.writer)?;
            w.writer.flush()?;
        }
        let (mut count, mut value) = (0u64, 0u128);
        for i in 0..n {
            match Response::read_from(&mut self.workers[i].reader)? {
                Response::Stats { count: c, value_cents_lo, value_cents_hi } => {
                    count += c;
                    value += join_u128(value_cents_lo, value_cents_hi);
                }
                other => return Err(IpcError::Unexpected(i, other)),
            }
        }
        Ok((count, value))
    }

    /// Point lookup through the owning worker.
    pub fn get(&mut self, key: u64) -> Result<Option<BookRecord>, IpcError> {
        let w = self.route(key);
        match self.call(w, &Request::Get(key))? {
            Response::Record(r) => Ok(r),
            other => Err(IpcError::Unexpected(w, other)),
        }
    }

    /// Graceful shutdown: Shutdown RPC, wait for children.
    pub fn shutdown(mut self) -> Result<(), IpcError> {
        for i in 0..self.workers.len() {
            let _ = self.call(i, &Request::Shutdown);
        }
        for (i, w) in self.workers.iter_mut().enumerate() {
            if let Some(mut child) = w.child.take() {
                let status = child.wait()?;
                if !status.success() {
                    return Err(IpcError::WorkerDied(i));
                }
            }
        }
        std::fs::remove_dir_all(&self.socket_dir).ok();
        Ok(())
    }
}

impl Drop for ProcessPool {
    fn drop(&mut self) {
        for w in &mut self.workers {
            if let Some(mut child) = w.child.take() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::{generate_stock_updates, DatasetSpec, KeyDist};

    #[test]
    fn in_process_pool_full_workflow() {
        let spec = DatasetSpec { records: 5_000, ..Default::default() };
        let records: Vec<BookRecord> = spec.iter().collect();
        let mut pool = ProcessPool::spawn_in_process(4).unwrap();
        assert_eq!(pool.load(&records).unwrap(), 5_000);

        let ups = generate_stock_updates(&spec, 5_000, KeyDist::PermuteAll, 77);
        let (applied, missing) = pool.update(&ups).unwrap();
        assert_eq!(applied, 5_000);
        assert_eq!(missing, 0);

        // Cross-check against an in-process store applying the same updates.
        let store = crate::memstore::ShardedStore::new(4, 4096);
        for r in &records {
            store.insert(*r);
        }
        for u in &ups {
            store.apply(u);
        }
        let (count, value) = pool.stats().unwrap();
        assert_eq!((count, value), store.value_sum_cents());

        // Point reads route correctly.
        let sample = spec.record_at(123);
        let got = pool.get(sample.isbn13).unwrap().unwrap();
        let expect = store.get(sample.isbn13).unwrap();
        assert_eq!(got, expect);

        pool.shutdown().unwrap();
    }

    #[test]
    fn missing_keys_reported() {
        let mut pool = ProcessPool::spawn_in_process(2).unwrap();
        pool.load(&[BookRecord::new(1, 1, 1)]).unwrap();
        let (applied, missing) = pool
            .update(&[
                StockUpdate { isbn13: 1, new_price_cents: 9, new_quantity: 9 },
                StockUpdate { isbn13: 2, new_price_cents: 9, new_quantity: 9 },
            ])
            .unwrap();
        assert_eq!((applied, missing), (1, 1));
        pool.shutdown().unwrap();
    }
}
