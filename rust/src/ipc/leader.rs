//! Leader side: spawn N worker processes, shard records/updates across
//! them by the same hash routing as the in-process store, and drive the
//! workload over Unix sockets.
//!
//! Two faces share the spawn/connect machinery:
//!
//! * [`ProcessPool`] — the batch workflow (`load`/`update`/`stats`/`get`),
//!   single-threaded, one caller;
//! * [`ServingPool`] — the `serve --processes N` backend built from a pool
//!   via [`ProcessPool::into_serving`]: every worker connection sits behind
//!   its own mutex so reactor threads issue RPCs concurrently, and
//!   scatter-gather verbs write to every touched worker before reading any
//!   response (per-worker pipelining).

use std::io::{BufReader, BufWriter, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::proto::{
    join_u128, ProtoError, Request, Response, MAX_FRAME, RECORD_ENTRY_BYTES, UPDATE_BYTES,
};
use crate::metrics::IpcMetrics;
use crate::storage::index::hash_key;
use crate::util::racecheck;
use crate::workload::record::{BookRecord, StockUpdate, RECORD_BYTES};

/// Records per `Load` frame: the largest whole-record count whose frame
/// (tag byte + payload) stays within [`MAX_FRAME`].
pub(crate) const LOAD_CHUNK_RECORDS: usize = (MAX_FRAME as usize - 1) / RECORD_BYTES;

/// Updates per `Update` frame (same bound as [`LOAD_CHUNK_RECORDS`]).
pub(crate) const UPDATE_CHUNK_RECORDS: usize = (MAX_FRAME as usize - 1) / UPDATE_BYTES;

/// Keys per `GetMany` frame — bounded by the *response* size (one
/// presence-prefixed record entry per key), which is the larger side.
pub(crate) const GET_MANY_CHUNK_KEYS: usize = (MAX_FRAME as usize - 1) / RECORD_ENTRY_BYTES;

/// How long a spawned worker gets to connect back before the leader gives
/// up (the child is killed and the spawn fails instead of hanging).
const SPAWN_ACCEPT_TIMEOUT: Duration = Duration::from_secs(10);
const SPAWN_POLL: Duration = Duration::from_millis(5);

#[derive(Debug)]
pub enum IpcError {
    Io(std::io::Error),
    Proto(ProtoError),
    Unexpected(usize, Response),
    WorkerDied { worker: usize, status: Option<i32> },
}

impl std::fmt::Display for IpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IpcError::Io(e) => write!(f, "io: {e}"),
            IpcError::Proto(e) => write!(f, "proto: {e}"),
            IpcError::Unexpected(w, resp) => {
                write!(f, "worker {w} sent unexpected response: {resp:?}")
            }
            IpcError::WorkerDied { worker, status: Some(c) } => {
                write!(f, "worker {worker} exited abnormally (status {c})")
            }
            IpcError::WorkerDied { worker, status: None } => {
                write!(f, "worker {worker} died")
            }
        }
    }
}

impl std::error::Error for IpcError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            IpcError::Io(e) => Some(e),
            IpcError::Proto(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for IpcError {
    fn from(e: std::io::Error) -> Self {
        IpcError::Io(e)
    }
}

impl From<ProtoError> for IpcError {
    fn from(e: ProtoError) -> Self {
        IpcError::Proto(e)
    }
}

struct WorkerConn {
    child: Option<Child>,
    reader: BufReader<UnixStream>,
    writer: BufWriter<UnixStream>,
}

impl WorkerConn {
    fn new(mut child: Option<Child>, stream: UnixStream) -> Result<WorkerConn, IpcError> {
        match stream.try_clone() {
            Ok(r) => Ok(WorkerConn {
                child,
                reader: BufReader::with_capacity(1 << 20, r),
                writer: BufWriter::with_capacity(1 << 20, stream),
            }),
            Err(e) => {
                if let Some(c) = child.as_mut() {
                    let _ = c.kill();
                    let _ = c.wait();
                }
                Err(IpcError::Io(e))
            }
        }
    }
}

impl Drop for WorkerConn {
    fn drop(&mut self) {
        // Kill-on-drop keeps every error path leak-free: a half-built pool
        // (spawn failure mid-loop) reaps the workers it already connected.
        if let Some(mut child) = self.child.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Route a key to its owning worker — the same upper-32-bit split of
/// [`hash_key`] the in-process `ShardedStore` uses for shard routing.
#[inline]
fn route_key(key: u64, n: usize) -> usize {
    ((hash_key(key) >> 32) % n as u64) as usize
}

/// A pool of worker processes, one hash-table shard each.
pub struct ProcessPool {
    workers: Vec<WorkerConn>,
    /// `Some` only when this pool created the directory (socket rendezvous
    /// of real spawned processes). In-process pools own no directory and
    /// must never delete one — the old code stored `env::temp_dir()` here
    /// and `shutdown()` recursively deleted the system temp dir.
    socket_dir: Option<PathBuf>,
}

impl ProcessPool {
    /// Spawn `n` worker processes by self-exec'ing the current binary with
    /// the hidden `ipc-worker` subcommand.
    pub fn spawn(n: usize) -> Result<Self, IpcError> {
        Self::spawn_with_exe(n, std::env::current_exe()?)
    }

    /// Spawn with an explicit worker binary (integration tests pass
    /// `env!("CARGO_BIN_EXE_membig")`; production uses `spawn`).
    pub fn spawn_with_exe(n: usize, exe: PathBuf) -> Result<Self, IpcError> {
        assert!(n > 0);
        // Fork-bomb guard: a worker process must never spawn its own pool.
        if std::env::var_os("MEMBIG_IPC_CHILD").is_some() {
            return Err(IpcError::Io(std::io::Error::other(
                "refusing to spawn a process pool from inside an ipc worker",
            )));
        }
        let socket_dir = std::env::temp_dir()
            .join(format!("membig_ipc_{}_{:x}", std::process::id(), hash_key(n as u64)));
        std::fs::create_dir_all(&socket_dir)?;
        match Self::spawn_workers(n, &exe, &socket_dir) {
            Ok(workers) => Ok(ProcessPool { workers, socket_dir: Some(socket_dir) }),
            Err(e) => {
                std::fs::remove_dir_all(&socket_dir).ok();
                Err(e)
            }
        }
    }

    fn spawn_workers(
        n: usize,
        exe: &Path,
        socket_dir: &Path,
    ) -> Result<Vec<WorkerConn>, IpcError> {
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let sock_path = socket_dir.join(format!("worker_{i}.sock"));
            std::fs::remove_file(&sock_path).ok();
            let listener = UnixListener::bind(&sock_path)?;
            let mut child = Command::new(exe)
                .arg("ipc-worker")
                .arg("--socket")
                .arg(&sock_path)
                .env("MEMBIG_IPC_CHILD", "1")
                .spawn()?;
            let stream = match Self::accept_worker(&listener, &mut child, i) {
                Ok(s) => s,
                Err(e) => {
                    let _ = child.kill();
                    let _ = child.wait();
                    return Err(e);
                }
            };
            workers.push(WorkerConn::new(Some(child), stream)?);
        }
        Ok(workers)
    }

    /// Accept one worker's connect-back without hanging the leader: the
    /// listener polls nonblocking, watching `child.try_wait()` so a worker
    /// that dies before connecting (bad exe, crash on startup) surfaces as
    /// [`IpcError::WorkerDied`] with its exit status instead of parking the
    /// process in `accept()` forever.
    fn accept_worker(
        listener: &UnixListener,
        child: &mut Child,
        worker: usize,
    ) -> Result<UnixStream, IpcError> {
        listener.set_nonblocking(true)?;
        let deadline = Instant::now() + SPAWN_ACCEPT_TIMEOUT;
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    // Accepted sockets inherit nonblocking on some Unixes.
                    stream.set_nonblocking(false)?;
                    return Ok(stream);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // Widen the accept-vs-child-exit race: a worker that
                    // connects and dies must never be misread as a timeout.
                    racecheck::perturb("ipc.accept.poll");
                    if let Some(status) = child.try_wait()? {
                        return Err(IpcError::WorkerDied { worker, status: status.code() });
                    }
                    if Instant::now() >= deadline {
                        return Err(IpcError::Io(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            format!("worker {worker} did not connect back within 10s"),
                        )));
                    }
                    std::thread::sleep(SPAWN_POLL);
                }
                Err(e) => return Err(IpcError::Io(e)),
            }
        }
    }

    /// In-process pool for tests: workers are threads serving socketpairs,
    /// exercising the identical protocol path without process spawn.
    pub fn spawn_in_process(n: usize) -> Result<Self, IpcError> {
        assert!(n > 0);
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let (leader_sock, worker_sock) = UnixStream::pair()?;
            std::thread::spawn(move || {
                let r = worker_sock.try_clone().expect("clone");
                let _ = super::worker::serve(r, worker_sock);
            });
            workers.push(WorkerConn::new(None, leader_sock)?);
        }
        Ok(ProcessPool { workers, socket_dir: None })
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// OS pids of spawned workers (empty for in-process pools) — lets
    /// integration tests SIGKILL a worker mid-flight.
    pub fn worker_pids(&self) -> Vec<u32> {
        self.workers.iter().filter_map(|w| w.child.as_ref().map(|c| c.id())).collect()
    }

    #[inline]
    pub fn route(&self, key: u64) -> usize {
        route_key(key, self.workers.len())
    }

    fn call(&mut self, worker: usize, req: &Request) -> Result<Response, IpcError> {
        let w = &mut self.workers[worker];
        req.write_to(&mut w.writer)?;
        w.writer.flush()?;
        Ok(Response::read_from(&mut w.reader)?)
    }

    /// Shard and load records; returns total loaded. Oversized shards are
    /// split into multiple ≤ [`MAX_FRAME`] frames.
    pub fn load(&mut self, records: &[BookRecord]) -> Result<u64, IpcError> {
        self.load_chunked(records, LOAD_CHUNK_RECORDS)
    }

    pub(crate) fn load_chunked(
        &mut self,
        records: &[BookRecord],
        per_frame: usize,
    ) -> Result<u64, IpcError> {
        let per_frame = per_frame.max(1);
        let n = self.workers.len();
        let mut parts: Vec<Vec<BookRecord>> = vec![Vec::new(); n];
        for r in records {
            parts[self.route(r.isbn13)].push(*r);
        }
        // Send every frame, then collect every response (per-worker
        // pipelining: workers chew their shares in parallel).
        let mut expect = vec![0usize; n];
        for (i, part) in parts.iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            let w = &mut self.workers[i];
            for chunk in part.chunks(per_frame) {
                Request::Load(chunk.to_vec()).write_to(&mut w.writer)?;
                expect[i] += 1;
            }
            w.writer.flush()?;
        }
        let mut total = 0;
        for (i, &frames) in expect.iter().enumerate() {
            for _ in 0..frames {
                match Response::read_from(&mut self.workers[i].reader)? {
                    Response::Loaded(k) => total += k,
                    other => return Err(IpcError::Unexpected(i, other)),
                }
            }
        }
        Ok(total)
    }

    /// Shard and apply updates in parallel across processes; returns
    /// (applied, missing). Chunks like [`ProcessPool::load`].
    pub fn update(&mut self, updates: &[StockUpdate]) -> Result<(u64, u64), IpcError> {
        self.update_chunked(updates, UPDATE_CHUNK_RECORDS)
    }

    pub(crate) fn update_chunked(
        &mut self,
        updates: &[StockUpdate],
        per_frame: usize,
    ) -> Result<(u64, u64), IpcError> {
        let per_frame = per_frame.max(1);
        let n = self.workers.len();
        let mut parts: Vec<Vec<StockUpdate>> = vec![Vec::new(); n];
        for u in updates {
            parts[self.route(u.isbn13)].push(*u);
        }
        let mut expect = vec![0usize; n];
        for (i, part) in parts.iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            let w = &mut self.workers[i];
            for chunk in part.chunks(per_frame) {
                Request::Update(chunk.to_vec()).write_to(&mut w.writer)?;
                expect[i] += 1;
            }
            w.writer.flush()?;
        }
        let (mut applied, mut missing) = (0, 0);
        for (i, &frames) in expect.iter().enumerate() {
            for _ in 0..frames {
                match Response::read_from(&mut self.workers[i].reader)? {
                    Response::Applied { applied: a, missing: m } => {
                        applied += a;
                        missing += m;
                    }
                    other => return Err(IpcError::Unexpected(i, other)),
                }
            }
        }
        Ok((applied, missing))
    }

    /// Aggregate stats across all workers.
    pub fn stats(&mut self) -> Result<(u64, u128), IpcError> {
        let n = self.workers.len();
        for i in 0..n {
            let w = &mut self.workers[i];
            Request::Stats.write_to(&mut w.writer)?;
            w.writer.flush()?;
        }
        let (mut count, mut value) = (0u64, 0u128);
        for i in 0..n {
            match Response::read_from(&mut self.workers[i].reader)? {
                Response::Stats { count: c, value_cents_lo, value_cents_hi } => {
                    count += c;
                    value += join_u128(value_cents_lo, value_cents_hi);
                }
                other => return Err(IpcError::Unexpected(i, other)),
            }
        }
        Ok((count, value))
    }

    /// Point lookup through the owning worker.
    pub fn get(&mut self, key: u64) -> Result<Option<BookRecord>, IpcError> {
        let w = self.route(key);
        match self.call(w, &Request::Get(key))? {
            Response::Record(r) => Ok(r),
            other => Err(IpcError::Unexpected(w, other)),
        }
    }

    /// Convert the loaded pool into the concurrent serving backend.
    pub fn into_serving(mut self) -> ServingPool {
        // The handoff moves every connection from single-caller to
        // mutex-shared use; any RPC still in flight here is a protocol bug.
        racecheck::perturb("ipc.handoff");
        let workers: Vec<Mutex<ServingWorker>> = std::mem::take(&mut self.workers)
            .into_iter()
            .map(|conn| Mutex::new(ServingWorker { conn, dead: false }))
            .collect();
        let n = workers.len();
        ServingPool { workers, socket_dir: self.socket_dir.take(), metrics: IpcMetrics::new(n) }
    }

    /// Graceful shutdown: Shutdown RPC, wait for children.
    pub fn shutdown(mut self) -> Result<(), IpcError> {
        for i in 0..self.workers.len() {
            let _ = self.call(i, &Request::Shutdown);
        }
        for (i, w) in self.workers.iter_mut().enumerate() {
            if let Some(mut child) = w.child.take() {
                let status = child.wait()?;
                if !status.success() {
                    return Err(IpcError::WorkerDied { worker: i, status: status.code() });
                }
            }
        }
        Ok(())
    }
}

impl Drop for ProcessPool {
    fn drop(&mut self) {
        // Children are reaped by each WorkerConn's Drop; only a socket
        // directory this pool itself created is removed here.
        if let Some(d) = self.socket_dir.take() {
            std::fs::remove_dir_all(&d).ok();
        }
    }
}

// ---------------------------------------------------------------------------
// Serving backend
// ---------------------------------------------------------------------------

/// One point operation for [`ServingPool::exec_points`] — the BATCH
/// scatter-gather path groups consecutive GET/UPDATE lines into one RPC
/// round per touched worker.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PointOp {
    Get(u64),
    Update(StockUpdate),
}

/// Reply for one [`PointOp`], in submission order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PointReply {
    Rec(Option<BookRecord>),
    Applied(bool),
}

struct ServingWorker {
    conn: WorkerConn,
    /// Sticky failure flag: once an RPC on this connection errors, the
    /// stream position is indeterminate, so every later call fails fast
    /// with `WorkerDied` instead of desyncing request/response frames.
    dead: bool,
}

fn lock(m: &Mutex<ServingWorker>) -> MutexGuard<'_, ServingWorker> {
    // A panic while holding the lock poisons it; the sticky `dead` flag is
    // the real safety net, so recover the guard rather than propagating.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn send_frames(i: usize, w: &mut ServingWorker, frames: &[Request]) -> Result<(), IpcError> {
    if w.dead {
        return Err(IpcError::WorkerDied { worker: i, status: None });
    }
    for f in frames {
        f.write_to(&mut w.conn.writer)?;
    }
    w.conn.writer.flush()?;
    Ok(())
}

fn short_reply(w: usize, got: usize, want: usize) -> IpcError {
    IpcError::Io(std::io::Error::other(format!(
        "worker {w} answered {got} of {want} expected entries"
    )))
}

/// The `serve --processes N` backend: shard-owning worker processes driven
/// concurrently from the server's reactor/worker threads. Point verbs hit
/// the owning worker; scatter verbs lock every touched worker in ascending
/// index order (deadlock-free against concurrent scatters), write all
/// frames, then gather — so workers execute their shares in parallel.
pub struct ServingPool {
    workers: Vec<Mutex<ServingWorker>>,
    socket_dir: Option<PathBuf>,
    metrics: IpcMetrics,
}

impl ServingPool {
    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Per-worker RPC counters and latency (surface of `STATS SERVER`).
    pub fn metrics(&self) -> &IpcMetrics {
        &self.metrics
    }

    /// OS pids of spawned workers (empty for in-process pools).
    pub fn worker_pids(&self) -> Vec<u32> {
        self.workers
            .iter()
            .filter_map(|m| lock(m).conn.child.as_ref().map(|c| c.id()))
            .collect()
    }

    #[inline]
    pub fn route(&self, key: u64) -> usize {
        route_key(key, self.workers.len())
    }

    /// One request, one response, against one worker.
    fn call_one(&self, i: usize, req: &Request) -> Result<Response, IpcError> {
        let t0 = Instant::now();
        let mut g = lock(&self.workers[i]);
        if g.dead {
            self.metrics.record_error(i);
            return Err(IpcError::WorkerDied { worker: i, status: None });
        }
        let res = (|| -> Result<Response, IpcError> {
            req.write_to(&mut g.conn.writer)?;
            g.conn.writer.flush()?;
            // Window between flush and read: concurrent call_one() calls on
            // *other* workers interleave here; this worker's lock is held,
            // so request/response frames must stay paired per connection.
            racecheck::perturb("ipc.rpc.roundtrip");
            Ok(Response::read_from(&mut g.conn.reader)?)
        })();
        match &res {
            Ok(_) => self.metrics.record_rpc(i, 1, t0.elapsed()),
            Err(_) => {
                g.dead = true;
                self.metrics.record_error(i);
            }
        }
        res
    }

    /// Scatter-gather core: `parts[i]` holds the frames for worker `i`
    /// (empty = untouched). Locks touched workers in ascending index
    /// order, writes + flushes everything, then reads `parts[i].len()`
    /// responses per worker through `on_resp`. Even when one worker fails
    /// mid-exchange, the others are still drained so their connections
    /// stay frame-synchronized; the first error is returned.
    fn scatter<F>(&self, parts: &[Vec<Request>], mut on_resp: F) -> Result<(), IpcError>
    where
        F: FnMut(usize, Response) -> Result<(), IpcError>,
    {
        debug_assert_eq!(parts.len(), self.workers.len());
        let t0 = Instant::now();
        let mut guards = Vec::new();
        for (i, part) in parts.iter().enumerate() {
            if !part.is_empty() {
                guards.push((i, lock(&self.workers[i])));
            }
        }
        let mut first_err: Option<IpcError> = None;
        let mut sent = vec![true; guards.len()];
        for (gi, (i, g)) in guards.iter_mut().enumerate() {
            if let Err(e) = send_frames(*i, g, &parts[*i]) {
                g.dead = true;
                self.metrics.record_error(*i);
                sent[gi] = false;
                first_err.get_or_insert(e);
            }
        }
        // All frames are in flight; workers chew their shares in parallel
        // while this thread still holds every touched lock. Concurrent
        // scatters queue on the ascending-order locks — widen the window
        // where that ordering is what prevents deadlock.
        racecheck::perturb("ipc.scatter.gather");
        for (gi, (i, g)) in guards.iter_mut().enumerate() {
            if !sent[gi] {
                continue;
            }
            let mut res = Ok(());
            for _ in 0..parts[*i].len() {
                res = match Response::read_from(&mut g.conn.reader) {
                    Ok(resp) => on_resp(*i, resp),
                    Err(e) => Err(IpcError::Proto(e)),
                };
                if res.is_err() {
                    break;
                }
            }
            match res {
                Ok(()) => self.metrics.record_rpc(*i, parts[*i].len() as u64, t0.elapsed()),
                Err(e) => {
                    g.dead = true;
                    self.metrics.record_error(*i);
                    first_err.get_or_insert(e);
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Point lookup through the owning worker.
    pub fn get(&self, key: u64) -> Result<Option<BookRecord>, IpcError> {
        let w = self.route(key);
        match self.call_one(w, &Request::Get(key))? {
            Response::Record(r) => Ok(r),
            other => Err(IpcError::Unexpected(w, other)),
        }
    }

    /// Point update through the owning worker; `true` when the key existed.
    pub fn update_one(&self, u: &StockUpdate) -> Result<bool, IpcError> {
        let w = self.route(u.isbn13);
        match self.call_one(w, &Request::Update(vec![*u]))? {
            Response::Applied { applied, .. } => Ok(applied == 1),
            other => Err(IpcError::Unexpected(w, other)),
        }
    }

    /// Multi-key read (MGET): results in input key order.
    pub fn get_many(&self, keys: &[u64]) -> Result<Vec<Option<BookRecord>>, IpcError> {
        let n = self.workers.len();
        let mut per_keys: Vec<Vec<u64>> = vec![Vec::new(); n];
        let mut plan = Vec::with_capacity(keys.len());
        for &k in keys {
            let w = self.route(k);
            plan.push((w, per_keys[w].len()));
            per_keys[w].push(k);
        }
        let mut parts: Vec<Vec<Request>> = vec![Vec::new(); n];
        for (i, ks) in per_keys.iter().enumerate() {
            for chunk in ks.chunks(GET_MANY_CHUNK_KEYS) {
                parts[i].push(Request::GetMany(chunk.to_vec()));
            }
        }
        let mut per: Vec<Vec<Option<BookRecord>>> = vec![Vec::new(); n];
        self.scatter(&parts, |i, resp| match resp {
            Response::Records(rs) => {
                per[i].extend(rs);
                Ok(())
            }
            other => Err(IpcError::Unexpected(i, other)),
        })?;
        for (i, ks) in per_keys.iter().enumerate() {
            if per[i].len() != ks.len() {
                return Err(short_reply(i, per[i].len(), ks.len()));
            }
        }
        Ok(plan.into_iter().map(|(w, j)| per[w][j]).collect())
    }

    /// Keyed update batch (MUPDATE): returns `(applied, missing)`.
    pub fn update_many(&self, ups: &[StockUpdate]) -> Result<(u64, u64), IpcError> {
        let n = self.workers.len();
        let mut per: Vec<Vec<StockUpdate>> = vec![Vec::new(); n];
        for u in ups {
            per[self.route(u.isbn13)].push(*u);
        }
        let mut parts: Vec<Vec<Request>> = vec![Vec::new(); n];
        for (i, us) in per.iter().enumerate() {
            for chunk in us.chunks(UPDATE_CHUNK_RECORDS) {
                parts[i].push(Request::Update(chunk.to_vec()));
            }
        }
        let (mut applied, mut missing) = (0u64, 0u64);
        self.scatter(&parts, |i, resp| match resp {
            Response::Applied { applied: a, missing: m } => {
                applied += a;
                missing += m;
                Ok(())
            }
            other => Err(IpcError::Unexpected(i, other)),
        })?;
        Ok((applied, missing))
    }

    /// Execute an ordered run of point ops (BATCH lines) with one `Group`
    /// frame per touched worker. Per-key ordering is preserved: equal keys
    /// route to the same worker and keep their submission order inside its
    /// group. Replies come back in submission order.
    pub fn exec_points(&self, ops: &[PointOp]) -> Result<Vec<PointReply>, IpcError> {
        let n = self.workers.len();
        let mut subs: Vec<Vec<Request>> = vec![Vec::new(); n];
        let mut plan = Vec::with_capacity(ops.len());
        for op in ops {
            let (key, req) = match op {
                PointOp::Get(k) => (*k, Request::Get(*k)),
                PointOp::Update(u) => (u.isbn13, Request::Update(vec![*u])),
            };
            let w = self.route(key);
            plan.push((w, subs[w].len()));
            subs[w].push(req);
        }
        let mut parts: Vec<Vec<Request>> = vec![Vec::new(); n];
        for (i, s) in subs.into_iter().enumerate() {
            if !s.is_empty() {
                // One group frame per worker: callers are bounded by the
                // server's MAX_BATCH (10k lines ≈ 300 KiB ≪ MAX_FRAME).
                parts[i] = vec![Request::Group(s)];
            }
        }
        let mut per: Vec<Vec<Response>> = vec![Vec::new(); n];
        self.scatter(&parts, |i, resp| match resp {
            Response::Group(rs) => {
                per[i] = rs;
                Ok(())
            }
            other => Err(IpcError::Unexpected(i, other)),
        })?;
        let mut out = Vec::with_capacity(ops.len());
        for (w, j) in plan {
            match per[w].get(j) {
                Some(Response::Record(r)) => out.push(PointReply::Rec(*r)),
                Some(Response::Applied { applied, .. }) => {
                    out.push(PointReply::Applied(*applied == 1))
                }
                Some(other) => return Err(IpcError::Unexpected(w, other.clone())),
                None => return Err(short_reply(w, per[w].len(), j + 1)),
            }
        }
        Ok(out)
    }

    /// Aggregate stats across all workers.
    pub fn stats(&self) -> Result<(u64, u128), IpcError> {
        let parts = vec![vec![Request::Stats]; self.workers.len()];
        let (mut count, mut value) = (0u64, 0u128);
        self.scatter(&parts, |i, resp| match resp {
            Response::Stats { count: c, value_cents_lo, value_cents_hi } => {
                count += c;
                value += join_u128(value_cents_lo, value_cents_hi);
                Ok(())
            }
            other => Err(IpcError::Unexpected(i, other)),
        })?;
        Ok((count, value))
    }

    /// Reset every worker's request-window counter (STATS RESET); returns
    /// the summed handled-count of the windows just closed.
    pub fn reset_windows(&self) -> Result<u64, IpcError> {
        let parts = vec![vec![Request::Reset]; self.workers.len()];
        let mut handled = 0u64;
        self.scatter(&parts, |i, resp| match resp {
            Response::ResetDone { handled: h } => {
                handled += h;
                Ok(())
            }
            other => Err(IpcError::Unexpected(i, other)),
        })?;
        Ok(handled)
    }

    /// Graceful shutdown: Shutdown RPC + wait on every child. Dead workers
    /// are killed instead of waited on (their Shutdown frame can't be
    /// delivered). Later RPCs fail fast with `WorkerDied`.
    pub fn shutdown(&self) -> Result<(), IpcError> {
        let mut result = Ok(());
        for (i, m) in self.workers.iter().enumerate() {
            let mut g = lock(m);
            if g.dead {
                if let Some(c) = g.conn.child.as_mut() {
                    let _ = c.kill();
                }
            } else {
                let _ = send_frames(i, &mut g, &[Request::Shutdown]);
                let _ = Response::read_from(&mut g.conn.reader);
            }
            g.dead = true;
            if let Some(mut child) = g.conn.child.take() {
                match child.wait() {
                    Ok(status) if !status.success() && result.is_ok() => {
                        result =
                            Err(IpcError::WorkerDied { worker: i, status: status.code() });
                    }
                    Err(e) if result.is_ok() => result = Err(IpcError::Io(e)),
                    _ => {}
                }
            }
        }
        result
    }
}

impl Drop for ServingPool {
    fn drop(&mut self) {
        // Children are reaped by each WorkerConn's Drop; only a socket
        // directory the originating pool created is removed here.
        if let Some(d) = self.socket_dir.take() {
            std::fs::remove_dir_all(&d).ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::{generate_stock_updates, DatasetSpec, KeyDist};

    #[test]
    fn in_process_pool_full_workflow() {
        let spec = DatasetSpec { records: 5_000, ..Default::default() };
        let records: Vec<BookRecord> = spec.iter().collect();
        let mut pool = ProcessPool::spawn_in_process(4).unwrap();
        assert_eq!(pool.load(&records).unwrap(), 5_000);

        let ups = generate_stock_updates(&spec, 5_000, KeyDist::PermuteAll, 77);
        let (applied, missing) = pool.update(&ups).unwrap();
        assert_eq!(applied, 5_000);
        assert_eq!(missing, 0);

        // Cross-check against an in-process store applying the same updates.
        let store = crate::memstore::ShardedStore::new(4, 4096);
        for r in &records {
            store.insert(*r);
        }
        for u in &ups {
            store.apply(u);
        }
        let (count, value) = pool.stats().unwrap();
        assert_eq!((count, value), store.value_sum_cents());

        // Point reads route correctly.
        let sample = spec.record_at(123);
        let got = pool.get(sample.isbn13).unwrap().unwrap();
        let expect = store.get(sample.isbn13).unwrap();
        assert_eq!(got, expect);

        pool.shutdown().unwrap();
    }

    #[test]
    fn missing_keys_reported() {
        let mut pool = ProcessPool::spawn_in_process(2).unwrap();
        pool.load(&[BookRecord::new(1, 1, 1)]).unwrap();
        let (applied, missing) = pool
            .update(&[
                StockUpdate { isbn13: 1, new_price_cents: 9, new_quantity: 9 },
                StockUpdate { isbn13: 2, new_price_cents: 9, new_quantity: 9 },
            ])
            .unwrap();
        assert_eq!((applied, missing), (1, 1));
        pool.shutdown().unwrap();
    }

    #[test]
    fn in_process_shutdown_preserves_temp_dir() {
        // Regression: shutdown() used to remove_dir_all(env::temp_dir())
        // for in-process pools. A sentinel planted in a temp subdirectory
        // must survive the pool's full lifecycle.
        let dir = std::env::temp_dir().join(format!("membig_sentinel_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let sentinel = dir.join("keep.txt");
        std::fs::write(&sentinel, b"survives").unwrap();

        let mut pool = ProcessPool::spawn_in_process(2).unwrap();
        pool.load(&[BookRecord::new(1, 100, 1)]).unwrap();
        assert!(pool.get(1).unwrap().is_some());
        pool.shutdown().unwrap();

        assert!(sentinel.exists(), "shutdown() must never delete the system temp dir");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_batches_chunk_into_multiple_frames() {
        // Tiny per-frame limits force the chunked path (load: 7/frame,
        // update: 3/frame) — the same code real pools run when a shard's
        // share exceeds MAX_FRAME.
        let mut pool = ProcessPool::spawn_in_process(2).unwrap();
        let records: Vec<BookRecord> =
            (1..=100).map(|i| BookRecord::new(i, i * 10, i as u32)).collect();
        assert_eq!(pool.load_chunked(&records, 7).unwrap(), 100);

        let ups: Vec<StockUpdate> = (1..=120)
            .map(|i| StockUpdate { isbn13: i, new_price_cents: i + 1, new_quantity: 2 })
            .collect();
        let (applied, missing) = pool.update_chunked(&ups, 3).unwrap();
        assert_eq!((applied, missing), (100, 20));

        let rec = pool.get(42).unwrap().unwrap();
        assert_eq!((rec.price_cents, rec.quantity), (43, 2));
        let (count, value) = pool.stats().unwrap();
        assert_eq!(count, 100);
        let expect: u128 = (1..=100u128).map(|i| (i + 1) * 2).sum();
        assert_eq!(value, expect);
        pool.shutdown().unwrap();
    }

    #[test]
    fn serving_pool_matches_store() {
        let spec = DatasetSpec { records: 4_000, ..Default::default() };
        let records: Vec<BookRecord> = spec.iter().collect();
        let mut pool = ProcessPool::spawn_in_process(3).unwrap();
        pool.load(&records).unwrap();
        let serving = pool.into_serving();

        let store = crate::memstore::ShardedStore::new(4, 4096);
        for r in &records {
            store.insert(*r);
        }

        // Point verbs.
        let sample = spec.record_at(77);
        assert_eq!(serving.get(sample.isbn13).unwrap(), store.get(sample.isbn13));
        assert_eq!(serving.get(42).unwrap(), None);
        let up = StockUpdate { isbn13: sample.isbn13, new_price_cents: 999, new_quantity: 9 };
        assert!(serving.update_one(&up).unwrap());
        store.apply(&up);
        assert!(!serving
            .update_one(&StockUpdate { isbn13: 42, new_price_cents: 1, new_quantity: 1 })
            .unwrap());

        // Scatter verbs, mixed hits and misses.
        let keys: Vec<u64> =
            (0..64).map(|i| spec.record_at(i * 31).isbn13).chain([42, 43]).collect();
        assert_eq!(serving.get_many(&keys).unwrap(), store.get_many(&keys));
        let ups = generate_stock_updates(&spec, 500, KeyDist::PermuteAll, 9);
        assert_eq!(serving.update_many(&ups).unwrap(), store.apply_many(&ups));

        // Grouped point runs preserve order and per-key sequencing.
        let k = spec.record_at(5).isbn13;
        let ops = vec![
            PointOp::Get(k),
            PointOp::Update(StockUpdate { isbn13: k, new_price_cents: 777, new_quantity: 3 }),
            PointOp::Get(k),
            PointOp::Get(42),
            PointOp::Update(StockUpdate { isbn13: 42, new_price_cents: 1, new_quantity: 1 }),
        ];
        let replies = serving.exec_points(&ops).unwrap();
        assert_eq!(replies.len(), 5);
        assert_eq!(replies[0], PointReply::Rec(store.get(k)));
        assert_eq!(replies[1], PointReply::Applied(true));
        match replies[2] {
            PointReply::Rec(Some(r)) => {
                assert_eq!((r.price_cents, r.quantity), (777, 3));
            }
            other => panic!("expected updated record, got {other:?}"),
        }
        assert_eq!(replies[3], PointReply::Rec(None));
        assert_eq!(replies[4], PointReply::Applied(false));
        store.update(k, |r| {
            r.price_cents = 777;
            r.quantity = 3;
        });

        // Aggregates agree after the same mutations.
        assert_eq!(serving.stats().unwrap(), store.value_sum_cents());

        // RPC metrics saw traffic; reset closes the window.
        assert!(serving.metrics().total_rpcs() > 0);
        assert!(serving.reset_windows().unwrap() > 0);
        serving.metrics().reset_epoch_counters();
        assert_eq!(serving.metrics().total_rpcs(), 0);

        serving.shutdown().unwrap();
    }
}
