//! Message passing between processes — the paper's §7 future work
//! ("message passing is to be investigated … including but not limited to
//! RPC, Networking Sockets …"), implemented as a first-class execution
//! mode: the leader process shards the store across N *worker processes*
//! (one per core) and drives them over Unix-domain sockets with a
//! length-prefixed binary RPC protocol.
//!
//! Same topology as the threaded pipeline — `T = {(p1,h1) … (pn,hn)}` with
//! processes instead of threads — so the `ablations` bench can measure the
//! IPC tax directly against shared memory.
//!
//! Beyond the batch workflow, [`ServingPool`] (built via
//! [`ProcessPool::into_serving`]) backs `membig serve --processes N`: the
//! live wire protocol routes point verbs to the owning worker and
//! scatter-gathers MGET/MUPDATE/BATCH across workers.

pub mod leader;
pub mod proto;
pub mod worker;

pub use leader::{IpcError, PointOp, PointReply, ProcessPool, ServingPool};
pub use proto::{Request, Response};
pub use worker::worker_main;
