//! Worker-process main loop: owns one hash-table shard, serves the leader's
//! RPCs over a Unix socket until `Shutdown`.
//!
//! Entered via the hidden `membig ipc-worker --socket <path>` subcommand
//! (the leader self-execs the current binary). Also callable in-process on
//! a `UnixStream` pair for tests — the loop is transport-agnostic over any
//! `Read + Write`.

use std::io::{BufReader, BufWriter, Read, Write};
use std::os::unix::net::UnixStream;

use super::proto::{split_u128, ProtoError, Request, Response};
use crate::memstore::HashTable;

/// Execute one data verb against the table. `Shutdown`, `Reset` and
/// `Group` are connection-level and handled by the caller.
fn apply_one(table: &mut HashTable, req: &Request) -> Result<Response, ProtoError> {
    match req {
        Request::Load(records) => {
            let mut n = 0u64;
            for r in records {
                table.insert(*r);
                n += 1;
            }
            Ok(Response::Loaded(n))
        }
        Request::Update(ups) => {
            let mut applied = 0u64;
            let mut missing = 0u64;
            for u in ups {
                if table.update(u.isbn13, |r| u.apply_to(r)) {
                    applied += 1;
                } else {
                    missing += 1;
                }
            }
            Ok(Response::Applied { applied, missing })
        }
        Request::Stats => {
            let (count, value) = table.value_sum_cents();
            let (lo, hi) = split_u128(value);
            Ok(Response::Stats { count, value_cents_lo: lo, value_cents_hi: hi })
        }
        Request::Get(key) => Ok(Response::Record(table.get(*key))),
        Request::GetMany(keys) => {
            Ok(Response::Records(keys.iter().map(|&k| table.get(k)).collect()))
        }
        Request::Shutdown | Request::Reset | Request::Group(_) => Err(ProtoError::Malformed(
            0,
            "connection-level verb where a data verb was expected".into(),
        )),
    }
}

/// Serve one leader connection until Shutdown / EOF. Returns the number of
/// requests handled.
pub fn serve<R: Read, W: Write>(input: R, output: W) -> Result<u64, ProtoError> {
    let mut input = BufReader::with_capacity(1 << 20, input);
    let mut output = BufWriter::with_capacity(1 << 20, output);
    let mut table = HashTable::new();
    let mut handled = 0u64;
    // Requests since the last `Reset` — the serving mode's STATS RESET
    // window, reported in `ResetDone`.
    let mut window = 0u64;
    loop {
        let req = match Request::read_from(&mut input) {
            Ok(r) => r,
            Err(ProtoError::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                return Ok(handled); // leader vanished: exit quietly
            }
            Err(e) => return Err(e),
        };
        match req {
            Request::Shutdown => {
                Response::Bye.write_to(&mut output)?;
                output.flush()?;
                return Ok(handled + 1);
            }
            Request::Reset => {
                Response::ResetDone { handled: window }.write_to(&mut output)?;
                window = 0;
                handled += 1;
            }
            Request::Group(subs) => {
                // One frame in, one frame out: sub-requests execute in
                // order, so same-key ops keep their submission sequence.
                let mut replies = Vec::with_capacity(subs.len());
                for sub in &subs {
                    replies.push(apply_one(&mut table, sub)?);
                }
                handled += subs.len() as u64;
                window += subs.len() as u64;
                Response::Group(replies).write_to(&mut output)?;
            }
            ref data => {
                apply_one(&mut table, data)?.write_to(&mut output)?;
                handled += 1;
                window += 1;
            }
        }
        output.flush()?;
    }
}

/// Process entrypoint: connect to the leader's socket and serve.
pub fn worker_main(socket_path: &str) -> Result<(), String> {
    let stream = UnixStream::connect(socket_path)
        .map_err(|e| format!("worker connect {socket_path}: {e}"))?;
    let reader = stream.try_clone().map_err(|e| e.to_string())?;
    serve(reader, stream).map_err(|e| e.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ipc::proto::join_u128;
    use crate::workload::record::{BookRecord, StockUpdate};

    /// Run the worker loop over in-memory pipes (no process spawn).
    fn talk(requests: Vec<Request>) -> Vec<Response> {
        let (leader_sock, worker_sock) = UnixStream::pair().unwrap();
        let worker = std::thread::spawn(move || {
            let r = worker_sock.try_clone().unwrap();
            serve(r, worker_sock).unwrap()
        });
        let mut out = BufWriter::new(leader_sock.try_clone().unwrap());
        let mut input = BufReader::new(leader_sock);
        let mut responses = Vec::new();
        for req in &requests {
            req.write_to(&mut out).unwrap();
            out.flush().unwrap();
            responses.push(Response::read_from(&mut input).unwrap());
        }
        drop(out);
        drop(input);
        worker.join().unwrap();
        responses
    }

    #[test]
    fn load_update_stats_get_shutdown() {
        let records =
            vec![BookRecord::new(101, 100, 2), BookRecord::new(102, 200, 3), BookRecord::new(103, 50, 4)];
        let responses = talk(vec![
            Request::Load(records),
            Request::Update(vec![
                StockUpdate { isbn13: 101, new_price_cents: 500, new_quantity: 1 },
                StockUpdate { isbn13: 999, new_price_cents: 1, new_quantity: 1 },
            ]),
            Request::Get(101),
            Request::Get(999),
            Request::Stats,
            Request::Shutdown,
        ]);
        assert_eq!(responses[0], Response::Loaded(3));
        assert_eq!(responses[1], Response::Applied { applied: 1, missing: 1 });
        assert_eq!(responses[2], Response::Record(Some(BookRecord::new(101, 500, 1))));
        assert_eq!(responses[3], Response::Record(None));
        match responses[4] {
            Response::Stats { count, value_cents_lo, value_cents_hi } => {
                assert_eq!(count, 3);
                // 500*1 + 200*3 + 50*4 = 1300
                assert_eq!(join_u128(value_cents_lo, value_cents_hi), 1300);
            }
            ref other => panic!("expected stats, got {other:?}"),
        }
        assert_eq!(responses[5], Response::Bye);
    }

    #[test]
    fn serving_verbs_get_many_group_reset() {
        let responses = talk(vec![
            Request::Load(vec![BookRecord::new(1, 100, 2), BookRecord::new(2, 200, 3)]),
            Request::GetMany(vec![2, 99, 1]),
            Request::Group(vec![
                Request::Get(1),
                Request::Update(vec![StockUpdate {
                    isbn13: 1,
                    new_price_cents: 111,
                    new_quantity: 4,
                }]),
                Request::Get(1),
            ]),
            Request::Reset,
            Request::Get(2),
            Request::Reset,
            Request::Shutdown,
        ]);
        assert_eq!(
            responses[1],
            Response::Records(vec![
                Some(BookRecord::new(2, 200, 3)),
                None,
                Some(BookRecord::new(1, 100, 2)),
            ])
        );
        assert_eq!(
            responses[2],
            Response::Group(vec![
                Response::Record(Some(BookRecord::new(1, 100, 2))),
                Response::Applied { applied: 1, missing: 0 },
                Response::Record(Some(BookRecord::new(1, 111, 4))),
            ])
        );
        // Window: Load + GetMany + 3 grouped sub-requests = 5; then the
        // next window saw exactly the one Get.
        assert_eq!(responses[3], Response::ResetDone { handled: 5 });
        assert_eq!(responses[5], Response::ResetDone { handled: 1 });
    }

    #[test]
    fn eof_terminates_cleanly() {
        let (leader_sock, worker_sock) = UnixStream::pair().unwrap();
        let worker = std::thread::spawn(move || {
            let r = worker_sock.try_clone().unwrap();
            serve(r, worker_sock)
        });
        drop(leader_sock); // immediate EOF
        assert_eq!(worker.join().unwrap().unwrap(), 0);
    }
}
