//! Wire protocol for leader ⇄ worker RPC.
//!
//! Frame: `u32 length | u8 tag | payload`. All integers little-endian.
//! Payloads are flat arrays of fixed-size structs (records are 24B encoded,
//! updates 20B raw) — no varints, no schema evolution; this is an internal
//! protocol pinned to the binary.
//!
//! Two verb families share the framing:
//!
//! * **batch** (`Load`/`Update`/`Stats`/`Get`/`Shutdown`) — the original
//!   scatter workflow used by `ProcessPool`;
//! * **serving** (`GetMany`/`Group`/`Reset`) — added for the
//!   `serve --processes N` backend: multi-key reads, a BATCH group frame
//!   carrying embedded sub-request frames (one nesting level only), and a
//!   stats-window reset.

use std::io::{Read, Write};

use crate::workload::record::{BookRecord, StockUpdate, RECORD_BYTES};

pub const MAX_FRAME: u32 = 64 << 20; // 64 MiB safety bound

/// Bytes of one encoded [`StockUpdate`] (isbn + price + qty, no checksum).
pub const UPDATE_BYTES: usize = 20;

/// Bytes of one entry in a [`Response::Records`] payload: a presence byte
/// followed by the fixed record encoding (zero-filled when absent).
pub const RECORD_ENTRY_BYTES: usize = 1 + RECORD_BYTES;

#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Bulk-load records into the worker's table.
    Load(Vec<BookRecord>),
    /// Apply a batch of updates.
    Update(Vec<StockUpdate>),
    /// Ask for (count, value_sum_cents).
    Stats,
    /// Point lookup.
    Get(u64),
    /// Clean shutdown.
    Shutdown,
    /// Multi-key lookup; answered by [`Response::Records`] in key order.
    GetMany(Vec<u64>),
    /// BATCH group frame: embedded sub-request frames executed in order and
    /// answered by one [`Response::Group`]. Groups do not nest, and
    /// `Shutdown` is not a valid sub-request.
    Group(Vec<Request>),
    /// Reset the worker's request-window counter; answered by
    /// [`Response::ResetDone`] carrying the count of the window just closed.
    Reset,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Loaded(u64),
    Applied { applied: u64, missing: u64 },
    Stats { count: u64, value_cents_lo: u64, value_cents_hi: u64 },
    Record(Option<BookRecord>),
    Bye,
    /// One entry per requested key, in request order.
    Records(Vec<Option<BookRecord>>),
    /// One embedded response frame per sub-request, in request order.
    Group(Vec<Response>),
    ResetDone { handled: u64 },
}

#[derive(Debug)]
pub enum ProtoError {
    Io(std::io::Error),
    TooLarge(u64),
    BadTag(u8),
    Malformed(u8, String),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "io: {e}"),
            ProtoError::TooLarge(n) => write!(f, "frame too large: {n} bytes"),
            ProtoError::BadTag(t) => write!(f, "unknown tag {t:#x}"),
            ProtoError::Malformed(t, why) => write!(f, "malformed payload for tag {t:#x}: {why}"),
        }
    }
}

impl std::error::Error for ProtoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ProtoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> Self {
        ProtoError::Io(e)
    }
}

const TAG_LOAD: u8 = 1;
const TAG_UPDATE: u8 = 2;
const TAG_STATS: u8 = 3;
const TAG_GET: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_GET_MANY: u8 = 6;
const TAG_GROUP: u8 = 7;
const TAG_RESET: u8 = 8;
const TAG_LOADED: u8 = 0x81;
const TAG_APPLIED: u8 = 0x82;
const TAG_STATS_R: u8 = 0x83;
const TAG_RECORD: u8 = 0x84;
const TAG_BYE: u8 = 0x85;
const TAG_RECORDS: u8 = 0x86;
const TAG_GROUP_R: u8 = 0x87;
const TAG_RESET_R: u8 = 0x88;

fn encode_update(u: &StockUpdate, out: &mut Vec<u8>) {
    out.extend_from_slice(&u.isbn13.to_le_bytes());
    out.extend_from_slice(&u.new_price_cents.to_le_bytes());
    out.extend_from_slice(&u.new_quantity.to_le_bytes());
}

fn decode_update(b: &[u8]) -> StockUpdate {
    StockUpdate {
        // lint:allow(hot-path-panic): fixed-width subslices of a length the
        // caller already validated — try_into on `[u8; N]` cannot fail.
        isbn13: u64::from_le_bytes(b[0..8].try_into().unwrap()),
        // lint:allow(hot-path-panic): as above.
        new_price_cents: u64::from_le_bytes(b[8..16].try_into().unwrap()),
        // lint:allow(hot-path-panic): as above.
        new_quantity: u32::from_le_bytes(b[16..20].try_into().unwrap()),
    }
}

/// Validate a payload size and return the frame length word (`1 + payload`).
/// The check happens on the *unnarrowed* length: `payload.len() as u32` on a
/// ≥ 4 GiB payload wraps before any comparison and would emit a corrupt
/// length prefix, so the cast only happens after the bound holds.
fn frame_len(payload_len: usize) -> Result<u32, ProtoError> {
    let len = (payload_len as u64)
        .checked_add(1)
        .ok_or(ProtoError::TooLarge(u64::MAX))?;
    if len > MAX_FRAME as u64 {
        return Err(ProtoError::TooLarge(len));
    }
    Ok(len as u32)
}

fn write_frame<W: Write>(w: &mut W, tag: u8, payload: &[u8]) -> Result<(), ProtoError> {
    let len = frame_len(payload.len())?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&[tag])?;
    w.write_all(payload)?;
    Ok(())
}

fn read_frame<R: Read>(r: &mut R) -> Result<(u8, Vec<u8>), ProtoError> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4);
    if len == 0 || len > MAX_FRAME {
        return Err(ProtoError::TooLarge(len as u64));
    }
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    let mut payload = vec![0u8; len as usize - 1];
    r.read_exact(&mut payload)?;
    Ok((tag[0], payload))
}

impl Request {
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<(), ProtoError> {
        match self {
            Request::Load(records) => {
                let mut payload = Vec::with_capacity(records.len() * RECORD_BYTES);
                for r in records {
                    payload.extend_from_slice(&r.encode());
                }
                write_frame(w, TAG_LOAD, &payload)
            }
            Request::Update(ups) => {
                let mut payload = Vec::with_capacity(ups.len() * UPDATE_BYTES);
                for u in ups {
                    encode_update(u, &mut payload);
                }
                write_frame(w, TAG_UPDATE, &payload)
            }
            Request::Stats => write_frame(w, TAG_STATS, &[]),
            Request::Get(key) => write_frame(w, TAG_GET, &key.to_le_bytes()),
            Request::Shutdown => write_frame(w, TAG_SHUTDOWN, &[]),
            Request::GetMany(keys) => {
                let mut payload = Vec::with_capacity(keys.len() * 8);
                for k in keys {
                    payload.extend_from_slice(&k.to_le_bytes());
                }
                write_frame(w, TAG_GET_MANY, &payload)
            }
            Request::Group(subs) => {
                let mut payload = Vec::new();
                for sub in subs {
                    if matches!(sub, Request::Group(_) | Request::Shutdown) {
                        return Err(ProtoError::Malformed(
                            TAG_GROUP,
                            "GROUP may not embed GROUP or SHUTDOWN".into(),
                        ));
                    }
                    sub.write_to(&mut payload)?;
                }
                write_frame(w, TAG_GROUP, &payload)
            }
            Request::Reset => write_frame(w, TAG_RESET, &[]),
        }
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Request, ProtoError> {
        let (tag, payload) = read_frame(r)?;
        Request::decode_frame(tag, payload, true)
    }

    fn decode_frame(tag: u8, payload: Vec<u8>, allow_group: bool) -> Result<Self, ProtoError> {
        match tag {
            TAG_LOAD => {
                if payload.len() % RECORD_BYTES != 0 {
                    return Err(ProtoError::Malformed(tag, format!("len {}", payload.len())));
                }
                let mut records = Vec::with_capacity(payload.len() / RECORD_BYTES);
                for chunk in payload.chunks_exact(RECORD_BYTES) {
                    records.push(
                        BookRecord::decode(chunk)
                            .map_err(|e| ProtoError::Malformed(tag, e.to_string()))?,
                    );
                }
                Ok(Request::Load(records))
            }
            TAG_UPDATE => {
                if payload.len() % UPDATE_BYTES != 0 {
                    return Err(ProtoError::Malformed(tag, format!("len {}", payload.len())));
                }
                Ok(Request::Update(
                    payload.chunks_exact(UPDATE_BYTES).map(decode_update).collect(),
                ))
            }
            TAG_STATS => Ok(Request::Stats),
            TAG_GET => {
                if payload.len() != 8 {
                    return Err(ProtoError::Malformed(tag, format!("len {}", payload.len())));
                }
                // lint:allow(hot-path-panic): length == 8 checked above;
                // try_into on the fixed subslice cannot fail.
                Ok(Request::Get(u64::from_le_bytes(payload[..8].try_into().unwrap())))
            }
            TAG_SHUTDOWN => Ok(Request::Shutdown),
            TAG_GET_MANY => {
                if payload.len() % 8 != 0 {
                    return Err(ProtoError::Malformed(tag, format!("len {}", payload.len())));
                }
                Ok(Request::GetMany(
                    payload
                        .chunks_exact(8)
                        // lint:allow(hot-path-panic): chunks_exact(8) only
                        // yields 8-byte slices; try_into cannot fail.
                        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                ))
            }
            TAG_GROUP if allow_group => {
                let mut subs = Vec::new();
                let mut cur = payload.as_slice();
                while !cur.is_empty() {
                    let (t, p) = read_frame(&mut cur)?;
                    if t == TAG_SHUTDOWN {
                        return Err(ProtoError::Malformed(tag, "SHUTDOWN inside GROUP".into()));
                    }
                    subs.push(Request::decode_frame(t, p, false)?);
                }
                Ok(Request::Group(subs))
            }
            TAG_GROUP => Err(ProtoError::Malformed(tag, "nested GROUP".into())),
            TAG_RESET => Ok(Request::Reset),
            t => Err(ProtoError::BadTag(t)),
        }
    }
}

impl Response {
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<(), ProtoError> {
        match self {
            Response::Loaded(n) => write_frame(w, TAG_LOADED, &n.to_le_bytes()),
            Response::Applied { applied, missing } => {
                let mut p = Vec::with_capacity(16);
                p.extend_from_slice(&applied.to_le_bytes());
                p.extend_from_slice(&missing.to_le_bytes());
                write_frame(w, TAG_APPLIED, &p)
            }
            Response::Stats { count, value_cents_lo, value_cents_hi } => {
                let mut p = Vec::with_capacity(24);
                p.extend_from_slice(&count.to_le_bytes());
                p.extend_from_slice(&value_cents_lo.to_le_bytes());
                p.extend_from_slice(&value_cents_hi.to_le_bytes());
                write_frame(w, TAG_STATS_R, &p)
            }
            Response::Record(opt) => match opt {
                None => write_frame(w, TAG_RECORD, &[]),
                Some(r) => write_frame(w, TAG_RECORD, &r.encode()),
            },
            Response::Bye => write_frame(w, TAG_BYE, &[]),
            Response::Records(recs) => {
                let mut p = Vec::with_capacity(recs.len() * RECORD_ENTRY_BYTES);
                for rec in recs {
                    match rec {
                        Some(r) => {
                            p.push(1);
                            p.extend_from_slice(&r.encode());
                        }
                        None => p.extend_from_slice(&[0u8; RECORD_ENTRY_BYTES]),
                    }
                }
                write_frame(w, TAG_RECORDS, &p)
            }
            Response::Group(subs) => {
                let mut payload = Vec::new();
                for sub in subs {
                    if matches!(sub, Response::Group(_)) {
                        return Err(ProtoError::Malformed(TAG_GROUP_R, "nested GROUP".into()));
                    }
                    sub.write_to(&mut payload)?;
                }
                write_frame(w, TAG_GROUP_R, &payload)
            }
            Response::ResetDone { handled } => {
                write_frame(w, TAG_RESET_R, &handled.to_le_bytes())
            }
        }
    }

    pub fn read_from<R: Read>(r: &mut R) -> Result<Response, ProtoError> {
        let (tag, payload) = read_frame(r)?;
        Response::decode_frame(tag, payload, true)
    }

    fn decode_frame(tag: u8, payload: Vec<u8>, allow_group: bool) -> Result<Self, ProtoError> {
        let u64_at = |off: usize| -> u64 {
            // lint:allow(hot-path-panic): every call site sits behind an
            // exact payload-length guard; the 8-byte subslice always exists.
            u64::from_le_bytes(payload[off..off + 8].try_into().unwrap())
        };
        match tag {
            TAG_LOADED if payload.len() == 8 => Ok(Response::Loaded(u64_at(0))),
            TAG_APPLIED if payload.len() == 16 => {
                Ok(Response::Applied { applied: u64_at(0), missing: u64_at(8) })
            }
            TAG_STATS_R if payload.len() == 24 => Ok(Response::Stats {
                count: u64_at(0),
                value_cents_lo: u64_at(8),
                value_cents_hi: u64_at(16),
            }),
            TAG_RECORD if payload.is_empty() => Ok(Response::Record(None)),
            TAG_RECORD if payload.len() == RECORD_BYTES => Ok(Response::Record(Some(
                BookRecord::decode(&payload).map_err(|e| ProtoError::Malformed(tag, e.to_string()))?,
            ))),
            TAG_BYE => Ok(Response::Bye),
            TAG_RECORDS if payload.len() % RECORD_ENTRY_BYTES == 0 => {
                let mut out = Vec::with_capacity(payload.len() / RECORD_ENTRY_BYTES);
                for chunk in payload.chunks_exact(RECORD_ENTRY_BYTES) {
                    match chunk[0] {
                        0 => out.push(None),
                        1 => out.push(Some(
                            BookRecord::decode(&chunk[1..])
                                .map_err(|e| ProtoError::Malformed(tag, e.to_string()))?,
                        )),
                        f => {
                            return Err(ProtoError::Malformed(tag, format!("presence byte {f}")))
                        }
                    }
                }
                Ok(Response::Records(out))
            }
            TAG_GROUP_R if allow_group => {
                let mut subs = Vec::new();
                let mut cur = payload.as_slice();
                while !cur.is_empty() {
                    let (t, p) = read_frame(&mut cur)?;
                    subs.push(Response::decode_frame(t, p, false)?);
                }
                Ok(Response::Group(subs))
            }
            TAG_GROUP_R => Err(ProtoError::Malformed(tag, "nested GROUP".into())),
            TAG_RESET_R if payload.len() == 8 => Ok(Response::ResetDone { handled: u64_at(0) }),
            t if matches!(
                t,
                TAG_LOADED | TAG_APPLIED | TAG_STATS_R | TAG_RECORD | TAG_RECORDS | TAG_RESET_R
            ) =>
            {
                Err(ProtoError::Malformed(t, format!("len {}", payload.len())))
            }
            t => Err(ProtoError::BadTag(t)),
        }
    }
}

/// Split/merge helpers for the u128 value sums crossing the wire as 2×u64.
pub fn split_u128(v: u128) -> (u64, u64) {
    (v as u64, (v >> 64) as u64)
}

pub fn join_u128(lo: u64, hi: u64) -> u128 {
    (lo as u128) | ((hi as u128) << 64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: Request) {
        let mut buf = Vec::new();
        req.write_to(&mut buf).unwrap();
        let got = Request::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(got, req);
    }

    fn roundtrip_resp(resp: Response) {
        let mut buf = Vec::new();
        resp.write_to(&mut buf).unwrap();
        let got = Response::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(got, resp);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(Request::Load(vec![
            BookRecord::new(9_780_000_000_001, 199, 44),
            BookRecord::new(9_780_000_000_002, 299, 55),
        ]));
        roundtrip_req(Request::Update(vec![StockUpdate {
            isbn13: 9_783_652_774_577,
            new_price_cents: 393,
            new_quantity: 495,
        }]));
        roundtrip_req(Request::Stats);
        roundtrip_req(Request::Get(12345));
        roundtrip_req(Request::Shutdown);
        roundtrip_req(Request::Load(vec![]));
        roundtrip_req(Request::Update(vec![]));
    }

    #[test]
    fn responses_roundtrip() {
        roundtrip_resp(Response::Loaded(42));
        roundtrip_resp(Response::Applied { applied: 10, missing: 3 });
        let (lo, hi) = split_u128(123_456_789_012_345_678_901_234_567u128);
        roundtrip_resp(Response::Stats { count: 7, value_cents_lo: lo, value_cents_hi: hi });
        roundtrip_resp(Response::Record(None));
        roundtrip_resp(Response::Record(Some(BookRecord::new(1, 2, 3))));
        roundtrip_resp(Response::Bye);
    }

    #[test]
    fn serving_verbs_roundtrip() {
        roundtrip_req(Request::GetMany(vec![1, 2, u64::MAX]));
        roundtrip_req(Request::GetMany(vec![]));
        roundtrip_req(Request::Reset);
        roundtrip_req(Request::Group(vec![
            Request::Get(7),
            Request::Update(vec![StockUpdate {
                isbn13: 7,
                new_price_cents: 100,
                new_quantity: 2,
            }]),
            Request::Stats,
        ]));
        roundtrip_req(Request::Group(vec![]));
        roundtrip_resp(Response::Records(vec![
            Some(BookRecord::new(1, 2, 3)),
            None,
            Some(BookRecord::new(9_780_000_000_001, 199, 44)),
        ]));
        roundtrip_resp(Response::Records(vec![]));
        roundtrip_resp(Response::ResetDone { handled: 12345 });
        roundtrip_resp(Response::Group(vec![
            Response::Record(Some(BookRecord::new(1, 2, 3))),
            Response::Applied { applied: 1, missing: 0 },
        ]));
        roundtrip_resp(Response::Group(vec![]));
    }

    #[test]
    fn groups_do_not_nest() {
        // Write side refuses to embed a group (or a shutdown) in a group.
        let mut buf = Vec::new();
        let nested = Request::Group(vec![Request::Group(vec![Request::Stats])]);
        assert!(matches!(nested.write_to(&mut buf), Err(ProtoError::Malformed(_, _))));
        let shutdown = Request::Group(vec![Request::Shutdown]);
        assert!(matches!(shutdown.write_to(&mut buf), Err(ProtoError::Malformed(_, _))));
        // Read side rejects a hand-built nested group frame too.
        let mut inner = Vec::new();
        write_frame(&mut inner, TAG_GROUP, &[]).unwrap();
        let mut outer = Vec::new();
        write_frame(&mut outer, TAG_GROUP, &inner).unwrap();
        assert!(matches!(
            Request::read_from(&mut outer.as_slice()),
            Err(ProtoError::Malformed(TAG_GROUP, _))
        ));
        // Same for response groups.
        let mut inner = Vec::new();
        write_frame(&mut inner, TAG_GROUP_R, &[]).unwrap();
        let mut outer = Vec::new();
        write_frame(&mut outer, TAG_GROUP_R, &inner).unwrap();
        assert!(matches!(
            Response::read_from(&mut outer.as_slice()),
            Err(ProtoError::Malformed(TAG_GROUP_R, _))
        ));
    }

    #[test]
    fn frame_len_rejects_oversize_before_narrowing() {
        // In range: largest payload that still fits the bound.
        assert_eq!(frame_len(0).unwrap(), 1);
        assert_eq!(frame_len(MAX_FRAME as usize - 1).unwrap(), MAX_FRAME);
        // Just over the bound.
        assert!(matches!(frame_len(MAX_FRAME as usize), Err(ProtoError::TooLarge(_))));
        // The regression: a payload whose `as u32` narrowing wraps to a tiny
        // value (4 GiB - 1 wraps `1 + len` to 0) must still be rejected —
        // the old code wrote a corrupt zero-length prefix here.
        assert!(matches!(frame_len(u32::MAX as usize), Err(ProtoError::TooLarge(_))));
        assert!(matches!(frame_len(usize::MAX), Err(ProtoError::TooLarge(_))));
    }

    #[test]
    fn u128_split_join() {
        for v in [0u128, 1, u64::MAX as u128, u128::MAX, 123_456_789_012_345_678_901_234_567] {
            let (lo, hi) = split_u128(v);
            assert_eq!(join_u128(lo, hi), v);
        }
    }

    #[test]
    fn multiple_frames_in_sequence() {
        let mut buf = Vec::new();
        Request::Stats.write_to(&mut buf).unwrap();
        Request::Get(9).write_to(&mut buf).unwrap();
        Request::Shutdown.write_to(&mut buf).unwrap();
        let mut r = buf.as_slice();
        assert_eq!(Request::read_from(&mut r).unwrap(), Request::Stats);
        assert_eq!(Request::read_from(&mut r).unwrap(), Request::Get(9));
        assert_eq!(Request::read_from(&mut r).unwrap(), Request::Shutdown);
        assert!(r.is_empty());
    }

    #[test]
    fn rejects_bad_frames() {
        // Unknown tag.
        let mut buf = Vec::new();
        write_frame(&mut buf, 0x77, &[1, 2, 3]).unwrap();
        assert!(matches!(Request::read_from(&mut buf.as_slice()), Err(ProtoError::BadTag(0x77))));
        // Oversized length prefix.
        let huge = (MAX_FRAME + 1).to_le_bytes();
        let mut data = huge.to_vec();
        data.push(TAG_STATS);
        assert!(matches!(
            Request::read_from(&mut data.as_slice()),
            Err(ProtoError::TooLarge(_))
        ));
        // Ragged update payload.
        let mut buf = Vec::new();
        write_frame(&mut buf, TAG_UPDATE, &[0u8; 21]).unwrap();
        assert!(matches!(
            Request::read_from(&mut buf.as_slice()),
            Err(ProtoError::Malformed(TAG_UPDATE, _))
        ));
        // Ragged multi-get payload.
        let mut buf = Vec::new();
        write_frame(&mut buf, TAG_GET_MANY, &[0u8; 9]).unwrap();
        assert!(matches!(
            Request::read_from(&mut buf.as_slice()),
            Err(ProtoError::Malformed(TAG_GET_MANY, _))
        ));
        // Bad presence byte in a records payload.
        let mut entry = [0u8; RECORD_ENTRY_BYTES];
        entry[0] = 9;
        let mut buf = Vec::new();
        write_frame(&mut buf, TAG_RECORDS, &entry).unwrap();
        assert!(matches!(
            Response::read_from(&mut buf.as_slice()),
            Err(ProtoError::Malformed(TAG_RECORDS, _))
        ));
        // Corrupt record in Load (checksum fails).
        let mut payload = BookRecord::new(1, 2, 3).encode().to_vec();
        payload[5] ^= 0xFF;
        let mut buf = Vec::new();
        write_frame(&mut buf, TAG_LOAD, &payload).unwrap();
        assert!(Request::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn truncated_stream_is_io_error() {
        let mut buf = Vec::new();
        Request::Get(1).write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(matches!(Request::read_from(&mut buf.as_slice()), Err(ProtoError::Io(_))));
    }
}
