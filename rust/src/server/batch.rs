//! Batch verbs for the line protocol: parsing and shard-affine execution.
//!
//! `MGET` and `MUPDATE` carry many keys in one request line; execution goes
//! through [`StorageEngine::get_many`] / [`StorageEngine::apply_many`], whose
//! memstore implementation pre-routes every key and takes each shard lock
//! once per batch instead of once per key — the paper's §4.2
//! group-at-a-time dispatch applied to the request path. `BATCH <n>` framing
//! (n follow-up lines, n response lines released as one group) lives in the
//! per-connection state machine (`server::reactor` on Linux, the blocking
//! `server::fallback` loop elsewhere); per-line execution goes through
//! `server::exec_batch_group` → `dispatch_into`.

use crate::storage::engine::StorageEngine;
use crate::workload::record::StockUpdate;

/// Upper bound on keys per MGET, update groups per MUPDATE and lines per
/// BATCH — caps per-request memory and shard lock hold time.
pub const MAX_BATCH: usize = 10_000;

/// Upper bound on the *total* bytes a `BATCH` may buffer before execution.
/// The per-line cap alone would still let MAX_BATCH near-cap lines pin
/// gigabytes on one connection.
pub const MAX_BATCH_BYTES: usize = 4 << 20;

/// Parse the argument tail of `MGET <k1> <k2> ...` into keys.
pub fn parse_mget(rest: &str) -> Result<Vec<u64>, String> {
    let mut keys = Vec::new();
    for tok in rest.split_ascii_whitespace() {
        match tok.parse::<u64>() {
            Ok(k) => keys.push(k),
            Err(_) => return Err(format!("MGET: bad key '{tok}'")),
        }
    }
    if keys.is_empty() {
        return Err("MGET expects at least one <isbn13> key".into());
    }
    if keys.len() > MAX_BATCH {
        return Err(format!("MGET limited to {MAX_BATCH} keys"));
    }
    Ok(keys)
}

/// Parse the argument tail of `MUPDATE <k c q>;<k c q>;...` — semicolon-
/// separated groups, whitespace-separated fields. A trailing `;` is allowed.
pub fn parse_mupdate(rest: &str) -> Result<Vec<StockUpdate>, String> {
    let mut ups = Vec::new();
    for group in rest.split(';') {
        let group = group.trim();
        if group.is_empty() {
            continue;
        }
        let mut t = group.split_ascii_whitespace();
        let key = t.next().and_then(|s| s.parse::<u64>().ok());
        let cents = t.next().and_then(|s| s.parse::<u64>().ok());
        let qty = t.next().and_then(|s| s.parse::<u32>().ok());
        match (key, cents, qty) {
            (Some(isbn13), Some(new_price_cents), Some(new_quantity)) if t.next().is_none() => {
                ups.push(StockUpdate { isbn13, new_price_cents, new_quantity });
            }
            _ => return Err(format!("MUPDATE: bad group '{group}' (expect <isbn13> <cents> <qty>)")),
        }
    }
    if ups.is_empty() {
        return Err("MUPDATE expects at least one <isbn13> <cents> <qty> group".into());
    }
    if ups.len() > MAX_BATCH {
        return Err(format!("MUPDATE limited to {MAX_BATCH} groups"));
    }
    Ok(ups)
}

/// Execute a parsed MGET straight into a response buffer: one line, entries
/// in key order — `OK <n> <price,qty|MISS> ...`. The hot batch path formats
/// integers with [`push_u64`](crate::util::fmt::push_u64) into the caller's
/// pooled buffer: no per-entry temporaries, no response `String`.
pub fn exec_mget_into(store: &dyn StorageEngine, keys: &[u64], out: &mut Vec<u8>) {
    use crate::util::fmt::push_u64;
    let vals = store.get_many(keys);
    out.reserve(8 + vals.len() * 12);
    out.extend_from_slice(b"OK ");
    push_u64(out, vals.len() as u64);
    for v in &vals {
        match v {
            Some(r) => {
                out.push(b' ');
                push_u64(out, r.price_cents);
                out.push(b',');
                push_u64(out, r.quantity as u64);
            }
            None => out.extend_from_slice(b" MISS"),
        }
    }
}

/// [`exec_mget_into`] as a `String` (direct unit tests, legacy callers).
pub fn exec_mget(store: &dyn StorageEngine, keys: &[u64]) -> String {
    let mut out = Vec::with_capacity(8 + keys.len() * 12);
    exec_mget_into(store, keys, &mut out);
    String::from_utf8(out).expect("MGET responses are ASCII")
}

/// Execute a parsed MUPDATE into a response buffer:
/// `OK applied=<a> missed=<m>`.
pub fn exec_mupdate_into(store: &dyn StorageEngine, ups: &[StockUpdate], out: &mut Vec<u8>) {
    use crate::util::fmt::push_u64;
    let (applied, missed) = store.apply_many(ups);
    out.extend_from_slice(b"OK applied=");
    push_u64(out, applied);
    out.extend_from_slice(b" missed=");
    push_u64(out, missed);
}

/// [`exec_mupdate_into`] as a `String` (direct unit tests, legacy callers).
pub fn exec_mupdate(store: &dyn StorageEngine, ups: &[StockUpdate]) -> String {
    let mut out = Vec::with_capacity(32);
    exec_mupdate_into(store, ups, &mut out);
    String::from_utf8(out).expect("MUPDATE responses are ASCII")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memstore::ShardedStore;
    use crate::workload::record::BookRecord;

    #[test]
    fn parse_mget_accepts_keys_rejects_junk() {
        assert_eq!(parse_mget("1 2 3").unwrap(), vec![1, 2, 3]);
        assert!(parse_mget("").is_err());
        assert!(parse_mget("1 two 3").is_err());
        assert!(parse_mget("-1").is_err());
    }

    #[test]
    fn parse_mupdate_groups() {
        let ups = parse_mupdate("1 100 5;2 200 6; 3 300 7 ;").unwrap();
        assert_eq!(ups.len(), 3);
        assert_eq!(ups[1], StockUpdate { isbn13: 2, new_price_cents: 200, new_quantity: 6 });
        assert!(parse_mupdate("").is_err());
        assert!(parse_mupdate("1 100").is_err());
        assert!(parse_mupdate("1 100 5 junk").is_err());
        assert!(parse_mupdate("1 100 5;bad").is_err());
    }

    #[test]
    fn exec_roundtrip_preserves_order_and_counts() {
        let store = ShardedStore::new(4, 64);
        store.insert(BookRecord::new(10, 100, 1));
        store.insert(BookRecord::new(20, 200, 2));
        let resp = exec_mupdate(
            &store,
            &parse_mupdate("10 111 9;999 1 1;20 222 8").unwrap(),
        );
        assert_eq!(resp, "OK applied=2 missed=1");
        let resp = exec_mget(&store, &parse_mget("20 999 10").unwrap());
        assert_eq!(resp, "OK 3 222,8 MISS 111,9");
    }
}
