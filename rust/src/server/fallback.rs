//! Portable blocking front end for non-Linux hosts: acceptor + bounded
//! `WorkerPool` over whole connections, read-timeout ticks, per-syscall
//! write timeouts. This is the pre-reactor architecture, kept verbatim so
//! the crate builds and serves the identical wire protocol everywhere the
//! raw-epoll core (`super::reactor`) is unavailable. Its known scaling
//! limits (live concurrency capped at `workers`, idle clients paying a
//! read-timeout tick, slow readers pinning a worker inside the write
//! timeout) are exactly what the reactor replaces — see DESIGN.md §11.

#![cfg(not(target_os = "linux"))]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::pool::WorkerPool;
use super::{
    batch, exec_batch_group, execute_one_into, reject_busy, reply_invalid_utf8, trim_pool,
    BatchScratch, Server, ServerConfig, MAX_LINE_BYTES,
};
use crate::durability::Persistence;
use crate::ipc::ServingPool;
use crate::metrics::ServerMetrics;
use crate::replication::ReplState;
use crate::runtime::AnalyticsService;
use crate::storage::engine::StorageEngine;

/// Granularity at which a blocked read notices shutdown and the idle
/// deadline (the reactor core needs neither: it sleeps in epoll).
const READ_TICK: Duration = Duration::from_millis(200);

/// Per-syscall socket write timeout: a client that stops reading fills its
/// TCP window and would otherwise pin a worker (and hang shutdown) in
/// `write_all` forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

impl Server {
    pub(super) fn accept_loop(self, listener: TcpListener) {
        // Non-blocking accept + short sleep so `stop` is observed between
        // clients without a wakeup pipe.
        listener.set_nonblocking(true).ok();
        // Queue capacity == max_conns: admission control guarantees at most
        // max_conns live connections, so `submit` never blocks the acceptor.
        let pool = {
            let store = self.store.clone();
            let engine = self.engine.clone();
            let persist = self.persist.clone();
            let procs = self.procs.clone();
            let repl = self.repl.clone();
            let stop = self.stop.clone();
            let metrics = self.metrics.clone();
            let cfg = self.config.clone();
            WorkerPool::new(
                self.config.workers,
                self.config.max_conns,
                move |stream: TcpStream| {
                    // Guard (not a trailing call) so the admission slot is
                    // released even if request handling panics.
                    let _guard = ActiveGuard(&metrics);
                    let _ = handle_client(
                        stream,
                        &store,
                        engine.as_ref(),
                        persist.as_deref(),
                        procs.as_deref(),
                        repl.as_deref(),
                        &stop,
                        &metrics,
                        &cfg,
                    );
                },
            )
        };
        let base = Duration::from_millis(5);
        let mut backoff = base;
        while !self.stop.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((stream, _)) => {
                    backoff = base;
                    if self.metrics.conns_active.get() >= self.config.max_conns as i64 {
                        self.metrics.conns_rejected.inc();
                        reject_busy(stream);
                        continue;
                    }
                    self.metrics.conns_accepted.inc();
                    self.metrics.conns_active.inc();
                    if pool.submit(stream).is_err() {
                        // Pool already shut down (stop raced this accept).
                        self.metrics.conns_active.dec();
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(base);
                }
                Err(_) => {
                    // Transient accept failure (EMFILE, ECONNABORTED, ...):
                    // record it and back off — only `stop` ends the loop.
                    self.metrics.accept_errors.inc();
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(500));
                }
            }
        }
        drop(pool); // closes the queue, drains it, joins every worker
    }
}

/// Decrements `conns_active` on drop — including a panicking unwind, so a
/// crashed handler can never leak an admission slot.
struct ActiveGuard<'a>(&'a ServerMetrics);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.conns_active.dec();
    }
}

enum ReadOutcome {
    Line,
    Eof,
    Stopped,
    /// No complete request within the idle window.
    IdleTimeout,
}

/// Read one request line as raw bytes, preserving a partially-received
/// request across read-timeout ticks: a slow client may deliver `"GET 12"`
/// now and `"34\n"` after the timeout, and both halves belong to one
/// request. `line` is appended to (never cleared here) — the caller clears
/// it after consuming a complete line, and validates the accumulated bytes
/// as UTF-8 **once per line**. Checks `stop` each tick. The idle `deadline`
/// is absolute and caller-supplied: one per request on the main loop, one
/// shared across a whole BATCH payload (so a drip-feeding client cannot
/// reset the clock per line).
///
/// Reads chunk-at-a-time (`fill_buf`/`consume`) instead of `read_line` so
/// the `MAX_LINE_BYTES` cap is enforced between chunks — a client
/// streaming forever without a newline gets its connection dropped, not an
/// unbounded buffer.
fn read_request_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut Vec<u8>,
    stop: &AtomicBool,
    deadline: Instant,
) -> std::io::Result<ReadOutcome> {
    loop {
        if stop.load(Ordering::Acquire) {
            return Ok(ReadOutcome::Stopped);
        }
        if Instant::now() >= deadline {
            return Ok(ReadOutcome::IdleTimeout);
        }
        if line.len() > MAX_LINE_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("request line exceeds {MAX_LINE_BYTES} bytes"),
            ));
        }
        let (complete, used) = {
            let buf = match reader.fill_buf() {
                Ok(b) => b,
                // Interrupted (EINTR) retries like std's read_line would.
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut
                        || e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    continue
                }
                Err(e) => return Err(e),
            };
            if buf.is_empty() {
                // EOF. A non-empty partial (no trailing newline) is still a
                // request — matches `read_line`'s end-of-stream semantics.
                return Ok(if line.is_empty() { ReadOutcome::Eof } else { ReadOutcome::Line });
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    line.extend_from_slice(&buf[..=i]);
                    (true, i + 1)
                }
                None => {
                    line.extend_from_slice(buf);
                    (false, buf.len())
                }
            }
        };
        reader.consume(used);
        if complete {
            return Ok(ReadOutcome::Line);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_client(
    stream: TcpStream,
    store: &Arc<dyn StorageEngine>,
    engine: Option<&Arc<AnalyticsService>>,
    persist: Option<&Persistence>,
    procs: Option<&ServingPool>,
    repl: Option<&ReplState>,
    stop: &AtomicBool,
    metrics: &ServerMetrics,
    cfg: &ServerConfig,
) -> std::io::Result<()> {
    // BSD-family kernels hand accepted sockets the listener's O_NONBLOCK;
    // clear it so the read timeout governs blocking.
    stream.set_nonblocking(false).ok();
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(READ_TICK))?;
    stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    // Per-connection pools: the line accumulator, the response buffer and
    // the BATCH scratch are reused across requests (trimmed back after an
    // outlier) — the steady-state request cycle performs no heap
    // allocation.
    let mut line: Vec<u8> = Vec::with_capacity(256);
    let mut resp: Vec<u8> = Vec::with_capacity(256);
    let mut scratch = BatchScratch::default();
    loop {
        match read_request_line(&mut reader, &mut line, stop, Instant::now() + cfg.idle_timeout)? {
            ReadOutcome::Line => {}
            ReadOutcome::Eof | ReadOutcome::Stopped => return Ok(()),
            ReadOutcome::IdleTimeout => {
                let _ = out.write_all(b"ERR idle timeout, closing connection\n");
                return Ok(());
            }
        }
        // Validate the accumulated bytes once per complete line; borrow the
        // request out of the buffer — no per-request copy. `line` is
        // cleared only after the last use of `req`.
        let req = match std::str::from_utf8(&line) {
            Ok(s) => s.trim(),
            Err(_) => {
                // Close, don't continue: the garbage could have been a
                // BATCH header, in which case payload lines are already in
                // flight and would execute as top-level requests —
                // permanently desyncing the reply stream (same no-resync
                // rule as malformed BATCH headers). Inside a BATCH payload
                // the count frames each line, so the group runner can ERR
                // per-line instead.
                resp.clear();
                reply_invalid_utf8(metrics, &mut resp);
                let _ = out.write_all(&resp);
                // Half-close + one bounded drain (reject_busy's pattern):
                // dropping the socket with those pipelined bytes unread
                // would RST and could discard the ERR reply.
                let _ = out.shutdown(Shutdown::Write);
                out.set_read_timeout(Some(Duration::from_millis(10))).ok();
                let mut sink = [0u8; 256];
                let _ = out.read(&mut sink);
                return Ok(());
            }
        };
        let verb = req.split_ascii_whitespace().next().unwrap_or("");
        if verb == "BATCH" {
            // The framing header is not counted as a request — the group
            // runner counts each payload line, so `requests` matches
            // executed ops.
            let quit = run_batch(
                req,
                &mut reader,
                &mut out,
                store,
                engine,
                persist,
                procs,
                repl,
                stop,
                metrics,
                cfg,
                &mut scratch,
            )?;
            line.clear();
            if quit {
                return Ok(());
            }
            continue;
        }
        resp.clear();
        execute_one_into(req, store, engine, persist, metrics, false, procs, repl, &mut resp);
        // Response + newline leave in one syscall.
        out.write_all(&resp)?;
        let quit = req == "QUIT";
        // An outlier request (MGET near the line cap) must not pin its
        // high-water buffers for the connection's remaining lifetime —
        // clear before trimming (`shrink_to` cannot go below `len`).
        line.clear();
        resp.clear();
        trim_pool(&mut line);
        trim_pool(&mut resp);
        if quit {
            return Ok(());
        }
    }
}

/// `BATCH <n>` framing: read `n` follow-up request lines, execute them all
/// through `exec_batch_group`, answer with `n` response lines in **one**
/// socket write — the whole group costs one round trip. Returns `Ok(true)`
/// when the connection must close (client vanished mid-batch, shutdown,
/// group sync failure, or the batch contained `QUIT`).
#[allow(clippy::too_many_arguments)]
fn run_batch(
    header: &str,
    reader: &mut BufReader<TcpStream>,
    out: &mut TcpStream,
    store: &Arc<dyn StorageEngine>,
    engine: Option<&Arc<AnalyticsService>>,
    persist: Option<&Persistence>,
    procs: Option<&ServingPool>,
    repl: Option<&ReplState>,
    stop: &AtomicBool,
    metrics: &ServerMetrics,
    cfg: &ServerConfig,
    scratch: &mut BatchScratch,
) -> std::io::Result<bool> {
    let mut parts = header.split_ascii_whitespace();
    parts.next(); // "BATCH"
    let n = parts.next().and_then(|s| s.parse::<usize>().ok());
    let n = match (n, parts.next()) {
        (Some(n), None) if (1..=batch::MAX_BATCH).contains(&n) => n,
        _ => {
            // A pipelining client may already have written payload lines we
            // cannot distinguish from top-level requests — close instead of
            // executing them (same no-resync rule as the payload-size cap).
            let msg = format!("ERR BATCH expects <n> in 1..={}, closing\n", batch::MAX_BATCH);
            out.write_all(msg.as_bytes())?;
            return Ok(true);
        }
    };
    scratch.payload.clear();
    scratch.bounds.clear();
    // One idle window for the entire payload — per-line deadlines would let
    // a drip-feeding client hold this worker for n × idle_timeout.
    let deadline = Instant::now() + cfg.idle_timeout;
    for _ in 0..n {
        scratch.line.clear();
        match read_request_line(reader, &mut scratch.line, stop, deadline)? {
            ReadOutcome::Line => {}
            ReadOutcome::Eof | ReadOutcome::Stopped | ReadOutcome::IdleTimeout => {
                return Ok(true)
            }
        }
        // Per-line MAX_LINE_BYTES is not enough here: n lines buffer before
        // execution, so cap the batch payload as a whole too.
        scratch.payload.extend_from_slice(&scratch.line);
        scratch.bounds.push(scratch.payload.len());
        if scratch.payload.len() > batch::MAX_BATCH_BYTES {
            let msg =
                format!("ERR BATCH payload exceeds {} bytes, closing\n", batch::MAX_BATCH_BYTES);
            out.write_all(msg.as_bytes())?;
            return Ok(true); // remaining lines are unread: cannot resync
        }
    }
    scratch.resp.clear();
    let quit = match exec_batch_group(
        &scratch.payload,
        &scratch.bounds,
        store,
        engine,
        persist,
        metrics,
        procs,
        repl,
        &mut scratch.resp,
    ) {
        Ok(quit) => quit,
        // Group sync failed: never deliver the buffered OKs.
        Err(()) => return Ok(true),
    };
    // The whole group's responses leave in one gathered write.
    out.write_all(&scratch.resp)?;
    scratch.trim();
    Ok(quit)
}
