//! Multi-process request execution: the `serve --processes N` glue between
//! the wire protocol and the [`ipc::ServingPool`](crate::ipc::ServingPool)
//! backend.
//!
//! In this mode the data set lives in N shard-owning worker *processes*
//! (paper §7's message-passing topology promoted to the serving path), not
//! in the server's address space. The dispatcher intercepts the data verbs:
//! `GET`/`UPDATE` become one RPC to the owning worker, `MGET`/`MUPDATE`
//! scatter-gather with per-worker pipelining, and inside a `BATCH` group
//! consecutive point lines are coalesced into one `Group` frame per touched
//! worker ([`ServingPool::exec_points`]) — per-key ordering is preserved
//! because equal keys route to the same worker and keep their submission
//! order inside its group. `ANALYTICS` is unavailable (the leader holds no
//! records to scan), and `STATS SERVER` gains the pool's per-worker RPC
//! counters and latency quantiles.
//!
//! Response bytes mirror the in-process arms in `dispatch_into` /
//! `server::batch` exactly: `--processes N` changes where the data lives,
//! never the protocol.

use std::sync::Arc;
use std::time::Instant;

use crate::ipc::{IpcError, PointOp, PointReply, ServingPool};
use crate::metrics::ServerMetrics;
use crate::runtime::AnalyticsService;
use crate::storage::engine::StorageEngine;
use crate::util::fmt::push_u64;
use crate::workload::record::StockUpdate;

use super::{batch, execute_one_into, reply_invalid_utf8};

/// Append a worker-RPC failure as a protocol error (no trailing newline —
/// callers frame). RPC failures are server-side faults, not client errors,
/// but the wire grammar has one error shape.
fn push_rpc_err(out: &mut Vec<u8>, e: &IpcError) {
    out.extend_from_slice(format!("ERR worker rpc: {e}").as_bytes());
}

/// Execute one data verb against the worker pool, appending the response
/// (no trailing newline). Returns `false` for verbs the multi-process path
/// does not own (`PING`, `QUIT`, errors, ...) — those fall through to the
/// shared in-process arms, which never touch the placeholder store.
pub(crate) fn dispatch_procs_into(
    verb: &str,
    rest: &str,
    pool: &ServingPool,
    metrics: Option<&ServerMetrics>,
    out: &mut Vec<u8>,
) -> bool {
    match verb {
        "GET" => {
            let mut parts = rest.split_ascii_whitespace();
            match (parts.next().and_then(|k| k.parse::<u64>().ok()), parts.next()) {
                (Some(key), None) => match pool.get(key) {
                    Ok(Some(r)) => {
                        out.extend_from_slice(b"OK ");
                        push_u64(out, r.price_cents);
                        out.push(b' ');
                        push_u64(out, r.quantity as u64);
                    }
                    Ok(None) => out.extend_from_slice(b"MISS"),
                    Err(e) => push_rpc_err(out, &e),
                },
                _ => out.extend_from_slice(b"ERR GET expects exactly <isbn13>"),
            }
        }
        "UPDATE" => {
            let mut parts = rest.split_ascii_whitespace();
            let key = parts.next().and_then(|k| k.parse::<u64>().ok());
            let cents = parts.next().and_then(|k| k.parse::<u64>().ok());
            let qty = parts.next().and_then(|k| k.parse::<u32>().ok());
            match (key, cents, qty, parts.next()) {
                (Some(k), Some(c), Some(q), None) => {
                    let u = StockUpdate { isbn13: k, new_price_cents: c, new_quantity: q };
                    match pool.update_one(&u) {
                        Ok(true) => out.extend_from_slice(b"OK"),
                        Ok(false) => out.extend_from_slice(b"MISS"),
                        Err(e) => push_rpc_err(out, &e),
                    }
                }
                _ => out.extend_from_slice(b"ERR UPDATE expects exactly <isbn13> <cents> <qty>"),
            }
        }
        "MGET" => match batch::parse_mget(rest) {
            Ok(keys) => {
                if let Some(m) = metrics {
                    m.batch_sizes.record(keys.len() as u64);
                }
                match pool.get_many(&keys) {
                    // Same bytes as `batch::exec_mget_into`, fed by RPC.
                    Ok(vals) => {
                        out.reserve(8 + vals.len() * 12);
                        out.extend_from_slice(b"OK ");
                        push_u64(out, vals.len() as u64);
                        for v in &vals {
                            match v {
                                Some(r) => {
                                    out.push(b' ');
                                    push_u64(out, r.price_cents);
                                    out.push(b',');
                                    push_u64(out, r.quantity as u64);
                                }
                                None => out.extend_from_slice(b" MISS"),
                            }
                        }
                    }
                    Err(e) => push_rpc_err(out, &e),
                }
            }
            Err(e) => out.extend_from_slice(format!("ERR {e}").as_bytes()),
        },
        "MUPDATE" => match batch::parse_mupdate(rest) {
            Ok(ups) => {
                if let Some(m) = metrics {
                    m.batch_sizes.record(ups.len() as u64);
                }
                match pool.update_many(&ups) {
                    Ok((applied, missed)) => {
                        out.extend_from_slice(b"OK applied=");
                        push_u64(out, applied);
                        out.extend_from_slice(b" missed=");
                        push_u64(out, missed);
                    }
                    Err(e) => push_rpc_err(out, &e),
                }
            }
            Err(e) => out.extend_from_slice(format!("ERR {e}").as_bytes()),
        },
        "STATS" => {
            let mut parts = rest.split_ascii_whitespace();
            match (parts.next(), parts.next()) {
                (None, _) => match pool.stats() {
                    Ok((n, v)) => {
                        let mut s = format!("OK count={n} value_cents={v}");
                        if let Some(m) = metrics {
                            s.push_str(&m.stats_suffix());
                        }
                        out.extend_from_slice(s.as_bytes());
                    }
                    Err(e) => push_rpc_err(out, &e),
                },
                (Some("SERVER"), None) => match metrics {
                    Some(m) => {
                        let mut s = m.stats_server_line();
                        s.push_str(&pool.metrics().stats_suffix());
                        out.extend_from_slice(s.as_bytes());
                    }
                    None => out.extend_from_slice(b"ERR server metrics unavailable"),
                },
                (Some("RESET"), None) => match metrics {
                    Some(m) => {
                        // The pool's RPC counters and the workers' request
                        // windows join the epoch alongside the server-side
                        // counters — a window failure still opens the epoch
                        // (the error is the report).
                        pool.metrics().reset_epoch_counters();
                        match pool.reset_windows() {
                            Ok(_) => out.extend_from_slice(
                                format!("OK epoch={}", m.reset_epoch()).as_bytes(),
                            ),
                            Err(e) => push_rpc_err(out, &e),
                        }
                    }
                    None => out.extend_from_slice(b"ERR server metrics unavailable"),
                },
                _ => out.extend_from_slice(b"ERR STATS expects no argument, SERVER or RESET"),
            }
        }
        "ANALYTICS" => {
            if !rest.is_empty() {
                out.extend_from_slice(b"ERR ANALYTICS takes no arguments");
            } else {
                out.extend_from_slice(
                    b"ERR analytics unavailable with --processes (workers own the records)",
                );
            }
        }
        _ => return false,
    }
    true
}

/// The latency histogram a grouped point op is charged to.
fn verb_of(op: &PointOp) -> &'static str {
    match op {
        PointOp::Get(_) => "GET",
        PointOp::Update(_) => "UPDATE",
    }
}

/// Classify one trimmed BATCH payload line as a point op iff it is exactly
/// `GET <u64>` or `UPDATE <u64> <u64> <u32>` — the shapes the grouped
/// scatter path accelerates. Anything else (including malformed point
/// verbs) executes inline and produces the regular response/error.
fn parse_point(line: &str) -> Option<PointOp> {
    let (verb, rest) = match line.split_once(|c: char| c.is_ascii_whitespace()) {
        Some((v, r)) => (v, r.trim()),
        None => (line, ""),
    };
    let mut t = rest.split_ascii_whitespace();
    match verb {
        "GET" => match (t.next().and_then(|s| s.parse::<u64>().ok()), t.next()) {
            (Some(k), None) => Some(PointOp::Get(k)),
            _ => None,
        },
        "UPDATE" => {
            let key = t.next().and_then(|s| s.parse::<u64>().ok());
            let cents = t.next().and_then(|s| s.parse::<u64>().ok());
            let qty = t.next().and_then(|s| s.parse::<u32>().ok());
            match (key, cents, qty, t.next()) {
                (Some(isbn13), Some(new_price_cents), Some(new_quantity), None) => {
                    Some(PointOp::Update(StockUpdate { isbn13, new_price_cents, new_quantity }))
                }
                _ => None,
            }
        }
        _ => None,
    }
}

/// Flush a pending run of point ops as one scatter via
/// [`ServingPool::exec_points`]: one `Group` frame per touched worker,
/// replies appended
/// in submission order. Emits exactly one response line per op even on RPC
/// failure — the connection's reply stream must stay in sync with the
/// payload lines. Per-op accounting mirrors `execute_one_into` (request
/// count + per-verb latency, amortized across the run).
fn flush_run(
    run: &mut Vec<PointOp>,
    pool: &ServingPool,
    metrics: &ServerMetrics,
    resp: &mut Vec<u8>,
) {
    if run.is_empty() {
        return;
    }
    let t0 = Instant::now();
    let result = pool.exec_points(run);
    let per_op = t0.elapsed() / run.len() as u32;
    match result {
        Ok(replies) => {
            for (op, reply) in run.iter().zip(&replies) {
                metrics.requests.inc();
                metrics.latency_for(verb_of(op)).record_duration(per_op);
                match reply {
                    PointReply::Rec(Some(r)) => {
                        resp.extend_from_slice(b"OK ");
                        push_u64(resp, r.price_cents);
                        resp.push(b' ');
                        push_u64(resp, r.quantity as u64);
                    }
                    PointReply::Rec(None) | PointReply::Applied(false) => {
                        resp.extend_from_slice(b"MISS")
                    }
                    PointReply::Applied(true) => resp.extend_from_slice(b"OK"),
                }
                resp.push(b'\n');
            }
        }
        Err(e) => {
            let msg = format!("ERR worker rpc: {e}");
            for op in run.iter() {
                metrics.requests.inc();
                metrics.latency_for(verb_of(op)).record_duration(per_op);
                resp.extend_from_slice(msg.as_bytes());
                resp.push(b'\n');
            }
        }
    }
    run.clear();
}

/// Execute a BATCH group against the worker pool: runs of consecutive
/// point lines coalesce into grouped scatters; every other line breaks the
/// run and executes inline (through the regular dispatcher, which routes
/// its own data verbs back to the pool). Returns whether the group
/// contained `QUIT`.
pub(crate) fn exec_batch_lines_grouped(
    payload: &[u8],
    bounds: &[usize],
    store: &Arc<dyn StorageEngine>,
    engine: Option<&Arc<AnalyticsService>>,
    metrics: &ServerMetrics,
    pool: &ServingPool,
    resp: &mut Vec<u8>,
) -> bool {
    let mut quit = false;
    let mut run: Vec<PointOp> = Vec::new();
    let mut start = 0usize;
    for &end in bounds {
        let raw = &payload[start..end];
        start = end;
        match std::str::from_utf8(raw) {
            Ok(s) => {
                let req = s.trim();
                match parse_point(req) {
                    Some(op) => run.push(op),
                    None => {
                        flush_run(&mut run, pool, metrics, resp);
                        execute_one_into(
                            req,
                            store,
                            engine,
                            None,
                            metrics,
                            true,
                            Some(pool),
                            None,
                            resp,
                        );
                        quit = quit || req == "QUIT";
                    }
                }
            }
            Err(_) => {
                flush_run(&mut run, pool, metrics, resp);
                reply_invalid_utf8(metrics, resp);
            }
        }
    }
    flush_run(&mut run, pool, metrics, resp);
    quit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ipc::ProcessPool;
    use crate::workload::record::BookRecord;

    fn pool_with(records: &[BookRecord]) -> ServingPool {
        let mut p = ProcessPool::spawn_in_process(3).unwrap();
        p.load(records).unwrap();
        p.into_serving()
    }

    fn run_verb(pool: &ServingPool, metrics: Option<&ServerMetrics>, line: &str) -> String {
        let line = line.trim();
        let (verb, rest) = match line.split_once(|c: char| c.is_ascii_whitespace()) {
            Some((v, r)) => (v, r.trim()),
            None => (line, ""),
        };
        let mut out = Vec::new();
        assert!(
            dispatch_procs_into(verb, rest, pool, metrics, &mut out),
            "verb {verb:?} must be owned by the procs path"
        );
        String::from_utf8(out).unwrap()
    }

    #[test]
    fn point_and_batch_verbs_match_protocol_bytes() {
        let pool = pool_with(&[BookRecord::new(1, 100, 2), BookRecord::new(2, 200, 3)]);
        assert_eq!(run_verb(&pool, None, "GET 1"), "OK 100 2");
        assert_eq!(run_verb(&pool, None, "GET 42"), "MISS");
        assert_eq!(run_verb(&pool, None, "GET"), "ERR GET expects exactly <isbn13>");
        assert_eq!(run_verb(&pool, None, "UPDATE 1 111 9"), "OK");
        assert_eq!(run_verb(&pool, None, "UPDATE 42 1 1"), "MISS");
        assert_eq!(run_verb(&pool, None, "GET 1"), "OK 111 9");
        assert_eq!(run_verb(&pool, None, "MGET 2 42 1"), "OK 3 200,3 MISS 111,9");
        assert_eq!(
            run_verb(&pool, None, "MUPDATE 1 5 5;42 1 1;2 6 6"),
            "OK applied=2 missed=1"
        );
        assert!(run_verb(&pool, None, "MGET").starts_with("ERR"));
        assert!(run_verb(&pool, None, "MUPDATE 1 2").starts_with("ERR"));
        // 5*5 + 6*6 = 61 cents across both live records.
        assert_eq!(run_verb(&pool, None, "STATS"), "OK count=2 value_cents=61");
        assert!(run_verb(&pool, None, "ANALYTICS").starts_with("ERR analytics unavailable"));
        pool.shutdown().unwrap();
    }

    #[test]
    fn stats_server_and_reset_cover_the_pool() {
        let pool = pool_with(&[BookRecord::new(7, 10, 1)]);
        let m = ServerMetrics::new();
        run_verb(&pool, Some(&m), "GET 7");
        let line = run_verb(&pool, Some(&m), "STATS SERVER");
        assert!(line.contains(" ipc_workers=3"), "{line}");
        assert!(line.contains(" ipc_w0_rpcs="), "{line}");
        assert!(pool.metrics().total_rpcs() > 0);
        assert_eq!(run_verb(&pool, Some(&m), "STATS RESET"), "OK epoch=1");
        assert_eq!(pool.metrics().total_rpcs(), 0, "pool counters join the epoch");
        assert_eq!(
            run_verb(&pool, None, "STATS SERVER"),
            "ERR server metrics unavailable"
        );
        pool.shutdown().unwrap();
    }

    #[test]
    fn batch_groups_point_runs_and_keeps_line_sync() {
        let pool = pool_with(&[BookRecord::new(1, 100, 2), BookRecord::new(2, 200, 3)]);
        let m = ServerMetrics::new();
        let store = crate::storage::engine::placeholder_engine();
        let mut payload = Vec::new();
        let mut bounds = Vec::new();
        for line in [
            "GET 1",
            "UPDATE 1 111 4",
            "GET 1", // same-key read observes the preceding grouped update
            "PING",  // breaks the run, executes inline
            "GET 2",
            "GET nonsense", // malformed point verb: inline ERR, not a run entry
            "QUIT",
        ] {
            payload.extend_from_slice(line.as_bytes());
            bounds.push(payload.len());
        }
        let mut resp = Vec::new();
        let quit = exec_batch_lines_grouped(&payload, &bounds, &store, None, &m, &pool, &mut resp);
        assert!(quit);
        let text = String::from_utf8(resp).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), bounds.len(), "one response line per payload line");
        assert_eq!(lines[0], "OK 100 2");
        assert_eq!(lines[1], "OK");
        assert_eq!(lines[2], "OK 111 4");
        assert_eq!(lines[3], "PONG");
        assert_eq!(lines[4], "OK 200 3");
        assert!(lines[5].starts_with("ERR"), "{}", lines[5]);
        assert_eq!(lines[6], "BYE");
        assert_eq!(m.requests.get(), bounds.len() as u64);
        pool.shutdown().unwrap();
    }
}
