//! Hand-written Linux syscall bindings for the event-driven serving core:
//! `epoll` (readiness), `eventfd` (cross-thread wakeup), `setrlimit`
//! (fd-heavy tests/benches raise their own `RLIMIT_NOFILE`) and `sigaction`
//! (SIGTERM/SIGINT graceful shutdown for `serve`). Zero external crates —
//! the same std-only discipline as the rest of the tree; these symbols live
//! in the libc that std already links, so declaring them adds no dependency.
//!
//! Safety model: every raw fd is owned by exactly one wrapper (`Epoll`,
//! `EventFd`) that closes it on drop; `epoll_wait` writes only into the
//! caller-provided event buffer, sized by the slice we pass. The
//! `EpollEvent` layout matches the kernel ABI: packed on x86 (the kernel
//! struct is `__attribute__((packed))` there), natural alignment elsewhere
//! — fields are therefore private and read **by value** through accessors
//! (taking a reference into a packed struct is UB).

#![cfg(target_os = "linux")]
// Whitelisted exception to the crate-root `#![deny(unsafe_code)]` — the one
// module allowed to speak raw FFI (see DESIGN.md §13).
#![allow(unsafe_code)]

use std::io;
use std::os::raw::{c_int, c_uint, c_void};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

// -- constants (uapi/linux/eventpoll.h, asm-generic/fcntl.h, resource.h) ----

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLL_CLOEXEC: c_int = 0o2000000; // == O_CLOEXEC
const EFD_CLOEXEC: c_int = 0o2000000;
const EFD_NONBLOCK: c_int = 0o4000; // == O_NONBLOCK

const RLIMIT_NOFILE: c_int = 7;

#[repr(C)]
struct RLimit {
    rlim_cur: u64,
    rlim_max: u64,
}

/// Kernel `struct epoll_event`. Packed on x86/x86_64 (kernel ABI), natural
/// layout on other architectures — exactly libc's definition.
#[repr(C)]
#[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
#[derive(Clone, Copy)]
pub struct EpollEvent {
    events: u32,
    data: u64,
}

impl EpollEvent {
    pub const fn zeroed() -> Self {
        EpollEvent { events: 0, data: 0 }
    }

    /// Readiness bits (EPOLLIN/OUT/ERR/HUP/RDHUP). Copies the field out of
    /// the (possibly packed) struct — never hands out a reference.
    #[inline]
    pub fn readiness(&self) -> u32 {
        self.events
    }

    /// The `u64` token registered with the fd.
    #[inline]
    pub fn token(&self) -> u64 {
        self.data
    }
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    fn close(fd: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
}

fn cvt(ret: c_int) -> io::Result<c_int> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

// ---------------------------------------------------------------------------
// Epoll
// ---------------------------------------------------------------------------

/// Owned epoll instance. `wait` fills a caller-provided buffer so the hot
/// loop allocates nothing.
pub struct Epoll {
    fd: c_int,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        // SAFETY: no pointer arguments; the returned fd (or -1) is checked
        // by `cvt` and, once wrapped, owned and closed exactly once in Drop.
        let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: c_int, interest: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events: interest, data: token };
        // SAFETY: `ev` is a live, properly laid-out `EpollEvent` (#[repr(C)],
        // kernel ABI) for the whole call; the kernel only reads it.
        cvt(unsafe { epoll_ctl(self.fd, op, fd, &mut ev) }).map(|_| ())
    }

    /// Register `fd` with `interest` bits; readiness events carry `token`.
    pub fn add(&self, fd: c_int, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest, token)
    }

    /// Re-arm an already-registered fd with a new interest set.
    pub fn modify(&self, fd: c_int, interest: u32, token: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest, token)
    }

    /// Deregister `fd`. Closing the fd also deregisters it implicitly, but
    /// only once every duplicate (e.g. `try_clone`) is gone — the explicit
    /// DEL is the reliable path.
    pub fn delete(&self, fd: c_int) -> io::Result<()> {
        let mut ev = EpollEvent::zeroed(); // ignored for DEL; non-null for pre-2.6.9 ABI
        // SAFETY: same contract as `ctl` — `ev` outlives the call and the
        // kernel treats it as read-only (and ignores it for DEL).
        cvt(unsafe { epoll_ctl(self.fd, EPOLL_CTL_DEL, fd, &mut ev) }).map(|_| ())
    }

    /// Block until readiness or `timeout` (None = forever). Returns how
    /// many entries of `events` were filled. EINTR retries internally, with
    /// the timeout re-armed in full — callers run their own deadline logic,
    /// so a marginally late tick is harmless and the code stays simple.
    pub fn wait(&self, events: &mut [EpollEvent], timeout: Option<Duration>) -> io::Result<usize> {
        let timeout_ms: c_int = match timeout {
            None => -1,
            // Round *up* so a 0 < t < 1ms deadline doesn't busy-spin at 0.
            Some(d) => d.as_millis().saturating_add(1).min(c_int::MAX as u128) as c_int,
        };
        loop {
            // SAFETY: the out-pointer and capacity both come from the same
            // live slice, so the kernel writes at most `events.len()`
            // entries into memory we exclusively borrow; every `EpollEvent`
            // bit pattern is a valid value.
            let n = unsafe {
                epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, timeout_ms)
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        // SAFETY: `self.fd` is the epoll fd this wrapper exclusively owns;
        // Drop runs once, so it is closed exactly once.
        unsafe { close(self.fd) };
    }
}

// SAFETY: the wrapper owns its fd, and every `&self` method only issues
// syscalls the kernel serializes internally — no thread-affine state.
unsafe impl Send for Epoll {}
// SAFETY: as above; concurrent `wait`/`ctl` from several threads is a
// supported epoll usage pattern.
unsafe impl Sync for Epoll {}

// ---------------------------------------------------------------------------
// EventFd
// ---------------------------------------------------------------------------

/// Nonblocking eventfd: the reactor wakeup primitive. `signal` is async-
/// signal-safe and never blocks (counter saturation would return EAGAIN,
/// which is fine — the reader is already due to wake).
pub struct EventFd {
    fd: c_int,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        // SAFETY: no pointer arguments; the returned fd (or -1) is checked
        // by `cvt` and, once wrapped, owned and closed exactly once in Drop.
        let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
        Ok(EventFd { fd })
    }

    pub fn raw(&self) -> c_int {
        self.fd
    }

    /// Bump the counter: wakes (or pre-wakes) whoever polls this fd.
    pub fn signal(&self) {
        let one: u64 = 1;
        // SAFETY: the buffer is a live 8-byte local and the count says 8;
        // eventfd writes are atomic counter adds, safe from any thread.
        let _ = unsafe { write(self.fd, &one as *const u64 as *const c_void, 8) };
    }

    /// Consume all pending signals (eventfd counter semantics: one read
    /// returns-and-zeroes the whole counter).
    pub fn drain(&self) {
        let mut v: u64 = 0;
        // SAFETY: the out-buffer is a live, exclusively-borrowed 8-byte
        // local and the count says 8 — the kernel writes at most that.
        let _ = unsafe { read(self.fd, &mut v as *mut u64 as *mut c_void, 8) };
    }
}

impl Drop for EventFd {
    fn drop(&mut self) {
        // SAFETY: `self.fd` is the eventfd this wrapper exclusively owns;
        // Drop runs once, so it is closed exactly once.
        unsafe { close(self.fd) };
    }
}

// SAFETY: owned fd; `signal`/`drain` are single atomic syscalls on an
// eventfd, explicitly designed for cross-thread use.
unsafe impl Send for EventFd {}
// SAFETY: as above — concurrent signal/drain from many threads is the
// primitive's intended usage.
unsafe impl Sync for EventFd {}

// ---------------------------------------------------------------------------
// RLIMIT_NOFILE
// ---------------------------------------------------------------------------

/// Raise the soft fd limit to `min(want, hard limit)` and return the limit
/// now in effect. The connection-scaling test and the idle-connection bench
/// open 512–1024 sockets per side; default soft limits (often 1024) would
/// turn them into EMFILE noise. Best-effort: on any error the current soft
/// limit is returned unchanged.
pub fn raise_nofile_limit(want: u64) -> u64 {
    let mut rl = RLimit { rlim_cur: 0, rlim_max: 0 };
    // SAFETY: `rl` is a live `#[repr(C)]` local matching the kernel's
    // `struct rlimit` layout; the kernel fills exactly that struct.
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut rl) } != 0 {
        return 0;
    }
    if rl.rlim_cur >= want {
        return rl.rlim_cur;
    }
    let new_cur = want.min(rl.rlim_max);
    let new = RLimit { rlim_cur: new_cur, rlim_max: rl.rlim_max };
    // SAFETY: `new` is a live `#[repr(C)]` local; the kernel only reads it.
    if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
        new_cur
    } else {
        rl.rlim_cur
    }
}

// ---------------------------------------------------------------------------
// statfs — free-disk preflight probe
// ---------------------------------------------------------------------------

/// Kernel `struct statfs` as laid out by glibc/musl on the 64-bit Linux
/// targets this module compiles for (x86_64, aarch64): `__fsword_t` is
/// `i64`, the block/file counts are `u64`, `f_fsid` is two `i32`s. Every
/// field must be declared for the layout to match even though the probe
/// only reads two of them.
#[cfg(target_pointer_width = "64")]
#[repr(C)]
#[allow(dead_code)] // layout-complete: unread fields position the read ones
struct Statfs {
    f_type: i64,
    f_bsize: i64,
    f_blocks: u64,
    f_bfree: u64,
    f_bavail: u64,
    f_files: u64,
    f_ffree: u64,
    f_fsid: [i32; 2],
    f_namelen: i64,
    f_frsize: i64,
    f_flags: i64,
    f_spare: [i64; 4],
}

#[cfg(target_pointer_width = "64")]
extern "C" {
    fn statfs(path: *const std::os::raw::c_char, buf: *mut Statfs) -> c_int;
}

/// Bytes an unprivileged writer can still put on the filesystem holding
/// `path` (`f_bavail × f_bsize` — the quota-visible number, not root's
/// `f_bfree`). `None` when the probe fails (path missing, interior NUL) —
/// callers skip their free-space warning rather than guess.
#[cfg(target_pointer_width = "64")]
pub fn free_disk_bytes(path: &std::path::Path) -> Option<u64> {
    use std::os::unix::ffi::OsStrExt as _;
    let c = std::ffi::CString::new(path.as_os_str().as_bytes()).ok()?;
    let mut s = std::mem::MaybeUninit::<Statfs>::uninit();
    // SAFETY: the path pointer is a live NUL-terminated CString for the
    // whole call and the out-pointer is sized for exactly one `Statfs`
    // (`#[repr(C)]`, kernel ABI); the kernel fills it only on success,
    // which the return code gates.
    let rc = unsafe { statfs(c.as_ptr(), s.as_mut_ptr()) };
    if rc != 0 {
        return None;
    }
    // SAFETY: rc == 0 means the kernel initialized the whole struct.
    let s = unsafe { s.assume_init() };
    let bsize = u64::try_from(s.f_bsize).ok()?;
    Some(s.f_bavail.saturating_mul(bsize))
}

/// 32-bit stub: the LFS `statfs64` layout differs — skip the probe (and
/// with it the advisory free-space warning) rather than misread the ABI.
#[cfg(not(target_pointer_width = "64"))]
pub fn free_disk_bytes(_path: &std::path::Path) -> Option<u64> {
    None
}

// ---------------------------------------------------------------------------
// Graceful shutdown signals (SIGTERM / SIGINT)
// ---------------------------------------------------------------------------

const SIGINT: c_int = 2;
const SIGTERM: c_int = 15;
/// Restart interruptible syscalls after the handler runs — the serve loop
/// polls [`shutdown_requested`] on a timer, so nothing needs EINTR to
/// surface, and std I/O elsewhere keeps working unperturbed.
const SA_RESTART: c_int = 0x1000_0000;

/// libc `struct sigaction` as laid out by glibc and musl on the 64-bit
/// Linux targets this module compiles for (x86_64, aarch64): the handler
/// union first, then the full 1024-bit signal mask, then flags (padded to
/// pointer alignment), then the restorer slot. We always call through the
/// libc wrapper, which fills in the real restorer before trapping into the
/// kernel, so leaving `sa_restorer` null here is correct.
#[repr(C)]
struct SigAction {
    sa_handler: usize,
    sa_mask: [u64; 16],
    sa_flags: c_int,
    sa_restorer: usize,
}

extern "C" {
    fn sigaction(signum: c_int, act: *const SigAction, oldact: *mut SigAction) -> c_int;
}

/// Process-wide latch flipped by the signal handler. Never reset: shutdown
/// is one-way.
static SHUTDOWN_REQUESTED: AtomicBool = AtomicBool::new(false);

/// The handler body is the *only* thing allowed in async-signal context: a
/// single atomic store (async-signal-safe per POSIX; no allocation, no
/// locks, no stdio).
extern "C" fn on_shutdown_signal(_sig: c_int) {
    SHUTDOWN_REQUESTED.store(true, Ordering::Release);
}

/// Install the SIGTERM/SIGINT handler that arms [`shutdown_requested`].
/// Call once at serve startup, before accepting connections; the serve loop
/// then polls the flag and runs the orderly teardown (fsync WAL, seal
/// replication, exit 0) itself — the handler does none of that work.
pub fn install_shutdown_handler() -> io::Result<()> {
    let act = SigAction {
        sa_handler: on_shutdown_signal as usize,
        sa_mask: [0; 16],
        sa_flags: SA_RESTART,
        sa_restorer: 0,
    };
    for sig in [SIGINT, SIGTERM] {
        // SAFETY: `act` is a live, correctly laid-out `SigAction` for the
        // duration of the call and libc only reads it; the handler it
        // installs performs one atomic store, which is async-signal-safe.
        cvt(unsafe { sigaction(sig, &act, std::ptr::null_mut()) })?;
    }
    Ok(())
}

/// True once SIGTERM or SIGINT has been delivered. Monotonic.
pub fn shutdown_requested() -> bool {
    SHUTDOWN_REQUESTED.load(Ordering::Acquire)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn eventfd_wakes_epoll() {
        let ep = Epoll::new().unwrap();
        let efd = EventFd::new().unwrap();
        ep.add(efd.raw(), EPOLLIN, 7).unwrap();
        let mut evs = [EpollEvent::zeroed(); 4];
        // Nothing pending: times out empty.
        assert_eq!(ep.wait(&mut evs, Some(Duration::from_millis(1))).unwrap(), 0);
        efd.signal();
        efd.signal();
        let n = ep.wait(&mut evs, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(n, 1);
        assert_eq!(evs[0].token(), 7);
        assert_ne!(evs[0].readiness() & EPOLLIN, 0);
        // Drain consumes both signals at once (counter semantics).
        efd.drain();
        assert_eq!(ep.wait(&mut evs, Some(Duration::from_millis(1))).unwrap(), 0);
    }

    #[test]
    fn epoll_reports_socket_readiness_and_interest_changes() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();

        let ep = Epoll::new().unwrap();
        ep.add(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 42).unwrap();
        let mut evs = [EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut evs, Some(Duration::from_millis(1))).unwrap(), 0);

        client.write_all(b"ping").unwrap();
        let n = ep.wait(&mut evs, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(n, 1);
        assert_eq!(evs[0].token(), 42);
        assert_ne!(evs[0].readiness() & EPOLLIN, 0);

        // Interest can be narrowed: with only EPOLLOUT armed, pending input
        // no longer reports (the pause-while-blocked mechanism).
        ep.modify(server.as_raw_fd(), EPOLLOUT, 42).unwrap();
        let n = ep.wait(&mut evs, Some(Duration::from_millis(20))).unwrap();
        assert_eq!(n, 1, "a fresh socket is write-ready");
        assert_eq!(evs[0].readiness() & EPOLLIN, 0);
        assert_ne!(evs[0].readiness() & EPOLLOUT, 0);

        ep.modify(server.as_raw_fd(), EPOLLIN | EPOLLRDHUP, 42).unwrap();
        drop(client);
        let n = ep.wait(&mut evs, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(n, 1);
        assert_ne!(
            evs[0].readiness() & (EPOLLIN | EPOLLRDHUP | EPOLLHUP),
            0,
            "peer close must surface"
        );
        let mut buf = [0u8; 16];
        let mut server = server;
        assert_eq!(server.read(&mut buf).unwrap(), 4, "payload still readable");
        ep.delete(server.as_raw_fd()).unwrap();
    }

    #[test]
    fn shutdown_flag_arms_on_sigterm() {
        extern "C" {
            fn raise(sig: c_int) -> c_int;
        }
        install_shutdown_handler().unwrap();
        // SAFETY: `raise` delivers the signal synchronously to this thread;
        // the handler installed above performs a single atomic store, so by
        // the time `raise` returns the flag is observable.
        let rc = unsafe { raise(SIGTERM) };
        assert_eq!(rc, 0);
        assert!(shutdown_requested(), "SIGTERM must arm the shutdown latch");
    }

    #[test]
    fn free_disk_probe_reports_space_or_declines() {
        // The build tree's filesystem exists and has *some* space; a
        // nonexistent path must decline rather than fabricate a number.
        if cfg!(target_pointer_width = "64") {
            let free = free_disk_bytes(&std::env::temp_dir());
            assert!(free.is_some_and(|b| b > 0), "temp dir probe: {free:?}");
        }
        assert_eq!(free_disk_bytes(std::path::Path::new("/definitely/not/here/xyz")), None);
    }

    #[test]
    fn nofile_limit_is_at_least_current() {
        let now = raise_nofile_limit(64);
        assert!(now >= 64 || now == 0, "soft limit should already exceed 64, got {now}");
        // Asking for less than the current limit is a no-op that reports
        // the (unchanged) current limit.
        let again = raise_nofile_limit(1);
        assert!(again >= now.min(64));
    }
}
