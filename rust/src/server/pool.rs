//! Bounded worker pool: N long-lived workers pull work items from a
//! bounded [`pipeline::channel`](crate::pipeline::channel) queue. Thread
//! count is fixed at construction and shutdown is a channel close + join
//! (no JoinHandle vector growing for the lifetime of the server).
//!
//! Generic over the work item. On Linux the reactor front end instantiates
//! it with `WorkerPool<BlockingJob>` — the executor for blocking verbs
//! (`ANALYTICS`, durable group-commit fsync) so reactor threads never
//! block on disk or the analytics engine; on other hosts the fallback
//! front end still runs whole connections through `WorkerPool<TcpStream>`.

use std::sync::Arc;
use std::thread::JoinHandle;

use crate::pipeline::channel::{bounded, Sender, TrySendError};

/// Why a [`WorkerPool::try_submit`] could not enqueue.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySubmitError<T> {
    /// Queue at capacity — caller applies its own backpressure (the
    /// reactor answers `ERR server busy` instead of blocking its loop).
    Full(T),
    /// Pool already shut down.
    Closed(T),
}

pub struct WorkerPool<T: Send + 'static> {
    tx: Option<Sender<T>>,
    workers: Vec<JoinHandle<()>>,
}

impl<T: Send + 'static> WorkerPool<T> {
    /// Spawn `workers` threads over a queue of `queue_depth` pending items.
    /// Each worker runs `handler` on one item at a time until the pool is
    /// shut down and the queue is drained.
    pub fn new<F>(workers: usize, queue_depth: usize, handler: F) -> Self
    where
        F: Fn(T) + Send + Sync + 'static,
    {
        assert!(workers > 0);
        let (tx, rx) = bounded::<T>(queue_depth);
        let handler = Arc::new(handler);
        let mut joins = Vec::with_capacity(workers);
        for i in 0..workers {
            let rx = rx.clone();
            let handler = handler.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("server-worker-{i}"))
                    .spawn(move || {
                        while let Ok(item) = rx.recv() {
                            // A panicking handler must not kill the worker —
                            // the pool would shrink permanently. The payload
                            // is already reported by the panic hook.
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| handler(item)),
                            );
                        }
                    })
                    .expect("spawn pool worker"),
            );
        }
        WorkerPool { tx: Some(tx), workers: joins }
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Hand an item to the pool; blocks while the queue is full
    /// (backpressure on the acceptor). `Err` returns the item if the pool
    /// has already shut down.
    pub fn submit(&self, item: T) -> Result<(), T> {
        match &self.tx {
            Some(tx) => tx.send(item).map_err(|e| e.0),
            None => Err(item),
        }
    }

    /// Non-blocking [`WorkerPool::submit`]: a full queue hands the item
    /// back immediately instead of parking the caller. Event-loop callers
    /// (the reactors) must use this — a reactor blocked on the pool queue
    /// freezes every connection it owns.
    pub fn try_submit(&self, item: T) -> Result<(), TrySubmitError<T>> {
        match &self.tx {
            Some(tx) => tx.try_send(item).map_err(|e| match e {
                TrySendError::Full(v) => TrySubmitError::Full(v),
                TrySendError::Closed(v) => TrySubmitError::Closed(v),
            }),
            None => Err(TrySubmitError::Closed(item)),
        }
    }

    /// Close the queue and join every worker. Queued items are still
    /// processed before workers observe the close ([`crate::pipeline::channel`]
    /// drains before reporting `Closed`).
    pub fn shutdown(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl<T: Send + 'static> Drop for WorkerPool<T> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;

    #[test]
    fn all_items_processed_with_fewer_workers_than_items() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let mut pool = {
            let seen = seen.clone();
            WorkerPool::new(2, 4, move |i: u64| {
                seen.lock().unwrap().push(i);
            })
        };
        for i in 0..64u64 {
            pool.submit(i).unwrap();
        }
        pool.shutdown();
        let mut got = seen.lock().unwrap().clone();
        got.sort_unstable();
        assert_eq!(got, (0..64).collect::<Vec<u64>>());
    }

    #[test]
    fn submit_after_shutdown_returns_item() {
        let mut pool = WorkerPool::new(1, 1, |_: u64| {});
        pool.shutdown();
        assert_eq!(pool.submit(9), Err(9));
        assert_eq!(pool.worker_count(), 0);
    }

    #[test]
    fn try_submit_full_reports_instead_of_blocking() {
        // One worker parked inside the handler (on `gate`), queue depth 1.
        let gate = Arc::new(Mutex::new(()));
        let held = gate.lock().unwrap();
        let mut pool = {
            let gate = gate.clone();
            WorkerPool::new(1, 1, move |_: u64| {
                let _g = gate.lock().unwrap();
            })
        };
        pool.submit(1).unwrap(); // worker dequeues this and parks on gate
        pool.submit(2).unwrap(); // returns only once 1 was dequeued → fills queue
        // Queue is now provably full and the worker provably stuck: a
        // blocking submit would deadlock this (single-threaded) test.
        assert_eq!(pool.try_submit(3), Err(TrySubmitError::Full(3)));
        drop(held);
        pool.shutdown();
        assert_eq!(pool.try_submit(9), Err(TrySubmitError::Closed(9)));
    }

    #[test]
    fn panicking_handler_does_not_kill_worker() {
        let count = Arc::new(AtomicU64::new(0));
        let mut pool = {
            let count = count.clone();
            WorkerPool::new(1, 8, move |i: u64| {
                if i == 3 {
                    panic!("boom (expected in this test)");
                }
                count.fetch_add(1, Ordering::Relaxed);
            })
        };
        for i in 0..8u64 {
            pool.submit(i).unwrap();
        }
        pool.shutdown();
        assert_eq!(count.load(Ordering::Relaxed), 7, "worker died on panic");
    }

    #[test]
    fn drop_joins_workers_and_drains_queue() {
        let count = Arc::new(AtomicU64::new(0));
        {
            let count = count.clone();
            let pool = WorkerPool::new(3, 8, move |_: u64| {
                count.fetch_add(1, Ordering::Relaxed);
            });
            for i in 0..20u64 {
                pool.submit(i).unwrap();
            }
            // Pool dropped here: must drain all 20 before joining.
        }
        assert_eq!(count.load(Ordering::Relaxed), 20);
    }
}
