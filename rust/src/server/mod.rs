//! One-server request loop (paper §4.3): a TCP line protocol over the live
//! memstore, demonstrating that a single machine serves reads, updates and
//! PJRT-backed analytics with no distributed infrastructure.
//!
//! Protocol (one request per line, space-separated, ASCII; trailing tokens
//! after a complete request are rejected):
//! ```text
//! GET <isbn13>                  → OK <price_cents> <qty> | MISS
//! UPDATE <isbn13> <cents> <qty> → OK | MISS
//! MGET <k1> <k2> ...            → OK <n> <price,qty|MISS> ...  (input order)
//! MUPDATE <k c q>;<k c q>;...   → OK applied=<a> missed=<m>
//! BATCH <n>                     → n follow-up request lines, answered with
//!                                 n response lines in one socket write
//! STATS                         → OK count=<n> value_cents=<v> conns_...
//! STATS SERVER                  → OK <conn counters + per-verb latency
//!                                 + read-path/WAL/snapshot gauges>
//! STATS RESET                   → OK epoch=<e> (fresh measurement window)
//! ANALYTICS                     → OK value=<dollars> ... (analytics backend)
//! PING                          → PONG
//! QUIT                          → BYE (closes connection)
//! ```
//! Unknown/malformed input → `ERR <reason>`.
//!
//! Topology: one acceptor thread feeds a **bounded worker pool**
//! ([`pool::WorkerPool`]) over a `pipeline::channel` queue — thread count is
//! fixed by [`ServerConfig::workers`], connections past
//! [`ServerConfig::max_conns`] are refused with `ERR server busy`, and the
//! batch verbs execute shard-affinely ([`batch`]): keys are pre-routed with
//! `ShardedStore::route_hashed` and each shard is visited once per batch, so
//! a loaded front end scales like the pipeline's workers instead of one
//! thread per socket. `GET`/`MGET` read the store **lock-free** (seqlock,
//! `memstore::shard`), so read throughput scales with reader threads.
//!
//! Hot path allocation discipline: request lines accumulate into a reusable
//! per-connection byte buffer and are UTF-8-validated **once per line** (no
//! per-chunk decode), the tokenizer works on borrowed slices, and responses
//! are formatted with an integer byte formatter into a pooled per-connection
//! buffer flushed in **one** write per request (one per whole BATCH group).
//! Steady state the request/response cycle of the point verbs allocates
//! nothing; the `allocs_saved` counter tracks responses served this way.
//!
//! Durability: built with [`Server::with_persistence`], every mutation
//! (`UPDATE`/`MUPDATE`/`BATCH` payload) is WAL-logged through
//! [`durability::Persistence`](crate::durability::Persistence) *before* it
//! is acknowledged — one group sync per request batch (`BATCH` defers each
//! line's sync and issues exactly one before the group's single response
//! write). Without a persistence layer the request path is byte-for-byte
//! the old RAM-only one.

pub mod batch;
pub mod pool;

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::durability::Persistence;
use crate::memstore::ShardedStore;
use crate::metrics::ServerMetrics;
use crate::runtime::AnalyticsService;
use crate::util::fmt::push_u64;
use crate::workload::record::StockUpdate;
use pool::WorkerPool;

/// Tunables for the request front end.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Pool worker threads; each owns one connection at a time.
    pub workers: usize,
    /// Admission limit on live connections (queued + in-flight); beyond it
    /// new sockets get `ERR server busy` and are closed.
    pub max_conns: usize,
    /// Per-connection read timeout — also the granularity at which idle
    /// connections notice shutdown.
    pub read_timeout: Duration,
    /// A connection that completes no request within this window is closed.
    /// Workers own their connection while serving it, so without this limit
    /// `workers` idle clients would starve every queued connection.
    pub idle_timeout: Duration,
    /// Per-syscall socket write timeout. A client that stops reading fills
    /// its TCP window and would otherwise pin a worker (and hang shutdown)
    /// in `write_all` forever.
    pub write_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ServerConfig {
            // Network front end is IO-bound: keep a floor of 4 so small
            // hosts still overlap slow clients.
            workers: cores.max(4),
            max_conns: 1024,
            read_timeout: Duration::from_millis(200),
            idle_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(10),
        }
    }
}

pub struct Server {
    store: Arc<ShardedStore>,
    engine: Option<Arc<AnalyticsService>>,
    persist: Option<Arc<Persistence>>,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<ServerMetrics>,
    config: ServerConfig,
}

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<ServerMetrics>,
}

impl Server {
    pub fn new(store: Arc<ShardedStore>, engine: Option<Arc<AnalyticsService>>) -> Self {
        Self::with_config(store, engine, ServerConfig::default())
    }

    pub fn with_config(
        store: Arc<ShardedStore>,
        engine: Option<Arc<AnalyticsService>>,
        config: ServerConfig,
    ) -> Self {
        Self::with_persistence(store, engine, config, None)
    }

    /// Full constructor: a server whose mutations are WAL-logged and
    /// group-committed through `persist` before they are acknowledged.
    /// The store behind `persist` must be the same `store` passed here —
    /// the persistence layer applies mutations itself so the log and the
    /// memory image can never diverge.
    pub fn with_persistence(
        store: Arc<ShardedStore>,
        engine: Option<Arc<AnalyticsService>>,
        mut config: ServerConfig,
        persist: Option<Arc<Persistence>>,
    ) -> Self {
        // Clamp here so the admission check and the pool agree: a raw
        // max_conns of 0 would otherwise reject every connection while the
        // pool still stood up a 1-slot queue.
        config.workers = config.workers.max(1);
        config.max_conns = config.max_conns.max(1);
        Server {
            store,
            engine,
            persist,
            stop: Arc::new(AtomicBool::new(false)),
            metrics: Arc::new(ServerMetrics::new()),
            config,
        }
    }

    /// Bind and serve on a background thread; returns a handle for shutdown.
    pub fn spawn(self, bind: &str) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let stop = self.stop.clone();
        let metrics = self.metrics.clone();
        let join = std::thread::spawn(move || self.accept_loop(listener));
        Ok(ServerHandle { addr, stop, join: Some(join), metrics })
    }

    fn accept_loop(self, listener: TcpListener) {
        // Non-blocking accept + short sleep so `stop` is observed between
        // clients without a wakeup pipe.
        listener.set_nonblocking(true).ok();
        // Queue capacity == max_conns: admission control guarantees at most
        // max_conns live connections, so `submit` never blocks the acceptor.
        let pool = {
            let store = self.store.clone();
            let engine = self.engine.clone();
            let persist = self.persist.clone();
            let stop = self.stop.clone();
            let metrics = self.metrics.clone();
            let cfg = self.config.clone();
            WorkerPool::new(
                self.config.workers,
                self.config.max_conns,
                move |stream: TcpStream| {
                    // Guard (not a trailing call) so the admission slot is
                    // released even if request handling panics.
                    let _guard = ActiveGuard(&metrics);
                    let _ = handle_client(
                        stream,
                        &store,
                        engine.as_ref(),
                        persist.as_deref(),
                        &stop,
                        &metrics,
                        &cfg,
                    );
                },
            )
        };
        let base = Duration::from_millis(5);
        let mut backoff = base;
        while !self.stop.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((stream, _)) => {
                    backoff = base;
                    if self.metrics.conns_active.get() >= self.config.max_conns as i64 {
                        self.metrics.conns_rejected.inc();
                        reject_busy(stream);
                        continue;
                    }
                    self.metrics.conns_accepted.inc();
                    self.metrics.conns_active.inc();
                    if pool.submit(stream).is_err() {
                        // Pool already shut down (stop raced this accept).
                        self.metrics.conns_active.dec();
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(base);
                }
                Err(_) => {
                    // Transient accept failure (EMFILE, ECONNABORTED, ...):
                    // record it and back off — only `stop` ends the loop.
                    self.metrics.accept_errors.inc();
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(500));
                }
            }
        }
        drop(pool); // closes the queue, drains it, joins every worker
    }
}

impl ServerHandle {
    /// Total requests executed (single verbs + batch payload lines).
    pub fn requests(&self) -> u64 {
        self.metrics.requests.get()
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Decrements `conns_active` on drop — including a panicking unwind, so a
/// crashed handler can never leak an admission slot.
struct ActiveGuard<'a>(&'a ServerMetrics);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.conns_active.dec();
    }
}

/// Turn away a connection over the admission limit: answer, half-close, and
/// briefly drain so a client that pipelined a request at connect still
/// receives the busy line instead of an RST that may discard it. Runs on a
/// short-lived helper thread — the acceptor must never block on a rejected
/// peer, especially under the overload that causes rejections.
fn reject_busy(stream: TcpStream) {
    let reject = move || {
        let mut stream = stream;
        stream.set_nonblocking(false).ok();
        let _ = stream.write_all(b"ERR server busy (connection limit reached)\n");
        let _ = stream.shutdown(Shutdown::Write);
        // One short read only — never a wait the client controls.
        stream.set_read_timeout(Some(Duration::from_millis(10))).ok();
        let mut sink = [0u8; 256];
        let _ = stream.read(&mut sink);
    };
    // If the spawn itself fails (thread exhaustion) the closure is dropped
    // and with it the stream: a hard close, which is the right fallback.
    let _ = std::thread::Builder::new().name("server-reject".into()).spawn(reject);
}

enum ReadOutcome {
    Line,
    Eof,
    Stopped,
    /// No complete request within the idle window.
    IdleTimeout,
}

/// Hard cap on one request line. MGET at MAX_BATCH keys is ~140 KiB, so
/// 1 MiB leaves ample headroom while bounding what a newline-less client
/// can pin in memory per connection.
const MAX_LINE_BYTES: usize = 1 << 20;

/// Read one request line as raw bytes, preserving a partially-received
/// request across read-timeout ticks: a slow client may deliver `"GET 12"`
/// now and `"34\n"` after the timeout, and both halves belong to one
/// request. `line` is appended to (never cleared here) — the caller clears
/// it after consuming a complete line, and validates the accumulated bytes
/// as UTF-8 **once per line** (the old path lossy-decoded every chunk into
/// a fresh `String`). Checks `stop` each tick. The idle `deadline` is
/// absolute and caller-supplied: one per request on the main loop, one
/// shared across a whole BATCH payload (so a drip-feeding client cannot
/// reset the clock per line).
///
/// Reads chunk-at-a-time (`fill_buf`/`consume`) instead of `read_line` so
/// the [`MAX_LINE_BYTES`] cap is enforced between chunks — a client
/// streaming forever without a newline gets its connection dropped, not an
/// unbounded buffer.
fn read_request_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut Vec<u8>,
    stop: &AtomicBool,
    deadline: Instant,
) -> std::io::Result<ReadOutcome> {
    loop {
        if stop.load(Ordering::Acquire) {
            return Ok(ReadOutcome::Stopped);
        }
        if Instant::now() >= deadline {
            return Ok(ReadOutcome::IdleTimeout);
        }
        if line.len() > MAX_LINE_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("request line exceeds {MAX_LINE_BYTES} bytes"),
            ));
        }
        let (complete, used) = {
            let buf = match reader.fill_buf() {
                Ok(b) => b,
                // Interrupted (EINTR) retries like std's read_line would.
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut
                        || e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    continue
                }
                Err(e) => return Err(e),
            };
            if buf.is_empty() {
                // EOF. A non-empty partial (no trailing newline) is still a
                // request — matches `read_line`'s end-of-stream semantics.
                return Ok(if line.is_empty() { ReadOutcome::Eof } else { ReadOutcome::Line });
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    line.extend_from_slice(&buf[..=i]);
                    (true, i + 1)
                }
                None => {
                    line.extend_from_slice(buf);
                    (false, buf.len())
                }
            }
        };
        reader.consume(used);
        if complete {
            return Ok(ReadOutcome::Line);
        }
    }
}

/// Per-connection pool capacity retained across requests. Buffers grow to
/// whatever one request needs, then are trimmed back to this after any
/// oversized use — one maximum-size BATCH (4 MiB payload + responses) must
/// not pin megabytes for the rest of a long-lived connection's life.
const RETAIN_BYTES: usize = 64 << 10;

/// Trim a pooled buffer that ballooned past the retention cap.
fn trim_pool(buf: &mut Vec<u8>) {
    if buf.capacity() > RETAIN_BYTES {
        buf.shrink_to(RETAIN_BYTES);
    }
}

/// Reusable per-connection buffers for the BATCH framing path. Steady state
/// a connection's batches allocate nothing: payload bytes, line bounds and
/// the group response all live in these pools.
#[derive(Default)]
struct BatchScratch {
    /// One reused accumulator for the payload read loop.
    line: Vec<u8>,
    /// Concatenated raw payload lines.
    payload: Vec<u8>,
    /// End offset of each payload line within `payload`.
    bounds: Vec<usize>,
    /// Response bytes for the whole group — flushed in one socket write.
    resp: Vec<u8>,
}

impl BatchScratch {
    /// Empty every pool, then trim ballooned capacity. Clearing first
    /// matters: `shrink_to` cannot drop capacity below `len`, so trimming
    /// a buffer still holding the (already-written) group response would
    /// be a no-op. Contents are dead by the time this runs.
    fn trim(&mut self) {
        self.line.clear();
        self.payload.clear();
        self.resp.clear();
        self.bounds.clear();
        trim_pool(&mut self.line);
        trim_pool(&mut self.payload);
        trim_pool(&mut self.resp);
        // `bounds` holds one usize per payload line (≤ MAX_BATCH entries);
        // trim it by the same byte budget as the byte pools.
        if self.bounds.capacity() * std::mem::size_of::<usize>() > RETAIN_BYTES {
            self.bounds.shrink_to(RETAIN_BYTES / std::mem::size_of::<usize>());
        }
    }
}

/// Count + answer a request line that failed UTF-8 validation — the one
/// copy of this accounting, charged to the `other` latency histogram so
/// `requests == Σ verb_n` holds across STATS windows.
fn reply_invalid_utf8(metrics: &ServerMetrics, out: &mut Vec<u8>) {
    metrics.requests.inc();
    metrics.latency_for("").record(0);
    out.extend_from_slice(b"ERR request is not valid UTF-8\n");
}

#[allow(clippy::too_many_arguments)]
fn handle_client(
    stream: TcpStream,
    store: &Arc<ShardedStore>,
    engine: Option<&Arc<AnalyticsService>>,
    persist: Option<&Persistence>,
    stop: &AtomicBool,
    metrics: &ServerMetrics,
    cfg: &ServerConfig,
) -> std::io::Result<()> {
    // BSD-family kernels hand accepted sockets the listener's O_NONBLOCK;
    // clear it so the read timeout governs blocking (on Linux a no-op).
    stream.set_nonblocking(false).ok();
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(cfg.read_timeout))?;
    stream.set_write_timeout(Some(cfg.write_timeout))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    // Per-connection pools: the line accumulator, the response buffer and
    // the BATCH scratch are reused across requests (trimmed back to
    // RETAIN_BYTES after an outlier) — the steady-state request cycle
    // performs no heap allocation.
    let mut line: Vec<u8> = Vec::with_capacity(256);
    let mut resp: Vec<u8> = Vec::with_capacity(256);
    let mut scratch = BatchScratch::default();
    loop {
        match read_request_line(&mut reader, &mut line, stop, Instant::now() + cfg.idle_timeout)? {
            ReadOutcome::Line => {}
            ReadOutcome::Eof | ReadOutcome::Stopped => return Ok(()),
            ReadOutcome::IdleTimeout => {
                let _ = out.write_all(b"ERR idle timeout, closing connection\n");
                return Ok(());
            }
        }
        // Validate the accumulated bytes once per complete line; borrow the
        // request out of the buffer — no per-request copy. `line` is
        // cleared only after the last use of `req`.
        let req = match std::str::from_utf8(&line) {
            Ok(s) => s.trim(),
            Err(_) => {
                // Close, don't continue: the garbage could have been a
                // BATCH header, in which case payload lines are already in
                // flight and would execute as top-level requests —
                // permanently desyncing the reply stream (same no-resync
                // rule as malformed BATCH headers). Inside a BATCH payload
                // the count frames each line, so `run_batch` can ERR
                // per-line instead.
                resp.clear();
                reply_invalid_utf8(metrics, &mut resp);
                let _ = out.write_all(&resp);
                // Half-close + one bounded drain (reject_busy's pattern):
                // dropping the socket with those pipelined bytes unread
                // would RST and could discard the ERR reply.
                let _ = out.shutdown(Shutdown::Write);
                out.set_read_timeout(Some(Duration::from_millis(10))).ok();
                let mut sink = [0u8; 256];
                let _ = out.read(&mut sink);
                return Ok(());
            }
        };
        let verb = req.split_ascii_whitespace().next().unwrap_or("");
        if verb == "BATCH" {
            // The framing header is not counted as a request — run_batch
            // counts each payload line, so `requests` matches executed ops.
            let quit = run_batch(
                req,
                &mut reader,
                &mut out,
                store,
                engine,
                persist,
                stop,
                metrics,
                cfg,
                &mut scratch,
            )?;
            line.clear();
            if quit {
                return Ok(());
            }
            continue;
        }
        resp.clear();
        execute_one_into(req, store, engine, persist, metrics, false, &mut resp);
        // Response + newline leave in one syscall (the old path paid two
        // writes per request and allocated the response `String`).
        out.write_all(&resp)?;
        let quit = req == "QUIT";
        // An outlier request (MGET near the line cap) must not pin its
        // high-water buffers for the connection's remaining lifetime —
        // clear before trimming (`shrink_to` cannot go below `len`).
        line.clear();
        resp.clear();
        trim_pool(&mut line);
        trim_pool(&mut resp);
        if quit {
            return Ok(());
        }
    }
}

/// Execute one request line with its per-request accounting (request count,
/// per-verb latency), appending the newline-terminated response to `out` —
/// shared by the single-request loop and the BATCH payload loop so the
/// bookkeeping cannot drift between them.
fn execute_one_into(
    req: &str,
    store: &Arc<ShardedStore>,
    engine: Option<&Arc<AnalyticsService>>,
    persist: Option<&Persistence>,
    metrics: &ServerMetrics,
    in_batch: bool,
    out: &mut Vec<u8>,
) {
    metrics.requests.inc();
    let verb = req.split_ascii_whitespace().next().unwrap_or("");
    // A nested BATCH payload line dispatches to an ERR; charge it to
    // `other` so batch_latency keeps whole-group samples only.
    let verb = if in_batch && verb == "BATCH" { "" } else { verb };
    let t0 = Instant::now();
    let ctx = RequestCtx { store, engine, metrics: Some(metrics), persist };
    dispatch_into(req, &ctx, in_batch, out);
    metrics.latency_for(verb).record_duration(t0.elapsed());
}

/// `BATCH <n>` framing: read `n` follow-up request lines, execute them all,
/// answer with `n` response lines in **one** socket write — the whole group
/// costs one round trip. Returns `Ok(true)` when the connection must close
/// (client vanished mid-batch, shutdown, or the batch contained `QUIT`).
#[allow(clippy::too_many_arguments)]
fn run_batch(
    header: &str,
    reader: &mut BufReader<TcpStream>,
    out: &mut TcpStream,
    store: &Arc<ShardedStore>,
    engine: Option<&Arc<AnalyticsService>>,
    persist: Option<&Persistence>,
    stop: &AtomicBool,
    metrics: &ServerMetrics,
    cfg: &ServerConfig,
    scratch: &mut BatchScratch,
) -> std::io::Result<bool> {
    let mut parts = header.split_ascii_whitespace();
    parts.next(); // "BATCH"
    let n = parts.next().and_then(|s| s.parse::<usize>().ok());
    let n = match (n, parts.next()) {
        (Some(n), None) if (1..=batch::MAX_BATCH).contains(&n) => n,
        _ => {
            // A pipelining client may already have written payload lines we
            // cannot distinguish from top-level requests — close instead of
            // executing them (same no-resync rule as the payload-size cap).
            let msg = format!("ERR BATCH expects <n> in 1..={}, closing\n", batch::MAX_BATCH);
            out.write_all(msg.as_bytes())?;
            return Ok(true);
        }
    };
    scratch.payload.clear();
    scratch.bounds.clear();
    // One idle window for the entire payload — per-line deadlines would let
    // a drip-feeding client hold this worker for n × idle_timeout.
    let deadline = Instant::now() + cfg.idle_timeout;
    for _ in 0..n {
        scratch.line.clear();
        match read_request_line(reader, &mut scratch.line, stop, deadline)? {
            ReadOutcome::Line => {}
            ReadOutcome::Eof | ReadOutcome::Stopped | ReadOutcome::IdleTimeout => {
                return Ok(true)
            }
        }
        // Per-line MAX_LINE_BYTES is not enough here: n lines buffer before
        // execution, so cap the batch payload as a whole too.
        scratch.payload.extend_from_slice(&scratch.line);
        scratch.bounds.push(scratch.payload.len());
        if scratch.payload.len() > batch::MAX_BATCH_BYTES {
            let msg =
                format!("ERR BATCH payload exceeds {} bytes, closing\n", batch::MAX_BATCH_BYTES);
            out.write_all(msg.as_bytes())?;
            return Ok(true); // remaining lines are unread: cannot resync
        }
    }
    metrics.batch_sizes.record(n as u64);
    // Time execution only, from here: the read loop above is dominated by
    // client transmission, which would drown the server-work signal the
    // per-verb histograms exist to compare.
    let t0 = Instant::now();
    let mut quit = false;
    let resp = &mut scratch.resp;
    resp.clear();
    let mut start = 0usize;
    for &end in &scratch.bounds {
        let raw = &scratch.payload[start..end];
        start = end;
        // One UTF-8 validation per payload line, on the raw bytes in place.
        match std::str::from_utf8(raw) {
            Ok(s) => {
                let req = s.trim();
                execute_one_into(req, store, engine, persist, metrics, true, resp);
                quit = quit || req == "QUIT";
            }
            Err(_) => reply_invalid_utf8(metrics, resp),
        }
    }
    // Group commit: every mutation in the batch deferred its sync to this
    // single call — one fsync per BATCH, issued *before* the one socket
    // write that acknowledges the group. If the sync fails we must not
    // deliver the buffered OKs (they would ack unlogged writes): drop the
    // responses and close the connection.
    if let Some(p) = persist {
        if let Err(e) = p.sync() {
            eprintln!("membig: WAL group sync failed, closing connection: {e}");
            return Ok(true);
        }
    }
    // The whole group's responses leave in one gathered write.
    out.write_all(resp)?;
    metrics.batch_latency.record_duration(t0.elapsed());
    scratch.trim();
    Ok(quit)
}

/// Everything a request may touch while executing. Bundled so the dispatch
/// signature stops growing a parameter per subsystem.
#[derive(Clone, Copy)]
pub struct RequestCtx<'a> {
    pub store: &'a Arc<ShardedStore>,
    pub engine: Option<&'a Arc<AnalyticsService>>,
    pub metrics: Option<&'a ServerMetrics>,
    /// When set, `UPDATE`/`MUPDATE` are logged + applied through the
    /// persistence layer (never acknowledged before the WAL has them).
    pub persist: Option<&'a Persistence>,
}

/// Parse + execute one request line (separated out for direct unit tests).
/// Strict parsing: unconsumed trailing tokens are an `ERR`, never ignored.
pub fn dispatch(line: &str, store: &Arc<ShardedStore>, engine: Option<&Arc<AnalyticsService>>) -> String {
    dispatch_ctx(line, &RequestCtx { store, engine, metrics: None, persist: None }, false)
}

/// [`dispatch`] with optional server metrics: batch sizes are recorded, the
/// basic `STATS` line gains connection counters, and `STATS SERVER` renders
/// the full per-verb report.
pub fn dispatch_with_metrics(
    line: &str,
    store: &Arc<ShardedStore>,
    engine: Option<&Arc<AnalyticsService>>,
    metrics: Option<&ServerMetrics>,
) -> String {
    dispatch_ctx(line, &RequestCtx { store, engine, metrics, persist: None }, false)
}

/// [`dispatch_into`] rendered to a `String` (tests, REPL-style callers).
/// The server itself never takes this path — responses go straight into the
/// pooled connection buffer.
pub fn dispatch_ctx(line: &str, ctx: &RequestCtx<'_>, in_batch: bool) -> String {
    let mut out = Vec::with_capacity(64);
    dispatch_into(line, ctx, in_batch, &mut out);
    out.pop(); // the newline dispatch_into frames with
    String::from_utf8(out).expect("responses echo valid-UTF-8 requests")
}

/// Core dispatcher: parse + execute one request line, appending the
/// newline-terminated response to `out`. The hot verbs tokenize the
/// borrowed line and format integers straight into the buffer — no
/// response `String`, no `format!` temporaries. `in_batch` marks a BATCH
/// payload line: its mutations defer their WAL sync to the one group
/// commit `run_batch` issues before the group's single response write.
pub fn dispatch_into(line: &str, ctx: &RequestCtx<'_>, in_batch: bool, out: &mut Vec<u8>) {
    let RequestCtx { store, engine, metrics, persist } = *ctx;
    let line = line.trim();
    let (verb, rest) = match line.split_once(|c: char| c.is_ascii_whitespace()) {
        Some((v, r)) => (v, r.trim()),
        None => (line, ""),
    };
    // Set by the arms whose response was formatted straight into the
    // pooled buffer (no String allocation); accounted once below so the
    // hot/cold classification lives in exactly one place per arm.
    let mut saved = false;
    match verb {
        "GET" => {
            let mut parts = rest.split_ascii_whitespace();
            match (parts.next().and_then(|k| k.parse::<u64>().ok()), parts.next()) {
                (Some(key), None) => {
                    match store.get(key) {
                        Some(r) => {
                            out.extend_from_slice(b"OK ");
                            push_u64(out, r.price_cents);
                            out.push(b' ');
                            push_u64(out, r.quantity as u64);
                        }
                        None => out.extend_from_slice(b"MISS"),
                    }
                    saved = true;
                }
                _ => out.extend_from_slice(b"ERR GET expects exactly <isbn13>"),
            }
        }
        "UPDATE" => {
            let mut parts = rest.split_ascii_whitespace();
            let key = parts.next().and_then(|k| k.parse::<u64>().ok());
            let cents = parts.next().and_then(|k| k.parse::<u64>().ok());
            let qty = parts.next().and_then(|k| k.parse::<u32>().ok());
            match (key, cents, qty, parts.next()) {
                (Some(k), Some(c), Some(q), None) => {
                    let u = StockUpdate { isbn13: k, new_price_cents: c, new_quantity: q };
                    let applied = match persist {
                        // WAL-first: the ack below only happens once the
                        // frame is logged (and synced, outside a BATCH).
                        Some(p) => match p.apply_update(&u, !in_batch) {
                            Ok(applied) => applied,
                            Err(e) => {
                                out.extend_from_slice(format!("ERR durability: {e}").as_bytes());
                                out.push(b'\n');
                                return;
                            }
                        },
                        None => store.apply(&u),
                    };
                    out.extend_from_slice(if applied { b"OK".as_slice() } else { b"MISS" });
                    saved = true;
                }
                _ => out.extend_from_slice(b"ERR UPDATE expects exactly <isbn13> <cents> <qty>"),
            }
        }
        "MGET" => match batch::parse_mget(rest) {
            Ok(keys) => {
                if let Some(m) = metrics {
                    m.batch_sizes.record(keys.len() as u64);
                }
                batch::exec_mget_into(store, &keys, out);
                saved = true;
            }
            Err(e) => out.extend_from_slice(format!("ERR {e}").as_bytes()),
        },
        "MUPDATE" => match batch::parse_mupdate(rest) {
            Ok(ups) => {
                if let Some(m) = metrics {
                    m.batch_sizes.record(ups.len() as u64);
                }
                match persist {
                    // Group commit: the whole MUPDATE is one WAL append
                    // run + one sync (deferred inside a BATCH).
                    Some(p) => match p.apply_many(&ups, !in_batch) {
                        Ok((applied, missed)) => {
                            out.extend_from_slice(b"OK applied=");
                            push_u64(out, applied);
                            out.extend_from_slice(b" missed=");
                            push_u64(out, missed);
                            saved = true;
                        }
                        Err(e) => {
                            out.extend_from_slice(format!("ERR durability: {e}").as_bytes())
                        }
                    },
                    None => {
                        batch::exec_mupdate_into(store, &ups, out);
                        saved = true;
                    }
                }
            }
            Err(e) => out.extend_from_slice(format!("ERR {e}").as_bytes()),
        },
        "STATS" => {
            let mut parts = rest.split_ascii_whitespace();
            match (parts.next(), parts.next()) {
                (None, _) => {
                    let (n, v) = store.value_sum_cents();
                    let mut s = format!("OK count={n} value_cents={v}");
                    if let Some(m) = metrics {
                        s.push_str(&m.stats_suffix());
                    }
                    out.extend_from_slice(s.as_bytes());
                }
                (Some("SERVER"), None) => match metrics {
                    Some(m) => {
                        let mut s = m.stats_server_line();
                        let rs = store.read_stats();
                        s.push_str(&format!(
                            " read_retries={} read_fallbacks={}",
                            rs.retries.get(),
                            rs.fallbacks.get()
                        ));
                        if let Some(p) = persist {
                            s.push_str(&p.stats_suffix());
                        }
                        out.extend_from_slice(s.as_bytes());
                    }
                    None => out.extend_from_slice(b"ERR server metrics unavailable"),
                },
                // Fresh measurement window: zero the counters + latency
                // histograms (and the WAL/checkpoint traffic and lock-free
                // read-path counters when present) so consecutive bench
                // runs cannot contaminate each other; the epoch counter
                // marks which window a report belongs to.
                (Some("RESET"), None) => match metrics {
                    Some(m) => {
                        if let Some(p) = persist {
                            p.metrics().reset_epoch_counters();
                        }
                        let rs = store.read_stats();
                        rs.retries.reset();
                        rs.fallbacks.reset();
                        out.extend_from_slice(format!("OK epoch={}", m.reset_epoch()).as_bytes());
                    }
                    None => out.extend_from_slice(b"ERR server metrics unavailable"),
                },
                _ => out.extend_from_slice(b"ERR STATS expects no argument, SERVER or RESET"),
            }
        }
        "ANALYTICS" => {
            if !rest.is_empty() {
                out.extend_from_slice(b"ERR ANALYTICS takes no arguments");
            } else {
                match engine {
                    None => out.extend_from_slice(b"ERR analytics engine not loaded"),
                    Some(eng) => match eng.analytics_for_store(Arc::clone(store), Vec::new()) {
                        Ok(r) => out.extend_from_slice(
                            format!(
                                "OK value={:.2} count={} mean_price={:.4} price_min={:.2} price_max={:.2}",
                                r.stats.total_value,
                                r.stats.count,
                                r.stats.mean_price,
                                r.stats.price_min,
                                r.stats.price_max
                            )
                            .as_bytes(),
                        ),
                        Err(e) => out.extend_from_slice(format!("ERR {e}").as_bytes()),
                    },
                }
            }
        }
        "PING" => {
            if rest.is_empty() {
                out.extend_from_slice(b"PONG");
                saved = true;
            } else {
                out.extend_from_slice(b"ERR PING takes no arguments");
            }
        }
        "QUIT" => {
            if rest.is_empty() {
                out.extend_from_slice(b"BYE");
                saved = true;
            } else {
                out.extend_from_slice(b"ERR QUIT takes no arguments");
            }
        }
        // Top-level BATCH framing is handled in the connection loop before
        // dispatch; reaching it here means a nested/out-of-place BATCH.
        "BATCH" => out.extend_from_slice(b"ERR BATCH cannot be nested"),
        "" => out.extend_from_slice(b"ERR empty request"),
        other => out.extend_from_slice(format!("ERR unknown command '{other}'").as_bytes()),
    }
    if saved {
        if let Some(m) = metrics {
            m.allocs_saved.inc();
        }
    }
    out.push(b'\n');
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn request(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        Ok(resp.trim_end().to_string())
    }

    /// Pipelined batch: one write carrying `BATCH <n>` plus all `lines`,
    /// then `n` response lines read back — one round trip for the group.
    pub fn batch(&mut self, lines: &[String]) -> std::io::Result<Vec<String>> {
        if lines.is_empty() {
            // `BATCH 0` is a protocol error; sending it would desync the
            // reply stream (one ERR line, zero reads here).
            return Ok(Vec::new());
        }
        if lines.len() > batch::MAX_BATCH {
            // The server would reject the header with one ERR line and then
            // treat every payload line as a top-level request — permanently
            // desyncing this connection. Refuse before writing anything.
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("batch of {} exceeds MAX_BATCH={}", lines.len(), batch::MAX_BATCH),
            ));
        }
        if let Some(bad) = lines.iter().find(|l| l.contains('\n')) {
            // An embedded newline would become an extra wire line: the
            // server answers n+1 responses while we read n — same desync.
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("batch line contains embedded newline: {bad:?}"),
            ));
        }
        let mut buf = format!("BATCH {}\n", lines.len());
        for l in lines {
            buf.push_str(l);
            buf.push('\n');
        }
        self.writer.write_all(buf.as_bytes())?;
        let mut out = Vec::with_capacity(lines.len());
        for _ in 0..lines.len() {
            let mut resp = String::new();
            if self.reader.read_line(&mut resp)? == 0 {
                // Server aborted the batch (payload cap, shutdown, ...):
                // surface the truncation instead of fabricating responses.
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    format!("connection closed after {} of {} batch responses", out.len(),
                        lines.len()),
                ));
            }
            out.push(resp.trim_end().to_string());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::DatasetSpec;

    fn store(n: u64) -> (Arc<ShardedStore>, DatasetSpec) {
        let spec = DatasetSpec { records: n, ..Default::default() };
        let s = Arc::new(ShardedStore::new(4, 1 << 10));
        for r in spec.iter() {
            s.insert(r);
        }
        (s, spec)
    }

    #[test]
    fn dispatch_get_update_stats() {
        let (s, spec) = store(100);
        let key = spec.record_at(5).isbn13;
        let rec = spec.record_at(5);
        assert_eq!(
            dispatch(&format!("GET {key}"), &s, None),
            format!("OK {} {}", rec.price_cents, rec.quantity)
        );
        assert_eq!(dispatch("GET 42", &s, None), "MISS");
        assert_eq!(dispatch(&format!("UPDATE {key} 999 7"), &s, None), "OK");
        assert_eq!(dispatch(&format!("GET {key}"), &s, None), "OK 999 7");
        let (n, v) = s.value_sum_cents();
        assert_eq!(dispatch("STATS", &s, None), format!("OK count={n} value_cents={v}"));
    }

    #[test]
    fn dispatch_mget_mupdate() {
        let (s, spec) = store(100);
        let a = spec.record_at(1).isbn13;
        let b = spec.record_at(2).isbn13;
        assert_eq!(dispatch(&format!("MUPDATE {a} 100 1;{b} 200 2;42 1 1"), &s, None),
            "OK applied=2 missed=1");
        assert_eq!(dispatch(&format!("MGET {a} 42 {b}"), &s, None), "OK 3 100,1 MISS 200,2");
    }

    #[test]
    fn dispatch_into_appends_newline_terminated_responses() {
        // The buffer API the server actually uses: responses accumulate in
        // the pooled buffer, each framed with exactly one newline.
        let (s, spec) = store(10);
        let key = spec.record_at(1).isbn13;
        let rec = spec.record_at(1);
        let ctx = RequestCtx { store: &s, engine: None, metrics: None, persist: None };
        let mut out = Vec::new();
        dispatch_into("PING", &ctx, false, &mut out);
        dispatch_into(&format!("GET {key}"), &ctx, false, &mut out);
        dispatch_into("GET 424242", &ctx, false, &mut out);
        let text = String::from_utf8(out).unwrap();
        assert_eq!(
            text,
            format!("PONG\nOK {} {}\nMISS\n", rec.price_cents, rec.quantity)
        );
    }

    #[test]
    fn dispatch_error_paths() {
        let (s, _) = store(10);
        // Short / malformed argument lists.
        assert!(dispatch("GET", &s, None).starts_with("ERR"));
        assert!(dispatch("GET notanumber", &s, None).starts_with("ERR"));
        assert!(dispatch("UPDATE 1 2", &s, None).starts_with("ERR"));
        assert!(dispatch("MGET", &s, None).starts_with("ERR"));
        assert!(dispatch("MGET a b", &s, None).starts_with("ERR"));
        assert!(dispatch("MUPDATE", &s, None).starts_with("ERR"));
        assert!(dispatch("MUPDATE 1 2", &s, None).starts_with("ERR"));
        assert!(dispatch("BOGUS", &s, None).starts_with("ERR"));
        assert!(dispatch("", &s, None).starts_with("ERR"));
        assert!(dispatch("ANALYTICS", &s, None).starts_with("ERR"));
        assert!(dispatch("BATCH 2", &s, None).starts_with("ERR"));
        // Trailing garbage is rejected on every verb.
        assert!(dispatch("GET 1 extra", &s, None).starts_with("ERR"));
        assert!(dispatch("UPDATE 1 2 3 junk", &s, None).starts_with("ERR"));
        assert!(dispatch("MUPDATE 1 2 3 junk", &s, None).starts_with("ERR"));
        assert!(dispatch("STATS BOGUS", &s, None).starts_with("ERR"));
        assert!(dispatch("STATS SERVER extra", &s, None).starts_with("ERR"));
        assert!(dispatch("PING please", &s, None).starts_with("ERR"));
        assert!(dispatch("QUIT now", &s, None).starts_with("ERR"));
        assert!(dispatch("ANALYTICS now", &s, None).starts_with("ERR"));
        assert_eq!(dispatch("PING", &s, None), "PONG");
    }

    #[test]
    fn stats_with_metrics_appends_connection_counters() {
        let (s, _) = store(10);
        let m = ServerMetrics::new();
        m.conns_accepted.inc();
        let resp = dispatch_with_metrics("STATS", &s, None, Some(&m));
        assert!(resp.starts_with("OK count=10"), "{resp}");
        assert!(resp.contains("conns_accepted=1"), "{resp}");
        let resp = dispatch_with_metrics("STATS SERVER", &s, None, Some(&m));
        assert!(resp.starts_with("OK conns_accepted=1"), "{resp}");
        assert!(resp.contains("read_retries=0"), "{resp}");
        assert!(resp.contains("read_fallbacks=0"), "{resp}");
        assert_eq!(dispatch("STATS SERVER", &s, None), "ERR server metrics unavailable");
    }

    #[test]
    fn hot_verbs_count_alloc_free_responses() {
        let (s, spec) = store(10);
        let key = spec.record_at(1).isbn13;
        let m = ServerMetrics::new();
        for req in [
            format!("GET {key}"),
            "GET 4242".into(),      // MISS is still alloc-free
            format!("UPDATE {key} 5 5"),
            format!("MGET {key} 4242"),
            format!("MUPDATE {key} 6 6"),
            "PING".into(),
        ] {
            dispatch_with_metrics(&req, &s, None, Some(&m));
        }
        assert_eq!(m.allocs_saved.get(), 6);
        // Cold paths (STATS, errors) are not counted.
        dispatch_with_metrics("STATS", &s, None, Some(&m));
        dispatch_with_metrics("GET not_a_key", &s, None, Some(&m));
        assert_eq!(m.allocs_saved.get(), 6);
    }

    #[test]
    fn stats_reset_starts_a_fresh_window() {
        let (s, spec) = store(10);
        let m = ServerMetrics::new();
        let key = spec.record_at(1).isbn13;
        let ctx = RequestCtx { store: &s, engine: None, metrics: Some(&m), persist: None };
        m.latency_for("GET").record(123);
        m.requests.add(4);
        s.read_stats().retries.add(9);
        assert_eq!(dispatch_ctx("STATS RESET", &ctx, false), "OK epoch=1");
        assert_eq!(m.get_latency.count(), 0);
        assert_eq!(m.requests.get(), 0);
        assert_eq!(s.read_stats().retries.get(), 0, "read-path counters join the epoch");
        let line = dispatch_ctx("STATS SERVER", &ctx, false);
        assert!(line.contains("epoch=1"), "{line}");
        assert!(line.contains("get_n=0"), "{line}");
        // RESET without metrics is an ERR, and parsing stays strict.
        assert!(dispatch(&format!("GET {key}"), &s, None).starts_with("OK"));
        assert!(dispatch("STATS RESET", &s, None).starts_with("ERR"));
        assert!(dispatch_ctx("STATS RESET extra", &ctx, false).starts_with("ERR"));
    }

    #[test]
    fn durable_dispatch_logs_before_acking() {
        use crate::durability::{DurabilityOptions, Persistence};
        let dir = std::env::temp_dir()
            .join(format!("membig_srv_dur_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let opts = DurabilityOptions {
            fsync: false,
            snapshot_every: std::time::Duration::ZERO,
            snapshot_wal_bytes: 0,
        };
        let (s, persist, _) = Persistence::open(&dir, opts.clone(), 4, || {
            let s = ShardedStore::new(4, 64);
            for k in 1..=20u64 {
                s.insert(crate::workload::record::BookRecord::new(k, 100, 1));
            }
            Ok(Arc::new(s))
        })
        .unwrap();
        let ctx = RequestCtx { store: &s, engine: None, metrics: None, persist: Some(&persist) };
        assert_eq!(dispatch_ctx("UPDATE 1 999 9", &ctx, false), "OK");
        assert_eq!(dispatch_ctx("UPDATE 777 1 1", &ctx, false), "MISS");
        assert_eq!(dispatch_ctx("MUPDATE 2 222 2;3 333 3;888 1 1", &ctx, false),
            "OK applied=2 missed=1");
        // In-batch mutations defer the sync; an explicit group sync lands them.
        assert_eq!(dispatch_ctx("UPDATE 4 444 4", &ctx, true), "OK");
        persist.sync().unwrap();
        assert_eq!(persist.metrics().wal_appends.get(), 6);
        let m = ServerMetrics::new();
        let mctx = RequestCtx { metrics: Some(&m), ..ctx };
        let line = dispatch_ctx("STATS SERVER", &mctx, false);
        assert!(line.contains("wal_appends=6"), "{line}");
        // STATS RESET opens a fresh window for the WAL counters too.
        assert_eq!(dispatch_ctx("STATS RESET", &mctx, false), "OK epoch=1");
        let line = dispatch_ctx("STATS SERVER", &mctx, false);
        assert!(line.contains("wal_appends=0"), "{line}");
        drop(persist);

        // The ack was WAL-backed: a reopen replays every response we gave.
        let (s2, persist2, _) =
            Persistence::open(&dir, opts, 4, || Err("must recover".into())).unwrap();
        assert_eq!(s2.get(1).unwrap().price_cents, 999);
        assert_eq!(s2.get(3).unwrap().quantity, 3);
        assert_eq!(s2.get(4).unwrap().price_cents, 444);
        drop(persist2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tcp_roundtrip_with_concurrent_clients() {
        let (s, spec) = store(1_000);
        let server = Server::new(s.clone(), None);
        let handle = server.spawn("127.0.0.1:0").unwrap();
        let addr = handle.addr;

        std::thread::scope(|scope| {
            for t in 0..4 {
                let spec = &spec;
                scope.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    assert_eq!(c.request("PING").unwrap(), "PONG");
                    for i in (t * 100)..(t * 100 + 100) {
                        let key = spec.record_at(i as u64).isbn13;
                        let resp = c.request(&format!("UPDATE {key} 123 {t}")).unwrap();
                        assert_eq!(resp, "OK");
                        let got = c.request(&format!("GET {key}")).unwrap();
                        assert_eq!(got, format!("OK 123 {t}"));
                    }
                    assert_eq!(c.request("QUIT").unwrap(), "BYE");
                });
            }
        });
        assert!(handle.requests() >= 4 * 202);
        assert!(handle.metrics.conns_accepted.get() >= 4);
        assert!(handle.metrics.allocs_saved.get() >= 4 * 202, "hot path must be pooled");
        assert_eq!(handle.metrics.conns_rejected.get(), 0);
        handle.shutdown();
    }
}
