//! One-server request loop (paper §4.3): a TCP line protocol over the live
//! memstore, demonstrating that a single machine serves reads, updates and
//! PJRT-backed analytics with no distributed infrastructure.
//!
//! Protocol (one request per line, space-separated, ASCII):
//! ```text
//! GET <isbn13>                      → OK <price_cents> <qty> | MISS
//! UPDATE <isbn13> <cents> <qty>     → OK | MISS
//! STATS                             → OK count=<n> value_cents=<v>
//! ANALYTICS                         → OK value=<dollars> mean_price=<p> ... (analytics backend)
//! PING                              → PONG
//! QUIT                              → BYE (closes connection)
//! ```
//! Unknown/malformed input → `ERR <reason>`. One thread per connection:
//! the store is shard-locked, so concurrent clients scale like the
//! pipeline's workers.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::memstore::ShardedStore;
use crate::runtime::AnalyticsService;
use crate::workload::record::StockUpdate;

pub struct Server {
    store: Arc<ShardedStore>,
    engine: Option<Arc<AnalyticsService>>,
    stop: Arc<AtomicBool>,
    pub requests: Arc<AtomicU64>,
}

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    pub requests: Arc<AtomicU64>,
}

impl Server {
    pub fn new(store: Arc<ShardedStore>, engine: Option<Arc<AnalyticsService>>) -> Self {
        Server {
            store,
            engine,
            stop: Arc::new(AtomicBool::new(false)),
            requests: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Bind and serve on a background thread; returns a handle for shutdown.
    pub fn spawn(self, bind: &str) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let stop = self.stop.clone();
        let requests = self.requests.clone();
        let join = std::thread::spawn(move || self.accept_loop(listener));
        Ok(ServerHandle { addr, stop, join: Some(join), requests })
    }

    fn accept_loop(self, listener: TcpListener) {
        listener.set_nonblocking(false).ok();
        // Accept with a timeout-ish pattern: check `stop` between clients by
        // using a short socket timeout on accept via non-blocking + sleep.
        listener.set_nonblocking(true).ok();
        let mut workers = Vec::new();
        while !self.stop.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let store = self.store.clone();
                    let engine = self.engine.clone();
                    let stop = self.stop.clone();
                    let requests = self.requests.clone();
                    workers.push(std::thread::spawn(move || {
                        let _ = handle_client(stream, &store, engine.as_ref(), &stop, &requests);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
        for w in workers {
            let _ = w.join();
        }
    }
}

impl ServerHandle {
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn handle_client(
    stream: TcpStream,
    store: &Arc<ShardedStore>,
    engine: Option<&Arc<AnalyticsService>>,
    stop: &AtomicBool,
    requests: &AtomicU64,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(std::time::Duration::from_millis(200)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Acquire) {
            return Ok(());
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(e) => return Err(e),
        }
        requests.fetch_add(1, Ordering::Relaxed);
        let response = dispatch(line.trim(), store, engine);
        out.write_all(response.as_bytes())?;
        out.write_all(b"\n")?;
        if line.trim() == "QUIT" {
            return Ok(());
        }
    }
}

/// Parse + execute one request line (separated out for direct unit tests).
pub fn dispatch(line: &str, store: &Arc<ShardedStore>, engine: Option<&Arc<AnalyticsService>>) -> String {
    let mut parts = line.split_ascii_whitespace();
    match parts.next() {
        Some("GET") => match parts.next().and_then(|k| k.parse::<u64>().ok()) {
            Some(key) => match store.get(key) {
                Some(r) => format!("OK {} {}", r.price_cents, r.quantity),
                None => "MISS".into(),
            },
            None => "ERR GET expects <isbn13>".into(),
        },
        Some("UPDATE") => {
            let key = parts.next().and_then(|k| k.parse::<u64>().ok());
            let cents = parts.next().and_then(|k| k.parse::<u64>().ok());
            let qty = parts.next().and_then(|k| k.parse::<u32>().ok());
            match (key, cents, qty) {
                (Some(k), Some(c), Some(q)) => {
                    let u = StockUpdate { isbn13: k, new_price_cents: c, new_quantity: q };
                    if store.apply(&u) {
                        "OK".into()
                    } else {
                        "MISS".into()
                    }
                }
                _ => "ERR UPDATE expects <isbn13> <cents> <qty>".into(),
            }
        }
        Some("STATS") => {
            let (n, v) = store.value_sum_cents();
            format!("OK count={n} value_cents={v}")
        }
        Some("ANALYTICS") => match engine {
            None => "ERR analytics engine not loaded".into(),
            Some(eng) => match eng.analytics_for_store(Arc::clone(store), Vec::new()) {
                Ok(r) => format!(
                    "OK value={:.2} count={} mean_price={:.4} price_min={:.2} price_max={:.2}",
                    r.stats.total_value,
                    r.stats.count,
                    r.stats.mean_price,
                    r.stats.price_min,
                    r.stats.price_max
                ),
                Err(e) => format!("ERR {e}"),
            },
        },
        Some("PING") => "PONG".into(),
        Some("QUIT") => "BYE".into(),
        Some(other) => format!("ERR unknown command '{other}'"),
        None => "ERR empty request".into(),
    }
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn request(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        Ok(resp.trim_end().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::DatasetSpec;

    fn store(n: u64) -> (Arc<ShardedStore>, DatasetSpec) {
        let spec = DatasetSpec { records: n, ..Default::default() };
        let s = Arc::new(ShardedStore::new(4, 1 << 10));
        for r in spec.iter() {
            s.insert(r);
        }
        (s, spec)
    }

    #[test]
    fn dispatch_get_update_stats() {
        let (s, spec) = store(100);
        let key = spec.record_at(5).isbn13;
        let rec = spec.record_at(5);
        assert_eq!(
            dispatch(&format!("GET {key}"), &s, None),
            format!("OK {} {}", rec.price_cents, rec.quantity)
        );
        assert_eq!(dispatch("GET 42", &s, None), "MISS");
        assert_eq!(dispatch(&format!("UPDATE {key} 999 7"), &s, None), "OK");
        assert_eq!(dispatch(&format!("GET {key}"), &s, None), "OK 999 7");
        let (n, v) = s.value_sum_cents();
        assert_eq!(dispatch("STATS", &s, None), format!("OK count={n} value_cents={v}"));
    }

    #[test]
    fn dispatch_error_paths() {
        let (s, _) = store(10);
        assert!(dispatch("GET", &s, None).starts_with("ERR"));
        assert!(dispatch("GET notanumber", &s, None).starts_with("ERR"));
        assert!(dispatch("UPDATE 1 2", &s, None).starts_with("ERR"));
        assert!(dispatch("BOGUS", &s, None).starts_with("ERR"));
        assert!(dispatch("", &s, None).starts_with("ERR"));
        assert!(dispatch("ANALYTICS", &s, None).starts_with("ERR"));
        assert_eq!(dispatch("PING", &s, None), "PONG");
    }

    #[test]
    fn tcp_roundtrip_with_concurrent_clients() {
        let (s, spec) = store(1_000);
        let server = Server::new(s.clone(), None);
        let handle = server.spawn("127.0.0.1:0").unwrap();
        let addr = handle.addr;

        std::thread::scope(|scope| {
            for t in 0..4 {
                let spec = &spec;
                scope.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    assert_eq!(c.request("PING").unwrap(), "PONG");
                    for i in (t * 100)..(t * 100 + 100) {
                        let key = spec.record_at(i as u64).isbn13;
                        let resp = c.request(&format!("UPDATE {key} 123 {t}")).unwrap();
                        assert_eq!(resp, "OK");
                        let got = c.request(&format!("GET {key}")).unwrap();
                        assert_eq!(got, format!("OK 123 {t}"));
                    }
                    assert_eq!(c.request("QUIT").unwrap(), "BYE");
                });
            }
        });
        assert!(handle.requests.load(Ordering::Relaxed) >= 4 * 202);
        handle.shutdown();
    }
}
