//! One-server request loop (paper §4.3): a TCP line protocol over the live
//! memstore, demonstrating that a single machine serves reads, updates and
//! PJRT-backed analytics with no distributed infrastructure.
//!
//! Protocol (one request per line, space-separated, ASCII; trailing tokens
//! after a complete request are rejected):
//! ```text
//! GET <isbn13>                  → OK <price_cents> <qty> | MISS
//! UPDATE <isbn13> <cents> <qty> → OK | MISS
//! MGET <k1> <k2> ...            → OK <n> <price,qty|MISS> ...  (input order)
//! MUPDATE <k c q>;<k c q>;...   → OK applied=<a> missed=<m>
//! BATCH <n>                     → n follow-up request lines, answered with
//!                                 n response lines in one write
//! STATS                         → OK count=<n> value_cents=<v> conns_...
//! STATS SERVER                  → OK <conn + reactor counters + per-verb
//!                                 latency + read-path/WAL/snapshot gauges>
//! STATS RESET                   → OK epoch=<e> (fresh measurement window)
//! ANALYTICS                     → OK value=<dollars> ... (analytics backend)
//! HEALTH                        → ok | degraded: <reason>[,<reason>...]
//! PING                          → PONG
//! QUIT                          → BYE (closes connection)
//! ```
//! Unknown/malformed input → `ERR <reason>`.
//!
//! Topology (Linux): an **event-driven reactor core** (`reactor` module) —
//! one acceptor blocking in its own epoll, and `ServerConfig::reactors`
//! reactor threads (default = cores), each owning an epoll instance (raw
//! `epoll_create1`/`epoll_ctl`/`epoll_wait` + `eventfd`, hand-declared in
//! the `sys` module — zero external crates), nonblocking sockets, and a slab of
//! per-connection state machines. Connections are dealt round-robin across
//! reactors at accept time; concurrent-connection capacity is decoupled
//! from thread count, and an idle connection costs zero wakeups between
//! events (idle deadlines live on a per-reactor lazy timer wheel).
//! Responses go through a bounded per-connection write buffer with
//! `EPOLLOUT`-driven backpressure: a client that stops reading gets its
//! buffer capped and the connection closed (`backpressure_closes`) instead
//! of pinning a thread inside a socket write timeout. The bounded
//! [`pool::WorkerPool`] survives as the executor for **blocking verbs** —
//! `ANALYTICS` and, with durability on, the mutations whose group commit
//! fsyncs — so reactor threads never block on disk or the analytics
//! engine. Admission control is unchanged: connections past
//! [`ServerConfig::max_conns`] are refused with `ERR server busy`.
//!
//! On non-Linux hosts the portable blocking front end (`fallback` module) —
//! acceptor + `WorkerPool` over whole connections, read-timeout ticks —
//! serves the identical wire protocol; the reactor counters then read 0.
//!
//! The batch verbs execute shard-affinely ([`batch`]): the engine's
//! `get_many`/`apply_many` pre-route keys and visit each shard once per
//! batch. `GET`/`MGET` read the memstore **lock-free** (seqlock,
//! `memstore::shard`), so read throughput scales with reactor threads.
//!
//! Storage: every serving path holds an `Arc<dyn `[`StorageEngine`]`>` —
//! the pure-memory store or the larger-than-RAM tier
//! (`storage::tiered`, `--memstore-budget-mb`). A spill-enabled engine's
//! point reads can touch disk and its updates can promote from disk or
//! trigger a spill, so the reactor classifies `GET`/`MGET`/`UPDATE`/
//! `MUPDATE`/`STATS` as blocking (pool hop, like `ANALYTICS`) exactly
//! when [`StorageEngine::spill_enabled`] reports it.
//!
//! Hot path allocation discipline: request lines accumulate into a reusable
//! per-connection byte buffer and are UTF-8-validated **once per line** (no
//! per-chunk decode), the tokenizer works on borrowed slices, and responses
//! are formatted with an integer byte formatter into a pooled per-connection
//! buffer flushed opportunistically (one write syscall per response batch).
//! Steady state the request/response cycle of the point verbs allocates
//! nothing; the `allocs_saved` counter tracks responses served this way.
//!
//! Durability: built with [`Server::with_persistence`], every mutation
//! (`UPDATE`/`MUPDATE`/`BATCH` payload) is WAL-logged through
//! [`durability::Persistence`](crate::durability::Persistence) *before* it
//! is acknowledged — one group sync per request batch (`BATCH` defers each
//! line's sync and issues exactly one before the group's responses are
//! released). Without a persistence layer the request path is byte-for-byte
//! the old RAM-only one.

pub mod batch;
#[cfg(not(target_os = "linux"))]
mod fallback;
pub mod pool;
mod procs;
mod reactor;
mod sys;

#[cfg(target_os = "linux")]
pub use reactor::raise_nofile_limit;

#[cfg(target_os = "linux")]
pub use sys::{free_disk_bytes, install_shutdown_handler, shutdown_requested};

/// Non-Linux stub: no statfs binding — the serve preflight simply skips
/// its advisory free-disk warning.
#[cfg(not(target_os = "linux"))]
pub fn free_disk_bytes(_path: &std::path::Path) -> Option<u64> {
    None
}

/// Non-Linux stub: no raw signal handling, `serve` only stops by kill (the
/// pre-PR-9 behavior on every platform).
#[cfg(not(target_os = "linux"))]
pub fn install_shutdown_handler() -> std::io::Result<()> {
    Ok(())
}

/// Non-Linux stub paired with [`install_shutdown_handler`].
#[cfg(not(target_os = "linux"))]
pub fn shutdown_requested() -> bool {
    false
}

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::durability::Persistence;
use crate::ipc::ServingPool;
use crate::metrics::ServerMetrics;
use crate::replication::ReplState;
use crate::runtime::AnalyticsService;
use crate::storage::engine::StorageEngine;
use crate::util::fmt::push_u64;
use crate::workload::record::StockUpdate;

/// Tunables for the request front end.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Blocking-verb executor threads (`ANALYTICS`, durable group-commit
    /// fsync). On non-Linux hosts this is the whole front end: each worker
    /// owns one connection at a time.
    pub workers: usize,
    /// Admission limit on live connections; beyond it new sockets get
    /// `ERR server busy` and are closed.
    pub max_conns: usize,
    /// Reactor (event-loop) threads. 0 = one per core. Ignored by the
    /// non-Linux fallback front end.
    pub reactors: usize,
    /// A connection that completes no request within this window is closed.
    /// Partial input does not extend it, so a drip-feeding client cannot
    /// hold its admission slot forever.
    pub idle_timeout: Duration,
    /// Hard cap on un-flushed response bytes buffered per connection. A
    /// peer that stops reading past this is disconnected (and counted in
    /// `backpressure_closes`) instead of pinning memory or — pre-reactor —
    /// a worker thread inside a socket write timeout.
    pub write_buf_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ServerConfig {
            // Blocking verbs are rare but latency-heavy (fsync, analytics);
            // a floor of 4 keeps them overlapped on small hosts.
            workers: cores.max(4),
            max_conns: 1024,
            reactors: 0,
            idle_timeout: Duration::from_secs(30),
            // Comfortably above the largest single BATCH response (a 4 MiB
            // payload answers in less than its own size), so only a
            // genuinely non-reading client ever hits it.
            write_buf_cap: 8 << 20,
        }
    }
}

pub struct Server {
    store: Arc<dyn StorageEngine>,
    engine: Option<Arc<AnalyticsService>>,
    persist: Option<Arc<Persistence>>,
    /// Multi-process backend (`serve --processes N`): when set, the data
    /// verbs route to shard-owning worker processes instead of `store`.
    procs: Option<Arc<ServingPool>>,
    /// Replication role + metrics (`--replicate-listen` / `--standby-of`).
    /// While the role is standby, every mutation answers
    /// `ERR readonly standby`; `None` leaves the wire byte-identical to a
    /// replication-free build.
    repl: Option<Arc<ReplState>>,
    stop: Arc<AtomicBool>,
    pub metrics: Arc<ServerMetrics>,
    config: ServerConfig,
}

pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<ServerMetrics>,
    /// Wakes the acceptor out of its epoll wait so shutdown is immediate.
    #[cfg(target_os = "linux")]
    wake: Option<Arc<sys::EventFd>>,
}

impl Server {
    pub fn new(store: Arc<dyn StorageEngine>, engine: Option<Arc<AnalyticsService>>) -> Self {
        Self::with_config(store, engine, ServerConfig::default())
    }

    pub fn with_config(
        store: Arc<dyn StorageEngine>,
        engine: Option<Arc<AnalyticsService>>,
        config: ServerConfig,
    ) -> Self {
        Self::with_persistence(store, engine, config, None)
    }

    /// Full constructor: a server whose mutations are WAL-logged and
    /// group-committed through `persist` before they are acknowledged.
    /// The store behind `persist` must be the same `store` passed here —
    /// the persistence layer applies mutations itself so the log and the
    /// memory image can never diverge.
    pub fn with_persistence(
        store: Arc<dyn StorageEngine>,
        engine: Option<Arc<AnalyticsService>>,
        mut config: ServerConfig,
        persist: Option<Arc<Persistence>>,
    ) -> Self {
        // Clamp here so the admission check, the pool and the reactors all
        // agree on the resolved values.
        config.workers = config.workers.max(1);
        config.max_conns = config.max_conns.max(1);
        if config.reactors == 0 {
            config.reactors =
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        }
        Server {
            store,
            engine,
            persist,
            procs: None,
            repl: None,
            stop: Arc::new(AtomicBool::new(false)),
            metrics: Arc::new(ServerMetrics::new()),
            config,
        }
    }

    /// Attach replication state: the role gate for mutations plus the
    /// `repl_*` metrics surfaced by `STATS SERVER`.
    pub fn set_replication(&mut self, repl: Arc<ReplState>) {
        self.repl = Some(repl);
    }

    /// Multi-process serving (`serve --processes N`): the data set lives in
    /// `procs`' shard-owning worker processes, and every data verb is an
    /// RPC to the owning worker(s). The placeholder store only backs the
    /// shared connection machinery — the procs dispatcher intercepts every
    /// verb that would read it. Analytics and durability are unavailable in
    /// this mode (rejected by `Config::validated`).
    pub fn with_procs(procs: Arc<ServingPool>, config: ServerConfig) -> Self {
        let mut server = Self::with_persistence(
            crate::storage::engine::placeholder_engine(),
            None,
            config,
            None,
        );
        server.procs = Some(procs);
        server
    }

    /// Bind and serve on a background thread; returns a handle for shutdown.
    pub fn spawn(self, bind: &str) -> std::io::Result<ServerHandle> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        self.spawn_on(listener, addr)
    }

    #[cfg(target_os = "linux")]
    fn spawn_on(
        self,
        listener: TcpListener,
        addr: std::net::SocketAddr,
    ) -> std::io::Result<ServerHandle> {
        let stop = self.stop.clone();
        let metrics = self.metrics.clone();
        let wake = Arc::new(sys::EventFd::new()?);
        let front = reactor::Frontend::build(
            self.store,
            self.engine,
            self.persist,
            self.procs,
            self.repl,
            metrics.clone(),
            stop.clone(),
            self.config,
        )?;
        let wake2 = wake.clone();
        let join = std::thread::Builder::new()
            .name("membig-acceptor".into())
            .spawn(move || reactor::accept_loop(listener, wake2, front))?;
        Ok(ServerHandle { addr, stop, join: Some(join), metrics, wake: Some(wake) })
    }

    #[cfg(not(target_os = "linux"))]
    fn spawn_on(
        self,
        listener: TcpListener,
        addr: std::net::SocketAddr,
    ) -> std::io::Result<ServerHandle> {
        let stop = self.stop.clone();
        let metrics = self.metrics.clone();
        let join = std::thread::Builder::new()
            .name("membig-acceptor".into())
            .spawn(move || self.accept_loop(listener))?;
        Ok(ServerHandle { addr, stop, join: Some(join), metrics })
    }
}

impl ServerHandle {
    /// Total requests executed (single verbs + batch payload lines).
    pub fn requests(&self) -> u64 {
        self.metrics.requests.get()
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Release);
        #[cfg(target_os = "linux")]
        if let Some(w) = &self.wake {
            w.signal();
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }

    pub fn shutdown(mut self) {
        self.stop_and_join();
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Turn away a connection over the admission limit: answer, half-close, and
/// briefly drain so a client that pipelined a request at connect still
/// receives the busy line instead of an RST that may discard it. Runs on a
/// short-lived helper thread — the acceptor must never block on a rejected
/// peer, especially under the overload that causes rejections.
pub(crate) fn reject_busy(stream: TcpStream) {
    let reject = move || {
        let mut stream = stream;
        stream.set_nonblocking(false).ok();
        let _ = stream.write_all(b"ERR server busy (connection limit reached)\n");
        let _ = stream.shutdown(Shutdown::Write);
        // One short read only — never a wait the client controls.
        stream.set_read_timeout(Some(Duration::from_millis(10))).ok();
        let mut sink = [0u8; 256];
        let _ = stream.read(&mut sink);
    };
    // If the spawn itself fails (thread exhaustion) the closure is dropped
    // and with it the stream: a hard close, which is the right fallback.
    let _ = std::thread::Builder::new().name("server-reject".into()).spawn(reject);
}

/// Hard cap on one request line. MGET at MAX_BATCH keys is ~140 KiB, so
/// 1 MiB leaves ample headroom while bounding what a newline-less client
/// can pin in memory per connection.
pub(crate) const MAX_LINE_BYTES: usize = 1 << 20;

/// Per-connection pool capacity retained across requests. Buffers grow to
/// whatever one request needs, then are trimmed back to this after any
/// oversized use — one maximum-size BATCH (4 MiB payload + responses) must
/// not pin megabytes for the rest of a long-lived connection's life.
const RETAIN_BYTES: usize = 64 << 10;

/// Trim a pooled buffer that ballooned past the retention cap.
pub(crate) fn trim_pool(buf: &mut Vec<u8>) {
    if buf.capacity() > RETAIN_BYTES {
        buf.shrink_to(RETAIN_BYTES);
    }
}

/// Reusable per-connection buffers for the BATCH framing path. Steady state
/// a connection's batches allocate nothing: payload bytes, line bounds and
/// the group response all live in these pools.
#[derive(Default)]
pub(crate) struct BatchScratch {
    /// One reused accumulator for the (fallback) payload read loop.
    pub(crate) line: Vec<u8>,
    /// Concatenated raw payload lines.
    pub(crate) payload: Vec<u8>,
    /// End offset of each payload line within `payload`.
    pub(crate) bounds: Vec<usize>,
    /// Response bytes for the whole group — released in one piece.
    pub(crate) resp: Vec<u8>,
}

impl BatchScratch {
    /// Empty every pool, then trim ballooned capacity. Clearing first
    /// matters: `shrink_to` cannot drop capacity below `len`, so trimming
    /// a buffer still holding the (already-written) group response would
    /// be a no-op. Contents are dead by the time this runs.
    pub(crate) fn trim(&mut self) {
        self.line.clear();
        self.payload.clear();
        self.resp.clear();
        self.bounds.clear();
        trim_pool(&mut self.line);
        trim_pool(&mut self.payload);
        trim_pool(&mut self.resp);
        // `bounds` holds one usize per payload line (≤ MAX_BATCH entries);
        // trim it by the same byte budget as the byte pools.
        if self.bounds.capacity() * std::mem::size_of::<usize>() > RETAIN_BYTES {
            self.bounds.shrink_to(RETAIN_BYTES / std::mem::size_of::<usize>());
        }
    }
}

/// Count + answer a request line that failed UTF-8 validation — the one
/// copy of this accounting, charged to the `other` latency histogram so
/// `requests == Σ verb_n` holds across STATS windows.
pub(crate) fn reply_invalid_utf8(metrics: &ServerMetrics, out: &mut Vec<u8>) {
    metrics.requests.inc();
    metrics.latency_for("").record(0);
    out.extend_from_slice(b"ERR request is not valid UTF-8\n");
}

/// Execute one request line with its per-request accounting (request count,
/// per-verb latency), appending the newline-terminated response to `out` —
/// shared by the reactor's inline path, the blocking pool and the fallback
/// front end so the bookkeeping cannot drift between them.
#[allow(clippy::too_many_arguments)] // the executor sits below RequestCtx
pub(crate) fn execute_one_into(
    req: &str,
    store: &Arc<dyn StorageEngine>,
    engine: Option<&Arc<AnalyticsService>>,
    persist: Option<&Persistence>,
    metrics: &ServerMetrics,
    in_batch: bool,
    procs: Option<&ServingPool>,
    repl: Option<&ReplState>,
    out: &mut Vec<u8>,
) {
    metrics.requests.inc();
    let verb = req.split_ascii_whitespace().next().unwrap_or("");
    // A nested BATCH payload line dispatches to an ERR; charge it to
    // `other` so batch_latency keeps whole-group samples only.
    let verb = if in_batch && verb == "BATCH" { "" } else { verb };
    let t0 = Instant::now();
    let ctx = RequestCtx { store, engine, metrics: Some(metrics), persist, procs, repl };
    dispatch_into(req, &ctx, in_batch, out);
    metrics.latency_for(verb).record_duration(t0.elapsed());
}

/// Execute a fully-accumulated `BATCH` group: `payload` holds the raw
/// payload lines back to back, `bounds` their end offsets. Every line runs
/// with its sync deferred, then — with durability on — exactly one group
/// commit lands the whole batch before the responses are released to the
/// caller's buffer. Returns `Ok(quit)` (the group contained `QUIT`), or
/// `Err(())` when the group sync failed: the buffered responses in `resp`
/// must **not** be delivered (they would ack unlogged writes) and the
/// connection must close.
#[allow(clippy::too_many_arguments)] // the executor sits below RequestCtx
pub(crate) fn exec_batch_group(
    payload: &[u8],
    bounds: &[usize],
    store: &Arc<dyn StorageEngine>,
    engine: Option<&Arc<AnalyticsService>>,
    persist: Option<&Persistence>,
    metrics: &ServerMetrics,
    procs: Option<&ServingPool>,
    repl: Option<&ReplState>,
    resp: &mut Vec<u8>,
) -> Result<bool, ()> {
    metrics.batch_sizes.record(bounds.len() as u64);
    // Time execution only: payload accumulation is dominated by client
    // transmission, which would drown the server-work signal the per-verb
    // histograms exist to compare.
    let t0 = Instant::now();
    let mut quit = false;
    if let Some(pool) = procs {
        // Multi-process backend: consecutive point lines coalesce into one
        // Group frame per touched worker instead of one RPC per line.
        quit = procs::exec_batch_lines_grouped(payload, bounds, store, engine, metrics, pool, resp);
    } else {
        let mut start = 0usize;
        for &end in bounds {
            let raw = &payload[start..end];
            start = end;
            // One UTF-8 validation per payload line, on the raw bytes in
            // place.
            match std::str::from_utf8(raw) {
                Ok(s) => {
                    let req = s.trim();
                    execute_one_into(req, store, engine, persist, metrics, true, None, repl, resp);
                    quit = quit || req == "QUIT";
                }
                Err(_) => reply_invalid_utf8(metrics, resp),
            }
        }
    }
    // Group commit: every mutation in the batch deferred its sync to this
    // single call — one fsync per BATCH, issued *before* the group's
    // responses are released.
    if let Some(p) = persist {
        if let Err(e) = p.sync() {
            eprintln!("membig: WAL group sync failed, closing connection: {e}");
            return Err(());
        }
    }
    metrics.batch_latency.record_duration(t0.elapsed());
    Ok(quit)
}

/// Everything a request may touch while executing. Bundled so the dispatch
/// signature stops growing a parameter per subsystem.
#[derive(Clone, Copy)]
pub struct RequestCtx<'a> {
    pub store: &'a Arc<dyn StorageEngine>,
    pub engine: Option<&'a Arc<AnalyticsService>>,
    pub metrics: Option<&'a ServerMetrics>,
    /// When set, `UPDATE`/`MUPDATE` are logged + applied through the
    /// persistence layer (never acknowledged before the WAL has them).
    pub persist: Option<&'a Persistence>,
    /// When set, the data verbs route to the multi-process worker pool
    /// (`serve --processes N`) and `store` is never read.
    pub procs: Option<&'a ServingPool>,
    /// When set, mutations are gated on the replication role (`ERR
    /// readonly standby` while the role is standby) and `STATS SERVER`
    /// carries the `repl_*` counters.
    pub repl: Option<&'a ReplState>,
}

/// [`dispatch_into`] rendered to a `String` — the single test-only
/// convenience wrapper (the PR-4 `dispatch`/`dispatch_with_metrics`/
/// `dispatch_ctx` String surface collapsed into it). The server itself
/// never takes this path — responses go straight into the pooled
/// connection buffer.
#[cfg(test)]
pub(crate) fn dispatch_str(line: &str, ctx: &RequestCtx<'_>, in_batch: bool) -> String {
    let mut out = Vec::with_capacity(64);
    dispatch_into(line, ctx, in_batch, &mut out);
    out.pop(); // the newline dispatch_into frames with
    String::from_utf8(out).expect("responses echo valid-UTF-8 requests")
}

/// Core dispatcher: parse + execute one request line, appending the
/// newline-terminated response to `out`. The hot verbs tokenize the
/// borrowed line and format integers straight into the buffer — no
/// response `String`, no `format!` temporaries. `in_batch` marks a BATCH
/// payload line: its mutations defer their WAL sync to the one group
/// commit `exec_batch_group` issues before the group's responses are
/// released.
pub fn dispatch_into(line: &str, ctx: &RequestCtx<'_>, in_batch: bool, out: &mut Vec<u8>) {
    let RequestCtx { store, engine, metrics, persist, procs, repl } = *ctx;
    let line = line.trim();
    let (verb, rest) = match line.split_once(|c: char| c.is_ascii_whitespace()) {
        Some((v, r)) => (v, r.trim()),
        None => (line, ""),
    };
    // One readonly gate for every front end (reactor, fallback, pool,
    // BATCH payload lines all dispatch through here): while this process
    // is a standby, mutations are refused before they touch the store or
    // the WAL. Promotion flips the role atomic and the very same verbs
    // start succeeding — no reconnect, no server restart.
    if matches!(verb, "UPDATE" | "MUPDATE") && repl.is_some_and(|r| r.is_standby()) {
        out.extend_from_slice(b"ERR readonly standby\n");
        return;
    }
    // Multi-process backend: the data verbs become worker RPCs; everything
    // else (PING/QUIT/BATCH framing errors/unknowns) falls through to the
    // shared arms below, which never read the placeholder store.
    if let Some(pool) = procs {
        if procs::dispatch_procs_into(verb, rest, pool, metrics, out) {
            out.push(b'\n');
            return;
        }
    }
    // Set by the arms whose response was formatted straight into the
    // pooled buffer (no String allocation); accounted once below so the
    // hot/cold classification lives in exactly one place per arm.
    let mut saved = false;
    match verb {
        "GET" => {
            let mut parts = rest.split_ascii_whitespace();
            match (parts.next().and_then(|k| k.parse::<u64>().ok()), parts.next()) {
                (Some(key), None) => {
                    match store.get(key) {
                        Some(r) => {
                            out.extend_from_slice(b"OK ");
                            push_u64(out, r.price_cents);
                            out.push(b' ');
                            push_u64(out, r.quantity as u64);
                        }
                        None => out.extend_from_slice(b"MISS"),
                    }
                    saved = true;
                }
                _ => out.extend_from_slice(b"ERR GET expects exactly <isbn13>"),
            }
        }
        "UPDATE" => {
            let mut parts = rest.split_ascii_whitespace();
            let key = parts.next().and_then(|k| k.parse::<u64>().ok());
            let cents = parts.next().and_then(|k| k.parse::<u64>().ok());
            let qty = parts.next().and_then(|k| k.parse::<u32>().ok());
            match (key, cents, qty, parts.next()) {
                (Some(k), Some(c), Some(q), None) => {
                    let u = StockUpdate { isbn13: k, new_price_cents: c, new_quantity: q };
                    let applied = match persist {
                        // WAL-first: the ack below only happens once the
                        // frame is logged (and synced, outside a BATCH).
                        Some(p) => match p.apply_update(&u, !in_batch) {
                            Ok(applied) => applied,
                            Err(e) => {
                                out.extend_from_slice(format!("ERR durability: {e}").as_bytes());
                                out.push(b'\n');
                                return;
                            }
                        },
                        None => store.apply(&u),
                    };
                    out.extend_from_slice(if applied { b"OK".as_slice() } else { b"MISS" });
                    saved = true;
                }
                _ => out.extend_from_slice(b"ERR UPDATE expects exactly <isbn13> <cents> <qty>"),
            }
        }
        "MGET" => match batch::parse_mget(rest) {
            Ok(keys) => {
                if let Some(m) = metrics {
                    m.batch_sizes.record(keys.len() as u64);
                }
                batch::exec_mget_into(store, &keys, out);
                saved = true;
            }
            Err(e) => out.extend_from_slice(format!("ERR {e}").as_bytes()),
        },
        "MUPDATE" => match batch::parse_mupdate(rest) {
            Ok(ups) => {
                if let Some(m) = metrics {
                    m.batch_sizes.record(ups.len() as u64);
                }
                match persist {
                    // Group commit: the whole MUPDATE is one WAL append
                    // run + one sync (deferred inside a BATCH).
                    Some(p) => match p.apply_many(&ups, !in_batch) {
                        Ok((applied, missed)) => {
                            out.extend_from_slice(b"OK applied=");
                            push_u64(out, applied);
                            out.extend_from_slice(b" missed=");
                            push_u64(out, missed);
                            saved = true;
                        }
                        Err(e) => {
                            out.extend_from_slice(format!("ERR durability: {e}").as_bytes())
                        }
                    },
                    None => {
                        batch::exec_mupdate_into(store, &ups, out);
                        saved = true;
                    }
                }
            }
            Err(e) => out.extend_from_slice(format!("ERR {e}").as_bytes()),
        },
        "STATS" => {
            let mut parts = rest.split_ascii_whitespace();
            match (parts.next(), parts.next()) {
                (None, _) => {
                    let (n, v) = store.value_sum_cents();
                    let mut s = format!("OK count={n} value_cents={v}");
                    if let Some(m) = metrics {
                        s.push_str(&m.stats_suffix());
                    }
                    out.extend_from_slice(s.as_bytes());
                }
                (Some("SERVER"), None) => match metrics {
                    Some(m) => {
                        let mut s = m.stats_server_line();
                        let rs = store.read_stats();
                        s.push_str(&format!(
                            " read_retries={} read_fallbacks={}",
                            rs.retries.get(),
                            rs.fallbacks.get()
                        ));
                        // Engine-specific counters (empty for the pure
                        // memstore; the tier_* block for a tiered engine).
                        s.push_str(&store.stats_suffix());
                        if let Some(p) = persist {
                            s.push_str(&p.stats_suffix());
                            // Storage-health block (`health_*`): the tiered
                            // engine carries its own via stats_suffix above;
                            // the durability layer's rides here.
                            s.push_str(&p.health().stats_suffix());
                        }
                        if let Some(r) = repl {
                            s.push_str(&r.metrics.stats_suffix());
                        }
                        out.extend_from_slice(s.as_bytes());
                    }
                    None => out.extend_from_slice(b"ERR server metrics unavailable"),
                },
                // Fresh measurement window: zero the counters + latency
                // histograms (and the WAL/checkpoint traffic and lock-free
                // read-path counters when present) so consecutive bench
                // runs cannot contaminate each other; the epoch counter
                // marks which window a report belongs to.
                (Some("RESET"), None) => match metrics {
                    Some(m) => {
                        if let Some(p) = persist {
                            p.metrics().reset_epoch_counters();
                            p.health().reset_epoch_counters();
                        }
                        if let Some(r) = repl {
                            r.metrics.reset_epoch_counters();
                        }
                        store.reset_stats_epoch();
                        out.extend_from_slice(format!("OK epoch={}", m.reset_epoch()).as_bytes());
                    }
                    None => out.extend_from_slice(b"ERR server metrics unavailable"),
                },
                _ => out.extend_from_slice(b"ERR STATS expects no argument, SERVER or RESET"),
            }
        }
        "ANALYTICS" => {
            if !rest.is_empty() {
                out.extend_from_slice(b"ERR ANALYTICS takes no arguments");
            } else {
                match engine {
                    None => out.extend_from_slice(b"ERR analytics engine not loaded"),
                    Some(eng) => match eng.analytics_for_store(Arc::clone(store), Vec::new()) {
                        Ok(r) => out.extend_from_slice(
                            format!(
                                "OK value={:.2} count={} mean_price={:.4} price_min={:.2} price_max={:.2}",
                                r.stats.total_value,
                                r.stats.count,
                                r.stats.mean_price,
                                r.stats.price_min,
                                r.stats.price_max
                            )
                            .as_bytes(),
                        ),
                        Err(e) => out.extend_from_slice(format!("ERR {e}").as_bytes()),
                    },
                }
            }
        }
        // One-line storage-health probe (DESIGN.md §16): `ok`, or
        // `degraded: <reasons>` naming every active degradation. Answers
        // from whichever layer owns persistent I/O — the durability stack,
        // or a spill-enabled engine's own health block — and a constant
        // `ok` when neither exists (pure RAM cannot degrade this way).
        "HEALTH" => {
            if rest.is_empty() {
                let line = match (persist, store.health_metrics()) {
                    (Some(p), _) => p.health().health_line(),
                    (None, Some(h)) => h.health_line(),
                    (None, None) => "ok".to_string(),
                };
                out.extend_from_slice(line.as_bytes());
            } else {
                out.extend_from_slice(b"ERR HEALTH takes no arguments");
            }
        }
        "PING" => {
            if rest.is_empty() {
                out.extend_from_slice(b"PONG");
                saved = true;
            } else {
                out.extend_from_slice(b"ERR PING takes no arguments");
            }
        }
        "QUIT" => {
            if rest.is_empty() {
                out.extend_from_slice(b"BYE");
                saved = true;
            } else {
                out.extend_from_slice(b"ERR QUIT takes no arguments");
            }
        }
        // Top-level BATCH framing is handled in the connection loop before
        // dispatch; reaching it here means a nested/out-of-place BATCH.
        "BATCH" => out.extend_from_slice(b"ERR BATCH cannot be nested"),
        "" => out.extend_from_slice(b"ERR empty request"),
        other => out.extend_from_slice(format!("ERR unknown command '{other}'").as_bytes()),
    }
    if saved {
        if let Some(m) = metrics {
            m.allocs_saved.inc();
        }
    }
    out.push(b'\n');
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    pub fn request(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        Ok(resp.trim_end().to_string())
    }

    /// Pipelined batch: one write carrying `BATCH <n>` plus all `lines`,
    /// then `n` response lines read back — one round trip for the group.
    pub fn batch(&mut self, lines: &[String]) -> std::io::Result<Vec<String>> {
        if lines.is_empty() {
            // `BATCH 0` is a protocol error; sending it would desync the
            // reply stream (one ERR line, zero reads here).
            return Ok(Vec::new());
        }
        if lines.len() > batch::MAX_BATCH {
            // The server would reject the header with one ERR line and then
            // treat every payload line as a top-level request — permanently
            // desyncing this connection. Refuse before writing anything.
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("batch of {} exceeds MAX_BATCH={}", lines.len(), batch::MAX_BATCH),
            ));
        }
        if let Some(bad) = lines.iter().find(|l| l.contains('\n')) {
            // An embedded newline would become an extra wire line: the
            // server answers n+1 responses while we read n — same desync.
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("batch line contains embedded newline: {bad:?}"),
            ));
        }
        let mut buf = format!("BATCH {}\n", lines.len());
        for l in lines {
            buf.push_str(l);
            buf.push('\n');
        }
        self.writer.write_all(buf.as_bytes())?;
        let mut out = Vec::with_capacity(lines.len());
        for _ in 0..lines.len() {
            let mut resp = String::new();
            if self.reader.read_line(&mut resp)? == 0 {
                // Server aborted the batch (payload cap, shutdown, ...):
                // surface the truncation instead of fabricating responses.
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    format!("connection closed after {} of {} batch responses", out.len(),
                        lines.len()),
                ));
            }
            out.push(resp.trim_end().to_string());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memstore::ShardedStore;
    use crate::workload::gen::DatasetSpec;

    fn store(n: u64) -> (Arc<dyn StorageEngine>, DatasetSpec) {
        let spec = DatasetSpec { records: n, ..Default::default() };
        let s: Arc<dyn StorageEngine> = Arc::new(ShardedStore::new(4, 1 << 10));
        for r in spec.iter() {
            s.insert(r);
        }
        (s, spec)
    }

    /// Bare dispatch: no metrics, no persistence, no procs.
    fn d(line: &str, s: &Arc<dyn StorageEngine>) -> String {
        let ctx = RequestCtx {
            store: s,
            engine: None,
            metrics: None,
            persist: None,
            procs: None,
            repl: None,
        };
        dispatch_str(line, &ctx, false)
    }

    /// Dispatch with server metrics attached.
    fn dm(line: &str, s: &Arc<dyn StorageEngine>, m: &ServerMetrics) -> String {
        let ctx = RequestCtx {
            store: s,
            engine: None,
            metrics: Some(m),
            persist: None,
            procs: None,
            repl: None,
        };
        dispatch_str(line, &ctx, false)
    }

    #[test]
    fn dispatch_get_update_stats() {
        let (s, spec) = store(100);
        let key = spec.record_at(5).isbn13;
        let rec = spec.record_at(5);
        assert_eq!(
            d(&format!("GET {key}"), &s),
            format!("OK {} {}", rec.price_cents, rec.quantity)
        );
        assert_eq!(d("GET 42", &s), "MISS");
        assert_eq!(d(&format!("UPDATE {key} 999 7"), &s), "OK");
        assert_eq!(d(&format!("GET {key}"), &s), "OK 999 7");
        let (n, v) = s.value_sum_cents();
        assert_eq!(d("STATS", &s), format!("OK count={n} value_cents={v}"));
    }

    #[test]
    fn dispatch_mget_mupdate() {
        let (s, spec) = store(100);
        let a = spec.record_at(1).isbn13;
        let b = spec.record_at(2).isbn13;
        assert_eq!(d(&format!("MUPDATE {a} 100 1;{b} 200 2;42 1 1"), &s),
            "OK applied=2 missed=1");
        assert_eq!(d(&format!("MGET {a} 42 {b}"), &s), "OK 3 100,1 MISS 200,2");
    }

    #[test]
    fn dispatch_into_appends_newline_terminated_responses() {
        // The buffer API the server actually uses: responses accumulate in
        // the pooled buffer, each framed with exactly one newline.
        let (s, spec) = store(10);
        let key = spec.record_at(1).isbn13;
        let rec = spec.record_at(1);
        let ctx = RequestCtx {
            store: &s,
            engine: None,
            metrics: None,
            persist: None,
            procs: None,
            repl: None,
        };
        let mut out = Vec::new();
        dispatch_into("PING", &ctx, false, &mut out);
        dispatch_into(&format!("GET {key}"), &ctx, false, &mut out);
        dispatch_into("GET 424242", &ctx, false, &mut out);
        let text = String::from_utf8(out).unwrap();
        assert_eq!(
            text,
            format!("PONG\nOK {} {}\nMISS\n", rec.price_cents, rec.quantity)
        );
    }

    #[test]
    fn dispatch_error_paths() {
        let (s, _) = store(10);
        // Short / malformed argument lists.
        assert!(d("GET", &s).starts_with("ERR"));
        assert!(d("GET notanumber", &s).starts_with("ERR"));
        assert!(d("UPDATE 1 2", &s).starts_with("ERR"));
        assert!(d("MGET", &s).starts_with("ERR"));
        assert!(d("MGET a b", &s).starts_with("ERR"));
        assert!(d("MUPDATE", &s).starts_with("ERR"));
        assert!(d("MUPDATE 1 2", &s).starts_with("ERR"));
        assert!(d("BOGUS", &s).starts_with("ERR"));
        assert!(d("", &s).starts_with("ERR"));
        assert!(d("ANALYTICS", &s).starts_with("ERR"));
        assert!(d("BATCH 2", &s).starts_with("ERR"));
        // Trailing garbage is rejected on every verb.
        assert!(d("GET 1 extra", &s).starts_with("ERR"));
        assert!(d("UPDATE 1 2 3 junk", &s).starts_with("ERR"));
        assert!(d("MUPDATE 1 2 3 junk", &s).starts_with("ERR"));
        assert!(d("STATS BOGUS", &s).starts_with("ERR"));
        assert!(d("STATS SERVER extra", &s).starts_with("ERR"));
        assert!(d("PING please", &s).starts_with("ERR"));
        assert!(d("QUIT now", &s).starts_with("ERR"));
        assert!(d("ANALYTICS now", &s).starts_with("ERR"));
        assert_eq!(d("PING", &s), "PONG");
    }

    #[test]
    fn stats_with_metrics_appends_connection_counters() {
        let (s, _) = store(10);
        let m = ServerMetrics::new();
        m.conns_accepted.inc();
        let resp = dm("STATS", &s, &m);
        assert!(resp.starts_with("OK count=10"), "{resp}");
        assert!(resp.contains("conns_accepted=1"), "{resp}");
        let resp = dm("STATS SERVER", &s, &m);
        assert!(resp.starts_with("OK conns_accepted=1"), "{resp}");
        assert!(resp.contains("read_retries=0"), "{resp}");
        assert!(resp.contains("read_fallbacks=0"), "{resp}");
        assert!(resp.contains("epoll_wakeups=0"), "{resp}");
        assert!(resp.contains("backpressure_closes=0"), "{resp}");
        assert_eq!(d("STATS SERVER", &s), "ERR server metrics unavailable");
    }

    #[test]
    fn hot_verbs_count_alloc_free_responses() {
        let (s, spec) = store(10);
        let key = spec.record_at(1).isbn13;
        let m = ServerMetrics::new();
        for req in [
            format!("GET {key}"),
            "GET 4242".into(),      // MISS is still alloc-free
            format!("UPDATE {key} 5 5"),
            format!("MGET {key} 4242"),
            format!("MUPDATE {key} 6 6"),
            "PING".into(),
        ] {
            dm(&req, &s, &m);
        }
        assert_eq!(m.allocs_saved.get(), 6);
        // Cold paths (STATS, errors) are not counted.
        dm("STATS", &s, &m);
        dm("GET not_a_key", &s, &m);
        assert_eq!(m.allocs_saved.get(), 6);
    }

    #[test]
    fn stats_reset_starts_a_fresh_window() {
        let (s, spec) = store(10);
        let m = ServerMetrics::new();
        let key = spec.record_at(1).isbn13;
        let ctx = RequestCtx {
            store: &s,
            engine: None,
            metrics: Some(&m),
            persist: None,
            procs: None,
            repl: None,
        };
        m.latency_for("GET").record(123);
        m.requests.add(4);
        s.read_stats().retries.add(9);
        assert_eq!(dispatch_str("STATS RESET", &ctx, false), "OK epoch=1");
        assert_eq!(m.get_latency.count(), 0);
        assert_eq!(m.requests.get(), 0);
        assert_eq!(s.read_stats().retries.get(), 0, "read-path counters join the epoch");
        let line = dispatch_str("STATS SERVER", &ctx, false);
        assert!(line.contains("epoch=1"), "{line}");
        assert!(line.contains("get_n=0"), "{line}");
        // RESET without metrics is an ERR, and parsing stays strict.
        assert!(d(&format!("GET {key}"), &s).starts_with("OK"));
        assert!(d("STATS RESET", &s).starts_with("ERR"));
        assert!(dispatch_str("STATS RESET extra", &ctx, false).starts_with("ERR"));
    }

    #[test]
    fn exec_batch_group_runs_lines_and_reports_quit() {
        let (s, spec) = store(20);
        let m = ServerMetrics::new();
        let key = spec.record_at(2).isbn13;
        // Payload of three lines, the last one QUIT; bounds mark line ends.
        let mut payload = Vec::new();
        let mut bounds = Vec::new();
        for line in [format!("GET {key}"), format!("UPDATE {key} 77 7"), "QUIT".to_string()] {
            payload.extend_from_slice(line.as_bytes());
            bounds.push(payload.len());
        }
        let mut resp = Vec::new();
        let quit =
            exec_batch_group(&payload, &bounds, &s, None, None, &m, None, None, &mut resp)
                .unwrap();
        assert!(quit);
        let text = String::from_utf8(resp).unwrap();
        let rec = spec.record_at(2);
        assert_eq!(
            text,
            format!("OK {} {}\nOK\nBYE\n", rec.price_cents, rec.quantity)
        );
        assert_eq!(s.get(key).unwrap().price_cents, 77);
        assert_eq!(m.requests.get(), 3, "each payload line is one request");
        assert_eq!(m.batch_sizes.count(), 1);
        assert_eq!(m.batch_latency.count(), 1);
        // An invalid-UTF-8 payload line ERRs individually; the group lives.
        let mut payload = Vec::new();
        let mut bounds = Vec::new();
        payload.extend_from_slice(b"PING");
        bounds.push(payload.len());
        payload.extend_from_slice(b"GET \xc3\x28");
        bounds.push(payload.len());
        let mut resp = Vec::new();
        let quit =
            exec_batch_group(&payload, &bounds, &s, None, None, &m, None, None, &mut resp)
                .unwrap();
        assert!(!quit);
        let text = String::from_utf8(resp).unwrap();
        assert!(text.starts_with("PONG\nERR"), "{text}");
    }

    #[test]
    fn durable_dispatch_logs_before_acking() {
        use crate::durability::{DurabilityOptions, Persistence};
        let dir = std::env::temp_dir()
            .join(format!("membig_srv_dur_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let opts = DurabilityOptions {
            fsync: false,
            snapshot_every: std::time::Duration::ZERO,
            snapshot_wal_bytes: 0,
        };
        let (s, persist, _) = Persistence::open(&dir, opts.clone(), 4, || {
            let s = ShardedStore::new(4, 64);
            for k in 1..=20u64 {
                s.insert(crate::workload::record::BookRecord::new(k, 100, 1));
            }
            Ok(Arc::new(s))
        })
        .unwrap();
        // Struct-field init does not unsize-coerce: rebind through the trait.
        let s: Arc<dyn StorageEngine> = s;
        let ctx = RequestCtx {
            store: &s,
            engine: None,
            metrics: None,
            persist: Some(&persist),
            procs: None,
            repl: None,
        };
        assert_eq!(dispatch_str("UPDATE 1 999 9", &ctx, false), "OK");
        assert_eq!(dispatch_str("UPDATE 777 1 1", &ctx, false), "MISS");
        assert_eq!(dispatch_str("MUPDATE 2 222 2;3 333 3;888 1 1", &ctx, false),
            "OK applied=2 missed=1");
        // In-batch mutations defer the sync; an explicit group sync lands them.
        assert_eq!(dispatch_str("UPDATE 4 444 4", &ctx, true), "OK");
        persist.sync().unwrap();
        assert_eq!(persist.metrics().wal_appends.get(), 6);
        let m = ServerMetrics::new();
        let mctx = RequestCtx { metrics: Some(&m), ..ctx };
        let line = dispatch_str("STATS SERVER", &mctx, false);
        assert!(line.contains("wal_appends=6"), "{line}");
        // STATS RESET opens a fresh window for the WAL counters too.
        assert_eq!(dispatch_str("STATS RESET", &mctx, false), "OK epoch=1");
        let line = dispatch_str("STATS SERVER", &mctx, false);
        assert!(line.contains("wal_appends=0"), "{line}");
        drop(persist);

        // The ack was WAL-backed: a reopen replays every response we gave.
        let (s2, persist2, _) =
            Persistence::open(&dir, opts, 4, || Err("must recover".into())).unwrap();
        assert_eq!(s2.get(1).unwrap().price_cents, 999);
        assert_eq!(s2.get(3).unwrap().quantity, 3);
        assert_eq!(s2.get(4).unwrap().price_cents, 444);
        drop(persist2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn health_verb_and_stats_carry_the_health_block() {
        use crate::durability::{DurabilityOptions, Persistence};
        // Pure memory: nothing can degrade, HEALTH is a constant ok.
        let (s, _) = store(10);
        assert_eq!(d("HEALTH", &s), "ok");
        assert!(d("HEALTH now", &s).starts_with("ERR"));

        // Durability attached: HEALTH answers from the persistence layer's
        // health block and STATS SERVER renders the health_* keys.
        let dir = std::env::temp_dir()
            .join(format!("membig_srv_health_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let opts = DurabilityOptions {
            fsync: false,
            snapshot_every: std::time::Duration::ZERO,
            snapshot_wal_bytes: 0,
        };
        let (ps, persist, _) =
            Persistence::open(&dir, opts, 2, || Ok(Arc::new(ShardedStore::new(2, 64))))
                .unwrap();
        let ps: Arc<dyn StorageEngine> = ps;
        let m = ServerMetrics::new();
        let ctx = RequestCtx {
            store: &ps,
            engine: None,
            metrics: Some(&m),
            persist: Some(&persist),
            procs: None,
            repl: None,
        };
        assert_eq!(dispatch_str("HEALTH", &ctx, false), "ok");
        let line = dispatch_str("STATS SERVER", &ctx, false);
        assert!(line.contains(" health_degraded=0"), "{line}");
        assert!(line.contains(" health_wal_errors=0"), "{line}");

        // Flip a degradation by hand: both surfaces must report it.
        persist.health().snapshot_backoff.set(1);
        persist.health().snapshot_errors.inc();
        assert_eq!(dispatch_str("HEALTH", &ctx, false), "degraded: snapshot-backoff");
        let line = dispatch_str("STATS SERVER", &ctx, false);
        assert!(line.contains(" health_degraded=1"), "{line}");
        assert!(line.contains(" health_snapshot_errors=1"), "{line}");

        // STATS RESET zeroes the error counters but never the state flags:
        // a reset must not make a degraded server look healthy.
        assert_eq!(dispatch_str("STATS RESET", &ctx, false), "OK epoch=1");
        let line = dispatch_str("STATS SERVER", &ctx, false);
        assert!(line.contains(" health_snapshot_errors=0"), "{line}");
        assert!(line.contains(" health_degraded=1"), "{line}");
        assert_eq!(dispatch_str("HEALTH", &ctx, false), "degraded: snapshot-backoff");
        persist.health().snapshot_backoff.set(0);
        assert_eq!(dispatch_str("HEALTH", &ctx, false), "ok");
        drop(persist);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn standby_role_gates_mutations_until_promotion() {
        let (s, spec) = store(10);
        let key = spec.record_at(1).isbn13;
        let repl = crate::replication::ReplState::standby();
        let m = ServerMetrics::new();
        let ctx = RequestCtx {
            store: &s,
            engine: None,
            metrics: Some(&m),
            persist: None,
            procs: None,
            repl: Some(&*repl),
        };
        // Reads flow; every mutation verb is refused with the exact line.
        assert!(dispatch_str(&format!("GET {key}"), &ctx, false).starts_with("OK"));
        assert_eq!(dispatch_str(&format!("UPDATE {key} 9 9"), &ctx, false),
            "ERR readonly standby");
        assert_eq!(dispatch_str(&format!("MUPDATE {key} 9 9"), &ctx, false),
            "ERR readonly standby");
        // BATCH payload lines hit the same gate.
        let mut payload = Vec::new();
        let mut bounds = Vec::new();
        for line in [format!("UPDATE {key} 9 9"), format!("GET {key}")] {
            payload.extend_from_slice(line.as_bytes());
            bounds.push(payload.len());
        }
        let mut resp = Vec::new();
        exec_batch_group(&payload, &bounds, &s, None, None, &m, None, Some(&*repl), &mut resp)
            .unwrap();
        let text = String::from_utf8(resp).unwrap();
        assert!(text.starts_with("ERR readonly standby\nOK"), "{text}");
        // STATS SERVER renders the replication bundle.
        let line = dispatch_str("STATS SERVER", &ctx, false);
        assert!(line.contains("repl_role=2"), "{line}");
        // Promotion flips the same dispatcher read-write.
        assert!(repl.promote());
        assert_eq!(dispatch_str(&format!("UPDATE {key} 9 9"), &ctx, false), "OK");
        let line = dispatch_str("STATS SERVER", &ctx, false);
        assert!(line.contains("repl_role=1"), "{line}");
        assert!(line.contains("repl_failovers=1"), "{line}");
        // STATS RESET clears replication counters, keeps the role gauge.
        assert_eq!(dispatch_str("STATS RESET", &ctx, false), "OK epoch=1");
        let line = dispatch_str("STATS SERVER", &ctx, false);
        assert!(line.contains("repl_failovers=0"), "{line}");
        assert!(line.contains("repl_role=1"), "{line}");
    }

    #[test]
    fn tcp_roundtrip_with_concurrent_clients() {
        let (s, spec) = store(1_000);
        let server = Server::new(s.clone(), None);
        let handle = server.spawn("127.0.0.1:0").unwrap();
        let addr = handle.addr;

        std::thread::scope(|scope| {
            for t in 0..4 {
                let spec = &spec;
                scope.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    assert_eq!(c.request("PING").unwrap(), "PONG");
                    for i in (t * 100)..(t * 100 + 100) {
                        let key = spec.record_at(i as u64).isbn13;
                        let resp = c.request(&format!("UPDATE {key} 123 {t}")).unwrap();
                        assert_eq!(resp, "OK");
                        let got = c.request(&format!("GET {key}")).unwrap();
                        assert_eq!(got, format!("OK 123 {t}"));
                    }
                    assert_eq!(c.request("QUIT").unwrap(), "BYE");
                });
            }
        });
        assert!(handle.requests() >= 4 * 202);
        assert!(handle.metrics.conns_accepted.get() >= 4);
        assert!(handle.metrics.allocs_saved.get() >= 4 * 202, "hot path must be pooled");
        assert_eq!(handle.metrics.conns_rejected.get(), 0);
        handle.shutdown();
    }
}
