//! Event-driven serving core: N reactor threads, each owning one epoll
//! instance, an eventfd-backed injector queue, a slab of nonblocking
//! per-connection state machines, and a lazy timer wheel for idle
//! deadlines. Replaces the blocking accept-loop + `WorkerPool<TcpStream>`
//! front end: concurrent-connection capacity is decoupled from thread
//! count (thousands of mostly-idle clients on a 4-core box), an idle
//! connection costs **zero wakeups** between events (its only standing
//! cost is one timer-wheel entry), and a client that stops reading gets a
//! bounded write buffer and a disconnect instead of pinning a thread
//! inside a socket write timeout.
//!
//! Topology:
//! - The acceptor thread blocks in its own epoll (listener + shutdown
//!   eventfd — no periodic poll tick) and hands accepted sockets
//!   round-robin to reactors through their injectors.
//! - Each reactor's epoll watches its injector eventfd plus every owned
//!   connection. Reads drain until `EWOULDBLOCK`; complete request lines
//!   execute **inline on the reactor** through the same zero-alloc
//!   `execute_one_into` / `BatchScratch` machinery as before; responses
//!   accumulate in a bounded per-connection write buffer flushed
//!   opportunistically and drained by `EPOLLOUT` when the socket pushes
//!   back.
//! - Blocking verbs never run on a reactor: `ANALYTICS` (engine latency)
//!   and — with durability on — `UPDATE`/`MUPDATE`/`BATCH` groups (group
//!   commit fsync) hop to the retained `WorkerPool`, now an executor for
//!   `BlockingJob`s instead of whole connections. The owning connection
//!   pauses (its read interest is dropped, so pipelined input backs up
//!   into TCP flow control) until the job's completion is injected back,
//!   which preserves per-connection response order.
//!
//! Backpressure policy: past `OUT_SOFT_LIMIT` of un-flushed response
//! bytes a connection stops **executing** (input stays buffered in the
//! kernel); past `ServerConfig::write_buf_cap` it is closed and counted
//! (`backpressure_closes`). A stalled-but-quiet client is reaped by the
//! idle deadline instead — either way no thread is ever pinned on a
//! non-reading peer.

#![cfg(target_os = "linux")]

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::pool::{TrySubmitError, WorkerPool};
use super::sys::{
    self, Epoll, EpollEvent, EventFd, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP,
};
use super::{
    batch, exec_batch_group, execute_one_into, reject_busy, reply_invalid_utf8, trim_pool,
    BatchScratch, ServerConfig, MAX_LINE_BYTES,
};
use crate::durability::Persistence;
use crate::ipc::ServingPool;
use crate::metrics::ServerMetrics;
use crate::runtime::AnalyticsService;
use crate::storage::engine::StorageEngine;

/// Injector-eventfd token; connection tokens are slab indices.
const WAKE_TOKEN: u64 = u64::MAX;

/// Max readiness events drained per `epoll_wait` (level-triggered: anything
/// beyond this simply reports again on the next wait).
const MAX_EVENTS: usize = 256;

/// Un-flushed response bytes past which a connection stops executing
/// further requests (input backs up into TCP flow control). Distinct from
/// the hard `write_buf_cap`, which closes the connection.
const OUT_SOFT_LIMIT: usize = 64 << 10;

/// Per-read chunk; also bounds how much one `read` can grow `in_buf`.
const READ_CHUNK: usize = 16 << 10;

// ---------------------------------------------------------------------------
// Shared state + cross-thread messages
// ---------------------------------------------------------------------------

/// Everything the reactors, the acceptor and the blocking pool share.
pub(crate) struct Shared {
    pub store: Arc<dyn StorageEngine>,
    pub engine: Option<Arc<AnalyticsService>>,
    pub persist: Option<Arc<Persistence>>,
    /// Multi-process worker pool (`serve --processes N`). Every data verb
    /// is then a worker RPC — a blocking hop, so those lines run on the
    /// `WorkerPool`, never on a reactor thread.
    pub procs: Option<Arc<ServingPool>>,
    /// Replication role gate + metrics (`--replicate-listen`/`--standby-of`).
    pub repl: Option<Arc<crate::replication::ReplState>>,
    pub metrics: Arc<ServerMetrics>,
    pub stop: Arc<AtomicBool>,
    pub cfg: ServerConfig,
}

/// Work the reactor sends to the blocking pool: one request line or one
/// fully-accumulated BATCH group, tagged with the connection it answers.
pub(crate) struct BlockingJob {
    reactor: usize,
    slot: usize,
    gen: u64,
    kind: JobKind,
}

enum JobKind {
    /// A single blocking request line (`ANALYTICS`, or a durable
    /// `UPDATE`/`MUPDATE` whose group commit fsyncs).
    Line(String),
    /// A BATCH group: raw payload + per-line end offsets, executed with one
    /// deferred group sync.
    Group { payload: Vec<u8>, bounds: Vec<usize> },
}

enum Msg {
    /// A freshly-accepted socket for this reactor to own.
    Accept(TcpStream),
    /// A blocking job finished; `resp` is appended to the connection's
    /// write buffer. `quit` closes after flushing; `fail` closes without
    /// acking (group sync failure — the responses must not be delivered).
    Done { slot: usize, gen: u64, resp: Vec<u8>, quit: bool, fail: bool },
}

/// One reactor's inbound message queue + wakeup eventfd.
pub(crate) struct Injector {
    queue: Mutex<VecDeque<Msg>>,
    wake: EventFd,
}

impl Injector {
    fn new() -> std::io::Result<Injector> {
        Ok(Injector { queue: Mutex::new(VecDeque::new()), wake: EventFd::new()? })
    }

    fn push(&self, msg: Msg) {
        // lint:allow(hot-path-panic): lock poisoning means a reactor thread
        // already panicked — propagating is the correct response.
        self.queue.lock().unwrap().push_back(msg);
        self.wake.signal();
    }

    fn drain(&self) -> VecDeque<Msg> {
        // lint:allow(hot-path-panic): same poisoning rationale as `push`.
        std::mem::take(&mut *self.queue.lock().unwrap())
    }
}

// ---------------------------------------------------------------------------
// Timer wheel (lazy)
// ---------------------------------------------------------------------------

/// Hashed timer wheel with **lazy** entries: arming is a push, re-arming is
/// just updating the connection's `deadline` field — when an entry fires,
/// the owner compares against the live deadline and re-inserts if it moved.
/// One entry per connection per idle window, zero per-request wheel work,
/// and an all-idle reactor computes its next epoll timeout from the first
/// occupied slot (no periodic tick at all).
struct TimerWheel {
    slots: Vec<Vec<(usize, u64)>>,
    tick: Duration,
    base: Instant,
    /// First tick index not yet processed.
    next_tick: u64,
    armed: usize,
}

impl TimerWheel {
    fn new(tick: Duration, nslots: usize, now: Instant) -> TimerWheel {
        TimerWheel {
            slots: (0..nslots).map(|_| Vec::new()).collect(),
            tick: tick.max(Duration::from_millis(1)),
            base: now,
            next_tick: 0,
            armed: 0,
        }
    }

    fn ticks_elapsed(&self, t: Instant) -> u64 {
        (t.saturating_duration_since(self.base).as_nanos() / self.tick.as_nanos()) as u64
    }

    /// Arm `(slot, gen)` to fire at the first tick boundary ≥ `deadline`.
    /// Deadlines beyond the wheel horizon alias into an earlier slot and
    /// fire early — the lazy re-check re-inserts them, trading a rare
    /// extra wakeup for never tracking rounds.
    fn insert(&mut self, deadline: Instant, slot: usize, gen: u64) {
        let t = (self.ticks_elapsed(deadline) + 1).max(self.next_tick);
        let idx = (t % self.slots.len() as u64) as usize;
        self.slots[idx].push((slot, gen));
        self.armed += 1;
    }

    /// Time until the earliest armed entry's tick, or `None` when nothing
    /// is armed (sleep forever — this is what makes idle connections free).
    fn next_timeout(&self, now: Instant) -> Option<Duration> {
        if self.armed == 0 {
            return None;
        }
        let n = self.slots.len() as u64;
        for off in 0..n {
            let t = self.next_tick + off;
            if !self.slots[(t % n) as usize].is_empty() {
                let due = self.base + Duration::from_nanos(self.tick.as_nanos() as u64 * t);
                return Some(due.saturating_duration_since(now));
            }
        }
        Some(self.tick)
    }

    /// Drain every entry whose tick has passed into `out`. Entries are
    /// *candidates* — the caller re-checks the live deadline and may
    /// re-insert.
    fn collect_due(&mut self, now: Instant, out: &mut Vec<(usize, u64)>) {
        let now_tick = self.ticks_elapsed(now);
        if self.armed == 0 {
            self.next_tick = now_tick + 1;
            return;
        }
        let n = self.slots.len() as u64;
        while self.next_tick <= now_tick && self.armed > 0 {
            let idx = (self.next_tick % n) as usize;
            if !self.slots[idx].is_empty() {
                self.armed -= self.slots[idx].len();
                out.append(&mut self.slots[idx]);
            }
            self.next_tick += 1;
        }
        if self.armed == 0 {
            self.next_tick = now_tick + 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Per-connection state machine
// ---------------------------------------------------------------------------

struct BatchState {
    expect: usize,
    /// Executes on the pool: durability is on (group commit fsync) or the
    /// payload contains an `ANALYTICS` line.
    blocking: bool,
}

struct Conn {
    stream: TcpStream,
    fd: std::os::raw::c_int,
    /// Guards cross-thread completions against slot reuse.
    gen: u64,
    /// Raw inbound bytes; `cursor` marks the parsed prefix (compacted
    /// after each processing pass).
    in_buf: Vec<u8>,
    cursor: usize,
    /// Pending response bytes from `out_pos` on.
    out: Vec<u8>,
    out_pos: usize,
    scratch: BatchScratch,
    batch: Option<BatchState>,
    /// A blocking job is in flight; execution (and reads) pause until its
    /// completion is injected back.
    blocked: bool,
    /// Flush whatever is buffered, then close.
    closing: bool,
    eof: bool,
    /// Interest bits currently registered with epoll.
    interest: u32,
    /// Idle deadline: moved forward on every *completed* request (partial
    /// input never extends it, so a drip-feeder cannot hold the slot).
    deadline: Instant,
}

impl Conn {
    fn pending_out(&self) -> usize {
        self.out.len() - self.out_pos
    }
}

/// Write as much pending output as the socket accepts. `false` = peer gone.
fn flush_out(conn: &mut Conn) -> bool {
    while conn.out_pos < conn.out.len() {
        match conn.stream.write(&conn.out[conn.out_pos..]) {
            Ok(0) => return false,
            Ok(n) => conn.out_pos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if conn.out_pos >= conn.out.len() {
        conn.out.clear();
        conn.out_pos = 0;
        trim_pool(&mut conn.out);
    }
    true
}

/// One `ERR server busy` response for a blocking request shed because the
/// executor queue was full, with the same per-request accounting as any
/// answered line (charged to the `other` histogram).
fn reply_busy_line(metrics: &ServerMetrics, out: &mut Vec<u8>) {
    metrics.requests.inc();
    metrics.latency_for("").record(0);
    out.extend_from_slice(b"ERR server busy (blocking executor saturated)\n");
}

/// Shed a whole BATCH group: the header promised `n` response lines, so
/// emit exactly `n` busy lines to keep the framing in sync.
fn reply_busy_group(metrics: &ServerMetrics, n: usize, out: &mut Vec<u8>) {
    for _ in 0..n {
        reply_busy_line(metrics, out);
    }
}

/// Leading-whitespace-insensitive prefix test on raw bytes (`ANALYTICS`
/// detection inside a BATCH payload, before UTF-8 validation).
fn line_starts_with(raw: &[u8], prefix: &[u8]) -> bool {
    let start = raw.iter().position(|b| !b.is_ascii_whitespace()).unwrap_or(raw.len());
    raw[start..].starts_with(prefix)
}

/// Parse + execute every complete request line buffered on `conn`, stopping
/// at a blocking hop, a close condition, or the output soft limit. Returns
/// whether any request completed (the caller then moves the idle deadline).
fn process_conn(
    shared: &Shared,
    pool: &WorkerPool<BlockingJob>,
    reactor: usize,
    slot: usize,
    conn: &mut Conn,
) -> bool {
    let mut executed = false;
    loop {
        if conn.closing || conn.blocked || conn.pending_out() > OUT_SOFT_LIMIT {
            break;
        }
        let buf_len = conn.in_buf.len();
        let (line_start, line_end, consumed_to) =
            match conn.in_buf[conn.cursor..].iter().position(|&b| b == b'\n') {
                Some(i) => (conn.cursor, conn.cursor + i, conn.cursor + i + 1),
                None => {
                    if conn.eof && conn.cursor < buf_len {
                        // EOF with a trailing unterminated line: still a
                        // request (read_line end-of-stream semantics).
                        (conn.cursor, buf_len, buf_len)
                    } else {
                        if buf_len - conn.cursor > MAX_LINE_BYTES {
                            let msg = format!(
                                "ERR request line exceeds {MAX_LINE_BYTES} bytes, closing\n"
                            );
                            conn.out.extend_from_slice(msg.as_bytes());
                            conn.closing = true;
                        }
                        break;
                    }
                }
            };
        conn.cursor = consumed_to;

        // ------------------------------------------------- BATCH payload
        if conn.batch.is_some() {
            let is_analytics =
                line_starts_with(&conn.in_buf[line_start..line_end], b"ANALYTICS");
            conn.scratch.payload.extend_from_slice(&conn.in_buf[line_start..line_end]);
            conn.scratch.bounds.push(conn.scratch.payload.len());
            if conn.scratch.payload.len() > batch::MAX_BATCH_BYTES {
                conn.out.extend_from_slice(
                    format!("ERR BATCH payload exceeds {} bytes, closing\n", batch::MAX_BATCH_BYTES)
                        .as_bytes(),
                );
                conn.batch = None;
                conn.closing = true;
                break;
            }
            // lint:allow(hot-path-panic): guarded by the `is_some` branch
            // this arm sits in; a None here is a state-machine bug.
            let st = conn.batch.as_mut().expect("checked is_some above");
            if is_analytics {
                st.blocking = true;
            }
            if conn.scratch.bounds.len() < st.expect {
                continue;
            }
            let blocking = st.blocking;
            conn.batch = None;
            executed = true;
            if blocking {
                let payload = std::mem::take(&mut conn.scratch.payload);
                let bounds = std::mem::take(&mut conn.scratch.bounds);
                let n_lines = bounds.len();
                let job = BlockingJob {
                    reactor,
                    slot,
                    gen: conn.gen,
                    kind: JobKind::Group { payload, bounds },
                };
                match pool.try_submit(job) {
                    Ok(()) => {
                        conn.blocked = true;
                        break;
                    }
                    Err(TrySubmitError::Full(_)) => {
                        // Executor saturated (orphaned jobs from vanished
                        // connections can pile up): shed the group without
                        // desyncing the BATCH framing — one busy line per
                        // payload line the header promised. Never block a
                        // reactor on the pool queue.
                        reply_busy_group(&shared.metrics, n_lines, &mut conn.out);
                        continue;
                    }
                    Err(TrySubmitError::Closed(_)) => {
                        // Pool already shut down (stop raced this request).
                        conn.closing = true;
                        break;
                    }
                }
            }
            conn.scratch.resp.clear();
            let outcome = exec_batch_group(
                &conn.scratch.payload,
                &conn.scratch.bounds,
                &shared.store,
                shared.engine.as_ref(),
                shared.persist.as_deref(),
                &shared.metrics,
                shared.procs.as_deref(),
                shared.repl.as_deref(),
                &mut conn.scratch.resp,
            );
            match outcome {
                Ok(quit) => {
                    conn.out.extend_from_slice(&conn.scratch.resp);
                    if quit {
                        conn.closing = true;
                    }
                }
                // Group sync failed: never deliver the buffered OKs.
                Err(()) => conn.closing = true,
            }
            conn.scratch.trim();
            if conn.closing {
                break;
            }
            continue;
        }

        // ------------------------------------------------ top-level line
        let req = match std::str::from_utf8(&conn.in_buf[line_start..line_end]) {
            Ok(s) => s.trim(),
            Err(_) => {
                // Close, don't continue: the garbage could have been a
                // BATCH header whose payload lines are already in flight —
                // executing them as top-level requests would permanently
                // desync the reply stream.
                reply_invalid_utf8(&shared.metrics, &mut conn.out);
                conn.closing = true;
                break;
            }
        };
        let verb = req.split_ascii_whitespace().next().unwrap_or("");
        if verb == "BATCH" {
            let mut parts = req.split_ascii_whitespace();
            parts.next();
            let n = parts.next().and_then(|s| s.parse::<usize>().ok());
            match (n, parts.next()) {
                (Some(n), None) if (1..=batch::MAX_BATCH).contains(&n) => {
                    conn.scratch.payload.clear();
                    conn.scratch.bounds.clear();
                    // With durability on, the whole group defers its WAL
                    // sync to one group commit — a blocking fsync, so the
                    // group executes on the pool. With a multi-process
                    // backend, the group scatter-gathers over worker RPCs —
                    // also never on a reactor thread. With a spill-enabled
                    // engine, any payload GET may fall through to disk —
                    // same pool hop.
                    conn.batch = Some(BatchState {
                        expect: n,
                        blocking: shared.persist.is_some()
                            || shared.procs.is_some()
                            || shared.store.spill_enabled(),
                    });
                }
                _ => {
                    conn.out.extend_from_slice(
                        format!("ERR BATCH expects <n> in 1..={}, closing\n", batch::MAX_BATCH)
                            .as_bytes(),
                    );
                    conn.closing = true;
                    break;
                }
            }
            continue;
        }
        let blocking_verb = verb == "ANALYTICS"
            || (shared.persist.is_some() && (verb == "UPDATE" || verb == "MUPDATE"))
            || (shared.procs.is_some()
                && matches!(verb, "GET" | "UPDATE" | "MGET" | "MUPDATE" | "STATS"))
            // Spill-enabled engine: point reads can touch disk runs, and
            // updates can both promote from disk (write-back) and trigger
            // a spill (run write + fsync), so every data verb hops to the
            // pool like ANALYTICS; pure-memory engines (spill_enabled()
            // == false) keep the inline seqlock path.
            || (shared.store.spill_enabled()
                && matches!(verb, "GET" | "MGET" | "UPDATE" | "MUPDATE" | "STATS"));
        if blocking_verb {
            executed = true;
            let job =
                BlockingJob { reactor, slot, gen: conn.gen, kind: JobKind::Line(req.to_string()) };
            match pool.try_submit(job) {
                Ok(()) => {
                    conn.blocked = true;
                    break;
                }
                Err(TrySubmitError::Full(_)) => {
                    reply_busy_line(&shared.metrics, &mut conn.out);
                    continue;
                }
                Err(TrySubmitError::Closed(_)) => {
                    conn.closing = true;
                    break;
                }
            }
        }
        execute_one_into(
            req,
            &shared.store,
            shared.engine.as_ref(),
            shared.persist.as_deref(),
            &shared.metrics,
            false,
            shared.procs.as_deref(),
            shared.repl.as_deref(),
            &mut conn.out,
        );
        executed = true;
        if req == "QUIT" {
            conn.closing = true;
            break;
        }
    }
    if conn.eof && conn.cursor >= conn.in_buf.len() && !conn.blocked {
        conn.closing = true;
    }
    if conn.cursor > 0 {
        conn.in_buf.drain(..conn.cursor);
        conn.cursor = 0;
        trim_pool(&mut conn.in_buf);
    }
    executed
}

// ---------------------------------------------------------------------------
// Reactor
// ---------------------------------------------------------------------------

struct Reactor {
    id: usize,
    epoll: Epoll,
    injector: Arc<Injector>,
    shared: Arc<Shared>,
    pool: Arc<WorkerPool<BlockingJob>>,
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    /// Slots freed during the current event batch. Withheld from `free`
    /// until the batch is fully processed: a stale readiness event already
    /// harvested for a closed connection must find the slot empty, not a
    /// fresh connection that reused it (tokens carry only the slot index).
    pending_free: Vec<usize>,
    wheel: TimerWheel,
    due_scratch: Vec<(usize, u64)>,
    gen_counter: u64,
}

enum Verdict {
    Keep(u32),
    Close,
    CloseBackpressure,
}

impl Reactor {
    fn new(
        id: usize,
        injector: Arc<Injector>,
        shared: Arc<Shared>,
        pool: Arc<WorkerPool<BlockingJob>>,
    ) -> std::io::Result<Reactor> {
        let epoll = Epoll::new()?;
        epoll.add(injector.wake.raw(), EPOLLIN, WAKE_TOKEN)?;
        // Tick ≤ idle/8 keeps eviction within ~12% of the configured
        // timeout; the 1 s cap bounds wheel-slot aliasing for huge idles.
        let tick = (shared.cfg.idle_timeout / 8)
            .clamp(Duration::from_millis(10), Duration::from_secs(1));
        let wheel = TimerWheel::new(tick, 64, Instant::now());
        Ok(Reactor {
            id,
            epoll,
            injector,
            shared,
            pool,
            conns: Vec::new(),
            free: Vec::new(),
            pending_free: Vec::new(),
            wheel,
            due_scratch: Vec::new(),
            gen_counter: 0,
        })
    }

    fn run(mut self) {
        let mut events = vec![EpollEvent::zeroed(); MAX_EVENTS];
        loop {
            let timeout = self.wheel.next_timeout(Instant::now());
            let n = match self.epoll.wait(&mut events, timeout) {
                Ok(n) => n,
                Err(_) => break,
            };
            self.shared.metrics.epoll_wakeups.inc();
            self.shared.metrics.ready_events.add(n as u64);
            if self.shared.stop.load(Ordering::Acquire) {
                break;
            }
            for ev in &events[..n] {
                let token = ev.token();
                if token == WAKE_TOKEN {
                    self.injector.wake.drain();
                    self.drain_injector();
                } else {
                    self.on_event(token as usize, ev.readiness());
                }
            }
            self.expire_timers(Instant::now());
            // Slots closed this round become reusable only now, once no
            // stale event from the harvested batch can still target them.
            self.free.append(&mut self.pending_free);
        }
        self.cleanup();
    }

    fn drain_injector(&mut self) {
        for msg in self.injector.drain() {
            match msg {
                Msg::Accept(stream) => self.register_conn(stream),
                Msg::Done { slot, gen, resp, quit, fail } => {
                    self.on_done(slot, gen, resp, quit, fail)
                }
            }
        }
    }

    fn register_conn(&mut self, stream: TcpStream) {
        if stream.set_nonblocking(true).is_err() {
            self.shared.metrics.conns_active.dec();
            return;
        }
        stream.set_nodelay(true).ok();
        let fd = stream.as_raw_fd();
        let slot = self.free.pop().unwrap_or_else(|| {
            self.conns.push(None);
            self.conns.len() - 1
        });
        self.gen_counter += 1;
        let now = Instant::now();
        let deadline = now + self.shared.cfg.idle_timeout;
        let interest = EPOLLIN | EPOLLRDHUP;
        if self.epoll.add(fd, interest, slot as u64).is_err() {
            self.free.push(slot);
            self.shared.metrics.conns_active.dec();
            return;
        }
        self.wheel.insert(deadline, slot, self.gen_counter);
        self.conns[slot] = Some(Conn {
            stream,
            fd,
            gen: self.gen_counter,
            in_buf: Vec::with_capacity(256),
            cursor: 0,
            out: Vec::with_capacity(256),
            out_pos: 0,
            scratch: BatchScratch::default(),
            batch: None,
            blocked: false,
            closing: false,
            eof: false,
            interest,
            deadline,
        });
    }

    fn on_event(&mut self, slot: usize, readiness: u32) {
        if !matches!(self.conns.get(slot), Some(Some(_))) {
            return; // stale event for a slot closed earlier in this batch
        }
        if readiness & (EPOLLHUP | EPOLLERR) != 0 {
            self.close_conn(slot);
            return;
        }
        if readiness & (EPOLLIN | EPOLLRDHUP) != 0 {
            if !self.read_socket(slot) {
                self.close_conn(slot);
                return;
            }
        }
        self.advance(slot);
    }

    /// Drain the socket until `EWOULDBLOCK` (or EOF). `false` = hard error.
    fn read_socket(&mut self, slot: usize) -> bool {
        // lint:allow(hot-path-panic): `on_event` verified the slot is live;
        // a None here is reactor-bookkeeping corruption worth crashing on.
        let conn = self.conns[slot].as_mut().expect("checked by on_event");
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            // Bound what one pass can buffer: a connection paused for
            // backpressure or a blocking hop stops reading entirely, and
            // the per-line / per-batch caps police the rest in process.
            if conn.in_buf.len() > MAX_LINE_BYTES + batch::MAX_BATCH_BYTES {
                return true;
            }
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.eof = true;
                    return true;
                }
                Ok(n) => conn.in_buf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
    }

    /// Post-IO driver: alternate flushing and executing until neither makes
    /// progress (socket full, input exhausted, blocking hop, or close),
    /// then re-arm interest or close. The flush→process loop matters: a
    /// connection that paused at the output soft limit must resume the
    /// moment its buffer drains into the kernel — the socket was already
    /// read dry, so no further readiness event would come to resume it.
    fn advance(&mut self, slot: usize) {
        let mut dead = false;
        loop {
            // lint:allow(hot-path-panic): callers only invoke `advance` on
            // live slots; slot bookkeeping is the invariant being asserted.
            let conn = self.conns[slot].as_mut().expect("advance on live conn");
            let pend_before = conn.pending_out();
            if !flush_out(conn) {
                dead = true;
                break;
            }
            let flushed = conn.pending_out() < pend_before;
            let executed = process_conn(&self.shared, &self.pool, self.id, slot, conn);
            if executed {
                conn.deadline = Instant::now() + self.shared.cfg.idle_timeout;
            }
            if conn.closing || conn.blocked || !(executed || flushed) {
                break;
            }
        }
        self.update_interest_or_close(slot, dead);
    }

    /// Decide the connection's fate from its post-`advance` state. No
    /// flushing happens here: draining the buffer *after* the execute loop
    /// ended could strand already-buffered requests below the soft limit
    /// with no event left to resume them — instead `EPOLLOUT` stays armed
    /// and the next readiness round runs `advance` again.
    fn update_interest_or_close(&mut self, slot: usize, dead: bool) {
        let verdict = {
            let cap = self.shared.cfg.write_buf_cap;
            // lint:allow(hot-path-panic): only reached from `advance`, which
            // already asserted the slot is live.
            let conn = self.conns[slot].as_mut().expect("live conn");
            if dead {
                Verdict::Close
            } else {
                let pending = conn.pending_out();
                if pending > cap {
                    Verdict::CloseBackpressure
                } else if conn.closing && pending == 0 {
                    Verdict::Close
                } else {
                    let paused = conn.blocked
                        || conn.closing
                        || conn.eof
                        || pending > OUT_SOFT_LIMIT;
                    // After EOF, RDHUP stays level-asserted forever — keep
                    // it armed and a connection parked on a blocking job
                    // would spin the reactor. Reads are over; only write
                    // drain (and implicit ERR/HUP) still matter.
                    let mut want = if conn.eof { 0 } else { EPOLLRDHUP };
                    if !paused {
                        want |= EPOLLIN;
                    }
                    if pending > 0 {
                        want |= EPOLLOUT;
                    }
                    Verdict::Keep(want)
                }
            }
        };
        match verdict {
            Verdict::Keep(want) => {
                let fd = {
                    // lint:allow(hot-path-panic): same live-slot invariant
                    // as the verdict block directly above.
                    let conn = self.conns[slot].as_mut().expect("live conn");
                    if conn.interest == want {
                        return;
                    }
                    conn.interest = want;
                    conn.fd
                };
                if self.epoll.modify(fd, want, slot as u64).is_err() {
                    self.close_conn(slot);
                }
            }
            Verdict::Close => self.close_conn(slot),
            Verdict::CloseBackpressure => {
                self.shared.metrics.backpressure_closes.inc();
                self.close_conn(slot);
            }
        }
    }

    fn on_done(&mut self, slot: usize, gen: u64, resp: Vec<u8>, quit: bool, fail: bool) {
        let live = matches!(self.conns.get(slot), Some(Some(c)) if c.gen == gen);
        if !live {
            return; // connection closed while the job ran
        }
        if fail {
            self.close_conn(slot);
            return;
        }
        {
            // lint:allow(hot-path-panic): the `live` generation check above
            // guarantees the slot holds this connection.
            let conn = self.conns[slot].as_mut().expect("checked live above");
            conn.blocked = false;
            conn.out.extend_from_slice(&resp);
            if quit {
                conn.closing = true;
            }
            conn.deadline = Instant::now() + self.shared.cfg.idle_timeout;
        }
        self.advance(slot);
    }

    fn expire_timers(&mut self, now: Instant) {
        let mut due = std::mem::take(&mut self.due_scratch);
        due.clear();
        self.wheel.collect_due(now, &mut due);
        for &(slot, gen) in &due {
            enum T {
                Fire,
                Rearm(Instant),
                Stale,
            }
            let t = match self.conns.get(slot).and_then(|c| c.as_ref()) {
                Some(c) if c.gen == gen => {
                    if c.blocked {
                        // A blocking job is in flight: the connection is
                        // waiting on *us*, not idle. Check again next
                        // window; the completion handler re-arms the real
                        // deadline, so an accepted request's response is
                        // never thrown away by eviction.
                        T::Rearm(now + self.shared.cfg.idle_timeout)
                    } else if c.deadline <= now {
                        T::Fire
                    } else {
                        T::Rearm(c.deadline)
                    }
                }
                _ => T::Stale,
            };
            match t {
                T::Fire => {
                    self.shared.metrics.timer_expirations.inc();
                    if let Some(c) = self.conns[slot].as_mut() {
                        // Only announce the eviction on a clean stream: with
                        // response bytes still pending, a direct write would
                        // splice the error into the middle of a partially
                        // delivered response.
                        if c.pending_out() == 0 {
                            let _ = c.stream.write(b"ERR idle timeout, closing connection\n");
                        }
                    }
                    self.close_conn(slot);
                }
                T::Rearm(deadline) => self.wheel.insert(deadline, slot, gen),
                T::Stale => {}
            }
        }
        self.due_scratch = due;
        self.due_scratch.clear();
    }

    fn close_conn(&mut self, slot: usize) {
        if let Some(conn) = self.conns[slot].take() {
            let _ = self.epoll.delete(conn.fd);
            self.shared.metrics.conns_active.dec();
            self.pending_free.push(slot);
            // `conn.stream` drops here, closing the fd.
        }
    }

    fn cleanup(&mut self) {
        for slot in 0..self.conns.len() {
            if self.conns[slot].is_some() {
                self.close_conn(slot);
            }
        }
        // Sockets accepted but never registered still hold admission slots.
        for msg in self.injector.drain() {
            if let Msg::Accept(_) = msg {
                self.shared.metrics.conns_active.dec();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Frontend: build reactors + blocking pool, then run the acceptor
// ---------------------------------------------------------------------------

pub(crate) struct Frontend {
    injectors: Vec<Arc<Injector>>,
    reactors: Vec<JoinHandle<()>>,
    pool: Arc<WorkerPool<BlockingJob>>,
    shared: Arc<Shared>,
}

impl Frontend {
    /// Stand up the injectors, the blocking-verb pool and every reactor
    /// thread. On any failure the already-spawned reactors are stopped and
    /// joined before the error propagates.
    #[allow(clippy::too_many_arguments)] // mirrors the Server fields 1:1
    pub(crate) fn build(
        store: Arc<dyn StorageEngine>,
        engine: Option<Arc<AnalyticsService>>,
        persist: Option<Arc<Persistence>>,
        procs: Option<Arc<ServingPool>>,
        repl: Option<Arc<crate::replication::ReplState>>,
        metrics: Arc<ServerMetrics>,
        stop: Arc<AtomicBool>,
        cfg: ServerConfig,
    ) -> std::io::Result<Frontend> {
        let shared = Arc::new(Shared { store, engine, persist, procs, repl, metrics, stop, cfg });
        let n = shared.cfg.reactors.max(1);
        let mut injectors = Vec::with_capacity(n);
        for _ in 0..n {
            injectors.push(Arc::new(Injector::new()?));
        }
        // Each blocked connection holds at most one in-flight job, and
        // admission caps live connections at max_conns; 2× absorbs jobs
        // whose connection died while they were queued.
        let pool = {
            let shared = shared.clone();
            let injectors = injectors.clone();
            Arc::new(WorkerPool::new(
                shared.cfg.workers.max(1),
                shared.cfg.max_conns.saturating_mul(2).max(1),
                move |job: BlockingJob| run_blocking_job(&shared, &injectors, job),
            ))
        };
        let mut reactors = Vec::with_capacity(n);
        for id in 0..n {
            let r = Reactor::new(id, injectors[id].clone(), shared.clone(), pool.clone());
            let spawned = r.and_then(|r| {
                std::thread::Builder::new()
                    .name(format!("membig-reactor-{id}"))
                    .spawn(move || r.run())
            });
            match spawned {
                Ok(j) => reactors.push(j),
                Err(e) => {
                    shared.stop.store(true, Ordering::Release);
                    for inj in &injectors {
                        inj.wake.signal();
                    }
                    for j in reactors {
                        let _ = j.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(Frontend { injectors, reactors, pool, shared })
    }
}

fn run_blocking_job(shared: &Shared, injectors: &[Arc<Injector>], job: BlockingJob) {
    let BlockingJob { reactor, slot, gen, kind } = job;
    let mut resp = Vec::with_capacity(128);
    let (quit, fail) = match kind {
        JobKind::Line(line) => {
            let req = line.trim();
            execute_one_into(
                req,
                &shared.store,
                shared.engine.as_ref(),
                shared.persist.as_deref(),
                &shared.metrics,
                false,
                shared.procs.as_deref(),
                shared.repl.as_deref(),
                &mut resp,
            );
            (req == "QUIT", false)
        }
        JobKind::Group { payload, bounds } => {
            match exec_batch_group(
                &payload,
                &bounds,
                &shared.store,
                shared.engine.as_ref(),
                shared.persist.as_deref(),
                &shared.metrics,
                shared.procs.as_deref(),
                shared.repl.as_deref(),
                &mut resp,
            ) {
                Ok(quit) => (quit, false),
                Err(()) => {
                    resp.clear();
                    (false, true)
                }
            }
        }
    };
    injectors[reactor].push(Msg::Done { slot, gen, resp, quit, fail });
}

/// The acceptor: blocks in its own epoll on the listener + the shutdown
/// eventfd (no poll tick), applies admission control, and deals accepted
/// sockets round-robin across the reactors. On shutdown it stops the
/// reactors (injector signals + joins) and then drops the blocking pool,
/// which drains queued jobs and joins its workers.
pub(crate) fn accept_loop(listener: TcpListener, wake: Arc<EventFd>, front: Frontend) {
    let Frontend { injectors, reactors, pool, shared } = front;
    listener.set_nonblocking(true).ok();
    let aep = match Epoll::new() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("membig: acceptor epoll unavailable: {e}");
            shared.stop.store(true, Ordering::Release);
            for inj in &injectors {
                inj.wake.signal();
            }
            for j in reactors {
                let _ = j.join();
            }
            drop(pool);
            return;
        }
    };
    let _ = aep.add(listener.as_raw_fd(), EPOLLIN, 0);
    let _ = aep.add(wake.raw(), EPOLLIN, 1);
    let mut events = [EpollEvent::zeroed(); 8];
    let mut rr = 0usize;
    let base = Duration::from_millis(5);
    let mut backoff = base;
    while !shared.stop.load(Ordering::Acquire) {
        if aep.wait(&mut events, None).is_err() {
            break;
        }
        wake.drain();
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    backoff = base;
                    if shared.metrics.conns_active.get() >= shared.cfg.max_conns as i64 {
                        shared.metrics.conns_rejected.inc();
                        reject_busy(stream);
                        continue;
                    }
                    shared.metrics.conns_accepted.inc();
                    shared.metrics.conns_active.inc();
                    injectors[rr].push(Msg::Accept(stream));
                    rr = (rr + 1) % injectors.len();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    // Transient accept failure (EMFILE, ECONNABORTED, ...):
                    // record, back off, re-enter the epoll wait — only
                    // shutdown ends the loop.
                    shared.metrics.accept_errors.inc();
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(Duration::from_millis(500));
                    break;
                }
            }
        }
    }
    for inj in &injectors {
        inj.wake.signal();
    }
    for j in reactors {
        let _ = j.join();
    }
    drop(pool);
}

/// Raise this process's fd soft limit (fd-heavy tests and benches).
/// Re-exported here so callers outside the crate never touch `sys`.
pub fn raise_nofile_limit(want: u64) -> u64 {
    sys::raise_nofile_limit(want)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_wheel_fires_after_deadline_not_before() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(Duration::from_millis(10), 8, t0);
        w.insert(t0 + Duration::from_millis(35), 3, 7);
        let mut due = Vec::new();
        w.collect_due(t0 + Duration::from_millis(20), &mut due);
        assert!(due.is_empty(), "fired {due:?} before the deadline");
        assert!(w.next_timeout(t0 + Duration::from_millis(20)).is_some());
        w.collect_due(t0 + Duration::from_millis(60), &mut due);
        assert_eq!(due, vec![(3, 7)]);
        assert_eq!(w.next_timeout(t0 + Duration::from_millis(60)), None, "wheel drained");
    }

    #[test]
    fn timer_wheel_idle_is_free_and_lazy_rearm_works() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(Duration::from_millis(10), 8, t0);
        assert_eq!(w.next_timeout(t0), None, "no timers → sleep forever");
        // Horizon aliasing: a deadline 20 ticks out on an 8-slot wheel
        // fires early as a candidate — the caller's lazy re-check then
        // re-inserts. Simulate one such round trip.
        let deadline = t0 + Duration::from_millis(200);
        w.insert(deadline, 1, 1);
        let mut due = Vec::new();
        let mut hops = 0;
        let mut now = t0;
        while hops < 64 {
            let Some(sleep) = w.next_timeout(now) else { break };
            now += sleep + Duration::from_millis(1);
            due.clear();
            w.collect_due(now, &mut due);
            for &(slot, gen) in &due {
                assert_eq!((slot, gen), (1, 1));
                if now < deadline {
                    w.insert(deadline, slot, gen); // lazy re-arm
                } else {
                    return; // fired at/after the true deadline: correct
                }
            }
            hops += 1;
        }
        panic!("entry never fired (now {now:?} vs deadline {deadline:?})");
    }

    #[test]
    fn timer_wheel_long_sleep_does_not_accumulate_tick_debt() {
        let t0 = Instant::now();
        let mut w = TimerWheel::new(Duration::from_millis(1), 8, t0);
        // Simulate waking hours later with nothing armed: collect must
        // jump `next_tick` forward, not iterate millions of empty ticks.
        let mut due = Vec::new();
        let later = t0 + Duration::from_secs(3600);
        let t = Instant::now();
        w.collect_due(later, &mut due);
        assert!(due.is_empty());
        assert!(t.elapsed() < Duration::from_millis(50), "tick debt was replayed");
        // And a timer inserted after the jump still fires promptly.
        w.insert(later + Duration::from_millis(5), 9, 9);
        w.collect_due(later + Duration::from_millis(20), &mut due);
        assert_eq!(due, vec![(9, 9)]);
    }

    #[test]
    fn line_starts_with_skips_leading_whitespace() {
        assert!(line_starts_with(b"ANALYTICS", b"ANALYTICS"));
        assert!(line_starts_with(b"  \tANALYTICS extra", b"ANALYTICS"));
        assert!(!line_starts_with(b"GET 1", b"ANALYTICS"));
        assert!(!line_starts_with(b"", b"ANALYTICS"));
        assert!(!line_starts_with(b"   ", b"ANALYTICS"));
    }
}
