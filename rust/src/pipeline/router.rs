//! Shard routing: partition a parsed batch of updates into per-shard
//! sub-batches *before* any shard is touched, so workers never contend.
//! This is the leader-side half of the paper's `T = {(t_i, h_i)}` mapping.

use crate::memstore::ShardedStore;
use crate::workload::record::StockUpdate;

/// Partition `batch` by destination shard. `out` is reused between calls to
/// keep the reader allocation-free in steady state (`out[s]` is cleared,
/// not reallocated).
pub fn route_batch(store: &ShardedStore, batch: &[StockUpdate], out: &mut Vec<Vec<StockUpdate>>) {
    let shards = store.shard_count();
    if out.len() != shards {
        out.clear();
        out.resize_with(shards, Vec::new);
    }
    for sub in out.iter_mut() {
        sub.clear();
    }
    for u in batch {
        out[store.route(u.isbn13)].push(*u);
    }
}

/// Partition a full update set into exactly `shards` owned vectors
/// (one-shot variant used by the in-memory executor and benches).
pub fn partition_updates(
    store: &ShardedStore,
    updates: &[StockUpdate],
) -> Vec<Vec<StockUpdate>> {
    let mut out = Vec::new();
    route_batch(store, updates, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::{generate_stock_updates, DatasetSpec, KeyDist};

    #[test]
    fn routing_preserves_every_update() {
        let spec = DatasetSpec { records: 10_000, ..Default::default() };
        let store = ShardedStore::new(8, 1 << 11);
        let ups = generate_stock_updates(&spec, 10_000, KeyDist::PermuteAll, 1);
        let parts = partition_updates(&store, &ups);
        assert_eq!(parts.len(), 8);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 10_000);
        // Every routed update must be in its owner shard.
        for (s, part) in parts.iter().enumerate() {
            for u in part {
                assert_eq!(store.route(u.isbn13), s);
            }
        }
    }

    #[test]
    fn reuse_clears_previous_contents() {
        let spec = DatasetSpec { records: 100, ..Default::default() };
        let store = ShardedStore::new(4, 64);
        let a = generate_stock_updates(&spec, 100, KeyDist::Uniform, 1);
        let b = generate_stock_updates(&spec, 50, KeyDist::Uniform, 2);
        let mut out = Vec::new();
        route_batch(&store, &a, &mut out);
        route_batch(&store, &b, &mut out);
        assert_eq!(out.iter().map(|p| p.len()).sum::<usize>(), 50);
    }

    #[test]
    fn shard_count_change_resizes() {
        let spec = DatasetSpec { records: 100, ..Default::default() };
        let ups = generate_stock_updates(&spec, 100, KeyDist::Uniform, 3);
        let mut out = Vec::new();
        route_batch(&ShardedStore::new(2, 64), &ups, &mut out);
        assert_eq!(out.len(), 2);
        route_batch(&ShardedStore::new(6, 64), &ups, &mut out);
        assert_eq!(out.len(), 6);
        assert_eq!(out.iter().map(|p| p.len()).sum::<usize>(), 100);
    }
}
