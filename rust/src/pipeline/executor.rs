//! Pipeline executors: the streaming file-fed path (production shape) and
//! the pre-materialized in-memory path (benchmark shape, isolates compute
//! from file I/O). Both implement the paper's proposed method; both return
//! a [`StreamReport`].

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use super::channel::bounded;
use super::router::{partition_updates, route_batch};
use crate::memstore::ShardedStore;
use crate::metrics::EngineMetrics;
use crate::workload::record::StockUpdate;
use crate::workload::stockfile::StockReader;

/// Outcome of one pipeline run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StreamReport {
    pub updates_applied: u64,
    pub updates_missing: u64,
    pub parse_errors: u64,
    pub batches: u64,
    pub backpressure_waits: u64,
}

#[derive(Debug)]
pub enum PipelineError {
    Io(std::io::Error),
    WorkerPanic(String),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::Io(e) => write!(f, "io: {e}"),
            PipelineError::WorkerPanic(w) => write!(f, "worker panicked: {w}"),
        }
    }
}

impl std::error::Error for PipelineError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PipelineError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for PipelineError {
    fn from(e: std::io::Error) -> Self {
        PipelineError::Io(e)
    }
}

/// Streaming executor: reads `stock_path`, routes batches of `batch_size`
/// to `workers` shard-affine threads through bounded queues of depth
/// `channel_depth`. One worker per shard (`store.shard_count()` must equal
/// `workers`).
pub fn run_streaming_update(
    store: &Arc<ShardedStore>,
    stock_path: &Path,
    batch_size: usize,
    channel_depth: usize,
    metrics: &EngineMetrics,
) -> Result<StreamReport, PipelineError> {
    let shards = store.shard_count();
    let mut reader = StockReader::open(stock_path)?;
    let t0 = Instant::now();

    // Per-shard SPSC queues (bounded → backpressure).
    let mut senders = Vec::with_capacity(shards);
    let mut receivers = Vec::with_capacity(shards);
    for _ in 0..shards {
        let (tx, rx) = bounded::<Vec<StockUpdate>>(channel_depth);
        senders.push(tx);
        receivers.push(rx);
    }

    let applied = std::sync::atomic::AtomicU64::new(0);
    let missing = std::sync::atomic::AtomicU64::new(0);
    let mut batches = 0u64;

    std::thread::scope(|scope| -> Result<(), PipelineError> {
        // Workers: each owns shard i exclusively.
        let mut handles = Vec::with_capacity(shards);
        for (i, rx) in receivers.into_iter().enumerate() {
            let store = Arc::clone(store);
            let applied = &applied;
            let missing = &missing;
            let metrics_ref = &*metrics;
            handles.push(scope.spawn(move || {
                let mut local_applied = 0u64;
                let mut local_missing = 0u64;
                while let Ok(batch) = rx.recv() {
                    let t = Instant::now();
                    let mut shard = store.shard(i);
                    for u in &batch {
                        if shard.update(u.isbn13, |r| u.apply_to(r)) {
                            local_applied += 1;
                        } else {
                            local_missing += 1;
                        }
                    }
                    drop(shard);
                    metrics_ref.batch_latency.record_duration(t.elapsed());
                }
                applied.fetch_add(local_applied, std::sync::atomic::Ordering::Relaxed);
                missing.fetch_add(local_missing, std::sync::atomic::Ordering::Relaxed);
            }));
        }

        // Reader/router (leader thread): parse → route → dispatch.
        let mut buf: Vec<StockUpdate> = Vec::with_capacity(batch_size);
        let mut routed: Vec<Vec<StockUpdate>> = Vec::new();
        loop {
            let more = reader.next_batch(&mut buf, batch_size)?;
            if buf.is_empty() {
                break;
            }
            route_batch(store, &buf, &mut routed);
            for (s, sub) in routed.iter_mut().enumerate() {
                if sub.is_empty() {
                    continue;
                }
                // Taking the Vec out avoids copying; replace with empty.
                let payload = std::mem::take(sub);
                if senders[s].send(payload).is_err() {
                    return Err(PipelineError::WorkerPanic(format!("worker {s} gone")));
                }
            }
            batches += 1;
            if !more {
                break;
            }
        }
        drop(senders); // close queues → workers drain and exit

        for (i, h) in handles.into_iter().enumerate() {
            h.join().map_err(|_| PipelineError::WorkerPanic(format!("worker {i}")))?;
        }
        Ok(())
    })?;

    metrics.phases.record("update_stream", t0.elapsed());
    let report = StreamReport {
        updates_applied: applied.into_inner(),
        updates_missing: missing.into_inner(),
        parse_errors: reader.errors,
        batches,
        backpressure_waits: 0, // filled below
    };
    metrics.records_updated.add(report.updates_applied);
    metrics.records_missing.add(report.updates_missing);
    metrics.parse_errors.add(report.parse_errors);
    metrics.batches.add(report.batches);
    Ok(report)
}

/// In-memory executor: apply pre-materialized updates with `n` shard-affine
/// threads. This isolates the paper's §5 compute claim (no file I/O): each
/// thread receives exactly the updates owned by its shard and holds that
/// shard's write guard uncontended (concurrent point reads stay lock-free
/// and simply fall back to the mutex while a guard pins the shard).
pub fn run_update_in_memory(
    store: &ShardedStore,
    updates: &[StockUpdate],
    metrics: &EngineMetrics,
) -> StreamReport {
    let t0 = Instant::now();
    let parts = partition_updates(store, updates);
    let applied = std::sync::atomic::AtomicU64::new(0);
    let missing = std::sync::atomic::AtomicU64::new(0);
    std::thread::scope(|scope| {
        for (i, part) in parts.iter().enumerate() {
            let applied = &applied;
            let missing = &missing;
            scope.spawn(move || {
                let mut a = 0u64;
                let mut m = 0u64;
                let mut shard = store.shard(i);
                for u in part {
                    if shard.update(u.isbn13, |r| u.apply_to(r)) {
                        a += 1;
                    } else {
                        m += 1;
                    }
                }
                drop(shard);
                applied.fetch_add(a, std::sync::atomic::Ordering::Relaxed);
                missing.fetch_add(m, std::sync::atomic::Ordering::Relaxed);
            });
        }
    });
    metrics.phases.record("update_memory", t0.elapsed());
    let report = StreamReport {
        updates_applied: applied.into_inner(),
        updates_missing: missing.into_inner(),
        parse_errors: 0,
        batches: parts.len() as u64,
        backpressure_waits: 0,
    };
    metrics.records_updated.add(report.updates_applied);
    metrics.records_missing.add(report.updates_missing);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gen::{generate_stock_updates, DatasetSpec, KeyDist};
    use crate::workload::stockfile::write_stock_file;

    fn store_from(spec: &DatasetSpec, shards: usize) -> Arc<ShardedStore> {
        let store = Arc::new(ShardedStore::new(
            shards,
            (spec.records as usize / shards).next_power_of_two(),
        ));
        for r in spec.iter() {
            store.insert(r);
        }
        store
    }

    fn tpath(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("membig_exec_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn streaming_applies_every_update() {
        let spec = DatasetSpec { records: 20_000, ..Default::default() };
        let store = store_from(&spec, 4);
        let ups = generate_stock_updates(&spec, 20_000, KeyDist::PermuteAll, 5);
        let path = tpath("all.dat");
        write_stock_file(&path, &ups).unwrap();

        let m = EngineMetrics::new();
        let rep = run_streaming_update(&store, &path, 1024, 8, &m).unwrap();
        assert_eq!(rep.updates_applied, 20_000);
        assert_eq!(rep.updates_missing, 0);
        assert_eq!(rep.parse_errors, 0);
        assert!(rep.batches >= 20);

        // Every record must now carry its update's values.
        let mut expect: std::collections::HashMap<u64, (u64, u32)> = Default::default();
        for u in &ups {
            expect.insert(u.isbn13, (u.new_price_cents, u.new_quantity));
        }
        for r in spec.iter() {
            let got = store.get(r.isbn13).unwrap();
            let (p, q) = expect[&r.isbn13];
            assert_eq!((got.price_cents, got.quantity), (p, q));
        }
    }

    #[test]
    fn streaming_counts_missing_and_parse_errors() {
        let spec = DatasetSpec { records: 100, ..Default::default() };
        let store = store_from(&spec, 2);
        let mut ups = generate_stock_updates(&spec, 50, KeyDist::Uniform, 5);
        // Add updates for keys not in the store.
        ups.push(StockUpdate { isbn13: 9_799_999_999_999, new_price_cents: 1, new_quantity: 1 });
        let path = tpath("miss.dat");
        write_stock_file(&path, &ups).unwrap();
        // Append garbage lines.
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        writeln!(f, "not$a$valid").unwrap();
        writeln!(f, "garbage").unwrap();
        drop(f);

        let m = EngineMetrics::new();
        let rep = run_streaming_update(&store, &path, 16, 4, &m).unwrap();
        assert_eq!(rep.updates_applied, 50);
        assert_eq!(rep.updates_missing, 1);
        assert_eq!(rep.parse_errors, 2);
        assert_eq!(m.records_missing.get(), 1);
    }

    #[test]
    fn in_memory_matches_streaming_result() {
        let spec = DatasetSpec { records: 5_000, ..Default::default() };
        let ups = generate_stock_updates(&spec, 5_000, KeyDist::PermuteAll, 9);

        let s1 = store_from(&spec, 4);
        let m1 = EngineMetrics::new();
        let rep1 = run_update_in_memory(&s1, &ups, &m1);
        assert_eq!(rep1.updates_applied, 5_000);

        let s2 = store_from(&spec, 4);
        let path = tpath("cmp.dat");
        write_stock_file(&path, &ups).unwrap();
        let m2 = EngineMetrics::new();
        run_streaming_update(&s2, &path, 512, 8, &m2).unwrap();

        assert_eq!(s1.value_sum_cents(), s2.value_sum_cents());
    }

    #[test]
    fn single_shard_works() {
        let spec = DatasetSpec { records: 1_000, ..Default::default() };
        let store = store_from(&spec, 1);
        let ups = generate_stock_updates(&spec, 1_000, KeyDist::PermuteAll, 2);
        let m = EngineMetrics::new();
        let rep = run_update_in_memory(&store, &ups, &m);
        assert_eq!(rep.updates_applied, 1_000);
    }

    #[test]
    fn empty_feed_is_ok() {
        let spec = DatasetSpec { records: 10, ..Default::default() };
        let store = store_from(&spec, 2);
        let path = tpath("empty.dat");
        std::fs::write(&path, "").unwrap();
        let m = EngineMetrics::new();
        let rep = run_streaming_update(&store, &path, 8, 2, &m).unwrap();
        assert_eq!(rep.updates_applied, 0);
        assert_eq!(rep.batches, 0);
    }
}
