//! Streaming update pipeline — the proposed method's execution engine.
//!
//! Topology (paper §4.2, adapted to a streaming data-pipeline):
//!
//! ```text
//!  Stock.dat ──reader──▶ parse batches ──route──▶ per-shard bounded queues
//!                                                   │        │        │
//!                                                 worker0  worker1  workerN   (one per core)
//!                                                   │        │        │
//!                                                 shard0   shard1   shardN    (exclusive)
//! ```
//!
//! Backpressure: queues are bounded; the reader blocks when a worker falls
//! behind, so memory stays flat regardless of feed size. Every blocking
//! event is counted (`backpressure_waits`).

pub mod channel;
pub mod executor;
pub mod router;

pub use channel::{bounded, Receiver, RecvError, SendError, Sender};
pub use executor::{run_streaming_update, run_update_in_memory, StreamReport};
pub use router::route_batch;
