//! Bounded MPMC channel on `Mutex` + `Condvar` (crossbeam-channel is not in
//! the offline vendor set; this is the minimal correct equivalent).
//!
//! Semantics:
//! - `send` blocks while full (backpressure) and fails once all receivers
//!   are gone;
//! - `recv` blocks while empty and returns `Err(Closed)` once all senders
//!   are gone *and* the queue is drained;
//! - dropping the last `Sender` closes the channel; same for receivers.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::util::racecheck;

#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Outcome of a [`Sender::try_send`] that could not enqueue.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// Queue at capacity — the value is handed back so the caller can
    /// apply its own backpressure policy instead of blocking.
    Full(T),
    /// All receivers are gone.
    Closed(T),
}

impl<T> TrySendError<T> {
    pub fn into_inner(self) -> T {
        match self {
            TrySendError::Full(v) | TrySendError::Closed(v) => v,
        }
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum RecvError {
    Closed,
}

struct Shared<T> {
    queue: Mutex<VecDeque<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    senders: AtomicUsize,
    receivers: AtomicUsize,
    /// Times a sender had to block on a full queue.
    pub send_blocks: AtomicU64,
    /// Times a receiver had to block on an empty queue.
    pub recv_blocks: AtomicU64,
}

pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Create a bounded channel of `capacity` items.
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity > 0);
    let shared = Arc::new(Shared {
        queue: Mutex::new(VecDeque::with_capacity(capacity)),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        capacity,
        senders: AtomicUsize::new(1),
        receivers: AtomicUsize::new(1),
        send_blocks: AtomicU64::new(0),
        recv_blocks: AtomicU64::new(0),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Sender<T> {
    /// Blocking send; `Err` returns the value if all receivers are gone.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if self.shared.receivers.load(Ordering::Acquire) == 0 {
                return Err(SendError(value));
            }
            if q.len() < self.shared.capacity {
                q.push_back(value);
                drop(q);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            self.shared.send_blocks.fetch_add(1, Ordering::Relaxed);
            // About to park on `not_full` (lock still held): the symmetric
            // close-vs-park window to the receiver side.
            racecheck::perturb("channel.send.park");
            q = self.shared.not_full.wait(q).unwrap();
        }
    }

    /// Non-blocking send: never parks the caller. A full queue hands the
    /// value back as [`TrySendError::Full`] — this is what lets the server
    /// reactors feed the blocking-verb pool without ever blocking an event
    /// loop on it.
    pub fn try_send(&self, value: T) -> Result<(), TrySendError<T>> {
        let mut q = self.shared.queue.lock().unwrap();
        if self.shared.receivers.load(Ordering::Acquire) == 0 {
            return Err(TrySendError::Closed(value));
        }
        if q.len() >= self.shared.capacity {
            return Err(TrySendError::Full(value));
        }
        q.push_back(value);
        drop(q);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Number of times senders blocked (backpressure events).
    pub fn send_blocks(&self) -> u64 {
        self.shared.send_blocks.load(Ordering::Relaxed)
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `Err(Closed)` once the channel is empty and all
    /// senders are dropped.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            if let Some(v) = q.pop_front() {
                drop(q);
                self.shared.not_full.notify_one();
                return Ok(v);
            }
            if self.shared.senders.load(Ordering::Acquire) == 0 {
                return Err(RecvError::Closed);
            }
            self.shared.recv_blocks.fetch_add(1, Ordering::Relaxed);
            // About to park on `not_empty` (lock still held). This is the
            // lost-wakeup window the PR-2 fix closes: the last sender's
            // notify must not be able to slip between the `senders` check
            // above and the `wait` below — it can't, because Drop notifies
            // under this same lock. The deterministic test in this module
            // holds a victim thread here to prove it.
            racecheck::perturb("channel.recv.park");
            q = self.shared.not_empty.wait(q).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Result<Option<T>, RecvError> {
        let mut q = self.shared.queue.lock().unwrap();
        if let Some(v) = q.pop_front() {
            drop(q);
            self.shared.not_full.notify_one();
            return Ok(Some(v));
        }
        if self.shared.senders.load(Ordering::Acquire) == 0 {
            return Err(RecvError::Closed);
        }
        Ok(None)
    }

    pub fn recv_blocks(&self) -> u64 {
        self.shared.recv_blocks.load(Ordering::Relaxed)
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.senders.fetch_add(1, Ordering::AcqRel);
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.receivers.fetch_add(1, Ordering::AcqRel);
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        if self.shared.senders.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Window between the count reaching zero and the wakeup: a
            // receiver can check `senders`, see zero, and return Closed on
            // its own — or see the pre-drop value and head for the condvar.
            racecheck::perturb("channel.close.sender");
            // Last sender: wake all receivers so they observe Closed. The
            // queue lock must be held while notifying — without it, a
            // receiver that has already checked `senders` (nonzero) but not
            // yet parked on the condvar misses this wakeup forever and
            // `recv` hangs instead of returning Closed.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        if self.shared.receivers.fetch_sub(1, Ordering::AcqRel) == 1 {
            // Same close-vs-park window as `Sender::drop`, sender side.
            racecheck::perturb("channel.close.receiver");
            // Last receiver: wake all senders so they observe Closed (lock
            // held for the same lost-wakeup reason as Sender::drop).
            let _q = self.shared.queue.lock().unwrap();
            self.shared.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_single_thread() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Ok(i));
        }
    }

    #[test]
    fn close_on_sender_drop() {
        let (tx, rx) = bounded::<u32>(2);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Err(RecvError::Closed));
    }

    #[test]
    fn send_fails_when_receivers_gone() {
        let (tx, rx) = bounded::<u32>(2);
        drop(rx);
        assert_eq!(tx.send(9), Err(SendError(9)));
    }

    #[test]
    fn backpressure_blocks_and_counts() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(0).unwrap();
        let t = std::thread::spawn(move || {
            tx.send(1).unwrap(); // must block until recv below
            tx.send_blocks()
        });
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(rx.recv(), Ok(0));
        let blocks = t.join().unwrap();
        assert!(blocks >= 1, "sender should have blocked");
        assert_eq!(rx.recv(), Ok(1));
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        const SENDERS: usize = 4;
        const RECEIVERS: usize = 3;
        // Miri executes every interleaving step in an interpreter; the
        // protocol coverage is identical at a fraction of the N.
        const PER_SENDER: usize = if cfg!(miri) { 200 } else { 10_000 };
        let (tx, rx) = bounded::<usize>(32);
        let got = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for t in 0..SENDERS {
                let tx = tx.clone();
                s.spawn(move || {
                    for i in 0..PER_SENDER {
                        tx.send(t * PER_SENDER + i).unwrap();
                    }
                });
            }
            drop(tx); // scope keeps only clones
            for _ in 0..RECEIVERS {
                let rx = rx.clone();
                let got = &got;
                s.spawn(move || {
                    let mut local = Vec::new();
                    while let Ok(v) = rx.recv() {
                        local.push(v);
                    }
                    got.lock().unwrap().extend(local);
                });
            }
            drop(rx);
        });
        let mut all = got.into_inner().unwrap();
        all.sort_unstable();
        assert_eq!(all.len(), SENDERS * PER_SENDER);
        all.dedup();
        assert_eq!(all.len(), SENDERS * PER_SENDER, "duplicates delivered");
    }

    #[test]
    fn close_wakeup_never_lost_under_race() {
        // Stress the close-vs-park window: the receiver may or may not be
        // waiting on the condvar when the last sender drops. A lost wakeup
        // hangs this test (visible as a suite timeout).
        let rounds = if cfg!(miri) { 20 } else { 200 };
        for _ in 0..rounds {
            let (tx, rx) = bounded::<u32>(1);
            let t = std::thread::spawn(move || rx.recv());
            drop(tx);
            assert_eq!(t.join().unwrap(), Err(RecvError::Closed));
        }
        for _ in 0..rounds {
            let (tx, rx) = bounded::<u32>(1);
            tx.send(0).unwrap(); // fill so the sender side must block
            let t = std::thread::spawn(move || tx.send(1));
            drop(rx);
            assert_eq!(t.join().unwrap(), Err(SendError(1)));
        }
    }

    /// Deterministic replay of the PR-2 lost-wakeup bug, not a stress
    /// sample: a racecheck hook holds a victim receiver *inside* the park
    /// window — `senders` already checked (nonzero), queue lock still
    /// held, condvar not yet waited on — while the main thread drops the
    /// last sender. Because `Sender::drop` notifies under the queue lock,
    /// the drop cannot complete until the victim reaches `wait`, so the
    /// wakeup is ordered after the park and `recv` returns `Closed`. If
    /// the notify is ever moved back outside the lock, it fires into this
    /// exact window, the victim parks forever, and the timeout below
    /// fails the test.
    #[test]
    #[cfg(feature = "racecheck")]
    fn close_vs_recv_deterministic_interleaving() {
        use std::sync::mpsc;

        let _serial = racecheck::hook_tests_guard();

        let (reached_tx, reached_rx) = mpsc::channel::<()>();
        // `mpsc::Sender` is `Send` but not `Sync`; the hook must be `Sync`.
        let reached_tx = std::sync::Mutex::new(reached_tx);
        racecheck::set_hook(move |point| {
            let victim = std::thread::current().name() == Some("racecheck-victim");
            if point == "channel.recv.park" && victim {
                let _ = reached_tx.lock().unwrap().send(());
                // Keep the window open long enough for the main thread to
                // run the whole `drop(tx)` path against it.
                std::thread::sleep(Duration::from_millis(100));
            }
        });

        let (tx, rx) = bounded::<u32>(1);
        let (done_tx, done_rx) = mpsc::channel();
        std::thread::Builder::new()
            .name("racecheck-victim".into())
            .spawn(move || {
                let _ = done_tx.send(rx.recv());
            })
            .unwrap();
        // Wait until the victim is provably inside the window, then close.
        reached_rx.recv().expect("victim never reached the park window");
        drop(tx);
        let got = done_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("lost close wakeup: victim parked forever (notify outside the queue lock?)");
        assert_eq!(got, Err(RecvError::Closed));
        racecheck::clear_hook();
    }

    #[test]
    fn try_recv_nonblocking() {
        let (tx, rx) = bounded::<u32>(2);
        assert_eq!(rx.try_recv(), Ok(None));
        tx.send(5).unwrap();
        assert_eq!(rx.try_recv(), Ok(Some(5)));
        drop(tx);
        assert_eq!(rx.try_recv(), Err(RecvError::Closed));
    }

    #[test]
    fn try_send_full_and_closed() {
        let (tx, rx) = bounded::<u32>(2);
        assert_eq!(tx.try_send(1), Ok(()));
        assert_eq!(tx.try_send(2), Ok(()));
        // At capacity: the value comes back instead of the caller parking.
        assert_eq!(tx.try_send(3), Err(TrySendError::Full(3)));
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(tx.try_send(3), Ok(()), "space freed by the recv");
        drop(rx);
        assert_eq!(tx.try_send(4), Err(TrySendError::Closed(4)));
        assert_eq!(TrySendError::Full(7u32).into_inner(), 7);
    }

    #[test]
    fn capacity_respected() {
        let (tx, rx) = bounded::<u32>(3);
        for i in 0..3 {
            tx.send(i).unwrap();
        }
        // Queue is full: try a timed send via helper thread.
        let t = std::thread::spawn(move || tx.send(99));
        std::thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "4th send must block at capacity 3");
        rx.recv().unwrap();
        t.join().unwrap().unwrap();
    }
}
