//! Standby side of WAL shipping: `--standby-of HOST:PORT`.
//!
//! The standby is an ordinary `serve` process whose mutations come from the
//! replication stream instead of clients (clients get `ERR readonly
//! standby`). It mirrors the primary's durable directory *exactly*: shipped
//! frames are applied through the standby's own
//! [`Persistence::apply_many`] — same codec, same group commit — so its
//! `(generation, offset)` WAL tip is byte-comparable with the primary's and
//! doubles as the resume cursor after any disconnect. A `SNP1` bootstrap
//! rebases the whole directory onto the primary's newest snapshot
//! ([`Persistence::rebase_to_snapshot`]); rotation is mirrored by running a
//! local checkpoint whenever the stream's generation bumps by one.
//!
//! A `STANDBY.json` marker in the durable dir records "this directory is a
//! replica mirror": present → a restart may resume from its WAL tip;
//! absent (fresh dir, or a promoted ex-standby) → the handshake demands a
//! snapshot. The marker is deleted on promotion, at which point the
//! directory is a normal primary directory.
//!
//! Failover: every stream message beats the [`FailoverClock`]; the monitor
//! thread promotes (CAS in [`ReplState`]), seals the WAL with a final
//! sync, and the server — which checks the role on every mutation — starts
//! taking writes. There is nothing to replay at promotion: frames were
//! applied on arrival, so the store already *is* the acked tip.

use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use super::heartbeat::{spawn_monitor, FailoverClock};
use super::{
    backoff_delay, decode_frames, fault_kill_now, read_stream_msg, write_ack, write_handshake,
    FaultKind, FaultPlan, Handshake, ReplState, StreamMsg,
};
use crate::durability::{DurabilityError, DurabilityOptions, Persistence, FRAME_BYTES};
use crate::memstore::ShardedStore;
use crate::util::iofault;
use crate::util::rng::Rng;

/// Fault-injection surface for the `STANDBY.json` marker write
/// (`MEMBIG_IO_FAULTS`, DESIGN.md §16).
const MARKER_SURFACE: &str = "marker";

/// How long a blocking stream read may sit before we re-check stop/promote.
/// An alive primary heartbeats every 250 ms, so a timeout here never fires
/// on a healthy link.
const STREAM_READ_TIMEOUT: Duration = Duration::from_secs(1);

/// Marker file: "this durable dir is a standby mirror of some primary".
pub(crate) fn marker_path(dir: &Path) -> PathBuf {
    dir.join("STANDBY.json")
}

fn write_marker(dir: &Path) {
    // Best-effort: a lost marker only costs a snapshot re-sync on restart.
    let _ = iofault::write_file(MARKER_SURFACE, &marker_path(dir), b"{\"role\":\"standby\"}\n");
}

/// Everything the standby threads share.
struct ApplyCtx {
    primary: String,
    dir: PathBuf,
    shards: usize,
    persist: Arc<Persistence>,
    repl: Arc<ReplState>,
    clock: Arc<FailoverClock>,
    stop: Arc<AtomicBool>,
    faults: FaultPlan,
}

/// Options for [`start`].
pub struct StandbyOpts {
    /// Primary's `--replicate-listen` address, `HOST:PORT`.
    pub primary: String,
    /// The standby's own durable directory (mirror of the primary's).
    pub dir: PathBuf,
    pub shards: usize,
    pub fsync: bool,
    /// Promote after this long without a primary heartbeat.
    pub failover_after: Duration,
    pub faults: FaultPlan,
}

/// Handle returned by [`start`]; lets shutdown seal the replication link.
pub struct Standby {
    stop: Arc<AtomicBool>,
}

impl Standby {
    /// Stop the apply and failover threads (they exit within their poll
    /// intervals). Called on graceful shutdown before the final WAL sync.
    pub fn seal(&self) {
        self.stop.store(true, Ordering::Release);
    }
}

/// Open (or resume) the standby's mirrored durable directory and start the
/// replication threads: the apply loop and the failover monitor. Returns
/// the live store + persistence for the read-only server to serve from.
pub fn start(
    opts: StandbyOpts,
    repl: Arc<ReplState>,
) -> Result<(Arc<ShardedStore>, Arc<Persistence>, Standby), DurabilityError> {
    // Local snapshot triggers are disabled: the standby checkpoints only
    // when the stream says the primary rotated, keeping `(generation,
    // offset)` in lockstep so resume cursors mean the same thing on both
    // sides.
    let dopts = DurabilityOptions {
        fsync: opts.fsync,
        snapshot_every: Duration::ZERO,
        snapshot_wal_bytes: 0,
    };
    let shards = opts.shards;
    let (store, persist, report) = Persistence::open(&opts.dir, dopts, shards, move || {
        Ok(Arc::new(ShardedStore::new(shards, 4096)))
    })?;
    let persist = Arc::new(persist);
    let need_snapshot = report.fresh || !marker_path(&opts.dir).exists();

    let stop = Arc::new(AtomicBool::new(false));
    let clock = Arc::new(FailoverClock::new());
    let ctx = ApplyCtx {
        primary: opts.primary,
        dir: opts.dir.clone(),
        shards,
        persist: persist.clone(),
        repl: repl.clone(),
        clock: clock.clone(),
        stop: stop.clone(),
        faults: opts.faults,
    };

    {
        let repl = repl.clone();
        let persist = persist.clone();
        let stop = stop.clone();
        let dir = opts.dir;
        let failover_after = opts.failover_after;
        spawn_monitor(clock, failover_after, stop.clone(), repl.clone(), move || {
            if repl.promote() {
                stop.store(true, Ordering::Release);
                let _ = std::fs::remove_file(marker_path(&dir));
                if let Err(e) = persist.sync() {
                    eprintln!("membig: promoted standby failed to seal WAL: {e}");
                }
                println!(
                    "membig: standby promoted to primary (no heartbeat for {} ms)",
                    failover_after.as_millis()
                );
            }
        });
    }

    let spawned = thread::Builder::new()
        .name("membig-repl-apply".into())
        .spawn(move || run_apply(ctx, need_snapshot));
    if let Err(e) = spawned {
        return Err(DurabilityError::Io(e));
    }

    Ok((store, persist, Standby { stop }))
}

/// Outer reconnect loop: capped exponential backoff + jitter between
/// attempts, resume position re-read from the durable WAL tip every time.
fn run_apply(ctx: ApplyCtx, mut need_snapshot: bool) {
    let mut rng = Rng::new(0x7365_7276_6572_7331 ^ u64::from(std::process::id()));
    let mut attempt: u32 = 0;
    let mut had_session = false;
    let mut applied_batches: u64 = 0;
    while !ctx.stop.load(Ordering::Acquire) {
        match TcpStream::connect(&ctx.primary) {
            Ok(sock) => {
                if had_session {
                    ctx.repl.metrics.reconnects.inc();
                }
                had_session = true;
                match run_session(&ctx, &sock, need_snapshot, &mut applied_batches) {
                    SessionEnd::Stopped => return,
                    SessionEnd::Reconnect { need_snapshot: ns, made_progress } => {
                        need_snapshot = ns;
                        attempt = if made_progress { 0 } else { attempt.saturating_add(1) };
                    }
                }
            }
            Err(_) => attempt = attempt.saturating_add(1),
        }
        if ctx.stop.load(Ordering::Acquire) {
            return;
        }
        thread::sleep(backoff_delay(attempt, &mut rng));
    }
}

enum SessionEnd {
    /// Shutdown or promotion: leave the loop entirely.
    Stopped,
    /// Link failed or diverged: back off and dial again.
    Reconnect { need_snapshot: bool, made_progress: bool },
}

fn run_session(
    ctx: &ApplyCtx,
    sock: &TcpStream,
    need_snapshot: bool,
    applied_batches: &mut u64,
) -> SessionEnd {
    let reconnect = |ns: bool, progress: bool| SessionEnd::Reconnect {
        need_snapshot: ns,
        made_progress: progress,
    };
    if sock.set_nodelay(true).is_err()
        || sock.set_read_timeout(Some(STREAM_READ_TIMEOUT)).is_err()
    {
        return reconnect(need_snapshot, false);
    }
    let mut io = sock;
    let (tip_gen, tip_off) = ctx.persist.wal_tip();
    let hs = Handshake { need_snapshot, generation: tip_gen, offset: tip_off };
    if write_handshake(&mut io, hs).is_err() {
        return reconnect(need_snapshot, false);
    }

    let mut progress = false;
    loop {
        if ctx.stop.load(Ordering::Acquire) {
            return SessionEnd::Stopped;
        }
        let msg = match read_stream_msg(&mut io) {
            Ok(m) => m,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle link; heartbeats lapsing is the monitor's call.
                continue;
            }
            Err(_) => return reconnect(false, progress),
        };
        ctx.clock.beat();
        match msg {
            StreamMsg::Snapshot { generation, bytes } => {
                match ctx.persist.rebase_to_snapshot(generation, &bytes, ctx.shards) {
                    Ok(_records) => {
                        ctx.repl.metrics.snapshot_resyncs.inc();
                        write_marker(&ctx.dir);
                        progress = true;
                        if write_ack(&mut io, generation, 0).is_err() {
                            return reconnect(false, progress);
                        }
                    }
                    Err(e) => {
                        eprintln!("membig: standby snapshot re-sync failed: {e}");
                        return reconnect(true, progress);
                    }
                }
            }
            StreamMsg::Heartbeat { generation, tip_offset } => {
                ctx.repl.metrics.heartbeats.inc();
                let (tg, to) = ctx.persist.wal_tip();
                if generation == tg {
                    let lag = tip_offset.saturating_sub(to);
                    ctx.repl.metrics.lag_bytes.set(lag as i64);
                    ctx.repl.metrics.lag_frames.set((lag / FRAME_BYTES as u64) as i64);
                }
                // Ack our position so the primary's lag gauge stays fresh
                // even when no frames flow.
                if write_ack(&mut io, tg, to).is_err() {
                    return reconnect(false, progress);
                }
            }
            StreamMsg::Wal { generation, start_offset, payload } => {
                *applied_batches += 1;
                match ctx.faults.at(*applied_batches) {
                    Some(FaultKind::Kill) => fault_kill_now(),
                    Some(FaultKind::Sever) => return reconnect(false, progress),
                    Some(FaultKind::Delay(ms)) => thread::sleep(Duration::from_millis(ms)),
                    // Dup is a primary-side action; harmless to ignore here.
                    Some(FaultKind::Dup) | None => {}
                }
                let (ups, consumed, clean) = decode_frames(&payload);
                if !clean {
                    // Torn/corrupt mid-stream: apply the valid whole-frame
                    // prefix, drop the rest, resume from our tip — exactly
                    // recovery's torn-tail rule.
                    ctx.repl.metrics.corrupt_frames.inc();
                }
                let (mut tg, mut to) = ctx.persist.wal_tip();
                if generation == tg + 1 && start_offset == 0 {
                    // The primary rotated; mirror it with a local
                    // checkpoint so generation numbers stay in lockstep.
                    match ctx.persist.checkpoint_now() {
                        Ok(st) if st.generation == generation => {
                            tg = generation;
                            to = 0;
                        }
                        _ => return reconnect(true, progress),
                    }
                }
                if generation < tg {
                    // Stale duplicate from before a rotation we already
                    // mirrored; drop it.
                    continue;
                }
                if generation > tg {
                    // Generation gap we cannot bridge locally: reconnect
                    // and let the primary stream from our durable tip.
                    return reconnect(false, progress);
                }
                let end = start_offset + consumed as u64;
                if end <= to {
                    // Entirely behind our tip: a duplicate (e.g. the dup
                    // fault, or a queue/disk overlap). Re-ack and move on.
                    if write_ack(&mut io, tg, to).is_err() {
                        return reconnect(false, progress);
                    }
                    if !clean {
                        return reconnect(false, progress);
                    }
                    continue;
                }
                if start_offset > to {
                    // Hole between our tip and this batch; resume cleanly.
                    return reconnect(false, progress);
                }
                // Overlapping prefix is already durable here; apply only
                // the frames past our tip. Offsets are frame-aligned on
                // both sides by construction.
                let skip = ((to - start_offset) / FRAME_BYTES as u64) as usize;
                let fresh = &ups[skip..];
                match ctx.persist.apply_many(fresh, true) {
                    Ok(_) => {
                        ctx.repl.metrics.frames_applied.add(fresh.len() as u64);
                        progress = true;
                        let (ng, no) = ctx.persist.wal_tip();
                        if write_ack(&mut io, ng, no).is_err() {
                            return reconnect(false, progress);
                        }
                    }
                    Err(e) => {
                        eprintln!("membig: standby failed to apply shipped frames: {e}");
                        return reconnect(false, progress);
                    }
                }
                if !clean {
                    return reconnect(false, progress);
                }
            }
        }
    }
}
